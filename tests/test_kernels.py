"""Per-kernel correctness sweeps: Pallas (interpret) vs pure-jnp oracles."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.spmv_ell import spmv_ell
from repro.kernels.ssd_scan import ssd_scan_kernel
from repro.models.ssd import ssd_chunked

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# spmv_ell
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("R,K,N", [(8, 3, 32), (300, 17, 1000), (256, 128, 128), (513, 1, 7)])
def test_spmv_ell_shapes(R, K, N, dtype):
    data = RNG.normal(size=(R, K)).astype(np.float32)
    cols = RNG.integers(0, N, size=(R, K)).astype(np.int32)
    x = RNG.normal(size=(N,)).astype(np.float32)
    d, xx = jnp.asarray(data, dtype), jnp.asarray(x, dtype)
    out = spmv_ell(d, jnp.asarray(cols), xx, interpret=True)
    want = ref.spmv_ell(d, jnp.asarray(cols), xx)
    tol = 2e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.slow
@given(
    r=st.integers(1, 64),
    k=st.integers(1, 16),
    n=st.integers(1, 128),
    seed=st.integers(0, 99),
)
@settings(max_examples=15, deadline=None)
def test_spmv_ell_property(r, k, n, seed):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(r, k)).astype(np.float32)
    cols = rng.integers(0, n, size=(r, k)).astype(np.int32)
    x = rng.normal(size=(n,)).astype(np.float32)
    out = spmv_ell(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(x), interpret=True)
    want = ref.spmv_ell(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,KV,D,causal,win",
    [
        (2, 64, 64, 4, 2, 32, True, None),
        (1, 48, 48, 4, 4, 16, True, 16),
        (2, 16, 64, 4, 2, 32, True, None),  # cached decode-style Sq < Sk
        (1, 64, 64, 2, 1, 64, False, None),  # bidirectional (encoder)
        (1, 100, 100, 2, 2, 32, True, 32),  # non-multiple of block
    ],
)
def test_flash_attention(B, Sq, Sk, H, KV, D, causal, win, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Sk, KV, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Sk, KV, D)), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, window=win,
                                 block_q=32, block_k=32, interpret=True)
    want = np.stack(
        [np.asarray(ref.attention(q[b], k[b], v[b], causal=causal, window=win), np.float32)
         for b in range(B)]
    )
    tol = 2e-4 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), want, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,H,P,N,Q",
    [(2, 32, 3, 4, 8, 8), (1, 50, 2, 16, 8, 16), (2, 128, 4, 8, 16, 32), (1, 7, 1, 2, 3, 4)],
)
def test_ssd_scan_vs_oracles(B, S, H, P, N, Q):
    x = RNG.normal(size=(B, S, H, P)).astype(np.float32)
    loga = (-np.abs(RNG.normal(size=(B, S, H))) * 0.2).astype(np.float32)
    b = RNG.normal(size=(B, S, N)).astype(np.float32)
    c = RNG.normal(size=(B, S, N)).astype(np.float32)
    out = ssd_scan_kernel(jnp.asarray(x), jnp.asarray(loga), jnp.asarray(b),
                          jnp.asarray(c), chunk=Q, interpret=True)
    chunked = ssd_chunked(jnp.asarray(x), jnp.asarray(loga), jnp.asarray(b),
                          jnp.asarray(c), chunk=Q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(chunked), rtol=2e-4, atol=2e-4)
    for bi in range(B):
        seq = ref.ssd_scan(jnp.asarray(x[bi]), jnp.exp(jnp.asarray(loga[bi])),
                           jnp.asarray(b[bi]), jnp.asarray(c[bi]))
        np.testing.assert_allclose(np.asarray(out[bi]), np.asarray(seq), rtol=5e-4, atol=5e-4)


@given(seed=st.integers(0, 99), q=st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_invariance(seed, q):
    """Output must not depend on the chunk size (pure blocking parameter)."""
    rng = np.random.default_rng(seed)
    B, S, H, P, N = 1, 24, 2, 4, 6
    x = rng.normal(size=(B, S, H, P)).astype(np.float32)
    loga = (-np.abs(rng.normal(size=(B, S, H))) * 0.3).astype(np.float32)
    b = rng.normal(size=(B, S, N)).astype(np.float32)
    c = rng.normal(size=(B, S, N)).astype(np.float32)
    outs = [
        np.asarray(ssd_scan_kernel(jnp.asarray(x), jnp.asarray(loga), jnp.asarray(b),
                                   jnp.asarray(c), chunk=qq, interpret=True))
        for qq in (q, S)
    ]
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
