"""Unit tests for the pod topology helpers (previously only exercised
indirectly through the planners)."""

import jax
import numpy as np
import pytest

from repro.comm.topology import (
    LOCAL_AXIS,
    POD_AXIS,
    WORLD_AXES,
    PodTopology,
    make_exchange_mesh,
)


def test_rank_layout_roundtrip():
    topo = PodTopology(npods=3, ppn=4)
    assert topo.nranks == 12
    for r in range(topo.nranks):
        p, l = topo.pod_of(r), topo.local_of(r)
        assert 0 <= p < topo.npods and 0 <= l < topo.ppn
        assert topo.rank_of(p, l) == r
    # row-major over (pod, local): rank 0..ppn-1 on pod 0, etc.
    assert [topo.pod_of(r) for r in range(12)] == [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]
    assert [topo.local_of(r) for r in range(4)] == [0, 1, 2, 3]


@pytest.mark.parametrize("npods,ppn", [(1, 1), (1, 8), (5, 1), (3, 2)])
def test_rank_layout_degenerate_shapes(npods, ppn):
    topo = PodTopology(npods=npods, ppn=ppn)
    seen = {topo.rank_of(p, l) for p in range(npods) for l in range(ppn)}
    assert seen == set(range(topo.nranks))


def test_agent_local_in_range_and_spreads():
    """The 3-Step agent assignment stays in [0, ppn) and, per source pod,
    spreads different destination pods over different local ranks."""
    topo = PodTopology(npods=4, ppn=4)
    for q in range(topo.npods):
        agents = [topo.agent_local(q, p) for p in range(topo.npods) if p != q]
        assert all(0 <= a < topo.ppn for a in agents)
        assert len(set(agents)) == len(agents)  # distinct while npods <= ppn+1


def test_agent_local_wraps_when_more_pods_than_ppn():
    topo = PodTopology(npods=5, ppn=2)
    for q in range(topo.npods):
        for p in range(topo.npods):
            assert 0 <= topo.agent_local(q, p) < topo.ppn


def test_pod_shift_rounds():
    assert PodTopology(npods=4, ppn=2).pod_shift_rounds() == [1, 2, 3]
    assert PodTopology(npods=1, ppn=4).pod_shift_rounds() == []
    # every ordered pod pair is covered exactly once across the shifts
    topo = PodTopology(npods=4, ppn=1)
    pairs = {
        (q, (q + d) % topo.npods)
        for d in topo.pod_shift_rounds()
        for q in range(topo.npods)
    }
    assert pairs == {(a, b) for a in range(4) for b in range(4) if a != b}


def test_make_exchange_mesh_single_device():
    mesh = make_exchange_mesh(PodTopology(npods=1, ppn=1))
    assert mesh.axis_names == WORLD_AXES == (POD_AXIS, LOCAL_AXIS)
    assert mesh.devices.shape == (1, 1)


def test_make_exchange_mesh_rejects_oversized_topology():
    need = jax.device_count() + 1
    with pytest.raises(ValueError, match="devices"):
        make_exchange_mesh(PodTopology(npods=need, ppn=1))
