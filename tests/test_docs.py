"""Docs can't rot: run the doctest blocks inside ``docs/*.md`` and
``README.md``, run the public-API module doctests, and check that every
intra-repo link in the docs resolves to a real file.
"""

import doctest
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = sorted(
    [os.path.join(REPO, "README.md")]
    + [
        os.path.join(REPO, "docs", f)
        for f in os.listdir(os.path.join(REPO, "docs"))
        if f.endswith(".md")
    ]
)

FENCE = re.compile(r"```(\w*)\n(.*?)```", re.DOTALL)
LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def _doctest_blocks(path):
    """(start line, block text) for every fenced python block with >>>."""
    text = open(path).read()
    out = []
    for m in FENCE.finditer(text):
        lang, body = m.group(1), m.group(2)
        if lang in ("python", "pycon", "") and ">>>" in body:
            line = text[: m.start()].count("\n") + 2
            out.append((line, body))
    return out


def test_docs_exist_and_have_doctests():
    names = {os.path.basename(p) for p in DOC_FILES}
    assert {"README.md", "architecture.md", "paper_mapping.md", "strategies.md"} <= names
    n_blocks = sum(len(_doctest_blocks(p)) for p in DOC_FILES)
    assert n_blocks >= 3, "docs lost their runnable examples"


@pytest.mark.parametrize("path", DOC_FILES, ids=[os.path.basename(p) for p in DOC_FILES])
def test_docs_doctest_blocks(path):
    """Every ``>>>`` block in the markdown docs must execute verbatim."""
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(optionflags=doctest.NORMALIZE_WHITESPACE)
    globs: dict = {}  # blocks within one document build on each other
    for line, block in _doctest_blocks(path):
        test = parser.get_doctest(
            block, globs, f"{os.path.basename(path)}:{line}", path, line
        )
        result = runner.run(test, clear_globs=False)
        globs.update(test.globs)  # get_doctest copies; carry names forward
        assert result.failed == 0, (
            f"doctest block at {os.path.basename(path)}:{line} failed "
            f"({result.failed}/{result.attempted})"
        )


def test_module_docstring_examples():
    """The public-API docstring examples marked as doctests must run."""
    import repro.comm.fusion
    import repro.core.advisor
    import repro.core.perfmodel

    total = 0
    for mod in (repro.core.perfmodel, repro.core.advisor, repro.comm.fusion):
        result = doctest.testmod(mod)
        assert result.failed == 0, f"doctest failure in {mod.__name__}"
        total += result.attempted
    assert total >= 15, "public-API doctests disappeared"


@pytest.mark.parametrize("path", DOC_FILES, ids=[os.path.basename(p) for p in DOC_FILES])
def test_docs_intra_repo_links_resolve(path):
    """Relative links in the docs must point at files that exist."""
    text = open(path).read()
    base = os.path.dirname(path)
    missing = []
    for m in LINK.finditer(text):
        target = m.group(1)
        if "://" in target or target.startswith("mailto:"):
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            missing.append(target)
    assert not missing, f"{os.path.basename(path)}: dead links {missing}"


def test_docs_code_references_resolve():
    """Backticked dotted ``repro.*`` references in the docs must import."""
    import importlib

    ref = re.compile(r"`(repro(?:\.\w+)+)`")
    unresolved = []
    for path in DOC_FILES:
        for name in set(ref.findall(open(path).read())):
            parts = name.split(".")
            obj = None
            for split in range(len(parts), 0, -1):
                try:
                    obj = importlib.import_module(".".join(parts[:split]))
                except ImportError:
                    continue
                for attr in parts[split:]:
                    obj = getattr(obj, attr, None)
                    if obj is None:
                        break
                break
            if obj is None:
                unresolved.append(f"{os.path.basename(path)}: {name}")
    assert not unresolved, f"dangling code references: {unresolved}"
