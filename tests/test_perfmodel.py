"""Unit + property tests for the paper's performance models (§2.2, §4)."""

import math

import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.core import (
    LASSEN,
    TPU_V5E_POD,
    CommPattern,
    Locality,
    PatternStats,
    Protocol,
    Space,
    Strategy,
    Transport,
    advise,
    figure43_pattern,
    max_rate,
    postal,
    predict,
    predict_all,
    t_copy,
    t_off,
    t_off_da,
    t_on,
    t_on_split,
)


# ---------------------------------------------------------------------------
# Table 2/3/4 values are reproduced verbatim
# ---------------------------------------------------------------------------


def test_lassen_table2_values():
    p = LASSEN.paths[(Space.CPU, Protocol.SHORT, Locality.ON_SOCKET)]
    assert (p.alpha, p.beta) == (3.67e-07, 1.32e-10)
    p = LASSEN.paths[(Space.GPU, Protocol.RENDEZVOUS, Locality.OFF_NODE)]
    assert (p.alpha, p.beta) == (1.10e-05, 1.72e-10)


def test_lassen_table3_table4():
    assert LASSEN.copy[1].h2d.alpha == 1.30e-05
    assert LASSEN.copy[4].d2h.beta == 1.50e-10
    assert LASSEN.rn_inv == 4.19e-11
    assert LASSEN.procs_per_node == 40
    assert LASSEN.gpus_per_node == 4


def test_protocol_selection():
    assert LASSEN.protocol_for(100, Space.CPU) is Protocol.SHORT
    assert LASSEN.protocol_for(10_000, Space.CPU) is Protocol.EAGER
    assert LASSEN.protocol_for(100_000, Space.CPU) is Protocol.RENDEZVOUS
    # short protocol is never used for device-aware messages (paper §3)
    assert LASSEN.protocol_for(100, Space.GPU) is Protocol.EAGER


# ---------------------------------------------------------------------------
# Primitive model properties
# ---------------------------------------------------------------------------


@given(
    alpha=st.floats(1e-8, 1e-4),
    beta=st.floats(1e-12, 1e-8),
    s=st.integers(1, 10**8),
)
def test_postal_positive_and_monotone(alpha, beta, s):
    t1 = postal(alpha, beta, s)
    t2 = postal(alpha, beta, 2 * s)
    assert t1 > 0 and t2 > t1


@given(
    s_proc=st.integers(1, 10**7),
    ppn=st.integers(1, 64),
    nmsgs=st.integers(1, 64),
)
def test_max_rate_reduces_to_postal_below_injection_limit(s_proc, ppn, nmsgs):
    """When ppn*R_b < R_N the max-rate model reduces to the postal model
    (paper, below eq. 2.2)."""
    alpha, beta = 1e-6, 1e-9  # R_b = 1e9 B/s
    rn_inv = 1e-11  # R_N = 1e11 B/s
    s_node = ppn * s_proc
    t = max_rate(alpha, beta, nmsgs, s_proc, s_node, rn_inv)
    if ppn * 1e9 < 1e11:
        assert t == pytest.approx(alpha * nmsgs + beta * s_proc)
    assert t >= alpha * nmsgs + max(s_node * rn_inv, 0)


@given(s=st.integers(1, 10**7))
def test_max_rate_injection_bound_dominates_for_many_procs(s):
    alpha, beta, rn_inv = 1e-6, 1e-10, 1e-10  # R_b == R_N
    ppn = 40
    t = max_rate(alpha, beta, 1, s, ppn * s, rn_inv)
    assert t == pytest.approx(alpha + ppn * s * rn_inv)


# ---------------------------------------------------------------------------
# Table 6 composites
# ---------------------------------------------------------------------------


def _stats(s_proc=4096.0, nmsg=32, nodes=4):
    return PatternStats(
        s_proc=s_proc,
        s_node=4 * s_proc,
        s_node_node=4 * s_proc / nodes,
        m_proc_node=nodes,
        m_node_node=max(nmsg // nodes, 1),
        m_proc=nmsg,
        num_dest_nodes=nodes,
    )


def test_all_modeled_pairs_evaluate():
    for machine in (LASSEN, TPU_V5E_POD):
        preds = predict_all(machine, _stats(), include_two_step_one=True)
        assert len(preds) == 10
        assert all(t > 0 and math.isfinite(t) for t in preds.values())


def test_split_device_aware_rejected():
    with pytest.raises(ValueError):
        predict(LASSEN, Strategy.SPLIT_MD, Transport.DEVICE_AWARE, _stats())


def test_two_step_one_is_lower_bound_of_two_step():
    """2-Step 1 is the best case of 2-Step (paper §4.6)."""
    s = _stats()
    for tr in (Transport.STAGED_HOST, Transport.DEVICE_AWARE):
        assert predict(LASSEN, Strategy.TWO_STEP_ONE, tr, s) <= predict(
            LASSEN, Strategy.TWO_STEP, tr, s
        )


@given(scale=st.floats(1.0, 64.0))
def test_models_monotone_in_volume(scale):
    base, scaled = _stats(), _stats(s_proc=4096.0 * scale)
    for (strat, tr), t in predict_all(LASSEN, base).items():
        assert predict(LASSEN, strat, tr, scaled) >= t * 0.999


def test_paper_headline_split_wins_at_high_message_count_many_nodes():
    """Fig 4.3b: Split+MD is most performant for 256 messages to 16 nodes at
    moderate message sizes (staged-through-host strategies dominate)."""
    pat = figure43_pattern(nbytes_per_msg=2048, n_inter_node_msgs=256, n_dest_nodes=16)
    adv = advise(pat, machine="lassen")
    staged = [r for r in adv.ranked if r.transport is Transport.STAGED_HOST]
    # a node-aware staged strategy must beat standard device-aware
    std_da = adv.time_for(Strategy.STANDARD, Transport.DEVICE_AWARE)
    assert staged[0].predicted_time < std_da
    assert adv.time_for(Strategy.SPLIT_MD, Transport.STAGED_HOST) < std_da


def test_duplicate_removal_only_helps_node_aware():
    pat = figure43_pattern(nbytes_per_msg=8192, n_inter_node_msgs=256, n_dest_nodes=16)
    plain = advise(pat, machine="lassen")
    dedup = advise(pat, machine="lassen", duplicate_fraction=0.25)
    assert dedup.time_for(Strategy.STANDARD, Transport.STAGED_HOST) == pytest.approx(
        plain.time_for(Strategy.STANDARD, Transport.STAGED_HOST)
    )
    assert dedup.time_for(Strategy.THREE_STEP, Transport.STAGED_HOST) < plain.time_for(
        Strategy.THREE_STEP, Transport.STAGED_HOST
    )


# ---------------------------------------------------------------------------
# CommPattern -> Table 7 stats
# ---------------------------------------------------------------------------


def test_pattern_stats_by_hand():
    # 2 nodes x 2 ranks; rank0 -> rank2 (100B), rank0 -> rank3 (50B), rank1 -> rank2 (30B)
    pat = CommPattern.from_messages(4, 2, [(0, 2, 100), (0, 3, 50), (1, 2, 30)])
    st_ = pat.stats()
    assert st_.s_proc == 150.0
    assert st_.s_node == 180.0
    assert st_.s_node_node == 180.0
    assert st_.m_node_node == 3
    assert st_.m_proc == 2
    assert st_.m_proc_node == 1
    assert st_.num_dest_nodes == 1


@given(
    ppn=st.integers(1, 4),
    nnodes=st.integers(2, 4),
    seed=st.integers(0, 999),
)
@settings(max_examples=30, deadline=None)
def test_pattern_stats_invariants(ppn, nnodes, seed):
    import numpy as np

    rng = np.random.default_rng(seed)
    n = ppn * nnodes
    msgs = []
    for _ in range(rng.integers(1, 20)):
        s, d = rng.integers(0, n, 2)
        if s // ppn != d // ppn:
            msgs.append((int(s), int(d), int(rng.integers(1, 10000))))
    pat = CommPattern.from_messages(n, ppn, msgs)
    stt = pat.stats()
    assert stt.s_node >= stt.s_proc >= 0
    assert stt.s_node >= stt.s_node_node
    assert stt.m_proc >= stt.m_proc_node
