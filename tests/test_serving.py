"""Serving front-end: continuous batching, admission, and the deterministic
traffic simulator (``repro.serving``).

The scheduler invariants are property-tested over seeded traces:

* FIFO within a fingerprint class (batches are lane prefixes);
* batch width never exceeds ``max_width`` or the memory budget;
* ripe lanes dispatch oldest-deadline-first (the no-starvation discipline);
* identical seeds produce identical event traces and identical p50/p99.

Also covers the ``launch/serve.py::routing_counts`` ragged source-rank
binning regression and the >= 3x coalescing-throughput acceptance pin.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.comm import PodTopology, random_pattern
from repro.runtime import AdmissionController, StragglerWatchdog
from repro.serving import (
    ContinuousBatcher,
    Request,
    RequestQueue,
    SimConfig,
    WorkloadClass,
    sequential_baseline,
    serving_report,
    simulate,
)
from repro.testing import make_trace, zipf_weights

TOPO = PodTopology(npods=2, ppn=4)


def _classes(n=4, local_size=32, max_elems=4):
    out = {}
    for i in range(n):
        pat = random_pattern(
            np.random.default_rng(100 + i), TOPO,
            local_size=local_size, max_elems=max_elems,
        )
        out[f"c{i}"] = WorkloadClass.from_pattern(pat, fp=f"c{i}")
    return out


CLASSES = _classes()
FPS = sorted(CLASSES)


def _check_schedule(events, window, caps):
    """Replay the event trace and assert every scheduling invariant.

    Reconstructs the queue from arrive/dispatch events; at each dispatch
    the batch must be (a) a FIFO prefix of its lane, (b) within the width
    cap, (c) from a ripe lane, and (d) the ripe lane with the OLDEST
    deadline -- the discipline that bounds waiting.
    """
    pending = {}  # fp -> [(arrival, rid), ...] in admission order
    for ev in events:
        if ev[0] == "arrive":
            _, t, rid, fp = ev
            pending.setdefault(fp, []).append((t, rid))
        elif ev[0] == "dispatch":
            _, t, fp, width, _key, rids = ev
            ripe = {}
            for f, lane in pending.items():
                if not lane:
                    continue
                deadline = lane[0][0] + window
                if deadline <= t or len(lane) >= caps[f]:
                    ripe[f] = deadline
            assert fp in ripe, f"dispatched unripe lane {fp} at t={t}"
            assert ripe[fp] == min(ripe.values()), "not oldest-deadline-first"
            lane = pending[fp]
            assert width <= caps[fp], f"width {width} exceeds cap {caps[fp]}"
            assert [r for _, r in lane[:width]] == list(rids), "not a FIFO prefix"
            del lane[:width]
    for fp, lane in pending.items():
        assert not lane, f"admitted requests of {fp} never dispatched: {lane}"


class TestSimulatorDeterminism:
    def test_same_seed_identical_everything(self):
        trace = make_trace(11, 300, FPS, pattern="poisson", rate=30000.0, skew=1.1)
        cfg = SimConfig(window=1e-3, max_width=8)
        r1 = simulate(CLASSES, trace, cfg)
        r2 = simulate(_classes(), make_trace(
            11, 300, FPS, pattern="poisson", rate=30000.0, skew=1.1), cfg)
        assert r1.events == r2.events
        assert r1.trace_hash == r2.trace_hash
        assert (r1.p50, r1.p99) == (r2.p50, r2.p99)
        assert r1.summary() == r2.summary()

    def test_different_seed_different_trace(self):
        cfg = SimConfig(window=1e-3, max_width=8)
        r1 = simulate(CLASSES, make_trace(1, 200, FPS), cfg)
        r2 = simulate(CLASSES, make_trace(2, 200, FPS), cfg)
        assert r1.trace_hash != r2.trace_hash

    @given(seed=st.integers(0, 10_000), pattern=st.sampled_from(
        ["poisson", "burst", "uniform"]))
    @settings(max_examples=10, deadline=None)
    def test_same_seed_identical_traces_property(self, seed, pattern):
        cfg = SimConfig(window=5e-4, max_width=8)
        mk = lambda: make_trace(seed, 120, FPS, pattern=pattern, rate=40000.0)
        assert simulate(CLASSES, mk(), cfg).events == simulate(CLASSES, mk(), cfg).events


class TestSchedulerInvariants:
    @given(
        seed=st.integers(0, 10_000),
        pattern=st.sampled_from(["poisson", "burst", "uniform"]),
        max_width=st.integers(1, 12),
        window_us=st.integers(0, 2000),
    )
    @settings(max_examples=15, deadline=None)
    def test_fifo_width_and_deadline_order(self, seed, pattern, max_width, window_us):
        window = window_us * 1e-6
        cfg = SimConfig(window=window, max_width=max_width)
        trace = make_trace(seed, 150, FPS, pattern=pattern, rate=50000.0, skew=1.3)
        res = simulate(CLASSES, trace, cfg)
        caps = {fp: max_width for fp in FPS}
        _check_schedule(res.events, window, caps)
        assert res.completed + res.rejected == len(trace)

    @given(seed=st.integers(0, 10_000), cap_requests=st.integers(1, 6))
    @settings(max_examples=10, deadline=None)
    def test_memory_budget_caps_width(self, seed, cap_requests):
        bpr = max(c.bytes_per_request for c in CLASSES.values())
        budget = bpr * cap_requests
        cfg = SimConfig(window=1e-3, max_width=8, memory_budget=budget)
        trace = make_trace(seed, 120, FPS, pattern="burst", rate=100000.0, burst=16)
        res = simulate(CLASSES, trace, cfg)
        for ev in res.events:
            if ev[0] == "dispatch":
                fp, width = ev[2], ev[3]
                assert width * CLASSES[fp].bytes_per_request <= budget
        caps = {
            fp: min(8, budget // CLASSES[fp].bytes_per_request) for fp in FPS
        }
        _check_schedule(res.events, 1e-3, caps)

    def test_no_wait_past_deadline_under_light_load(self):
        # A steady trickle well under capacity: every request must dispatch
        # by its coalescing deadline plus the time the executor may already
        # be busy (one max-width batch per class ahead of it).
        cfg = SimConfig(window=2e-3, max_width=8)
        trace = make_trace(5, 200, FPS, pattern="uniform", rate=2000.0)
        res = simulate(CLASSES, trace, cfg)
        batcher = ContinuousBatcher(CLASSES, window=cfg.window, max_width=8)
        t_max = max(
            batcher.advise(fp, 8).best.predicted_time + cfg.host_overhead_s
            for fp in FPS
        )
        bound = cfg.window + len(FPS) * t_max
        arrivals = {r.rid: r.arrival for r in trace}
        for ev in res.events:
            if ev[0] == "dispatch":
                t, rids = ev[1], ev[5]
                for rid in rids:
                    assert t - arrivals[rid] <= bound + 1e-12

    def test_fifo_completion_order_within_class(self):
        trace = make_trace(9, 250, FPS, pattern="burst", rate=80000.0, burst=24)
        res = simulate(CLASSES, trace, SimConfig(window=1e-3, max_width=8))
        admitted, dispatched = {}, {}
        for ev in res.events:
            if ev[0] == "arrive":
                admitted.setdefault(ev[3], []).append(ev[2])
            elif ev[0] == "dispatch":
                dispatched.setdefault(ev[2], []).extend(ev[5])
        assert admitted == dispatched


class TestAdmission:
    def test_controller_counts_and_reset(self):
        ac = AdmissionController(max_queue_depth=2, reject_burst=3)
        assert ac.admit(0) and ac.admit(1)
        assert not ac.admit(2) and not ac.admit(5)
        assert ac.admit(1)  # streak resets on success
        assert (ac.admitted, ac.rejected) == (3, 2)

    def test_rejection_bursts_escalate_through_watchdog(self):
        wd = StragglerWatchdog(budget=2)
        ac = AdmissionController(max_queue_depth=1, watchdog=wd, reject_burst=4)
        ac.admit(0)
        for _ in range(8):  # two full bursts of consecutive rejections
            ac.admit(1)
        assert ac.rejected == 8
        kinds = [e.get("kind") for e in wd.events]
        assert kinds == ["admission_overload", "admission_overload"]
        assert ac.escalations == 1  # second event exhausts budget=2

    def test_overload_sheds_and_still_serves_admitted(self):
        cfg = SimConfig(window=1e-3, max_width=8, max_queue_depth=8)
        trace = make_trace(3, 400, FPS, pattern="burst", rate=1e6, burst=400)
        res = simulate(CLASSES, trace, cfg)
        assert res.rejected > 0
        assert res.completed + res.rejected == len(trace)
        assert res.completed == sum(1 for e in res.events if e[0] == "arrive")
        caps = {fp: 8 for fp in FPS}
        _check_schedule(res.events, cfg.window, caps)

    def test_validation(self):
        with pytest.raises(ValueError):
            AdmissionController(max_queue_depth=0)
        with pytest.raises(ValueError):
            AdmissionController(reject_burst=0)


class TestQueueAndBatcher:
    def test_lanes_are_fifo(self):
        q = RequestQueue()
        for i in range(6):
            assert q.submit(Request(arrival=0.1 * i, rid=i, fp=f"c{i % 2}"))
        assert len(q) == 6
        assert [r.rid for r in q.take("c0", 2)] == [0, 2]
        assert [r.rid for r in q.take("c0", 9)] == [4]
        assert q.peek_oldest("c0") is None
        assert [fp for fp, _, _ in q.lanes()] == ["c1"]

    def test_batcher_validation(self):
        with pytest.raises(ValueError):
            ContinuousBatcher({})
        with pytest.raises(ValueError):
            ContinuousBatcher(CLASSES, max_width=0)
        bpr = min(c.bytes_per_request for c in CLASSES.values())
        with pytest.raises(ValueError):  # budget below one request
            ContinuousBatcher(CLASSES, memory_budget=bpr - 1)
        with pytest.raises(KeyError):
            ContinuousBatcher(CLASSES).submit(Request(0.0, 0, "nope"))

    def test_advice_is_memoized_per_width(self):
        b = ContinuousBatcher(CLASSES, max_width=8)
        a1 = b.advise("c0", 8)
        a2 = b.advise("c0", 8)
        assert a1 is a2
        assert (b.advice_hits, b.advice_misses) == (1, 1)
        b.advise("c0", 4)
        assert b.advice_misses == 2

    def test_batch_strategy_comes_from_advisor(self):
        b = ContinuousBatcher(CLASSES, window=0.0, max_width=8)
        for i in range(8):
            b.submit(Request(arrival=0.0, rid=i, fp="c0"))
        batch = b.next_batch(0.0)
        assert batch is not None and batch.width == 8
        assert batch.payload_width == 8  # base_width 1
        best = b.advise("c0", 8).best
        assert batch.key == best.key
        assert batch.predicted_time == best.predicted_time
        assert batch.strategy in ("standard", "two_step", "three_step", "split")

    def test_workload_class_validation(self):
        cls = CLASSES["c0"]
        with pytest.raises(ValueError):
            WorkloadClass(fp="x", stats=cls.stats, bytes_per_request=0)
        with pytest.raises(ValueError):
            WorkloadClass(fp="x", stats=cls.stats, bytes_per_request=1, base_width=0)
        with pytest.raises(ValueError):  # key / fingerprint mismatch
            ContinuousBatcher({"other": cls})


class TestThroughputAcceptance:
    def test_coalesced_throughput_at_least_3x_sequential(self):
        """Acceptance pin: k=8 coalescing >= 3x sequential dispatch on the
        same skewed-fingerprint burst trace (deterministic model numbers)."""
        trace = make_trace(7, 256, FPS, pattern="burst",
                           rate=200000.0, skew=1.2, burst=32)
        cfg = SimConfig(window=1e-3, max_width=8)
        rep = serving_report(CLASSES, trace, cfg)
        assert rep["speedup"] >= 3.0
        assert rep["coalesced"]["completed"] == 256
        assert rep["sequential"]["completed"] == 256
        assert rep["coalesced"]["p99_s"] < rep["sequential"]["p99_s"]
        assert rep["coalesced"]["mean_width"] > 4.0

    def test_sequential_baseline_is_width_one(self):
        trace = make_trace(4, 60, FPS, pattern="poisson", rate=50000.0)
        res = sequential_baseline(CLASSES, trace, SimConfig(max_width=8))
        assert res.mean_width == 1.0
        assert res.batches == res.completed == 60


class TestTraces:
    def test_zipf_weights(self):
        w = zipf_weights(4, skew=1.0)
        assert np.isclose(w.sum(), 1.0)
        assert all(w[i] > w[i + 1] for i in range(3))
        assert np.allclose(zipf_weights(4, skew=0.0), 0.25)

    def test_trace_shapes_and_validation(self):
        t = make_trace(0, 50, FPS, pattern="uniform", rate=1000.0)
        assert len(t) == 50
        assert [r.rid for r in t] == list(range(50))
        assert all(t[i].arrival <= t[i + 1].arrival for i in range(49))
        with pytest.raises(ValueError):
            make_trace(0, 10, FPS, pattern="nope")
        with pytest.raises(ValueError):
            make_trace(0, 10, FPS, rate=0.0)
        burst = make_trace(0, 32, FPS, pattern="burst", burst=8, rate=8000.0)
        times = sorted({r.arrival for r in burst})
        assert len(times) == 4  # 32 requests in 4 simultaneous groups

    def test_skew_concentrates_on_hot_class(self):
        t = make_trace(0, 500, FPS, skew=1.5)
        hot = sum(1 for r in t if r.fp == FPS[0])
        assert hot > 500 // len(FPS)


class TestRoutingCountsRagged:
    """`launch/serve.py::routing_counts` must bin tokens by their batch
    row's block-sharded owner (np.array_split convention), not by flat
    index -- the two disagree whenever B % nranks != 0."""

    @staticmethod
    def _setup(V=32, M=8, E=8, seed=0):
        from types import SimpleNamespace

        rng = np.random.default_rng(seed)
        params = {
            "embed": rng.standard_normal((V, M)).astype(np.float32),
            "seg_moe": {"moe": {
                "router": rng.standard_normal((1, M, E)).astype(np.float32)
            }},
        }
        cfg = SimpleNamespace(
            family="moe", moe=SimpleNamespace(top_k=2, n_experts=E)
        )
        return params, cfg, rng

    def test_row_sums_match_block_sharding_ragged(self):
        from repro.launch.serve import routing_counts

        params, cfg, rng = self._setup()
        nranks = 4
        B, S = 5, 3  # ragged: 5 % 4 != 0
        tokens = rng.integers(0, 32, (B, S))
        counts = routing_counts(params, cfg, tokens, nranks)
        sizes = np.array([2, 1, 1, 1])  # array_split of 5 rows over 4 ranks
        assert counts.sum() == B * S * cfg.moe.top_k
        np.testing.assert_array_equal(
            counts.sum(axis=1), sizes * S * cfg.moe.top_k
        )

    def test_flat_index_binning_was_wrong_on_ragged(self):
        params, cfg, rng = self._setup()
        nranks = 4
        B, S, k = 5, 3, cfg.moe.top_k
        tokens = rng.integers(0, 32, (B, S))
        # the pre-fix formula splits batch row 1 across ranks 0 and 1
        N = B * S
        old_src = np.repeat(np.arange(N) * nranks // N, k)
        row_of = np.repeat(np.arange(B), S * k)
        owner = np.repeat(np.arange(nranks), [2, 1, 1, 1])
        assert (old_src != owner[row_of]).any()

    def test_equal_split_unchanged(self):
        from repro.launch.serve import routing_counts

        params, cfg, rng = self._setup()
        nranks = 4
        B, S, k = 8, 4, cfg.moe.top_k
        tokens = rng.integers(0, 32, (B, S))
        counts = routing_counts(params, cfg, tokens, nranks)
        # old flat-index binning agrees exactly when B % nranks == 0
        toks = tokens.reshape(-1)
        logits = params["embed"][toks] @ np.asarray(
            params["seg_moe"]["moe"]["router"])[0]
        top = np.argsort(-logits, axis=-1)[:, :k]
        e_per = cfg.moe.n_experts // nranks
        src = np.repeat(np.arange(toks.size) * nranks // toks.size, k)
        dst = np.minimum(top.reshape(-1) // e_per, nranks - 1)
        old = np.zeros((nranks, nranks), dtype=np.int64)
        np.add.at(old, (src, dst), 1)
        np.testing.assert_array_equal(counts, old)

    def test_flat_token_stream(self):
        from repro.launch.serve import routing_counts

        params, cfg, rng = self._setup()
        nranks = 4
        tokens = rng.integers(0, 32, 10)  # flat [N]: N % nranks != 0
        counts = routing_counts(params, cfg, tokens, nranks)
        np.testing.assert_array_equal(
            counts.sum(axis=1), np.array([3, 3, 2, 2]) * cfg.moe.top_k
        )

    def test_from_routing_workload_class(self):
        from repro.launch.serve import routing_counts

        params, cfg, rng = self._setup()
        counts = routing_counts(params, cfg, rng.integers(0, 32, (8, 4)), 8)
        cls = WorkloadClass.from_routing(counts, ppn=4, d_model=16, fp="moe")
        assert cls.kind == "moe"
        assert cls.base_width == 16
        assert cls.bytes_per_request == int(counts.sum()) * 16 * 4
