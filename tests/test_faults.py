"""Chaos-hardening tests: deterministic fault injection, wire integrity
verification, and the self-healing recovery ladder.

Four layers of guarantees:

* **Determinism** -- compiling the same seeded :class:`FaultPlan` twice
  yields bitwise-identical masks, and injections land exclusively on
  DCI-crossing halo slots (``split_phase.from_local`` slots stay clean).
* **Happy-path preservation** -- with ``verify=False`` and no plan, outputs
  are bitwise identical to the unguarded executor; ``verify=True`` alone
  changes nothing either.
* **Detection + recovery** -- injected corruption raises a structured
  :class:`ExchangeIntegrityError`; the ladder recovers via retry / codec
  demotion / strategy re-advise, recording health + watchdog events; a
  faulted solve still converges and names the recovery path in
  ``SolveResult.status``.
* **Executor lockstep** (slow, 8-device subprocess) -- the same plan drives
  ``execute_numpy`` and the device executor to identical corrupted outputs
  and identical error diagnostics for all 4 strategies x a lossy codec.
"""

import numpy as np
import pytest

from repro.comm import faults as F
from repro.comm.exchange import (
    PodTopology,
    execute_numpy,
    plan,
    random_pattern,
    split_phase,
)
from repro.runtime.watchdog import StragglerWatchdog
from repro.solve import NumpySpMV, cg, spd_system
from repro.sparse import partition_csr, thermal_like

ALL_STRATEGIES = ("standard", "two_step", "three_step", "split")
TOPO = PodTopology(npods=4, ppn=2)


def _pattern(seed=3, local_size=24):
    return random_pattern(np.random.default_rng(seed), TOPO, local_size)


def _payload(pat, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((pat.topo.nranks, pat.local_size)).astype(dtype)


# ---------------------------------------------------------------------------
# Determinism + confinement
# ---------------------------------------------------------------------------


def test_compiled_faults_deterministic():
    pat = _pattern()
    fp = F.FaultPlan(
        seed=11,
        specs=(
            F.FaultSpec(kind="corrupt", prob=0.7, frac=0.3),
            F.FaultSpec(kind="perturb", prob=0.5),
            F.FaultSpec(kind="zero", prob=0.4),
        ),
    )
    for strat in ALL_STRATEGIES:
        sp = plan(strat, pat, message_cap_bytes=256)
        a = F.compile_faults(sp, "bf16", fp)
        b = F.compile_faults(sp, "bf16", fp)
        assert len(a.injections) == len(b.injections) > 0, strat
        for ia, ib in zip(a.injections, b.injections):
            assert (ia.ordinal, ia.op_index, ia.kind) == (ib.ordinal, ib.op_index, ib.kind)
            np.testing.assert_array_equal(ia.np_mask, ib.np_mask)
            np.testing.assert_array_equal(ia.dev_mask, ib.dev_mask)
        # masks live on DCI hops only: every a2a_pod mask has empty diagonal
        for inj in a.injections:
            if inj.stage_kind == "a2a_pod":
                diag = np.arange(TOPO.npods)
                assert not inj.np_mask[diag, :, diag].any()


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
@pytest.mark.parametrize("kind", ["corrupt", "perturb", "zero"])
def test_injection_confined_to_inter_pod_slots(strategy, kind):
    """Faulted output may differ from clean only on halo slots whose source
    rank lives on ANOTHER pod (split_phase.from_local slots stay clean)."""
    pat = _pattern()
    sp = plan(strategy, pat, message_cap_bytes=256)
    x = _payload(pat)
    fp = F.FaultPlan(seed=5, specs=(F.FaultSpec(kind=kind, prob=1.0, frac=1.0),))
    clean = execute_numpy(sp, x)
    faulted = execute_numpy(sp, x, faults=fp)
    diff = ~((faulted == clean) | (np.isnan(faulted) & np.isnan(clean)))
    assert diff.any(), "fault plan with prob=1 must corrupt something"
    split = split_phase(pat)
    on_pod = np.asarray(split.from_local)
    assert not (diff & on_pod).any(), "on-pod halo data was corrupted"


def test_fault_plan_call_gating_and_spec_filters():
    fp = F.FaultPlan(seed=1, specs=(F.FaultSpec(),), active_calls=(0, 2))
    assert fp.active(0) and fp.active(2) and not fp.active(1)
    assert F.FaultPlan(seed=1, specs=(F.FaultSpec(),)).active(99)
    spec = F.FaultSpec(strategies=("two_step",), codecs=("lossy",))
    assert spec.matches("two_step", "bf16")
    assert spec.matches("two_step", "int8")
    assert not spec.matches("two_step", "none")
    assert not spec.matches("standard", "bf16")
    with pytest.raises(ValueError, match="unknown fault kind"):
        F.FaultSpec(kind="melt")
    with pytest.raises(ValueError, match="at least one"):
        F.FaultPlan(seed=0, specs=())


# ---------------------------------------------------------------------------
# Happy-path preservation (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["none", "bf16", "int8"])
def test_verify_mode_is_bitwise_invisible_numpy(wire):
    pat = _pattern()
    x = _payload(pat)
    for strat in ALL_STRATEGIES:
        sp = plan(strat, pat, message_cap_bytes=256)
        base = execute_numpy(sp, x, wire=wire)
        checked = execute_numpy(sp, x, wire=wire, verify=True)
        np.testing.assert_array_equal(base, checked, err_msg=(strat, wire))


def test_inactive_fault_call_is_bitwise_clean():
    """A FaultPlan gated to call 0 leaves call 1 bitwise identical to the
    fault-free executor -- the property the retry rung relies on."""
    pat = _pattern()
    x = _payload(pat)
    sp = plan("two_step", pat, message_cap_bytes=256)
    fp = F.FaultPlan(seed=5, specs=(F.FaultSpec(),), active_calls=(0,))
    clean = execute_numpy(sp, x, wire="bf16")
    np.testing.assert_array_equal(
        execute_numpy(sp, x, wire="bf16", faults=fp, fault_call=1), clean
    )
    assert not np.array_equal(
        execute_numpy(sp, x, wire="bf16", faults=fp, fault_call=0), clean
    )


# ---------------------------------------------------------------------------
# Detection
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wire", ["none", "bf16", "f16", "int8"])
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_corruption_detected_for_every_strategy_and_codec(strategy, wire):
    pat = _pattern()
    sp = plan(strategy, pat, message_cap_bytes=256)
    x = _payload(pat)
    fp = F.FaultPlan(seed=7, specs=(F.FaultSpec(kind="corrupt"),))
    with pytest.raises(F.ExchangeIntegrityError) as ei:
        execute_numpy(sp, x, wire=wire, faults=fp, verify=True)
    err = ei.value
    d = err.diagnostics()
    assert d["strategy"] == strategy and d["codec"] == wire
    assert d["hop_class"] == "inter_pod"
    assert d["stage_kind"] in ("a2a_pod", "permute")
    assert "integrity violation" in str(err)


def test_zero_and_perturb_detected_nan_counted():
    pat = _pattern()
    sp = plan("standard", pat, message_cap_bytes=256)
    x = _payload(pat)
    for kind in ("zero", "perturb"):
        fp = F.FaultPlan(seed=3, specs=(F.FaultSpec(kind=kind, frac=1.0),))
        with pytest.raises(F.ExchangeIntegrityError):
            execute_numpy(sp, x, wire="bf16", faults=fp, verify=True)
    # nan corruption trips the non-finite count -> infinite violation
    fp = F.FaultPlan(seed=3, specs=(F.FaultSpec(kind="corrupt"),))
    with pytest.raises(F.ExchangeIntegrityError) as ei:
        execute_numpy(sp, x, wire="none", faults=fp, verify=True)
    assert ei.value.violation == np.inf


def test_slow_fault_adds_latency_not_values():
    import time

    pat = _pattern()
    sp = plan("two_step", pat, message_cap_bytes=256)
    x = _payload(pat)
    fp = F.FaultPlan(seed=2, specs=(F.FaultSpec(kind="slow", delay_s=0.05),))
    t0 = time.monotonic()
    out = execute_numpy(sp, x, faults=fp, verify=True)  # no raise
    assert time.monotonic() - t0 >= 0.05
    np.testing.assert_array_equal(out, execute_numpy(sp, x))


def test_tolerance_scales_with_codec():
    # lossy drift within the codec bound passes; the same drift is a
    # violation under codec "none"
    amax = np.float32(2.0)
    sum_abs = np.float32(100.0)
    nelem = 64
    drift_ok = float(F.sum_tolerance("bf16", nelem, amax, sum_abs, True)) * 0.5
    pre = (sum_abs, np.float32(0), amax)
    post = (sum_abs + np.float32(drift_ok), np.float32(0), amax)
    assert F.check_violation(pre, post, nelem, "bf16", True) <= 0.0
    assert F.check_violation(pre, post, nelem, "none", False) > 0.0


# ---------------------------------------------------------------------------
# Recovery ladder + health + watchdog
# ---------------------------------------------------------------------------


def _numpy_ladder(pat, x, faults, wire="bf16", health=None, **kw):
    """Drive run_ladder through execute_numpy -- the exact wiring
    NumpySpMV._guarded_halo uses (the device twin is exercised by the slow
    subprocess tests below)."""
    calls = {"n": 0}

    def attempt(strategy, w):
        idx = calls["n"]
        calls["n"] += 1
        sp = plan(strategy, pat, message_cap_bytes=256)
        return execute_numpy(sp, x, wire=w, faults=faults, fault_call=idx, verify=True)

    return F.run_ladder(
        attempt, strategy="two_step", wire=wire, health=health,
        choose_alternative=F.advise_alternative(pat), **kw
    )


def test_ladder_retry_recovers_transient_fault():
    pat = _pattern()
    x = _payload(pat)
    fp = F.FaultPlan(seed=7, specs=(F.FaultSpec(),), active_calls=(0,))
    health = F.HealthTracker()
    out, path = _numpy_ladder(pat, x, fp, health=health)
    sp = plan("two_step", pat, message_cap_bytes=256)
    np.testing.assert_array_equal(out, execute_numpy(sp, x, wire="bf16"))
    assert path.key == "retry:two_step/bf16"
    assert health.failures == {("two_step", "bf16"): 1}
    assert health.recovery_count == 1 and health.last_recovery == path.key


def test_ladder_demotes_lossy_codec():
    pat = _pattern()
    x = _payload(pat)
    # persistent fault that only fires under lossy codecs
    fp = F.FaultPlan(seed=7, specs=(F.FaultSpec(codecs=("lossy",)),))
    health = F.HealthTracker()
    out, path = _numpy_ladder(pat, x, fp, health=health)
    sp = plan("two_step", pat, message_cap_bytes=256)
    np.testing.assert_array_equal(out, execute_numpy(sp, x))
    assert path.key == "demote:two_step/none"
    assert health.is_degraded("two_step", "bf16")
    assert not health.is_degraded("two_step", "none")


def test_ladder_readvises_strategy_and_feeds_watchdog():
    pat = _pattern()
    x = _payload(pat)
    wd = StragglerWatchdog(budget=10)
    health = F.HealthTracker(watchdog=wd)
    # persistent fault pinned to two_step across ALL codecs: only a
    # strategy change cures it
    fp = F.FaultPlan(seed=7, specs=(F.FaultSpec(strategies=("two_step",)),))
    out, path = _numpy_ladder(pat, x, fp, health=health)
    assert path.action == "readvise"
    assert path.strategy in ALL_STRATEGIES and path.strategy != "two_step"
    sp = plan(path.strategy, pat, message_cap_bytes=256)
    np.testing.assert_array_equal(out, execute_numpy(sp, x))
    # both rungs' failures were recorded and escalated to the watchdog
    assert health.is_degraded("two_step", "bf16")
    assert health.is_degraded("two_step", "none")
    assert all(e["kind"] == "exchange_integrity" for e in wd.events)
    assert len(wd.events) == 3  # initial + retry + demotion attempts


def test_ladder_exhaustion_reraises():
    pat = _pattern()
    x = _payload(pat)
    fp = F.FaultPlan(seed=7, specs=(F.FaultSpec(),))  # fires everywhere
    with pytest.raises(F.ExchangeIntegrityError):
        _numpy_ladder(pat, x, fp, fallback=False, max_retries=1)


def test_health_penalty_biases_advisor():
    from repro.core.advisor import EXECUTABLE_STRATEGY, advise

    pat = _pattern()
    cp = pat.to_comm_pattern()
    clean = advise(cp, machine="tpu_v5e_pod")
    health = F.HealthTracker()
    best_clean = EXECUTABLE_STRATEGY[clean.best.strategy]
    health.failures[(best_clean, "none")] = 1
    biased = advise(cp, machine="tpu_v5e_pod", health=health)
    assert EXECUTABLE_STRATEGY[biased.best.strategy] != best_clean
    # the unpenalized ranking is untouched by a default tracker
    empty = advise(cp, machine="tpu_v5e_pod", health=F.HealthTracker())
    assert [r.key for r in empty.ranked] == [r.key for r in clean.ranked]
    assert health.penalty(best_clean, "none") == F.DEGRADED_PENALTY
    assert health.penalty(best_clean, "bf16") == F.SUSPECT_PENALTY
    assert health.penalty("three_step", "none") == 1.0


# ---------------------------------------------------------------------------
# Solver resilience
# ---------------------------------------------------------------------------


def _solver_setup(wire="none", **op_kw):
    rng = np.random.default_rng(0)
    A = spd_system(thermal_like(145, rng))  # 144 rows -> 18 per rank
    part = partition_csr(A, PodTopology(npods=4, ppn=2))
    b = rng.normal(size=(8, part.rows_per_rank))
    return NumpySpMV(part, strategy="two_step", wire=wire, **op_kw), b


def test_solver_histories_unchanged_by_guard_plumbing():
    """verify=False + no FaultPlan: residual histories bitwise identical
    to the plain operator (acceptance criterion)."""
    op_plain, b = _solver_setup()
    op_wire, _ = _solver_setup(wire="bf16")
    res = cg(op_plain, b, tol=1e-8)
    assert res.converged and res.status == "converged" and res.restarts == 0
    assert cg(op_plain, b, tol=1e-8).residuals == res.residuals


def test_solver_recovers_from_injected_dci_corruption():
    fp = F.FaultPlan(seed=11, specs=(F.FaultSpec(kind="corrupt"),), active_calls=(0,))
    op, b = _solver_setup(wire="bf16", verify=True, faults=fp)
    clean_op, _ = _solver_setup(wire="bf16")
    res = cg(op, b, tol=1e-6)
    assert res.converged
    assert res.status == "converged+exchange:retry:two_step/bf16"
    assert op.last_recovery == "retry:two_step/bf16"
    # after the transient call-0 fault, the guarded halo path is bitwise
    # the clean one, so the whole history matches the clean solve
    assert res.residuals == cg(clean_op, b, tol=1e-6).residuals


def test_solver_demotion_path_converges():
    fp = F.FaultPlan(seed=11, specs=(F.FaultSpec(codecs=("lossy",)),))
    op, b = _solver_setup(wire="bf16", verify=True, faults=fp)
    res = cg(op, b, tol=1e-6)
    assert res.converged
    assert res.status.endswith("+exchange:demote:two_step/none")


def test_overlap_guarded_halo_matches_barrier():
    fp = F.FaultPlan(seed=11, specs=(F.FaultSpec(),), active_calls=(0,))
    op, b = _solver_setup(wire="bf16", verify=True, faults=fp, overlap=True)
    res = cg(op, b, tol=1e-6)
    assert res.converged and "+exchange:retry" in res.status


def test_cg_restart_on_nonfinite_residual():
    class Flaky:
        """Delegates to a real operator but poisons ONE matvec."""

        def __init__(self, op, poison_at):
            self._op, self._n, self._at = op, 0, poison_at
            self.topo, self.rows_per_rank = op.topo, op.rows_per_rank

        def __call__(self, v):
            out = self._op(v)
            if self._n == self._at:
                out = np.full_like(out, np.nan)
            self._n += 1
            return out

    base, b = _solver_setup()
    res = cg(Flaky(base, 3), b, tol=1e-6)
    assert res.converged
    assert res.restarts == 1 and res.status == "converged+restart"
    # second poisoning after the restart ends the solve with the reason
    res2 = cg(Flaky(base, 0), b, x0=b, tol=1e-300, maxiter=5)
    assert not res2.converged


def test_bicgstab_tolerance_guard_reports_breakdown():
    from repro.solve import bicgstab

    op, b = _solver_setup()
    # orthogonal-ish shadow breakdown: force rho ~ 0 by solving with an
    # rhs whose first iterate annihilates <rhat, r>; easiest determinate
    # trigger is a poisoned matvec as above
    class Nullify:
        def __init__(self, op):
            self._op, self._n = op, 0
            self.topo, self.rows_per_rank = op.topo, op.rows_per_rank

        def __call__(self, v):
            self._n += 1
            if self._n == 1:
                return np.zeros_like(np.asarray(self._op(v)))
            return self._op(v)

    res = bicgstab(Nullify(op), b, tol=1e-10, maxiter=200)
    # v = A p == 0 makes denom = <rhat, v> = 0: the old exact-zero guard
    # silently truncated; now the solve restarts and reports its path
    assert res.restarts == 1 and "+restart" in res.status
    assert res.converged, res.status


def test_healthy_bicgstab_status_plumbing():
    from repro.solve import bicgstab

    op, b = _solver_setup()
    res = bicgstab(op, b, tol=1e-8)
    assert res.converged and res.status == "converged" and res.restarts == 0
    hard = cg(op, b, tol=1e-300, maxiter=3)
    assert hard.status.startswith(("maxiter", "stagnation"))


# ---------------------------------------------------------------------------
# Executor lockstep (slow: 8-device subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_numpy_device_fault_lockstep(subproc):
    subproc(
        """
import numpy as np
from repro.comm.exchange import random_pattern, execute_numpy, PodTopology
from repro.comm.strategies import IrregularExchange
from repro.comm import faults as F

topo = PodTopology(npods=4, ppn=2)
pat = random_pattern(np.random.default_rng(3), topo, local_size=24)
x = np.random.default_rng(0).standard_normal((topo.nranks, pat.local_size)).astype(np.float32)

for kind in ("corrupt", "perturb", "zero"):
    fp = F.FaultPlan(seed=7, specs=(F.FaultSpec(kind=kind, scale=0.5),))
    for strat in ("standard", "two_step", "three_step", "split"):
        for wire in ("bf16", "int8"):
            ex = IrregularExchange(pat, strat, message_cap_bytes=256, wire=wire, verify=True)
            sp = ex.plan  # the device plan (fused) drives BOTH executors
            # clean, verified outputs agree bitwise
            out_dev = np.asarray(ex(x))
            out_np = execute_numpy(sp, x, wire=wire, verify=True)
            assert np.array_equal(out_dev, out_np), ("clean", strat, wire)
            # identical injections -> identical corrupted outputs (bitwise,
            # nan positions included)
            exf = IrregularExchange(pat, strat, message_cap_bytes=256, wire=wire,
                                    faults=fp, max_retries=0, fallback=False)
            out_devf = np.asarray(exf._raw_call(x, 0))
            out_npf = execute_numpy(sp, x, wire=wire, faults=fp)
            assert out_devf.tobytes() == out_npf.tobytes(), ("fault", kind, strat, wire)
            # identical ExchangeIntegrityError diagnostics
            exv = IrregularExchange(pat, strat, message_cap_bytes=256, wire=wire,
                                    faults=fp, verify=True, max_retries=0, fallback=False)
            try:
                exv._raw_call(x, 0)
                d_dev = None
            except F.ExchangeIntegrityError as e:
                d_dev = e.diagnostics()
            try:
                execute_numpy(sp, x, wire=wire, faults=fp, verify=True)
                d_np = None
            except F.ExchangeIntegrityError as e:
                d_np = e.diagnostics()
            assert d_dev is not None and d_dev == d_np, (kind, strat, wire, d_dev, d_np)
print("FAULT LOCKSTEP OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_device_ladder_recovers(subproc):
    subproc(
        """
import numpy as np
from repro.comm.exchange import random_pattern, PodTopology
from repro.comm.strategies import IrregularExchange
from repro.comm import faults as F

topo = PodTopology(npods=4, ppn=2)
pat = random_pattern(np.random.default_rng(3), topo, local_size=24)
x = np.random.default_rng(0).standard_normal((topo.nranks, pat.local_size)).astype(np.float32)

# transient -> retry
fp = F.FaultPlan(seed=7, specs=(F.FaultSpec(),), active_calls=(0,))
ex = IrregularExchange(pat, "two_step", message_cap_bytes=256, wire="bf16",
                       faults=fp, verify=True)
ref = np.asarray(IrregularExchange(pat, "two_step", message_cap_bytes=256, wire="bf16")(x))
assert np.array_equal(np.asarray(ex(x)), ref)
assert ex.last_recovery == "retry:two_step/bf16", ex.last_recovery

# persistent lossy-only -> demote
fp2 = F.FaultPlan(seed=7, specs=(F.FaultSpec(codecs=("lossy",)),))
ex2 = IrregularExchange(pat, "two_step", message_cap_bytes=256, wire="bf16",
                        faults=fp2, verify=True)
ref2 = np.asarray(IrregularExchange(pat, "two_step", message_cap_bytes=256)(x))
assert np.array_equal(np.asarray(ex2(x)), ref2)
assert ex2.last_recovery == "demote:two_step/none", ex2.last_recovery

# persistent per-strategy -> readvise
fp3 = F.FaultPlan(seed=7, specs=(F.FaultSpec(strategies=("two_step",)),))
ex3 = IrregularExchange(pat, "two_step", message_cap_bytes=256, wire="bf16",
                        faults=fp3, verify=True)
out3 = np.asarray(ex3(x))
assert ex3.last_recovery.startswith("readvise:"), ex3.last_recovery
alt = ex3.last_recovery.split(":")[1].split("/")[0]
ref3 = np.asarray(IrregularExchange(pat, alt, message_cap_bytes=256)(x))
assert np.array_equal(out3, ref3)
print("DEVICE LADDER OK")
""",
        devices=8,
    )
