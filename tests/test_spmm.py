"""Property tests for the blocked-ELL SpMM kernel and its oracle.

The kernel/oracle/exchange triangle: :func:`repro.kernels.spmv_ell.spmm_ell`
must match the jnp oracle for random shapes/dtypes/ELL widths, and its k=1
column must degenerate *exactly* (bitwise) to the existing SpMV kernel --
that exactness is what makes the batched serving path a drop-in replacement
for the per-column loop.
"""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.kernels import ref
from repro.kernels.spmv_ell import spmm_ell, spmv_ell

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "R,K,N,C",
    [
        (8, 3, 32, 1),  # degenerate single column
        (300, 17, 1000, 5),  # ragged everything
        (256, 128, 128, 64),  # K at the lane width, wide rhs
        (513, 1, 7, 2),  # single-entry rows
        (70, 200, 64, 130),  # K and C both above one tile
    ],
)
def test_spmm_ell_shapes(R, K, N, C, dtype):
    rng = np.random.default_rng(R * 1000 + K)  # order-independent draws
    data = rng.normal(size=(R, K)).astype(np.float32)
    cols = rng.integers(0, N, size=(R, K)).astype(np.int32)
    x = rng.normal(size=(N, C)).astype(np.float32)
    d, xx = jnp.asarray(data, dtype), jnp.asarray(x, dtype)
    out = spmm_ell(d, jnp.asarray(cols), xx, interpret=True)
    want = ref.spmm_ell(d, jnp.asarray(cols), xx)
    assert out.shape == (R, C)
    # bf16 tolerance covers a K-term bf16 accumulation whose reduction order
    # may differ between the jitted kernel and the eager oracle
    tol = 2e-5 if dtype == np.float32 else 2e-2 * max(np.sqrt(K), 1.0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.slow
@given(
    r=st.integers(1, 64),
    k=st.integers(1, 16),
    n=st.integers(1, 128),
    c=st.integers(1, 8),
    seed=st.integers(0, 99),
)
@settings(max_examples=15, deadline=None)
def test_spmm_ell_property(r, k, n, c, seed):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(r, k)).astype(np.float32)
    cols = rng.integers(0, n, size=(r, k)).astype(np.int32)
    x = rng.normal(size=(n, c)).astype(np.float32)
    out = spmm_ell(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(x), interpret=True)
    want = ref.spmm_ell(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@given(
    r=st.integers(1, 80),
    k=st.integers(1, 20),
    n=st.integers(1, 96),
    seed=st.integers(0, 99),
)
@settings(max_examples=15, deadline=None)
def test_spmm_k1_degenerates_to_spmv_exactly(r, k, n, seed):
    """A single-column rhs must reproduce the SpMV kernel bit-for-bit: same
    K padding, same reduction order, one degenerate column tile."""
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(r, k)).astype(np.float32)
    cols = rng.integers(0, n, size=(r, k)).astype(np.int32)
    v = rng.normal(size=(n,)).astype(np.float32)
    mv = spmv_ell(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(v), interpret=True)
    mm = spmm_ell(
        jnp.asarray(data), jnp.asarray(cols), jnp.asarray(v[:, None]), interpret=True
    )
    np.testing.assert_array_equal(np.asarray(mm)[:, 0], np.asarray(mv))


@given(seed=st.integers(0, 99), c=st.integers(2, 6))
@settings(max_examples=10, deadline=None)
def test_spmm_oracle_columns_are_spmv_oracles(seed, c):
    """The oracle itself is column-separable: column c of spmm == spmv on
    column c (locks the reduction-order contract the kernel relies on)."""
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(40, 7)).astype(np.float32)
    cols = rng.integers(0, 50, size=(40, 7)).astype(np.int32)
    x = rng.normal(size=(50, c)).astype(np.float32)
    mm = np.asarray(ref.spmm_ell(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(x)))
    for j in range(c):
        mv = np.asarray(
            ref.spmv_ell(jnp.asarray(data), jnp.asarray(cols), jnp.asarray(x[:, j]))
        )
        np.testing.assert_array_equal(mm[:, j], mv)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spmm_ell_wide_sweep(dtype):
    """Interpret-mode Pallas sweep across tile boundaries (slow marker)."""
    for R, K, N, C in [(64, 96, 256, 64), (129, 64, 300, 129), (256, 130, 64, 16)]:
        rng = np.random.default_rng(R * 1000 + K)
        data = rng.normal(size=(R, K)).astype(np.float32)
        cols = rng.integers(0, N, size=(R, K)).astype(np.int32)
        x = rng.normal(size=(N, C)).astype(np.float32)
        d, xx = jnp.asarray(data, dtype), jnp.asarray(x, dtype)
        out = spmm_ell(d, jnp.asarray(cols), xx, interpret=True)
        want = ref.spmm_ell(d, jnp.asarray(cols), xx)
        tol = 2e-5 if dtype == np.float32 else 2e-2 * max(np.sqrt(K), 1.0)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol,
        )
