"""CSRMatrix structural-invariant tests.

The dataclass documents "indices sorted per row"; downstream code
(partition canonical orders, the ELL rewrite) silently relies on it, so
``CSRMatrix.validate`` now enforces it and every generator is
property-tested against it.  All generators funnel through ``_from_coo``
(lexsort by (row, col) + dedup), which is what establishes the invariant;
a generator bypassing it would be caught here.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.sparse.matrices import GENERATORS, CSRMatrix, banded, _from_coo


@given(
    seed=st.integers(0, 1000),
    name=st.sampled_from(sorted(GENERATORS)),
    n=st.sampled_from([32, 64, 128, 144]),
)
@settings(max_examples=30, deadline=None)
def test_generators_satisfy_csr_invariants(seed, name, n):
    A = GENERATORS[name](n, np.random.default_rng(seed))
    assert A.validate() is A
    # per-row view agrees: sorted strictly (no duplicate columns)
    for i in range(A.n):
        cols, _ = A.row(i)
        assert (np.diff(cols) > 0).all(), (name, i)


@given(seed=st.integers(0, 200), bw=st.integers(1, 9))
@settings(max_examples=15, deadline=None)
def test_banded_satisfies_csr_invariants(seed, bw):
    banded(48, bw, np.random.default_rng(seed)).validate()


def test_from_coo_sorts_and_dedups_unsorted_input():
    rows = np.array([1, 0, 1, 1, 0])
    cols = np.array([2, 1, 0, 2, 1])  # row 1 unsorted + dup (1,2); dup (0,1)
    vals = np.arange(5, dtype=np.float64)
    A = _from_coo(3, rows, cols, vals)
    A.validate()
    np.testing.assert_array_equal(A.indices, [1, 0, 2])
    np.testing.assert_array_equal(A.indptr, [0, 1, 3, 3])
    # dedup keeps the first occurrence in the original order
    np.testing.assert_array_equal(A.data, [1.0, 2.0, 0.0])


def test_from_coo_sums_duplicates_and_accepts_empty():
    rows = np.array([0, 0, 1])
    cols = np.array([1, 1, 0])
    vals = np.array([2.0, 3.0, 4.0])
    A = _from_coo(2, rows, cols, vals, duplicates="sum")
    np.testing.assert_array_equal(A.to_dense(), [[0.0, 5.0], [4.0, 0.0]])
    with pytest.raises(ValueError, match="duplicates"):
        _from_coo(2, rows, cols, vals, duplicates="max")
    # empty COO input is a valid all-empty matrix, not a crash
    for dup in ("first", "sum"):
        E = _from_coo(3, np.array([], np.int64), np.array([], np.int64),
                      np.array([], np.float64), duplicates=dup)
        assert E.validate().nnz == 0
        np.testing.assert_array_equal(E.indptr, [0, 0, 0, 0])


def test_solve_problems_on_diagonal_only_matrix():
    """spd_system/shifted_system must survive a matrix with no off-diagonal
    entries (the empty-COO edge of the symmetrization path)."""
    from repro.solve import shifted_system, spd_system

    n = 4
    D = CSRMatrix(
        n=n,
        indptr=np.arange(n + 1, dtype=np.int64),
        indices=np.arange(n, dtype=np.int32),
        data=np.full(n, 2.0, np.float32),
    )
    S = spd_system(D)
    np.testing.assert_array_equal(S.to_dense(), np.eye(n, dtype=np.float32))
    T = shifted_system(D)
    np.testing.assert_array_equal(T.to_dense(), 0.5 * np.eye(n, dtype=np.float32))


def test_validate_rejects_malformed():
    ok = GENERATORS["thermal_like"](64, np.random.default_rng(0))
    # unsorted indices within a row
    bad = ok.indices.copy()
    s, e = ok.indptr[1], ok.indptr[2]
    assert e - s >= 2
    bad[s], bad[s + 1] = bad[s + 1], bad[s]
    with pytest.raises(ValueError, match="not strictly sorted within row 1"):
        CSRMatrix(ok.n, ok.indptr, bad, ok.data).validate()
    # duplicate column in a row
    dup = ok.indices.copy()
    dup[s + 1] = dup[s]
    with pytest.raises(ValueError, match="not strictly sorted"):
        CSRMatrix(ok.n, ok.indptr, dup, ok.data).validate()
    # column id out of range
    oob = ok.indices.copy()
    oob[0] = ok.n
    with pytest.raises(ValueError, match="out of range"):
        CSRMatrix(ok.n, ok.indptr, oob, ok.data).validate()
    # indptr not monotone
    ptr = ok.indptr.copy()
    ptr[1], ptr[2] = ptr[2], ptr[1]
    with pytest.raises(ValueError, match="non-decreasing"):
        CSRMatrix(ok.n, ptr, ok.indices, ok.data).validate()
    # length mismatch
    with pytest.raises(ValueError, match="length"):
        CSRMatrix(ok.n, ok.indptr, ok.indices[:-1], ok.data[:-1]).validate()
    # indptr shape
    with pytest.raises(ValueError, match="indptr shape"):
        CSRMatrix(ok.n + 1, ok.indptr, ok.indices, ok.data).validate()


def test_validate_accepts_empty_rows():
    # row 0 and row 2 empty: indptr repeats, boundary mask must not wrap
    A = CSRMatrix(
        n=3,
        indptr=np.array([0, 0, 2, 2]),
        indices=np.array([0, 2], np.int32),
        data=np.ones(2, np.float32),
    )
    assert A.validate() is A
