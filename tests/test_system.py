"""End-to-end system behaviour: training convergence, fault tolerance,
elastic resharding, distributed SpMV, hierarchical collectives."""

import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig
from repro.runtime.trainer import SimulatedFailure


def tiny_cfg():
    cfg = get_config("stablelm-3b")
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab_size=512, dtype="float32",
    )


def test_training_loss_decreases():
    mesh = make_host_mesh(1, 1)
    t = Trainer(
        tiny_cfg(), mesh,
        TrainerConfig(steps=40, log_every=5, checkpoint_every=1000, batch=8, seq_len=64),
        AdamWConfig(peak_lr=3e-3, warmup_steps=4, total_steps=40),
    )
    out = t.run(resume=False)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.1, losses


def test_failure_injection_and_lossless_restart():
    """Train to 20 with a crash at 15; resume must replay 10..20 and produce
    the exact same final state as an uninterrupted run (deterministic data +
    checkpointed optimizer/step)."""
    mesh = make_host_mesh(1, 1)
    common = dict(log_every=5, checkpoint_every=10, batch=4, seq_len=32)
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        # uninterrupted reference
        ref = Trainer(tiny_cfg(), mesh, TrainerConfig(steps=20, checkpoint_dir=d1, **common))
        ref_out = ref.run(resume=False)
        # crash at 15, restart
        t = Trainer(tiny_cfg(), mesh, TrainerConfig(steps=20, checkpoint_dir=d2,
                                                    fail_at_step=15, **common))
        with pytest.raises(SimulatedFailure):
            t.run(resume=False)
        t.ckpt.wait()
        t2 = Trainer(tiny_cfg(), mesh, TrainerConfig(steps=20, checkpoint_dir=d2, **common))
        out = t2.run(resume=True)
        for a, b in zip(jax.tree.leaves(ref_out["state"]["params"]),
                        jax.tree.leaves(out["state"]["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


@pytest.mark.slow
def test_elastic_resharding_across_meshes(subproc):
    """Checkpoint written on a 1x1 mesh restores and continues on 2x4."""
    subproc(
        """
import dataclasses, tempfile, os
import jax, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.runtime import Trainer, TrainerConfig

cfg = dataclasses.replace(get_config("stablelm-3b"), n_layers=2, d_model=64, d_ff=128,
                          n_heads=4, n_kv_heads=2, head_dim=16, vocab_size=512, dtype="float32")
d = tempfile.mkdtemp()
common = dict(log_every=5, checkpoint_every=10, batch=8, seq_len=32)
t1 = Trainer(cfg, make_host_mesh(1, 1), TrainerConfig(steps=10, checkpoint_dir=d, **common))
t1.run(resume=False)
# resume on a different mesh: 2-way data x 4-way model
t2 = Trainer(cfg, make_host_mesh(2, 4), TrainerConfig(steps=20, checkpoint_dir=d, **common))
out = t2.run(resume=True)
assert out["history"][-1]["step"] == 20
assert np.isfinite(out["history"][-1]["loss"])
print("ELASTIC OK", out["history"][-1])
""",
        devices=8,
    )


@pytest.mark.slow
def test_distributed_spmv_all_strategies(subproc):
    subproc(
        """
import numpy as np
from repro.comm.topology import PodTopology
from repro.sparse import audikw_like, thermal_like, build

rng = np.random.default_rng(42)
topo = PodTopology(npods=2, ppn=4)
for gen in (lambda: audikw_like(64, rng), lambda: thermal_like(64, rng)):
    A = gen()
    v = rng.normal(size=(A.n,)).astype(np.float32)
    want = A.spmv(v)
    for strat in ("standard", "two_step", "three_step", "split", "auto"):
        sp = build(A, topo, strategy=strat, use_pallas=True)
        out = np.asarray(sp(v.reshape(topo.nranks, -1))).reshape(-1)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
print("SPMV OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_hierarchical_collectives_and_compression(subproc):
    subproc(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import (psum_hierarchical, psum_flat, all_to_all_hierarchical, Compressor)
from repro.compat import shard_map

mesh = jax.make_mesh((2, 4), ("pod", "data"))
x = np.random.default_rng(0).normal(size=(8, 5, 3)).astype(np.float32)

def body(v):
    return psum_hierarchical(v, "pod", "data"), psum_flat(v, "pod", "data")
f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod", "data")),
                          out_specs=(P(("pod", "data")), P(("pod", "data")))))
a, b = f(x)
np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

def body2(v):
    return (all_to_all_hierarchical(v, "pod", "data"),
            jax.lax.all_to_all(v, ("pod", "data"), 0, 0, tiled=True))
g = jax.jit(shard_map(body2, mesh=mesh, in_specs=P(("pod", "data")),
                          out_specs=(P(("pod", "data")), P(("pod", "data")))))
y, z = g(np.arange(64.0, dtype=np.float32).reshape(64, 1))
np.testing.assert_allclose(np.asarray(y), np.asarray(z))

comp = Compressor()
def body3(v, r):
    return psum_hierarchical(v, "pod", "data", comp, r)
h = jax.jit(shard_map(body3, mesh=mesh,
                          in_specs=(P(("pod", "data")), P(("pod", "data"))),
                          out_specs=(P(("pod", "data")), P(("pod", "data")))))
xs = np.random.default_rng(1).normal(size=(8, 16)).astype(np.float32)
out, res = h(xs, np.zeros((8, 4), np.float32))
true = xs.sum(0)
rel = np.abs(np.asarray(out)[0] - true).max() / np.abs(true).max()
assert rel < 0.02, rel
assert np.isfinite(np.asarray(res)).all()
print("HIER OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_moe_dispatch_shard_map_matches_local(subproc):
    """Expert-parallel a2a dispatch == replicated-local dispatch when
    capacities are loose."""
    subproc(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import MoEConfig
from repro.models.moe import MoELayer
from repro.models.sharding import init_params

mesh = jax.make_mesh((4,), ("data",))
moe = MoELayer(32, MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0))
p = init_params(moe.params(), jax.random.PRNGKey(0), jnp.float32)
x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 4, 32)), jnp.float32)
y_local = moe(p, x, mesh=None)
y_dist = moe(p, x, mesh=mesh)
np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_dist), rtol=2e-3, atol=2e-3)
print("MOE OK")
""",
        devices=4,
    )
