"""Unit tests: core layers (RoPE, norms, GQA grouping) and the HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.launch.hlo_analysis import analyze
from repro.models.layers import attend_chunked, attend_dot, rmsnorm, rmsnorm_params, rope
from repro.models.sharding import init_params

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def test_rope_preserves_norm():
    x = jnp.asarray(RNG.normal(size=(2, 8, 4, 16)), jnp.float32)
    pos = jnp.arange(8)[None, :]
    y = rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_position_property():
    """q_i . k_j depends only on i - j after rotation."""
    D = 16
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 1, D)), jnp.float32)

    def dot_at(i, j):
        qi = rope(q, jnp.asarray([[i]]))
        kj = rope(k, jnp.asarray([[j]]))
        return float((qi * kj).sum())

    assert dot_at(5, 3) == pytest.approx(dot_at(9, 7), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)


def test_partial_rope_leaves_tail_untouched():
    x = jnp.asarray(RNG.normal(size=(1, 4, 2, 16)), jnp.float32)
    y = rope(x, jnp.arange(4)[None], fraction=0.5)
    np.testing.assert_array_equal(np.asarray(x[..., 8:]), np.asarray(y[..., 8:]))
    assert not np.allclose(np.asarray(x[..., :8]), np.asarray(y[..., :8]))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@given(scale=st.floats(0.5, 10.0), seed=st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_rmsnorm_scale_invariance(scale, seed):
    """Scale invariance is exact up to the eps regularizer (x kept O(1))."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 3, 8)), jnp.float32)
    p = init_params(rmsnorm_params(8), jax.random.PRNGKey(0), jnp.float32)
    a = rmsnorm(p, x)
    b = rmsnorm(p, x * scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=5e-3)


# ---------------------------------------------------------------------------
# attention equivalences
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("win", [None, 8])
def test_chunked_equals_dot_attention(win):
    q = jnp.asarray(RNG.normal(size=(2, 24, 4, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 24, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 24, 2, 16)), jnp.float32)
    a = attend_dot(q, k, v, causal=True, window=win)
    b = attend_chunked(q, k, v, causal=True, window=win, block=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# HLO analyzer on a known program
# ---------------------------------------------------------------------------


def test_analyzer_counts_scanned_dot_flops_and_trips():
    D, L = 64, 7

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None

        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    txt = jax.jit(f).lower(jax.ShapeDtypeStruct((D, D), jnp.float32)).compile().as_text()
    st_ = analyze(txt)
    # one D^3 matmul per trip: 2*D^3*L FLOPs
    assert st_.flops == pytest.approx(2 * D**3 * L, rel=1e-6)
    assert st_.collective_bytes == 0.0
    # memory: at least the L carry writes of the [D,D] f32 tensor
    assert st_.mem_bytes >= L * D * D * 4


def test_analyzer_handles_empty_program():
    st_ = analyze("")
    assert st_.flops == 0 and st_.collective_bytes == 0
