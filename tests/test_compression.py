"""Compressor dtype/round-trip regression tests.

The int8 quantizer must round-trip a payload in the payload's own floating
dtype: a bfloat16 leaf that comes back float32 silently upcasts the
error-feedback residual state carried across steps (the PR-4 bugfix).  These
run in-process under a 1-device shard_map so ``pmax`` has its axis in scope.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm.compression import Compressor
from repro.compat import shard_map


def _round_trip(x: jnp.ndarray):
    """compress -> (trivial 1-pod psum) -> decompress, plus the residual."""
    comp = Compressor()
    mesh = jax.make_mesh((1,), ("pod",))

    def body(v):
        q, scale = comp.compress(v[0], "pod")
        q_sum = jax.lax.psum(q.astype(jnp.int32), "pod")
        out = comp.decompress(q_sum, scale)
        residual = v[0] - comp.decompress(q.astype(jnp.int32), scale)
        return out[None], residual[None]

    fn = jax.jit(
        shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=(P("pod"), P("pod")))
    )
    out, res = fn(x[None])
    return out[0], res[0]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_round_trip_preserves_dtype(dtype):
    x = jnp.asarray(np.linspace(-1.0, 1.0, 32), dtype)
    out, res = _round_trip(x)
    assert out.dtype == dtype, f"payload upcast: {dtype} -> {out.dtype}"
    assert res.dtype == dtype, f"residual upcast: {dtype} -> {res.dtype}"


def test_round_trip_reconstructs_float32():
    x = jnp.asarray(np.linspace(-3.0, 3.0, 64), jnp.float32)
    out, res = _round_trip(x)
    # |error| <= scale/2 per element; with amax=3 and qmax=127 that is ~0.012
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=3.0 / 127)
    # error feedback closes the loop: x == decompressed + residual
    np.testing.assert_allclose(
        np.asarray(out + res), np.asarray(x), rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_all_zero_payload_is_finite(dtype):
    """An all-zero shard must keep a positive scale in the payload's own
    dtype (float16 is the sharp case: float32.tiny flushes to zero there,
    and a float32 constant would promote the scale out of the dtype)."""
    out, res = _round_trip(jnp.zeros((16,), dtype))
    assert np.isfinite(np.asarray(out, np.float32)).all()
    np.testing.assert_array_equal(np.asarray(out, np.float32), 0.0)
    np.testing.assert_array_equal(np.asarray(res, np.float32), 0.0)


def test_nonfinite_element_never_poisons_neighbors():
    """One inf/nan in the shard must not set the quantization scale (an inf
    scale decodes EVERY element to nan): finite neighbors keep the normal
    error bound, inf saturates sign-preserved at the finite amax, nan
    contributes 0 -- and the error-feedback residual keeps the
    non-finiteness at exactly those elements so divergence is not lost."""
    x = np.linspace(-3.0, 3.0, 32).astype(np.float32)
    x[4], x[9], x[20] = np.inf, -np.inf, np.nan
    out, res = _round_trip(jnp.asarray(x))
    out, res = np.asarray(out), np.asarray(res)
    finite = np.isfinite(x)
    assert np.isfinite(out).all()  # summed codes cannot carry non-finite
    np.testing.assert_allclose(out[finite], x[finite], atol=3.0 / 127)
    assert out[4] > 0 and out[9] < 0 and out[4] == -out[9] == np.abs(out[finite]).max()
    assert out[20] == 0.0
    assert np.isposinf(res[4]) and np.isneginf(res[9]) and np.isnan(res[20])
    np.testing.assert_allclose(out[finite] + res[finite], x[finite], rtol=1e-6, atol=1e-6)


def test_decompress_multiplies_at_full_precision():
    """Multi-pod int32 sums exceed bf16's exact-integer range (256); the
    dequantize multiply must run at float32-or-wider and round only the
    final product to the payload dtype."""
    comp = Compressor()
    q_sum = jnp.asarray([514], jnp.int32)  # rounds to 512 if cast to bf16
    scale = jnp.asarray(3.0, jnp.bfloat16)
    out = comp.decompress(q_sum, scale)
    assert out.dtype == jnp.bfloat16
    # 514 * 3 = 1542 -> 1544 in bf16; a bf16-cast q_sum would give
    # 512 * 3 = 1536
    assert float(out[0]) == 1544.0


def test_compress_scale_dtype_follows_payload():
    comp = Compressor()
    mesh = jax.make_mesh((1,), ("pod",))

    def body(v):
        q, scale = comp.compress(v[0], "pod")
        return q[None], scale[None]

    fn = jax.jit(
        shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=(P("pod"), P("pod")))
    )
    for dtype in (jnp.float32, jnp.bfloat16, jnp.float16):
        q, scale = fn(jnp.ones((1, 8), dtype))
        assert q.dtype == jnp.int8
        assert scale.dtype == dtype
