"""Fusion-pass properties: fused programs are equivalent and never cost more.

The oracle chain is three-deep: the vectorized token simulator (checked
inside ``fuse`` itself), the jax-free numpy value executor, and
``ExchangePattern.reference``.  Fused and unfused programs must agree
bit-for-bit on all of them, for every strategy.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.comm import _legacy_planner as legacy
from repro.comm.exchange import (
    A2ALocal,
    A2APod,
    Gather,
    PermuteWorld,
    execute_numpy,
    plan,
    random_pattern,
)
from repro.comm.fusion import compose_gathers, fuse, fuse_stages
from repro.comm.topology import PodTopology

STRATEGIES = ("standard", "two_step", "three_step", "split")


# ---------------------------------------------------------------------------
# Property: fused == unfused == reference, and wire bytes never increase
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 500),
    npods=st.sampled_from([2, 3]),
    ppn=st.sampled_from([2, 4]),
    strategy=st.sampled_from(list(STRATEGIES)),
)
@settings(max_examples=40, deadline=None)
def test_fused_bit_identical_to_unfused_and_reference(seed, npods, ppn, strategy):
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=npods, ppn=ppn)
    pat = random_pattern(rng, topo, local_size=6, p_connect=0.5, max_elems=4)
    sp = plan(strategy, pat, message_cap_bytes=48)
    fp = fuse(sp)  # verify=True replays the token simulator internally

    local = rng.normal(size=(topo.nranks, 6)).astype(np.float32)
    ref = pat.reference(local)
    H = pat.max_recv_size()
    out_unfused = execute_numpy(sp, local)
    out_fused = execute_numpy(fp, local)
    # bit-identical: pure data movement, no arithmetic
    np.testing.assert_array_equal(out_fused, out_unfused)
    np.testing.assert_array_equal(out_fused[:, :H], ref[:, :H])

    # wire bytes never increase (fusion only drops on-device gathers)
    assert fp.wire_intra_pod_bytes <= sp.wire_intra_pod_bytes
    assert fp.wire_inter_pod_bytes <= sp.wire_inter_pod_bytes
    assert fp.intra_pod_bytes == sp.intra_pod_bytes
    assert fp.inter_pod_bytes == sp.inter_pod_bytes
    # and the program got strictly shorter (every strategy starts with a
    # Gather feeding a collective)
    assert len(fp.stages) < len(sp.stages)
    assert fp.fused and not sp.fused


@given(seed=st.integers(0, 200))
@settings(max_examples=10, deadline=None)
def test_fused_batched_payloads_match_reference(seed):
    """Trailing feature dims ride along unchanged through fused programs."""
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=2, ppn=2)
    pat = random_pattern(rng, topo, local_size=5, p_connect=0.6, max_elems=3)
    local = rng.normal(size=(topo.nranks, 5, 3)).astype(np.float32)
    ref = pat.reference(local)
    H = pat.max_recv_size()
    for strategy in STRATEGIES:
        fp = fuse(plan(strategy, pat, message_cap_bytes=32))
        out = execute_numpy(fp, local)
        np.testing.assert_array_equal(out[:, :H], ref[:, :H])


# ---------------------------------------------------------------------------
# Planner parity: the vectorized planner reproduces the legacy programs
# ---------------------------------------------------------------------------


def _assert_plans_equal(a, b):
    assert len(a.stages) == len(b.stages)
    for s, t in zip(a.stages, b.stages):
        assert type(s) is type(t)
        if isinstance(s, Gather):
            np.testing.assert_array_equal(s.idx, t.idx)
        elif isinstance(s, (A2ALocal, A2APod)):
            assert s.buflen == t.buflen
        elif isinstance(s, PermuteWorld):
            assert s.rounds == t.rounds and s.blks == t.blks
            for u, v in zip(s.sels, t.sels):
                np.testing.assert_array_equal(u, v)
    for f in (
        "out_size",
        "intra_pod_bytes",
        "inter_pod_bytes",
        "wire_intra_pod_bytes",
        "wire_inter_pod_bytes",
    ):
        assert getattr(a, f) == getattr(b, f), f


@given(
    seed=st.integers(0, 300),
    strategy=st.sampled_from(list(STRATEGIES)),
)
@settings(max_examples=20, deadline=None)
def test_vectorized_planner_matches_legacy(seed, strategy):
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=int(rng.integers(2, 4)), ppn=int(rng.integers(2, 5)))
    L = int(rng.integers(3, 8))
    pat = random_pattern(
        rng, topo, local_size=L, p_connect=float(rng.uniform(0.1, 0.9)),
        max_elems=min(5, L),
    )
    cap = int(rng.integers(16, 128))
    _assert_plans_equal(
        plan(strategy, pat, message_cap_bytes=cap),
        legacy.plan(strategy, pat, message_cap_bytes=cap),
    )


# ---------------------------------------------------------------------------
# Rewrite unit tests
# ---------------------------------------------------------------------------


def test_adjacent_gathers_compose_to_one():
    """R1: Gather;Gather -> one Gather with the composed index map."""
    # 1-rank program, local = [a, b, c]: w_in = 0, L = 3, so ext0 = local
    # with PAD sentinel 3.  g1 picks [c, a, PAD];
    # g2 picks [g1[2](PAD), g1[0](c), local b, PAD]
    g1 = np.array([[2, 0, 3]], dtype=np.int32)
    # ext1 = concat(g1_out(3), local(3)), sentinel 6
    g2 = np.array([[2, 0, 4, 6]], dtype=np.int32)
    fused = compose_gathers(g1, g2, w_in=0, local_size=3)
    np.testing.assert_array_equal(fused, [[3, 2, 1, 3]])

    stages = fuse_stages((Gather(idx=g1), Gather(idx=g2)), local_size=3)
    assert len(stages) == 1 and isinstance(stages[0], Gather)
    np.testing.assert_array_equal(stages[0].idx, fused)


def test_identity_gather_dropped():
    """R4: an identity Gather on the current buffer is eliminated."""
    g = np.array([[0, 1], [1, 0]], dtype=np.int32)  # L=2, w=0: reads local
    ident = np.array([[0, 1], [0, 1]], dtype=np.int32)  # identity on width-2 buf
    stages = fuse_stages((Gather(idx=g), Gather(idx=ident)), local_size=2)
    assert len(stages) == 1
    np.testing.assert_array_equal(stages[0].idx, g)


def test_gather_folds_into_a2a_input_layout():
    """R2: Gather feeding an A2A becomes the collective's idx."""
    rng = np.random.default_rng(0)
    topo = PodTopology(npods=2, ppn=2)
    pat = random_pattern(rng, topo, local_size=4, p_connect=0.8, max_elems=3)
    fp = fuse(plan("standard", pat))
    kinds = [type(s).__name__ for s in fp.stages]
    assert kinds == ["A2APod", "A2ALocal", "Gather"]
    assert fp.stages[0].idx is not None and fp.stages[1].idx is not None
