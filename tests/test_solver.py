"""Krylov solver correctness over the node-aware exchange.

Three layers of guarantees:

* **Algebra** -- CG / BiCGStab on the jax-free numpy executor converge to
  the ``np.linalg.solve`` reference on all three matrix regimes
  (property-tested over seeds / regimes / strategies).
* **Executor equivalence** -- residual histories are *bitwise identical*
  across every strategy and across barrier-vs-split-phase execution on the
  numpy executor (every strategy delivers the same canonical halo buffer,
  so the whole solve trajectory must agree bit for bit), and on 8 devices
  with the Pallas kernels (slow subprocess test).
* **Amortization plumbing** -- one solve incurs exactly ONE exchange-plan
  miss (the property ``advise_solver`` prices), visible via
  ``repro.comm.cache_stats()``.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.comm import strategies as comm_strategies
from repro.comm.topology import PodTopology
from repro.sparse import partition_csr, thermal_like
from repro.sparse.matrices import GENERATORS
from repro.solve import (
    NumpyReductions,
    NumpySpMV,
    bicgstab,
    build_numpy,
    cg,
    shifted_system,
    spd_system,
)

ALL_STRATEGIES = ("standard", "two_step", "three_step", "split")
TOPO = PodTopology(npods=2, ppn=4)


def _rhs(part, rng, dtype=np.float64):
    return rng.normal(size=(TOPO.nranks, part.rows_per_rank)).astype(dtype)


def _dense_solve(A, b):
    return np.linalg.solve(A.to_dense().astype(np.float64), b.reshape(-1))


# ---------------------------------------------------------------------------
# Algebra: solvers vs the dense numpy reference, all three regimes
# ---------------------------------------------------------------------------


@pytest.mark.slow
@given(
    seed=st.integers(0, 500),
    regime=st.sampled_from(sorted(GENERATORS)),
    strategy=st.sampled_from(list(ALL_STRATEGIES)),
    overlap=st.sampled_from([False, True]),
)
@settings(max_examples=12, deadline=None)
def test_cg_matches_dense_reference(seed, regime, strategy, overlap):
    rng = np.random.default_rng(seed)
    A = spd_system(GENERATORS[regime](144, rng))
    part = partition_csr(A, TOPO)
    op = NumpySpMV(part, strategy=strategy, overlap=overlap)
    b = _rhs(part, rng)
    res = cg(op, b, tol=1e-10, maxiter=2000)
    assert res.converged, (regime, strategy, res.final_residual)
    want = _dense_solve(A, b)
    np.testing.assert_allclose(res.x.reshape(-1), want, rtol=1e-6, atol=1e-7)
    # the recursive residual history is honest: recompute the true residual
    r_true = b - np.asarray(op(res.x))
    bnorm = np.linalg.norm(b.reshape(-1))
    assert np.linalg.norm(r_true.reshape(-1)) / bnorm < 1e-8


@pytest.mark.slow
@given(
    seed=st.integers(0, 500),
    regime=st.sampled_from(sorted(GENERATORS)),
    strategy=st.sampled_from(list(ALL_STRATEGIES)),
)
@settings(max_examples=9, deadline=None)
def test_bicgstab_matches_dense_reference(seed, regime, strategy):
    rng = np.random.default_rng(seed)
    A = shifted_system(GENERATORS[regime](144, rng))
    part = partition_csr(A, TOPO)
    op = NumpySpMV(part, strategy=strategy)
    b = _rhs(part, rng)
    res = bicgstab(op, b, tol=1e-10, maxiter=2000)
    assert res.converged, (regime, strategy, res.final_residual)
    want = _dense_solve(A, b)
    np.testing.assert_allclose(res.x.reshape(-1), want, rtol=1e-6, atol=1e-7)


def test_cg_spd_problem_is_required():
    """On a raw random-valued (indefinite) matrix CG must fail safely: the
    pAp<=0 breakdown guard trips instead of NaN-ing the iterate."""
    rng = np.random.default_rng(3)
    A = GENERATORS["thermal_like"](256, rng)  # random values: not SPD
    part = partition_csr(A, TOPO)
    res = cg(NumpySpMV(part), _rhs(part, rng), tol=1e-10, maxiter=50)
    assert not res.converged
    assert np.isfinite(res.x).all()


# ---------------------------------------------------------------------------
# Executor equivalence: bitwise-identical histories (acceptance criterion)
# ---------------------------------------------------------------------------


def test_cg_histories_bitwise_identical_across_strategies_and_overlap():
    """repro.solve.cg on thermal_like converges to 1e-6 relative residual
    with IDENTICAL iteration counts -- and in fact bitwise-identical
    residual histories and iterates -- across all strategies and overlap
    on/off on the numpy executor."""
    rng = np.random.default_rng(0)
    A = spd_system(thermal_like(256, rng))
    part = partition_csr(A, TOPO)
    b = _rhs(part, rng)
    results = {}
    for strategy in ALL_STRATEGIES:
        for overlap in (False, True):
            op = NumpySpMV(part, strategy=strategy, overlap=overlap)
            results[(strategy, overlap)] = cg(op, b, tol=1e-6)
    ref = results[("standard", False)]
    assert ref.converged and ref.final_residual <= 1e-6
    assert ref.iterations > 5
    assert len(ref.residuals) == ref.iterations + 1
    for key, res in results.items():
        assert res.converged, key
        assert res.iterations == ref.iterations, key
        assert res.residuals == ref.residuals, f"history drift for {key}"
        np.testing.assert_array_equal(res.x, ref.x, err_msg=str(key))


def test_bicgstab_histories_bitwise_identical_across_strategies():
    rng = np.random.default_rng(7)
    A = shifted_system(GENERATORS["random_block"](144, rng))
    part = partition_csr(A, TOPO)
    b = _rhs(part, rng)
    results = [
        bicgstab(NumpySpMV(part, strategy=s, overlap=ov), b, tol=1e-8)
        for s in ALL_STRATEGIES
        for ov in (False, True)
    ]
    ref = results[0]
    assert ref.converged
    for res in results[1:]:
        assert res.residuals == ref.residuals
        np.testing.assert_array_equal(res.x, ref.x)


# ---------------------------------------------------------------------------
# Amortization plumbing: ONE plan per solve
# ---------------------------------------------------------------------------


def test_full_solve_incurs_exactly_one_plan_miss():
    """The whole point of the solver workload: every iteration reuses the
    single cached exchange plan, so a full solve = exactly one plan miss."""
    rng = np.random.default_rng(1)
    A = spd_system(thermal_like(256, rng))
    part = partition_csr(A, TOPO)
    b = _rhs(part, rng)
    comm_strategies.clear_caches()
    op = NumpySpMV(part, strategy="two_step")
    res = cg(op, b, tol=1e-6)
    stats = comm_strategies.cache_stats()
    assert res.converged and res.matvecs > 5
    assert stats.plan_misses == 1, stats
    assert stats.plan_hits == 0, stats
    assert stats.split_misses == 0, stats
    # a second solve on a rebuilt operator re-plans nothing at all
    op2 = NumpySpMV(part, strategy="two_step")
    cg(op2, b, tol=1e-6)
    stats = comm_strategies.cache_stats()
    assert stats.plan_misses == 1 and stats.plan_hits == 1, stats
    comm_strategies.clear_caches()


def test_overlapped_solve_plans_both_phases_once():
    rng = np.random.default_rng(1)
    A = spd_system(thermal_like(256, rng))
    part = partition_csr(A, TOPO)
    b = _rhs(part, rng)
    comm_strategies.clear_caches()
    op = NumpySpMV(part, strategy="split", overlap=True)
    res = cg(op, b, tol=1e-6)
    stats = comm_strategies.cache_stats()
    assert res.converged
    # one split-phase decomposition + one plan per phase, zero re-plans
    assert stats.split_misses == 1 and stats.split_hits == 0, stats
    assert stats.plan_misses == 2 and stats.plan_hits == 0, stats
    comm_strategies.clear_caches()


# ---------------------------------------------------------------------------
# API edges
# ---------------------------------------------------------------------------


def test_solver_edge_cases():
    rng = np.random.default_rng(5)
    A = spd_system(thermal_like(64, rng))
    op = build_numpy(A, TOPO, strategy="two_step")
    L = op.rows_per_rank
    # zero rhs: trivially converged, no matvecs
    res = cg(op, np.zeros((TOPO.nranks, L)))
    assert res.converged and res.iterations == 0 and res.matvecs == 0
    assert res.residuals == (0.0,)
    # warm start from the exact solution: converged before iterating
    b = _rhs(op.partition, rng)
    exact = cg(op, b, tol=1e-12, maxiter=2000)
    warm = cg(op, b, x0=exact.x, tol=1e-6)
    assert warm.converged and warm.iterations == 0 and warm.matvecs == 1
    # maxiter exhaustion reports non-convergence with full history
    hard = cg(op, b, tol=1e-16, maxiter=3)
    assert not hard.converged and hard.iterations == 3
    assert len(hard.residuals) == 4
    # shape validation
    with pytest.raises(ValueError, match="b must be"):
        cg(op, np.zeros((TOPO.nranks, L + 1)))
    with pytest.raises(ValueError, match="expected"):
        op(np.zeros((TOPO.nranks, L + 1)))
    with pytest.raises(ValueError, match="unknown strategy"):
        NumpySpMV(op.partition, strategy="bogus")


def test_early_returns_route_through_finish_status(monkeypatch):
    """Regression: the zero-rhs and warm-start exits must report through
    ``_finish_status`` like every other exit path -- the recovery-suffix
    contract (``+exchange:<strategy>`` when the operator healed mid-solve)
    holds for trivial solves too.  Pins every early-return path for both
    solvers and proves the routing by counting ``_finish_status`` calls."""
    import repro.solve.krylov as K

    rng = np.random.default_rng(5)
    A = spd_system(thermal_like(64, rng))
    op = build_numpy(A, TOPO, strategy="two_step")
    L = op.rows_per_rank
    z = np.zeros((TOPO.nranks, L))
    b = _rhs(op.partition, rng)

    calls = []
    orig = K._finish_status

    def spy(status, restarts, op_, rc0):
        calls.append(status)
        return orig(status, restarts, op_, rc0)

    monkeypatch.setattr(K, "_finish_status", spy)
    for solver in (cg, bicgstab):
        # zero rhs: trivially converged, no matvecs, clean status
        calls.clear()
        r = solver(op, z)
        assert calls == ["converged"], f"{solver.__name__} bypassed _finish_status"
        assert r.status == "converged" and r.restarts == 0
        assert r.converged and r.iterations == 0 and r.matvecs == 0
        assert r.residuals == (0.0,)
        # warm start from the exact solution: one true-residual matvec, no
        # iterations, same routing
        exact = solver(op, b, tol=1e-10, maxiter=2000)
        calls.clear()
        warm = solver(op, b, x0=exact.x, tol=1e-6)
        assert calls == ["converged"]
        assert warm.status == "converged" and warm.restarts == 0
        assert warm.converged and warm.iterations == 0 and warm.matvecs == 1
        assert len(warm.residuals) == 1


def test_numpy_reductions_hierarchical_order():
    red = NumpyReductions(TOPO)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(TOPO.nranks, 17))
    y = rng.normal(size=(TOPO.nranks, 17))
    assert red.dot(x, y) == pytest.approx(float(x.reshape(-1) @ y.reshape(-1)))
    assert red.norm(x) == pytest.approx(float(np.linalg.norm(x)))
    # deterministic: bitwise-stable across calls
    assert red.dot(x, y) == red.dot(x, y)


def test_numpy_operator_matches_csr_spmv():
    rng = np.random.default_rng(11)
    for regime in sorted(GENERATORS):
        A = spd_system(GENERATORS[regime](144, rng))
        part = partition_csr(A, TOPO)
        v = rng.normal(size=(TOPO.nranks, part.rows_per_rank))
        for strategy in ALL_STRATEGIES:
            for overlap in (False, True):
                op = NumpySpMV(part, strategy=strategy, overlap=overlap)
                got = np.asarray(op(v)).reshape(-1)
                np.testing.assert_allclose(
                    got, A.spmv(v.reshape(-1)), rtol=1e-6, atol=1e-9
                )


# ---------------------------------------------------------------------------
# Device path: DistributedSpMV + hierarchical DeviceReductions (serving path)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_cg_on_devices_all_strategies_and_overlap(subproc):
    subproc(
        """
import numpy as np
from repro.comm import Compressor
from repro.comm.topology import PodTopology
from repro.sparse import thermal_like, partition_csr, DistributedSpMV
from repro.solve import DeviceReductions, cg, spd_system

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
A = spd_system(thermal_like(64, rng))
part = partition_csr(A, topo)
b = rng.normal(size=(topo.nranks, part.rows_per_rank)).astype(np.float32)
results = {}
for strat in ("standard", "two_step", "three_step", "split"):
    for ov in (False, True):
        op = DistributedSpMV(part, strategy=strat, use_pallas=True, overlap=ov)
        results[(strat, ov)] = cg(op, b, tol=1e-6)
ref = results[("standard", False)]
assert ref.converged and ref.final_residual <= 1e-6, ref
for key, res in results.items():
    # Pallas kernels make overlap bitwise; histories must agree exactly
    assert res.residuals == ref.residuals, (key, res.residuals[-3:])
    assert res.iterations == ref.iterations, key
want = np.linalg.solve(A.to_dense().astype(np.float64), b.reshape(-1).astype(np.float64))
np.testing.assert_allclose(ref.x.reshape(-1), want, rtol=1e-3, atol=1e-4)

# int8-compressed inter-pod reductions: converges, just less tightly
red = DeviceReductions(topo, compressor=Compressor())
op = DistributedSpMV(part, strategy="two_step")
comp = cg(op, b, tol=1e-4, maxiter=200, reductions=red)
assert comp.converged, comp.final_residual
print("SOLVER DEVICES OK", ref.iterations, "iters")
""",
        devices=8,
    )
