"""Tier-1 guard for the benchmark scripts: ``benchmarks/run.py --smoke``.

Benchmark code is not imported by the library, so without this test it can
rot silently (stale imports, renamed APIs).  The smoke pass runs every
section in a reduced configuration and this test asserts the run succeeds
and that the load-bearing rows -- including the SpMM k-sweep with its
fused-beats-looped claim -- are present.
"""

import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.mark.slow
def test_benchmarks_run_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # sections spawn their own device subprocesses
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke"],
        capture_output=True,
        text=True,
        timeout=900,
        cwd=REPO,
        env=env,
    )
    assert proc.returncode == 0, (
        f"--smoke failed (rc={proc.returncode})\n--- stdout ---\n"
        f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
    )
    out = proc.stdout
    for marker in (
        "table2/lassen/",  # params
        "fig4.3/",  # modeled
        "payload_width/k64",  # modeled: k sweep
        "fig4.2/audikw_like/",  # validation
        "fig5.1/thermal_like/",  # spmv
        "kswp/8r/k4",  # spmv: SpMM k-sweep (smoke topology)
        "overlap/2p/f0.25/k1",  # overlap: split-phase sweep
        "overlap/2p/f0.75/k4",
        "solver/thermal_like/two_step/ov1",  # solver: CG workload sweep
        "solver/random_block/standard/ov0",
        "solver/audikw_like/advisor",
        "planning/8r/",  # planning
        "kernel/spmm_ell/interpret/k4",  # kernels
    ):
        assert marker in out, f"missing benchmark row {marker!r}\n{out[-4000:]}"

    # the overlap sweep's acceptance property in miniature: at interior
    # fraction 0.75 / k=4 the overlap-aware model must predict a win (the
    # values are model outputs, not timings, so this is deterministic)
    m = re.search(r"overlap/2p/f0\.75/k4,.*model_win=([0-9.]+)x", out)
    assert m, f"overlap row unparsable\n{out[-2000:]}"
    assert float(m.group(1)) > 1.0, f"no modeled overlap win: {m.group(0)}"

    # the k-sweep's acceptance property in miniature: by k=4 the fused SpMM
    # path must beat k independent exchange+SpMV rounds (the margin is ~k on
    # the exchange count, so this is timing-noise safe)
    m = re.search(r"kswp/8r/k4,.*looped_us=([0-9.]+) fused_us=([0-9.]+)", out)
    assert m, f"k-sweep row unparsable\n{out[-2000:]}"
    looped, fused = float(m.group(1)), float(m.group(2))
    assert fused < looped, f"fused SpMM ({fused}us) not beating looped ({looped}us)"
    assert "parity=ok" in out

    # the solver sweep's acceptance property in miniature: CG converged on
    # every regime row with a residual at or under the 1e-6 target
    solver_rows = re.findall(r"solver/\w+/\w+/ov[01],.*conv=(\d) relres=([0-9.eE+-]+)", out)
    assert solver_rows, f"no solver rows\n{out[-2000:]}"
    for conv, relres in solver_rows:
        assert conv == "1" and float(relres) <= 1e-6, (conv, relres)
