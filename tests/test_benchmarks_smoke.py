"""Tier-1 guard for the benchmark scripts: ``benchmarks/run.py --smoke``.

Benchmark code is not imported by the library, so without this test it can
rot silently (stale imports, renamed APIs).  The smoke pass runs every
section in a reduced configuration and this test asserts the run succeeds,
that the load-bearing rows -- including the SpMM k-sweep with its
fused-beats-looped claim and the wire-codec byte reductions -- are
present, and that the machine-readable ``BENCH_exchange.json`` record has
the pinned schema.
"""

import json
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
BENCH_JSON = os.path.join(REPO, "BENCH_exchange.json")


def test_record_never_written_by_failing_or_partial_runs(tmp_path):
    """The tracked record's contract is failures == [] with every section
    ok, so a broken environment (or a single-section iteration) must leave
    the committed trajectory file untouched -- only a full passing run may
    replace it.  (A full run in a broken environment once clobbered the
    record with 7 failed sections; this pins the guard.)"""
    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import maybe_write_record
    finally:
        sys.path.remove(REPO)

    path = str(tmp_path / "BENCH_exchange.json")
    every = ["params", "spmv"]

    # failing run, even a full one: no write
    report = {"schema": 1, "smoke": True, "sections": {}, "failures": ["spmv"]}
    assert maybe_write_record(report, every, every, path=path) is False
    assert not os.path.exists(path)

    # a not-ok section must block the write even if failures[] is out of
    # sync with it (the guard enforces the record contract directly)
    report = {
        "schema": 1,
        "smoke": True,
        "sections": {"spmv": {"elapsed_s": 0.1, "ok": False}},
        "failures": [],
    }
    assert maybe_write_record(report, every, every, path=path) is False
    assert not os.path.exists(path)

    # passing but partial run: no write
    report = {"schema": 1, "smoke": True, "sections": {}, "failures": []}
    assert maybe_write_record(report, ["params"], every, path=path) is False
    assert not os.path.exists(path)

    # full passing run: writes, with the wire counters attached.  The
    # fused-solve measurement needs an 8-device subprocess, so this
    # hermetic test injects a synthetic record through the test seam.
    fused = {"speedup": 2.5, "cache": {"plan_misses": 1, "fused_misses": 1,
                                       "fused_hits": 1}}
    assert maybe_write_record(report, every, every, path=path,
                              fused_record=fused) is True
    with open(path) as f:
        written = json.load(f)
    assert written["failures"] == []
    assert written["fused_solve"] == fused
    assert set(written["wire_bytes"]["codecs"]) == {
        "standard",
        "two_step",
        "three_step",
        "split",
    }
    assert written["moe_dispatch"]["hit_rate"] >= 0.9
    # schema 4: the serving acceptance record rides every full write
    assert written["serving"]["speedup"] >= 3.0
    assert len(written["serving"]["trace_hash"]) == 40
    # schema 6: so does the serving-chaos record (jax-free, deterministic)
    assert written["serving_chaos"]["completion_rate"] >= 0.99


@pytest.mark.slow
def test_benchmarks_run_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # sections spawn their own device subprocesses
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke"],
        capture_output=True,
        text=True,
        timeout=900,
        cwd=REPO,
        env=env,
    )
    assert proc.returncode == 0, (
        f"--smoke failed (rc={proc.returncode})\n--- stdout ---\n"
        f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
    )
    out = proc.stdout
    for marker in (
        "table2/lassen/",  # params
        "fig4.3/",  # modeled
        "payload_width/k64",  # modeled: k sweep
        "fig4.2/audikw_like/",  # validation
        "fig5.1/thermal_like/",  # spmv
        "kswp/8r/k4",  # spmv: SpMM k-sweep (smoke topology)
        "overlap/2p/f0.25/k1",  # overlap: split-phase sweep
        "overlap/2p/f0.75/k4",
        "solver/thermal_like/two_step/ov1",  # solver: CG workload sweep
        "solver/random_block/standard/ov0",
        "solver/audikw_like/advisor",
        "solver/fused/two_step",  # solver: fused whole-solve vs host loop
        "wiremodel/tiny/k1",  # wire: model crossover sweep
        "wiremodel/big/k1",
        "wire/2p/standard/none",  # wire: measured codec sweep
        "wire/2p/two_step/bf16",
        "wire/2p/split/int8",
        "planning/8r/",  # planning
        "fingerprint/8r",  # planning: plan-cache key micro-benchmark
        "kernel/spmm_ell/interpret/k4",  # kernels
        "chaos/two_step/bf16",  # chaos: recovery ladder sweep
        "chaos/split/bf16",
        "chaosserve/storm",  # chaos: serving executor under a fault storm
        "chaosverify/two_step/bf16",  # chaos: verify-mode overhead
        "moestats/8r/uniform",  # moe_dispatch: routing economics
        "moe/8r/uniform/all_to_all/none",  # moe_dispatch: baseline column
        "moe/8r/skewed/two_step/bf16",  # moe_dispatch: strategy x codec
        "moeplan/8r/skewed",  # moe_dispatch: plan-cache behaviour
        "serving/burst/w0us/auto",  # serving: simulated sweep
        "serving/burst/w1000us/two_step",  # serving: pinned-strategy column
        "serving/acceptance/burst/k8",  # serving: acceptance cell
        "serving/replay/8r/",  # serving: measured fused-SpMM replay
    ):
        assert marker in out, f"missing benchmark row {marker!r}\n{out[-4000:]}"

    # the overlap sweep's acceptance property in miniature: at interior
    # fraction 0.75 / k=4 the overlap-aware model must predict a win (the
    # values are model outputs, not timings, so this is deterministic)
    m = re.search(r"overlap/2p/f0\.75/k4,.*model_win=([0-9.]+)x", out)
    assert m, f"overlap row unparsable\n{out[-2000:]}"
    assert float(m.group(1)) > 1.0, f"no modeled overlap win: {m.group(0)}"

    # the k-sweep's acceptance property in miniature: by k=4 the fused SpMM
    # path must beat k independent exchange+SpMV rounds (the margin is ~k on
    # the exchange count, so this is timing-noise safe)
    m = re.search(r"kswp/8r/k4,.*looped_us=([0-9.]+) fused_us=([0-9.]+)", out)
    assert m, f"k-sweep row unparsable\n{out[-2000:]}"
    looped, fused = float(m.group(1)), float(m.group(2))
    assert fused < looped, f"fused SpMM ({fused}us) not beating looped ({looped}us)"
    assert "parity=ok" in out

    # the solver sweep's acceptance property in miniature: CG converged on
    # every regime row with a residual at or under the 1e-6 target
    solver_rows = re.findall(r"solver/\w+/\w+/ov[01],.*conv=(\d) relres=([0-9.eE+-]+)", out)
    assert solver_rows, f"no solver rows\n{out[-2000:]}"
    for conv, relres in solver_rows:
        assert conv == "1" and float(relres) <= 1e-6, (conv, relres)

    # the fused front-end's acceptance property in miniature: the fused
    # whole-solve program beats the host-driven loop by >= 2x on the
    # reference problem (maxiter=120), with identical trajectories
    m = re.search(r"solver/fused/two_step,.*speedup=([0-9.]+)x parity=ok", out)
    assert m, f"fused solver row unparsable\n{out[-2000:]}"
    assert float(m.group(1)) >= 2.0, f"fused under 2x: {m.group(0)}"

    # the wire sweep's acceptance property in miniature: every measured
    # codec row passed its parity check, and the bf16 wire reports >= 1.8x
    # inter-pod byte reduction for every strategy
    wire_rows = re.findall(
        r"wire/2p/(\w+)/(\w+),.*reduction=([0-9.]+)x parity=ok", out
    )
    assert len(wire_rows) >= 16, f"missing wire rows\n{out[-2000:]}"
    for strat, codec, red in wire_rows:
        if codec == "bf16":
            assert float(red) >= 1.8, (strat, codec, red)
        if codec == "none":
            assert float(red) == 1.0, (strat, red)

    # the chaos sweep's acceptance property in miniature: every seeded
    # fault scenario recovered (the ladder's job), and every verify-mode
    # parity check passed
    chaos_rows = re.findall(r"chaos/(\w+)/(\w+),.*recovered=(\d+)/(\d+)", out)
    assert chaos_rows, f"no chaos rows\n{out[-2000:]}"
    for strat, codec, got, want in chaos_rows:
        assert got == want and int(want) > 0, (strat, codec, got, want)
    assert re.search(r"chaosverify/\w+/\w+,.*parity=ok", out)

    # the serving-chaos storm's acceptance property in miniature: the
    # executor ladder completes >= 99% of admitted requests under the
    # seeded fault storm (the ISSUE 10 bar), with every injected fault
    # either recovered or accounted for as a shed
    m = re.search(r"chaosserve/storm,.*completed=(\d+)/(\d+)", out)
    assert m, f"chaosserve row unparsable\n{out[-2000:]}"
    done, admitted = int(m.group(1)), int(m.group(2))
    assert admitted > 0 and done / admitted >= 0.99, m.group(0)

    # the MoE dispatch sweep's acceptance properties in miniature: every
    # measured (strategy, codec) row passed its parity check against the
    # all-to-all baseline, and the jittering skewed load held the plan
    # caches at >= 90% hits (the tentpole's bucketing acceptance number)
    moe_rows = re.findall(r"moe/8r/(\w+)/(\w+)/(\w+),.*parity=ok", out)
    assert len(moe_rows) >= 10, f"missing moe rows\n{out[-2000:]}"
    m = re.search(
        r"moeplan/8r/skewed,.*bucket_hit_rate=([0-9.]+) exchange_hit_rate=([0-9.]+)",
        out,
    )
    assert m, f"moeplan row unparsable\n{out[-2000:]}"
    assert float(m.group(1)) >= 0.9 and float(m.group(2)) >= 0.9, m.group(0)

    # the fingerprint micro-benchmark's acceptance property: the bytes-hash
    # plan-cache key beats the string-join it replaced (the margin is ~2-3x,
    # so best-of-N timing keeps this noise-safe), and memoized re-reads are
    # sub-microsecond
    m = re.search(r"fingerprint/8r,.*strjoin_us=[0-9.]+ speedup=([0-9.]+)x memo_ns=(\d+)", out)
    assert m, f"fingerprint row unparsable\n{out[-2000:]}"
    assert float(m.group(1)) > 1.0, f"fingerprint slower than strjoin: {m.group(0)}"
    assert int(m.group(2)) < 1000, m.group(0)

    # the serving sweep's acceptance properties in miniature: the coalescing
    # acceptance cell holds the >= 3x speedup over sequential dispatch (model
    # numbers: deterministic), and the real fused-SpMM replay kept numerical
    # parity between the coalesced and per-request paths
    m = re.search(r"serving/acceptance/burst/k8,.*speedup=([0-9.]+)x", out)
    assert m, f"serving acceptance row unparsable\n{out[-2000:]}"
    assert float(m.group(1)) >= 3.0, f"coalescing under 3x: {m.group(0)}"
    assert re.search(r"serving/replay/8r/k\d+,.*parity=ok", out)

    # machine-readable record: schema, per-section timings, wire counters
    with open(BENCH_JSON) as f:
        report = json.load(f)
    assert report["schema"] == 6
    assert report["smoke"] is True
    assert report["failures"] == []
    for name, sec in report["sections"].items():
        assert sec["ok"] is True, name
        assert sec["elapsed_s"] >= 0.0
    assert set(report["sections"]) >= {"params", "spmv", "overlap", "solver", "wire"}
    counters = report["wire_bytes"]["codecs"]
    assert set(counters) == {"standard", "two_step", "three_step", "split"}
    for strat, per_codec in counters.items():
        none = per_codec["none"]
        assert set(per_codec) == {"none", "bf16", "f16", "int8"}
        for codec, c in per_codec.items():
            # codecs never touch intra-pod bytes
            assert c["intra_pod_bytes"] == none["intra_pod_bytes"], (strat, codec)
        assert (
            none["inter_pod_bytes"] / per_codec["bf16"]["inter_pod_bytes"] >= 1.8
        ), strat

    # schema 2: chaos-recovery tally covers every strategy x lossy codec
    # and every scenario recovered via some ladder rung
    chaos = report["chaos_recovery"]
    assert set(chaos) == {
        f"{s}/{c}"
        for s in ("standard", "two_step", "three_step", "split")
        for c in ("bf16", "f16", "int8")
    }
    for key, tally in chaos.items():
        assert tally["recovered"] == tally["attempts"] > 0, (key, tally)
        assert (
            tally["retry"] + tally["demote"] + tally["readvise"] + tally["clean_pass"]
            == tally["recovered"]
        ), (key, tally)

    # schema 3: MoE routing counters -- the simulated plan-cache hit rate
    # holds the >= 90% acceptance bar, and the bucketed dispatch pattern
    # never ships more bytes than the uniform all-to-all it replaces
    moe = report["moe_dispatch"]
    assert moe["hit_rate"] >= 0.9, moe
    assert moe["replans"] >= 1 and moe["batches"] > moe["replans"], moe
    assert set(moe["strategies"]) == {"standard", "two_step", "three_step", "split"}
    for strat, per in moe["strategies"].items():
        uni, buck = per["uniform"], per["bucketed"]
        assert buck["inter_pod_bytes"] <= uni["inter_pod_bytes"], (strat, per)
        assert buck["intra_pod_bytes"] <= uni["intra_pod_bytes"], (strat, per)
        assert buck["inter_pod_bytes"] > 0, (strat, per)

    # schema 4: the serving record -- coalescing holds the >= 3x acceptance
    # speedup, both runs completed the whole trace, and the deterministic
    # simulator's trace hash is committed (a diff means the scheduler made
    # different decisions, not just different timings)
    serving = report["serving"]
    assert serving["speedup"] >= 3.0, serving
    assert serving["max_width"] == 8 and serving["window_s"] == 1e-3
    assert len(serving["trace_hash"]) == 40
    co, sq = serving["coalesced"], serving["sequential"]
    assert co["completed"] == sq["completed"] > 0
    assert co["rejected"] == sq["rejected"] == 0
    assert co["p99_s"] < sq["p99_s"], serving
    assert co["mean_width"] > 4.0 and sq["mean_width"] == 1.0

    # schema 5: the fused-solve record -- the measured >= 2x acceptance
    # speedup at a >= 100-iteration horizon, identical host/fused
    # trajectories, and the one-plan-miss / one-compile cache pins
    fs = report["fused_solve"]
    assert fs["speedup"] >= 2.0, fs
    assert fs["problem"]["maxiter"] >= 100 and fs["problem"]["devices"] == 8
    assert fs["host"]["iterations"] == fs["fused"]["iterations"] > 0, fs
    assert fs["host"]["status"] == fs["fused"]["status"], fs
    assert fs["fused"]["us_per_iter"] < fs["host"]["us_per_iter"], fs
    assert fs["cache"] == {"plan_misses": 1, "fused_misses": 1, "fused_hits": 1}

    # schema 6: the serving-chaos record -- the executor recovery ladder
    # holds the >= 99% completion acceptance bar under the seeded storm,
    # the tallies are internally consistent, and the deterministic trace
    # hash is committed (a diff = different fault-handling decisions)
    sc = report["serving_chaos"]
    assert sc["admitted"] == sc["completed"] + sc["shed"] > 0, sc
    assert sc["completion_rate"] >= 0.99, sc
    assert sc["fault_events"] >= sc["recoveries"] >= 0, sc
    assert sc["probes"] >= sc["probe_recoveries"] >= 0, sc
    assert 0.0 <= sc["shed_rate"] <= 1.0 - sc["completion_rate"] + 1e-9, sc
    assert 0.0 <= sc["deadline_miss_rate"] <= 1.0, sc
    assert len(sc["trace_hash"]) == 40
