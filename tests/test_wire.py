"""Wire codec tests: round-trip bounds, codec="none" bitwise identity,
byte accounting, and device-executor parity (8-device subprocess).

The acceptance property of the wire layer (ISSUE 5): ``codec="none"`` is
bitwise identical to the codec-free executor for all 4 strategies x
barrier/overlap; lossy codecs deliver inter-pod halo values within their
pinned per-element error bounds while every on-pod value stays bit-exact;
and the reported ``wire_bytes`` show the inter-pod byte reduction.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.comm import wire
from repro.comm.exchange import (
    ExchangePattern,
    Need,
    execute_numpy,
    plan,
    random_pattern,
    split_phase,
)
from repro.comm.fusion import fuse
from repro.comm.topology import PodTopology

STRATEGIES = ("standard", "two_step", "three_step", "split")
LOSSY = ("bf16", "f16", "int8")


def _pattern(seed=0, npods=2, ppn=4, local_size=6):
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=npods, ppn=ppn)
    return topo, random_pattern(rng, topo, local_size, p_connect=0.6, max_elems=4)


# ---------------------------------------------------------------------------
# Codec round-trip properties (numpy reference implementation)
# ---------------------------------------------------------------------------


def test_roundtrip_exact_for_representable_values():
    """bf16/f16 wires are lossless for values their mantissa can hold."""
    exact = np.float32([0.0, 1.0, -1.0, 1.5, 0.25, -2.75, 128.0, 3.0e-3 * 0])
    np.testing.assert_array_equal(wire.roundtrip_np(exact, "bf16", 1), exact)
    np.testing.assert_array_equal(wire.roundtrip_np(exact, "f16", 1), exact)
    # int8 is exact for 0 and +/- the block max
    blocks = np.float32([[127.0, -127.0, 0.0]])
    np.testing.assert_array_equal(wire.roundtrip_np(blocks, "int8", 1), blocks)


@given(seed=st.integers(0, 200), codec=st.sampled_from(LOSSY))
@settings(max_examples=40, deadline=None)
def test_roundtrip_bounded_relative_error(seed, codec):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(5, 17)) * 10.0 ** rng.integers(-3, 4)).astype(np.float32)
    rt = wire.roundtrip_np(x, codec, block_ndim=1)
    bound = wire.REL_ERROR_BOUND[codec]
    floor = wire.ABS_ERROR_FLOOR[codec]
    if codec == "int8":
        # per-block bound relative to the block's max magnitude
        amax = np.abs(x).max(axis=1, keepdims=True)
        assert (np.abs(rt - x) <= bound * amax * (1 + 1e-6)).all()
    else:
        assert (np.abs(rt - x) <= bound * np.abs(x) * (1 + 1e-6) + floor).all()


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16"])
@pytest.mark.parametrize("codec", wire.WIRE_CODECS)
def test_roundtrip_preserves_dtype(dtype, codec):
    """Payload dtype survives every codec, including bf16 payloads."""
    import ml_dtypes

    dt = np.dtype(getattr(ml_dtypes, dtype, None) or dtype)
    x = np.linspace(-1, 1, 16).astype(dt)
    rt = wire.roundtrip_np(x, codec, 1)
    assert rt.dtype == dt, f"{codec} upcast {dt} -> {rt.dtype}"


def test_narrow_payloads_pass_through_untouched():
    """A codec never widens and never re-encodes an already-narrow payload:
    a bf16 payload on a bf16 wire (or f16 on f16) is the identity."""
    import ml_dtypes

    xb = np.linspace(-3, 3, 16).astype(ml_dtypes.bfloat16)
    assert wire.roundtrip_np(xb, "bf16", 1) is xb
    xh = np.linspace(-3, 3, 16).astype(np.float16)
    assert wire.roundtrip_np(xh, "f16", 1) is xh
    assert wire.roundtrip_np(xh, "bf16", 1) is xh  # equal width: no win
    xi = np.arange(8, dtype=np.int32)
    assert wire.roundtrip_np(xi, "int8", 1) is xi  # non-float: never encoded
    assert not wire.applies("bf16", np.float16)
    assert wire.applies("int8", np.float16)


def test_bf16_payload_is_floating_for_the_int8_wire():
    """ml_dtypes.bfloat16 has numpy kind 'V', not 'f' -- the codec layer
    must still recognize it as a floating payload so the int8 wire really
    quantizes it (the byte accounting already promises the reduction)."""
    import ml_dtypes

    assert wire.applies("int8", ml_dtypes.bfloat16)
    x = np.array([1.0, 0.004], ml_dtypes.bfloat16)
    rt = wire.roundtrip_np(x, "int8", 1)
    assert rt.dtype == x.dtype
    # actually quantized: 0.004 lands on the nearest 1/127 step
    assert float(rt[1]) != float(x[1])
    assert abs(float(rt[1]) - float(x[1])) <= wire.REL_ERROR_BOUND["int8"] * 1.01


def test_cast_codecs_saturate_instead_of_overflowing():
    """Finite payload values above the wire type's max must saturate to it,
    never become infinities on the wire (bf16's window is narrow --
    ~3.39e38..f32 max -- but a diverging solve lands in it)."""
    import ml_dtypes

    big = np.float32([3.402e38, -3.402e38, 1.0e5, 1.0])
    for codec, wdt in (("bf16", ml_dtypes.bfloat16), ("f16", np.float16)):
        rt = wire.roundtrip_np(big, codec, 1)
        assert np.isfinite(rt).all(), (codec, rt)
        fmax = wire.ml_finfo_max(wdt)
        assert float(np.abs(rt).max()) <= fmax


def test_cast_codecs_propagate_true_nonfinite():
    """Saturation is for *finite* overflow only: a genuine inf/nan payload
    (a diverging solve) must cross the wire non-finite so downstream
    ``isfinite`` guards still fire -- bf16/f16 both represent inf/nan."""
    x = np.float32([np.inf, -np.inf, np.nan, 1.0, 3.402e38])
    for codec in ("bf16", "f16"):
        rt = wire.roundtrip_np(x, codec, 1)
        assert np.isposinf(rt[0]) and np.isneginf(rt[1]) and np.isnan(rt[2]), (codec, rt)
        assert rt[3] == 1.0
        # the finite out-of-range magnitude still saturates, never overflows
        assert np.isfinite(rt[4]), (codec, rt)


def test_int8_nonfinite_never_poisons_the_block():
    """One inf/nan in a wire block decodes to nan (the reserved
    INT8_NONFINITE code; int8 cannot carry inf) while every finite
    neighbor keeps the pinned bound against the block's *finite* max."""
    x = np.float32([[np.inf, 1.0, 2.0], [np.nan, 0.5, -np.inf]])
    rt = wire.roundtrip_np(x, "int8", 1)
    nonfinite = ~np.isfinite(x)
    assert np.isnan(rt[nonfinite]).all(), rt
    bound = wire.REL_ERROR_BOUND["int8"]
    finite_amax = np.max(np.where(nonfinite, 0.0, np.abs(x)), axis=1, keepdims=True)
    err = np.abs(rt - x)[~nonfinite]
    assert (err <= bound * np.broadcast_to(finite_amax, x.shape)[~nonfinite] * (1 + 1e-6)).all()
    # an all-non-finite block is all nan, not an error
    assert np.isnan(wire.roundtrip_np(np.float32([[np.nan, np.inf]]), "int8", 1)).all()


def test_device_encode_decode_matches_oracle_on_nonfinite():
    """The executor's jnp encode/decode pair is bit-identical to the numpy
    oracle for payloads containing inf/nan (the lockstep the 8-device
    parity test relies on, checked here without devices)."""
    import jax.numpy as jnp

    from repro.comm import strategies as S

    x = np.float32(
        [[np.inf, 1.0, -2.0], [np.nan, 0.5, -np.inf], [1.0, 2.0, 3.0], [0.0, 0.0, 0.0]]
    )
    for codec in LOSSY:
        payload, aux = S._encode_blocks(jnp.asarray(x), codec)
        dec = np.asarray(S._decode_blocks(payload, aux, jnp.float32))
        np.testing.assert_array_equal(dec, wire.roundtrip_np(x, codec, block_ndim=1))


def test_int8_zero_blocks_stay_zero():
    """All-PAD / all-zero wire blocks must decode to exact zeros (the
    executor's PAD handling relies on it)."""
    z = np.zeros((3, 9), np.float32)
    np.testing.assert_array_equal(wire.roundtrip_np(z, "int8", 1), z)


def test_unknown_codec_rejected():
    with pytest.raises(ValueError):
        wire.check_codec("zstd")
    with pytest.raises(ValueError):
        execute_numpy(plan("standard", _pattern()[1]), np.zeros((8, 6), np.float32), wire="zstd")


def test_spmv_unknown_strategy_with_auto_wire_raises_value_error():
    """A fixed-but-unknown strategy plus wire="auto" must fail with the
    naming ValueError, not a bare StopIteration from the ranking lookup."""
    from repro.sparse.matrices import thermal_like
    from repro.sparse.partition import partition_csr
    from repro.sparse.spmv import DistributedSpMV

    topo = PodTopology(npods=2, ppn=4)
    part = partition_csr(thermal_like(64, np.random.default_rng(0)), topo)
    with pytest.raises(ValueError, match="unknown strategy"):
        DistributedSpMV(part, strategy="two_step_1", wire="auto")


# ---------------------------------------------------------------------------
# Numpy executor: none is bitwise, lossy codecs are bounded, on-pod exact
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 300),
    strategy=st.sampled_from(STRATEGIES),
    fused=st.sampled_from([False, True]),
)
@settings(max_examples=30, deadline=None)
def test_codec_none_is_bitwise_identical(seed, strategy, fused):
    topo, pat = _pattern(seed)
    sp = plan(strategy, pat, message_cap_bytes=48)
    if fused:
        sp = fuse(sp)
    local = np.random.default_rng(seed).normal(size=(topo.nranks, 6)).astype(np.float32)
    base = execute_numpy(sp, local)
    np.testing.assert_array_equal(execute_numpy(sp, local, wire="none"), base)


@given(
    seed=st.integers(0, 300),
    strategy=st.sampled_from(STRATEGIES),
    codec=st.sampled_from(LOSSY),
)
@settings(max_examples=30, deadline=None)
def test_codec_bounded_error_and_onpod_exact(seed, strategy, codec):
    """Lossy codecs: inter-pod halo slots within the pinned bound, on-pod
    slots (deliverable without crossing DCI) bit-exact."""
    topo, pat = _pattern(seed)
    sp = fuse(plan(strategy, pat, message_cap_bytes=48))
    rng = np.random.default_rng(seed)
    local = rng.normal(size=(topo.nranks, 6)).astype(np.float32)
    ref = pat.reference(local)
    H = pat.max_recv_size()
    out = execute_numpy(sp, local, wire=codec)[:, :H]
    bound = wire.REL_ERROR_BOUND[codec]
    scale = np.abs(local).max()  # every wire block's amax is <= this
    assert (np.abs(out - ref[:, :H]) <= bound * scale * (1 + 1e-6)).all()
    # slots whose source is on the destination's own pod never cross DCI
    dec = split_phase(pat)
    onpod = dec.from_local[:, :H] & dec.valid[:, :H]
    np.testing.assert_array_equal(out[onpod], ref[:, :H][onpod])


@given(seed=st.integers(0, 200), codec=st.sampled_from(LOSSY))
@settings(max_examples=20, deadline=None)
def test_batched_payload_rides_the_codec(seed, codec):
    """[nranks, L, k] payloads go through the same wire blocks; each column
    stays within the same bound."""
    topo, pat = _pattern(seed, npods=2, ppn=2, local_size=5)
    sp = fuse(plan("two_step", pat))
    rng = np.random.default_rng(seed)
    loc3 = rng.normal(size=(topo.nranks, 5, 3)).astype(np.float32)
    ref = pat.reference(loc3)
    H = pat.max_recv_size()
    out = execute_numpy(sp, loc3, wire=codec)[:, :H]
    bound = wire.REL_ERROR_BOUND[codec] * np.abs(loc3).max()
    assert (np.abs(out - ref[:, :H]) <= bound * (1 + 1e-6)).all()


def test_empty_pattern_and_zero_inter_pod_traffic():
    """Edge cases: a pattern with no needs at all, and one whose needs are
    all on-pod (zero inter-pod traffic) -- every codec must be a no-op."""
    topo = PodTopology(npods=2, ppn=2)
    empty = ExchangePattern(topo=topo, local_size=4, needs=())
    onpod = ExchangePattern(
        topo=topo,
        local_size=4,
        needs=(Need(0, 1, (0, 2)), Need(3, 2, (1,))),
    )
    local = np.random.default_rng(0).normal(size=(topo.nranks, 4)).astype(np.float32)
    for pat in (empty, onpod):
        for strategy in STRATEGIES:
            sp = fuse(plan(strategy, pat, message_cap_bytes=16))
            base = execute_numpy(sp, local)
            for codec in wire.WIRE_CODECS:
                np.testing.assert_array_equal(
                    execute_numpy(sp, local, wire=codec), base
                )
                intra, inter = wire.scaled_wire_bytes(sp, codec)
                if pat is onpod:
                    assert intra == sp.wire_intra_pod_bytes


# ---------------------------------------------------------------------------
# Byte accounting
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 200), strategy=st.sampled_from(STRATEGIES))
@settings(max_examples=25, deadline=None)
def test_scaled_wire_bytes_properties(seed, strategy):
    topo, pat = _pattern(seed)
    sp = plan(strategy, pat, message_cap_bytes=48)
    # "none" reproduces the planner's accounting verbatim
    assert wire.scaled_wire_bytes(sp, "none") == (
        sp.wire_intra_pod_bytes,
        sp.wire_inter_pod_bytes,
    )
    for codec in LOSSY:
        intra, inter = wire.scaled_wire_bytes(sp, codec)
        # intra-pod hops are never touched by a wire codec
        assert intra == sp.wire_intra_pod_bytes
        assert inter <= sp.wire_inter_pod_bytes
        if sp.wire_inter_pod_bytes:
            # the acceptance target: >= 1.8x reduction for the 16-bit wires,
            # more for int8 (scale side information costs a little back)
            assert sp.wire_inter_pod_bytes / inter >= 1.8, (codec, strategy)
    # fusion must not change the accounting (wire cost is monotone)
    fused = fuse(sp)
    for codec in wire.WIRE_CODECS:
        assert wire.scaled_wire_bytes(fused, codec) == wire.scaled_wire_bytes(sp, codec)


def test_wire_itemsize_and_ratio():
    assert wire.wire_itemsize("none", 4) == 4
    assert wire.wire_itemsize("bf16", 4) == 2
    assert wire.wire_itemsize("int8", 4) == 1
    # never wider than the payload
    assert wire.wire_itemsize("bf16", 2) == 2
    assert wire.wire_itemsize("f16", 1) == 1
    assert wire.compression_ratio("int8") == 0.25


# ---------------------------------------------------------------------------
# Device executor (8-device subprocess): parity with the numpy oracle
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_device_codec_none_bitwise_and_lossy_bounded(subproc):
    """All 4 strategies x barrier/overlap: codec "none" delivers bits equal
    to the codec-free executor; lossy codecs match the numpy oracle exactly
    and the reference within the pinned bound; wire_bytes report >= 1.8x
    inter-pod reduction for bf16."""
    subproc(
        """
import numpy as np
from repro.comm import wire
from repro.comm.exchange import execute_numpy, random_pattern
from repro.comm.strategies import IrregularExchange, STRATEGY_NAMES
from repro.comm.topology import PodTopology

rng = np.random.default_rng(11)
topo = PodTopology(npods=2, ppn=4)
pat = random_pattern(rng, topo, local_size=7, p_connect=0.6, max_elems=5)
local = rng.normal(size=(topo.nranks, 7)).astype(np.float32)
ref = pat.reference(local)
H = pat.max_recv_size()
for strat in STRATEGY_NAMES:
    ex0 = IrregularExchange(pat, strat, message_cap_bytes=32)
    base = np.asarray(ex0(local))
    exn = IrregularExchange(pat, strat, message_cap_bytes=32, wire="none")
    # barrier: none is bitwise the codec-free program
    np.testing.assert_array_equal(np.asarray(exn(local)), base)
    # overlap (split-phase): none merges bit-identically too
    h = exn.start(local)
    np.testing.assert_array_equal(np.asarray(h.finish()), base)
    for codec in ("bf16", "f16", "int8"):
        exw = IrregularExchange(pat, strat, message_cap_bytes=32, wire=codec)
        out = np.asarray(exw(local))
        # device executor == numpy oracle, bit for bit, even when lossy
        np.testing.assert_array_equal(out, execute_numpy(exw.plan, local, wire=codec))
        bound = wire.REL_ERROR_BOUND[codec] * np.abs(local).max() * (1 + 1e-6)
        assert np.abs(out[:, :H] - ref[:, :H]).max() <= bound, (strat, codec)
        # split-phase with a codec stays within the same bound
        hw = exw.start(local)
        mer = np.asarray(hw.finish())
        assert np.abs(mer[:, :H] - ref[:, :H]).max() <= bound, (strat, codec)
        # on-pod phase of the split exchange is full precision
        np.testing.assert_array_equal(
            np.asarray(hw.local_halo), np.asarray(exn.start(local).local_halo)
        )
    i0, j0 = exn.wire_bytes
    ib, jb = IrregularExchange(pat, strat, message_cap_bytes=32, wire="bf16").wire_bytes
    assert ib == i0 and j0 / jb >= 1.8, (strat, (i0, j0), (ib, jb))
print("DEVICE WIRE OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_device_bf16_payload_rides_untouched(subproc):
    """A bfloat16 payload on a bf16 wire crosses DCI losslessly (the codec
    is the identity for already-narrow payloads) -- dtype preserved."""
    subproc(
        """
import numpy as np
import jax.numpy as jnp
from repro.comm.exchange import random_pattern
from repro.comm.strategies import IrregularExchange
from repro.comm.topology import PodTopology

rng = np.random.default_rng(5)
topo = PodTopology(npods=2, ppn=4)
pat = random_pattern(rng, topo, local_size=5, p_connect=0.6, max_elems=3)
local = jnp.asarray(rng.normal(size=(topo.nranks, 5)), jnp.bfloat16)
ex0 = IrregularExchange(pat, "two_step")
exw = IrregularExchange(pat, "two_step", wire="bf16")
out0 = np.asarray(ex0(local).astype(jnp.float32))
outw = exw(local)
assert outw.dtype == jnp.bfloat16, outw.dtype
np.testing.assert_array_equal(np.asarray(outw.astype(jnp.float32)), out0)
print("BF16 PAYLOAD OK")
""",
        devices=8,
    )
