"""Advisor regression pins: payload-width-aware strategy crossovers.

The table below locks in the advised (strategy, transport) for a grid of
(pattern, machine, payload width k) cases so the k-aware byte terms can't
silently drift.  The rows were chosen so that several patterns *flip* winner
as k grows -- the message-count-bound -> bandwidth-bound transition the
batched SpMM path exists to exploit.
"""

import pytest

from repro.core import (
    MODELED_PAIRS,
    ComputeProfile,
    Strategy,
    Transport,
    advise,
    advise_solver,
    advise_stats,
    figure43_pattern,
    get_machine,
    predict,
    predict_overlapped,
    predict_phases,
    predict_reduction,
    predict_setup,
    predict_solver,
)

#: (machine, (msg bytes, inter-node msgs, dest nodes), k) -> advised key.
#: Recorded from the models at pin time; a change here is a deliberate
#: model change, not noise -- update only with a perfmodel/advisor PR.
PINS = [
    # lassen: moderate messages -- 2-Step's per-proc-to-node messages win at
    # k=1; at k>=16 the on-node redistribute amortizes and 3-Step's single
    # deduped node-node message wins.
    ("lassen", (2048, 256, 16), 1, "two_step/device_aware"),
    ("lassen", (2048, 256, 16), 4, "two_step/device_aware"),
    ("lassen", (2048, 256, 16), 16, "three_step/device_aware"),
    ("lassen", (2048, 256, 16), 64, "three_step/device_aware"),
    # lassen: small messages, few nodes -- standard until the widened bytes
    # make node-aware dedup worthwhile.
    ("lassen", (512, 64, 4), 1, "standard/staged_host"),
    ("lassen", (512, 64, 4), 64, "two_step/device_aware"),
    ("lassen", (8192, 64, 16), 1, "standard/staged_host"),
    ("lassen", (8192, 64, 16), 4, "three_step/device_aware"),
    # tpu: rendezvous-size messages flip from standard to Split as k scales
    # bytes past the pod-egress knee.
    ("tpu_v5e_pod", (65536, 32, 4), 1, "standard/staged_host"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, "split_dd/staged_host"),
    ("tpu_v5e_pod", (2048, 32, 4), 1, "standard/staged_host"),
    ("tpu_v5e_pod", (2048, 32, 4), 64, "split_dd/staged_host"),
    # no-flip pins: tiny pattern stays latency-bound at every width
    ("tpu_v5e_pod", (256, 32, 4), 1, "standard/staged_host"),
    ("tpu_v5e_pod", (256, 32, 4), 64, "standard/staged_host"),
]


@pytest.mark.parametrize("machine,scenario,k,expected", PINS)
def test_advised_strategy_pinned(machine, scenario, k, expected):
    size, nmsgs, nodes = scenario
    pat = figure43_pattern(size, nmsgs, nodes)
    adv = advise(pat, machine=machine, payload_width=k)
    assert adv.best.key == expected, (
        f"advisor drift for {machine}/{scenario}/k={k}: "
        f"got {adv.best.key}, pinned {expected}"
    )


# ---------------------------------------------------------------------------
# Overlap-aware crossovers (split-phase pipeline, PR 3)
# ---------------------------------------------------------------------------

#: (machine, scenario, k, compute multiple of the base winner's comm time,
#:  interior fraction) -> advised key.  The intended physics: light compute
#: -> the comm-optimal strategy wins and overlapping it is free; heavy
#: interior compute -> Standard+overlap wins because its entire (large)
#: inter-node phase hides behind compute while node-aware strategies keep
#: paying their unhideable on-node phases; low interior fraction -> the
#: node-aware winner holds.
OVERLAP_PINS = [
    ("lassen", (2048, 256, 16), 1, 0.5, 0.9, "two_step/device_aware+overlap"),
    ("lassen", (2048, 256, 16), 1, 2.0, 0.9, "standard/staged_host+overlap"),
    ("lassen", (2048, 256, 16), 1, 2.0, 0.2, "two_step/device_aware+overlap"),
    ("lassen", (8192, 64, 16), 4, 0.5, 0.9, "three_step/device_aware+overlap"),
    ("lassen", (8192, 64, 16), 4, 2.0, 0.9, "standard/staged_host+overlap"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, 0.5, 0.9, "split_dd/staged_host+overlap"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, 2.0, 0.9, "standard/staged_host+overlap"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, 2.0, 0.2, "split_dd/staged_host+overlap"),
]


@pytest.mark.parametrize("machine,scenario,k,mult,frac,expected", OVERLAP_PINS)
def test_overlap_advised_strategy_pinned(machine, scenario, k, mult, frac, expected):
    pat = figure43_pattern(*scenario)
    base = advise(pat, machine=machine, payload_width=k)
    profile = ComputeProfile.from_fraction(base.best.predicted_time * mult, frac)
    adv = advise(pat, machine=machine, payload_width=k, compute=profile)
    assert adv.best.key == expected, (
        f"overlap advisor drift for {machine}/{scenario}/k={k}/"
        f"compute={mult}x/frac={frac}: got {adv.best.key}, pinned {expected}"
    )


def test_overlap_never_slower_than_barrier():
    """For every pair the overlapped variant is <= its barrier variant:
    ``local + max(inter, t_int) + t_bnd <= local + inter + t_int + t_bnd``."""
    pat = figure43_pattern(8192, 64, 16)
    profile = ComputeProfile.from_fraction(1e-3, 0.8)
    adv = advise(pat, machine="lassen", compute=profile)
    seen = 0
    for r in adv.ranked:
        if r.overlap:
            continue
        ov = adv.time_for(r.strategy, r.transport, overlap=True)
        assert ov <= r.predicted_time * (1 + 1e-12)
        seen += 1
    assert seen >= 6


def test_predict_phases_sums_to_predict():
    """The (local, inter) factoring must reproduce Table 6 exactly."""
    pairs = MODELED_PAIRS + [
        (Strategy.TWO_STEP_ONE, Transport.STAGED_HOST),
        (Strategy.TWO_STEP_ONE, Transport.DEVICE_AWARE),
    ]
    for machine in ("lassen", "tpu_v5e_pod"):
        m = get_machine(machine)
        for scenario in [(2048, 256, 16), (512, 64, 4), (65536, 32, 4)]:
            stats = figure43_pattern(*scenario).stats()
            for s, tr in pairs:
                ph = predict_phases(m, s, tr, stats)
                assert ph.total == pytest.approx(predict(m, s, tr, stats), rel=1e-12)


def test_predict_overlapped_saturates():
    """Once interior compute exceeds the inter-node phase, more interior
    compute raises T by exactly the excess (the comm is fully hidden)."""
    m = get_machine("lassen")
    stats = figure43_pattern(8192, 64, 16).stats()
    ph = predict_phases(m, Strategy.THREE_STEP, Transport.DEVICE_AWARE, stats)
    big = 10.0 * ph.inter
    t1 = predict_overlapped(m, Strategy.THREE_STEP, Transport.DEVICE_AWARE, stats, big, 0.0)
    t2 = predict_overlapped(m, Strategy.THREE_STEP, Transport.DEVICE_AWARE, stats, 2 * big, 0.0)
    assert t2 - t1 == pytest.approx(big, rel=1e-9)
    with pytest.raises(ValueError):
        predict_overlapped(m, Strategy.THREE_STEP, Transport.DEVICE_AWARE, stats, -1.0, 0.0)


def test_overlap_ranking_superset_and_flag():
    """With a compute profile every (strategy, transport) appears exactly
    twice -- overlap on and off -- and keys carry the +overlap suffix."""
    pat = figure43_pattern(2048, 32, 4)
    base = advise(pat, machine="tpu_v5e_pod")
    adv = advise(
        pat, machine="tpu_v5e_pod", compute=ComputeProfile.from_fraction(1e-4, 0.5)
    )
    assert len(adv.ranked) == 2 * len(base.ranked)
    overlapped = {r.key for r in adv.ranked if r.overlap}
    barrier = {r.key for r in adv.ranked if not r.overlap}
    assert {k + "+overlap" for k in barrier} == overlapped


def test_payload_width_flips_exist():
    """At least one pinned pattern must flip winner across k (the whole point
    of the payload-width terms); guards against a degenerate widened()."""
    flips = 0
    seen = {}
    for machine, scenario, k, expected in PINS:
        prev = seen.setdefault((machine, scenario), expected)
        if prev != expected:
            flips += 1
    assert flips >= 3


# ---------------------------------------------------------------------------
# Wire-codec crossovers (inter-pod compression, PR 5)
# ---------------------------------------------------------------------------

#: (machine, scenario, k, wire candidates) -> advised key.  The intended
#: physics: latency-bound tiny patterns keep ``none`` (the codec's launch
#: alpha cannot pay for bytes it barely shrinks); bandwidth-bound patterns
#: flip to a compressed wire, sometimes flipping the *strategy* with it
#: (compression substitutes for dedup: standard+wire overtakes node-aware
#: variants whose unhideable on-node phases compression cannot shrink);
#: Split keeps ``none`` longest because its inter phase is already spread
#: over every on-pod rank (``s_node/ppn``).  Recorded from the models at
#: pin time; a change here is a deliberate model change, not noise.
NB = ("none", "bf16")
WIRE_PINS = [
    ("lassen", (2048, 256, 16), 1, "auto", "two_step/device_aware+wire:int8"),
    ("lassen", (2048, 256, 16), 16, "auto", "two_step/device_aware+wire:int8"),
    ("lassen", (512, 64, 4), 1, "auto", "standard/staged_host+wire:int8"),
    ("lassen", (8192, 64, 16), 4, "auto", "standard/staged_host+wire:int8"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, "auto", "split_dd/staged_host"),
    ("tpu_v5e_pod", (2048, 32, 4), 64, "auto", "standard/staged_host+wire:int8"),
    ("tpu_v5e_pod", (256, 32, 4), 1, "auto", "standard/staged_host"),
    ("tpu_v5e_pod", (256, 32, 4), 64, "auto", "standard/staged_host+wire:int8"),
    # int8 excluded (accuracy budget): bf16 takes the same crossovers
    ("lassen", (2048, 256, 16), 1, NB, "two_step/device_aware+wire:bf16"),
    ("lassen", (2048, 256, 16), 16, NB, "three_step/device_aware+wire:bf16"),
    ("lassen", (8192, 64, 16), 4, NB, "standard/staged_host+wire:bf16"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, NB, "split_dd/staged_host"),
    ("tpu_v5e_pod", (256, 32, 4), 1, NB, "standard/staged_host"),
    ("tpu_v5e_pod", (256, 32, 4), 64, NB, "standard/staged_host+wire:bf16"),
]


@pytest.mark.parametrize("machine,scenario,k,wire,expected", WIRE_PINS)
def test_wire_advised_strategy_pinned(machine, scenario, k, wire, expected):
    pat = figure43_pattern(*scenario)
    adv = advise(pat, machine=machine, payload_width=k, wire=wire)
    assert adv.best.key == expected, (
        f"wire advisor drift for {machine}/{scenario}/k={k}/wire={wire}: "
        f"got {adv.best.key}, pinned {expected}"
    )


def test_wire_pins_flip_with_width_and_candidates():
    """The wire grid must contain both none-wins and codec-wins rows, and at
    least one scenario that flips as k grows -- the codec crossover the
    wire terms exist to model."""
    auto = [p for p in WIRE_PINS if p[3] == "auto"]
    assert any(p[4].endswith("+wire:int8") for p in auto)
    assert any("+wire" not in p[4] for p in auto)
    by_scen = {}
    flips = 0
    for machine, scenario, k, wire, expected in auto:
        prev = by_scen.setdefault((machine, scenario), expected)
        if prev != expected:
            flips += 1
    assert flips >= 1


def test_wire_default_ranking_unchanged():
    """Without a wire argument the ranking must not contain wire variants
    (the paper's full-precision ranking is the default)."""
    pat = figure43_pattern(2048, 256, 16)
    adv = advise(pat, machine="lassen")
    assert all(r.wire == "none" for r in adv.ranked)
    assert all("+wire" not in r.key for r in adv.ranked)


def test_wire_bad_arguments_raise_value_error():
    """Codec validation matches the executor side (ValueError, not
    KeyError): a typo'd ``wire=`` name fails the same way for the advisor,
    ``IrregularExchange`` and ``execute_numpy``; an explicit empty
    candidate set is rejected instead of producing an empty ranking whose
    ``best`` would IndexError."""
    from repro.core import get_wire

    pat = figure43_pattern(2048, 256, 16)
    for bad in ("zstd", ("bf16", "zstd")):
        with pytest.raises(ValueError, match="unknown wire codec"):
            advise(pat, machine="lassen", wire=bad)
    with pytest.raises(ValueError, match="unknown wire codec"):
        get_wire("zstd")
    with pytest.raises(ValueError, match="at least one codec"):
        advise(pat, machine="lassen", wire=())


def test_wire_variants_cover_every_pair():
    """wire="auto" ranks every (strategy, transport) x codec exactly once
    and the none-variant times equal the default ranking."""
    from repro.core import WIRE_MODELS

    pat = figure43_pattern(8192, 64, 16)
    base = advise(pat, machine="lassen")
    adv = advise(pat, machine="lassen", wire="auto")
    assert len(adv.ranked) == len(WIRE_MODELS) * len(base.ranked)
    for r in base.ranked:
        assert adv.time_for(r.strategy, r.transport) == pytest.approx(
            r.predicted_time
        )


def test_wire_never_shrinks_messages_only_bytes():
    """A wire codec must leave latency-bound terms alone: on a tiny
    64-byte-message pattern every codec variant is strictly slower than
    ``none`` (alpha terms untouched, codec launch overhead added)."""
    from repro.core import WIRE_MODELS, get_machine, predict

    m = get_machine("lassen")
    stats = figure43_pattern(64, 64, 8).stats()
    for s, tr in MODELED_PAIRS:
        base = predict(m, s, tr, stats)
        for codec in WIRE_MODELS:
            if codec == "none":
                assert predict(m, s, tr, stats, wire=codec) == base
            else:
                assert predict(m, s, tr, stats, wire=codec) > base, (s, tr, codec)


def test_wire_phases_sum_to_predict():
    """predict_phases(..., wire) must stay consistent with predict(..., wire)
    for every codec -- the Table 6 factoring invariant extended."""
    from repro.core import WIRE_MODELS

    for machine in ("lassen", "tpu_v5e_pod"):
        m = get_machine(machine)
        for scenario in [(2048, 256, 16), (65536, 32, 4)]:
            stats = figure43_pattern(*scenario).stats()
            for s, tr in MODELED_PAIRS:
                for codec in WIRE_MODELS:
                    ph = predict_phases(m, s, tr, stats, wire=codec)
                    assert ph.total == pytest.approx(
                        predict(m, s, tr, stats, wire=codec), rel=1e-12
                    )


def test_wire_overlap_codec_compute_is_unhideable():
    """In the overlapped pipeline the codec's encode+decode term lands in
    T_local: with interior compute large enough to hide every inter phase,
    the wired variant is *slower* than none by exactly t_codec."""
    from repro.core import t_codec

    m = get_machine("lassen")
    stats = figure43_pattern(8192, 64, 16).stats()
    big = 1.0  # hides any inter phase
    for s, tr in MODELED_PAIRS:
        t_none = predict_overlapped(m, s, tr, stats, big, 0.0)
        t_bf16 = predict_overlapped(m, s, tr, stats, big, 0.0, wire="bf16")
        assert t_bf16 - t_none == pytest.approx(
            t_codec("bf16", stats.s_node), rel=1e-9
        )


# ---------------------------------------------------------------------------
# Iteration-amortized (solver) crossovers -- PR 4
# ---------------------------------------------------------------------------

#: (machine, scenario, k, iters) -> advised key for a whole solve.  The
#: intended physics: node-aware communicator construction is several
#: metadata rounds, standard setup is nearly free, so at iters=1 the
#: standard strategy wins patterns it loses per-call and the node-aware
#: winner takes over once its setup amortizes.  Recorded from the models at
#: pin time; a change here is a deliberate model change, not noise.
SOLVER_PINS = [
    # lassen, the paper's flagship pattern: per-call winner is 2-Step, but a
    # 1-iteration "solve" cannot amortize its communicator construction.
    ("lassen", (2048, 256, 16), 1, 1, "standard/staged_host"),
    ("lassen", (2048, 256, 16), 1, 5, "two_step/device_aware"),
    ("lassen", (2048, 256, 16), 1, 500, "two_step/device_aware"),
    # wide payloads: the k-aware per-call winner (3-Step) needs a few
    # iterations before its setup beats 2-Step's.
    ("lassen", (2048, 256, 16), 16, 1, "two_step/device_aware"),
    ("lassen", (2048, 256, 16), 16, 10, "three_step/device_aware"),
    ("lassen", (2048, 256, 16), 16, 1000, "three_step/device_aware"),
    # latency-bound small pattern: standard wins at every horizon
    ("lassen", (512, 64, 4), 1, 1, "standard/staged_host"),
    ("lassen", (512, 64, 4), 1, 1000, "standard/staged_host"),
    # tpu, rendezvous-size widened payload: Split's Algorithm-1 setup is the
    # most expensive of all, so its per-call win needs ~50 iterations.
    ("tpu_v5e_pod", (65536, 32, 4), 4, 10, "standard/staged_host"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, 50, "split_dd/staged_host"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, 1000, "split_dd/staged_host"),
    ("tpu_v5e_pod", (256, 32, 4), 1, 1000, "standard/staged_host"),
]


@pytest.mark.parametrize("machine,scenario,k,iters,expected", SOLVER_PINS)
def test_solver_advised_strategy_pinned(machine, scenario, k, iters, expected):
    pat = figure43_pattern(*scenario)
    adv = advise_solver(pat, iters, machine=machine, payload_width=k)
    assert adv.best.key == expected, (
        f"solver advisor drift for {machine}/{scenario}/k={k}/iters={iters}: "
        f"got {adv.best.key}, pinned {expected}"
    )


#: overlap-aware amortized pins: (machine, scenario, compute multiple of the
#: per-call winner's comm time, interior fraction, iters) -> key.
SOLVER_OVERLAP_PINS = [
    ("lassen", (2048, 256, 16), 0.5, 0.9, 2, "standard/staged_host+overlap"),
    ("lassen", (2048, 256, 16), 0.5, 0.9, 50, "two_step/device_aware+overlap"),
    ("lassen", (2048, 256, 16), 2.0, 0.9, 50, "standard/staged_host+overlap"),
]


@pytest.mark.parametrize("machine,scenario,mult,frac,iters,expected", SOLVER_OVERLAP_PINS)
def test_solver_overlap_advised_pinned(machine, scenario, mult, frac, iters, expected):
    pat = figure43_pattern(*scenario)
    base = advise(pat, machine=machine)
    profile = ComputeProfile.from_fraction(base.best.predicted_time * mult, frac)
    adv = advise_solver(pat, iters, machine=machine, compute=profile)
    assert adv.best.key == expected, (
        f"solver overlap drift for {machine}/{scenario}/compute={mult}x/"
        f"frac={frac}/iters={iters}: got {adv.best.key}, pinned {expected}"
    )


# ---------------------------------------------------------------------------
# Fused-front-end crossovers (whole-solve lax.while_loop, PR 9)
# ---------------------------------------------------------------------------

#: (machine, scenario, k, iters, reductions/iter, matvecs/iter) -> advised
#: key with fused="auto".  The intended physics: the fused whole-solve
#: program trades t_trace up front for zero per-iteration host dispatches,
#: so short solves keep the host-driven loop and long solves flip to
#: ``+fused`` around iters ~ t_trace / (launches_per_iter * t_launch)
#: (~125 for CG's 4 dispatches/iter, earlier for BiCGStab's 10).  Recorded
#: from the models at pin time; a change here is a deliberate model change,
#: not noise.
FUSED_PINS = [
    # CG accounting (1 matvec, 2 reductions): host loop wins until the trace
    # cost amortizes at a few hundred iterations.
    ("lassen", (2048, 256, 16), 1, 5, 2.0, 1.0, "two_step/device_aware"),
    ("lassen", (2048, 256, 16), 1, 100, 2.0, 1.0, "two_step/device_aware"),
    ("lassen", (2048, 256, 16), 1, 400, 2.0, 1.0, "two_step/device_aware+fused"),
    ("lassen", (2048, 256, 16), 1, 500, 2.0, 1.0, "two_step/device_aware+fused"),
    # BiCGStab accounting (2 matvecs, 6 reductions): 10 dispatches/iter pull
    # the crossover earlier.
    ("lassen", (2048, 256, 16), 1, 50, 6.0, 2.0, "two_step/device_aware"),
    ("lassen", (2048, 256, 16), 1, 100, 6.0, 2.0, "two_step/device_aware+fused"),
    # tpu, widened rendezvous payload: the strategy flip (standard -> Split)
    # and the front-end flip (host -> fused) happen at different horizons.
    ("tpu_v5e_pod", (65536, 32, 4), 4, 10, 2.0, 1.0, "standard/staged_host"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, 100, 2.0, 1.0, "split_dd/staged_host"),
    ("tpu_v5e_pod", (65536, 32, 4), 4, 500, 2.0, 1.0, "split_dd/staged_host+fused"),
]


@pytest.mark.parametrize("machine,scenario,k,iters,red,mvs,expected", FUSED_PINS)
def test_fused_advised_strategy_pinned(machine, scenario, k, iters, red, mvs, expected):
    pat = figure43_pattern(*scenario)
    adv = advise_solver(
        pat, iters, machine=machine, payload_width=k, fused="auto",
        reductions_per_iter=red, matvecs_per_iter=mvs,
    )
    assert adv.best.key == expected, (
        f"fused advisor drift for {machine}/{scenario}/k={k}/iters={iters}: "
        f"got {adv.best.key}, pinned {expected}"
    )


def test_fused_pins_flip_with_iters():
    """Each fused-pin scenario must flip to +fused as iters grows -- the
    T_launch amortization the LaunchModel exists to capture."""
    seen = {}
    flips = 0
    for machine, scenario, k, iters, red, mvs, expected in FUSED_PINS:
        prev = seen.setdefault((machine, scenario, k, red), expected)
        if prev != expected:
            flips += 1
    assert flips >= 3
    assert any(p[6].endswith("+fused") for p in FUSED_PINS)
    assert any(not p[6].endswith("+fused") for p in FUSED_PINS)


def test_fused_none_keeps_legacy_ranking():
    """advise_solver(fused=None) (the default) must stay byte-identical to
    the pre-LaunchModel behavior: no +fused keys, fused flags all False,
    and totals exactly matching predict_solver without launch terms."""
    pat = figure43_pattern(2048, 256, 16)
    adv = advise_solver(pat, 100, machine="lassen")
    assert all(not r.fused for r in adv.ranked)
    assert all("+fused" not in r.key for r in adv.ranked)
    m = get_machine("lassen")
    stats = pat.stats()
    ref = predict_solver(m, Strategy.TWO_STEP, Transport.DEVICE_AWARE, stats, 100)
    assert adv.time_for(Strategy.TWO_STEP, Transport.DEVICE_AWARE) == pytest.approx(
        ref[2], rel=1e-12
    )


def test_fused_auto_ranks_both_front_ends():
    """fused="auto" doubles the ranking: every (strategy, transport) pair
    appears as host and +fused, the fused variant paying more setup and
    strictly less per-iteration time."""
    pat = figure43_pattern(2048, 256, 16)
    base = advise_solver(pat, 100, machine="lassen")
    adv = advise_solver(pat, 100, machine="lassen", fused="auto")
    assert len(adv.ranked) == 2 * len(base.ranked)
    host = {(r.strategy, r.transport): r for r in adv.ranked if not r.fused}
    fused = {(r.strategy, r.transport): r for r in adv.ranked if r.fused}
    assert set(host) == set(fused)
    for pair, h in host.items():
        f = fused[pair]
        assert f.setup_time > h.setup_time
        assert f.iter_time < h.iter_time
        assert f.key == h.key + "+fused"


def test_launch_model_terms():
    """predict_solver's launch accounting: fused=False adds exactly
    t_launch * launches_per_iter to per_iter; fused=True adds exactly
    t_trace + t_launch to setup; fused=None adds nothing."""
    from repro.core import LaunchModel, launches_per_iter

    m = get_machine("lassen")
    stats = figure43_pattern(2048, 256, 16).stats()
    lm = LaunchModel(t_launch=1e-4, t_trace=1e-2)
    args = (m, Strategy.TWO_STEP, Transport.DEVICE_AWARE, stats)
    s0, p0, t0 = predict_solver(*args, iters=50)
    sh, ph, th = predict_solver(*args, iters=50, fused=False, launch=lm)
    sf, pf, tf = predict_solver(*args, iters=50, fused=True, launch=lm)
    n = launches_per_iter(1.0, 2.0, False)
    assert n == 4.0
    assert launches_per_iter(1.0, 2.0, True) == 7.0
    assert launches_per_iter(2.0, 6.0, False) == 10.0
    assert sh == s0 and ph == pytest.approx(p0 + lm.t_launch * n, rel=1e-12)
    assert pf == p0 and sf == pytest.approx(s0 + lm.t_trace + lm.t_launch, rel=1e-12)
    assert th == pytest.approx(sh + 50 * ph, rel=1e-12)
    assert tf == pytest.approx(sf + 50 * pf, rel=1e-12)
    with pytest.raises(ValueError, match="fused="):
        advise_solver(figure43_pattern(512, 64, 4), 10, fused="yes")


def test_solver_pins_flip_with_iters():
    """At least one pinned scenario must flip winner as iters grows -- the
    amortization effect advise_solver exists to model."""
    flips = 0
    seen = {}
    for machine, scenario, k, iters, expected in SOLVER_PINS:
        prev = seen.setdefault((machine, scenario, k), expected)
        if prev != expected:
            flips += 1
    assert flips >= 3


def test_setup_cost_orders_standard_cheapest():
    """Standard communication needs no communicator construction; every
    node-aware strategy pays more setup on the same pattern."""
    for machine in ("lassen", "tpu_v5e_pod"):
        m = get_machine(machine)
        for scenario in [(2048, 256, 16), (512, 64, 4), (65536, 32, 4)]:
            stats = figure43_pattern(*scenario).stats()
            std = min(
                predict_setup(m, Strategy.STANDARD, tr, stats)
                for tr in (Transport.STAGED_HOST, Transport.DEVICE_AWARE)
            )
            for s, tr in MODELED_PAIRS:
                if s is Strategy.STANDARD:
                    continue
                assert predict_setup(m, s, tr, stats) > std, (machine, scenario, s, tr)


def test_solver_total_is_setup_plus_iters():
    m = get_machine("lassen")
    stats = figure43_pattern(2048, 256, 16).stats()
    setup, per_iter, total = predict_solver(
        m, Strategy.TWO_STEP, Transport.DEVICE_AWARE, stats, iters=37,
        reductions_per_iter=6.0,
    )
    assert total == pytest.approx(setup + 37 * per_iter, rel=1e-12)
    # reductions are strategy-independent but must be part of per_iter
    red = predict_reduction(m, stats)
    assert red > 0
    base = predict(m, Strategy.TWO_STEP, Transport.DEVICE_AWARE, stats)
    assert per_iter == pytest.approx(base + 6.0 * red, rel=1e-12)
    with pytest.raises(ValueError):
        predict_solver(m, Strategy.TWO_STEP, Transport.DEVICE_AWARE, stats, iters=0)
    with pytest.raises(ValueError):
        advise_solver(figure43_pattern(2048, 256, 16), iters=0, machine="lassen")


def test_solver_amortized_limit_matches_per_call_advice():
    """As iters -> inf the setup term vanishes: the amortized winner must be
    the per-call winner (reductions shift every variant equally)."""
    for machine, scenario in [
        ("lassen", (2048, 256, 16)),
        ("lassen", (512, 64, 4)),
        ("tpu_v5e_pod", (65536, 32, 4)),
    ]:
        pat = figure43_pattern(*scenario)
        per_call = advise(pat, machine=machine).best
        amortized = advise_solver(pat, 10**7, machine=machine).best
        assert (amortized.strategy, amortized.transport) == (
            per_call.strategy,
            per_call.transport,
        ), (machine, scenario)


def test_solver_overlap_variants_rank_together():
    """With a compute profile every modeled pair appears twice (barrier and
    +overlap), and the overlapped total is never worse."""
    pat = figure43_pattern(8192, 64, 16)
    profile = ComputeProfile.from_fraction(1e-4, 0.8)
    adv = advise_solver(pat, 100, machine="lassen", compute=profile)
    barrier = {r.key: r.total_time for r in adv.ranked if not r.overlap}
    overlapped = {r.key: r.total_time for r in adv.ranked if r.overlap}
    assert {k + "+overlap" for k in barrier} == set(overlapped)
    for k, t in barrier.items():
        assert overlapped[k + "+overlap"] <= t * (1 + 1e-12)


# ---------------------------------------------------------------------------
# widened() invariants
# ---------------------------------------------------------------------------


def _stats():
    return figure43_pattern(1024, 64, 8).stats()


def test_widened_scales_bytes_not_messages():
    s = _stats()
    w = s.widened(8)
    assert (w.s_proc, w.s_node, w.s_node_node) == (
        8 * s.s_proc,
        8 * s.s_node,
        8 * s.s_node_node,
    )
    assert (w.m_proc, w.m_proc_node, w.m_node_node, w.num_dest_nodes) == (
        s.m_proc,
        s.m_proc_node,
        s.m_node_node,
        s.num_dest_nodes,
    )


def test_widened_identity_and_validation():
    s = _stats()
    assert s.widened(1) is s
    with pytest.raises(ValueError):
        s.widened(0)


def test_pattern_stats_widened_composes():
    pat = figure43_pattern(1024, 64, 8)
    assert pat.stats().widened(4) == pat.stats().widened(2).widened(2)


def test_advise_stats_payload_width_equals_prewidened():
    s = _stats()
    a = advise_stats(s, machine="lassen", payload_width=16)
    b = advise_stats(s.widened(16), machine="lassen")
    assert [r.key for r in a.ranked] == [r.key for r in b.ranked]
    for ra, rb in zip(a.ranked, b.ranked):
        assert ra.predicted_time == pytest.approx(rb.predicted_time)


def test_predictions_monotone_in_payload_width():
    """Wider payloads can only cost more time for every modeled pair."""
    s = _stats()
    base = advise_stats(s, machine="lassen", include_two_step_one=True)
    wide = advise_stats(
        s, machine="lassen", include_two_step_one=True, payload_width=32
    )
    for r in base.ranked:
        assert wide.time_for(r.strategy, r.transport) >= r.predicted_time * 0.999
