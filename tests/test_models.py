"""Per-arch smoke tests (reduced configs) + decode consistency.

Every assigned architecture instantiates a REDUCED config of the same family
and runs one forward/train step on CPU asserting output shapes and no NaNs;
the full configs are exercised only by the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import tree_flatten_with_path
from repro.configs import ARCH_IDS, get_config
from repro.models import LMModel

RNG = np.random.default_rng(0)


def shrink(cfg, dtype="float32"):
    kw = dict(
        n_layers=2, d_model=64, d_ff=128 if cfg.d_ff else 0, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=16, vocab_size=256,
        cross_context=8 if cfg.cross_context else 0, dtype=dtype,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
        )
    if cfg.mla:
        kw["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=16, rope_head_dim=8, nope_head_dim=16, v_head_dim=16
        )
        kw["head_dim"] = 24
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8, head_dim=8, chunk=8)
    if cfg.encoder:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=2, context=8)
    if cfg.window:
        kw["window"] = 8
    if cfg.cross_attn_every:
        kw["cross_attn_every"] = 2
    return dataclasses.replace(cfg, **kw)


def make_batch(model, cfg, B=2, S=16):
    tokens = jnp.asarray(RNG.integers(0, 256, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if model.ctx_len():
        batch["ctx"] = jnp.asarray(
            RNG.normal(size=(B, model.ctx_len(), cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = shrink(get_config(arch))
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(model, cfg)
    logits = model.apply(params, batch["tokens"], batch.get("ctx"))
    assert logits.shape == (2, 16, model.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    for path, g in tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), f"NaN grad at {jax.tree_util.keystr(path)}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_full_forward(arch):
    cfg = shrink(get_config(arch))
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S, EXTRA = 2, 12, 3
    toks = jnp.asarray(RNG.integers(0, 256, (B, S + EXTRA)), jnp.int32)
    ctx = (
        jnp.asarray(RNG.normal(size=(B, model.ctx_len(), cfg.d_model)), jnp.float32)
        if model.ctx_len()
        else None
    )
    full = model.apply(params, toks, ctx)
    last, cache = model.prefill(params, toks[:, :S], ctx)
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full[:, S - 1]), rtol=5e-3, atol=5e-3
    )
    # grow linear caches to S+EXTRA
    grown = model.init_cache(B, S + EXTRA, jnp.float32)

    def blend(dst, src):
        if dst.shape != src.shape:
            return dst.at[tuple(slice(0, s) for s in src.shape)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    cache = jax.tree.map(blend, grown, cache)
    for t in range(EXTRA):
        logits, cache = model.decode_step(params, toks[:, S + t : S + t + 1], cache, jnp.int32(S + t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, S + t]), rtol=5e-2, atol=5e-2
        )


def test_chunked_attention_equals_dot():
    cfg = shrink(get_config("qwen3-32b"))
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(2))
    toks = jnp.asarray(RNG.integers(0, 256, (2, 32)), jnp.int32)
    a = model.apply(params, toks, impl="dot")
    b = model.apply(params, toks, impl="chunked")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


def test_head_padding_rules():
    from repro.models.transformer import pad_heads

    assert pad_heads(56, 8, 16) == (64, 8)  # deepseek-coder on 16-way TP
    assert pad_heads(25, 5, 16) == (32, 8)  # hymba
    assert pad_heads(20, 20, 16) == (32, 32)  # whisper (MHA)
    assert pad_heads(40, 8, 16) == (48, 8)  # llama4
    assert pad_heads(64, 8, 1) == (64, 8)  # no-op at tp=1
