"""Tier-1 guard for the example scripts (mirrors test_benchmarks_smoke).

Examples are not imported by the library, so without this test they rot
silently.  Every file in ``examples/`` is executed in a subprocess with
smoke-sized arguments; a new example file is picked up automatically (and
runs with no arguments unless registered in ``ARGS``).
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
EXAMPLES = os.path.join(REPO, "examples")

#: smoke-sized arguments per example (keep each file under ~1 minute)
ARGS = {
    "chaos_serving.py": [],
    "krylov_solve.py": ["--fused"],
    "quickstart.py": [],
    "strategy_advisor.py": ["--messages", "32", "--nodes", "4", "--payload-width", "8"],
    "serve_lm.py": ["--arch", "deepseek-v2-lite-16b", "--batch", "1",
                    "--prompt-len", "8", "--gen", "3", "--advise-dispatch"],
    "train_lm.py": ["--steps", "2", "--ckpt", "/tmp/repro_examples_smoke_ckpt"],
}

#: a line that must appear in stdout when the example succeeded
EXPECT = {
    "chaos_serving.py": "chaos serving",
    "krylov_solve.py": "fused whole-solve",
    "quickstart.py": "split",  # strategy table printed after execution
    "strategy_advisor.py": "best strategy",
    "serve_lm.py": "dispatch advice",
    "train_lm.py": "loss:",
}

EXAMPLE_FILES = sorted(
    f for f in os.listdir(EXAMPLES) if f.endswith(".py") and not f.startswith("_")
)


def test_every_example_is_covered():
    """New examples must at least run; known ones must have smoke args."""
    assert EXAMPLE_FILES, "examples/ directory is empty?"
    assert set(ARGS) <= set(EXAMPLE_FILES), "ARGS lists a deleted example"


@pytest.mark.slow
@pytest.mark.parametrize("fname", EXAMPLE_FILES)
def test_example_runs(fname):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)  # examples manage their own device counts
    proc = subprocess.run(
        [sys.executable, os.path.join(EXAMPLES, fname)] + ARGS.get(fname, []),
        capture_output=True,
        text=True,
        timeout=600,
        cwd=REPO,
        env=env,
    )
    assert proc.returncode == 0, (
        f"{fname} failed (rc={proc.returncode})\n--- stdout ---\n"
        f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
    )
    marker = EXPECT.get(fname)
    if marker:
        assert marker in proc.stdout, (
            f"{fname}: expected {marker!r} in output\n{proc.stdout[-2000:]}"
        )
