"""Fused whole-solve programs (repro.solve.fused): host-loop parity pins.

The fused front-end compiles an entire CG/BiCGStab solve -- exchange stages,
masked-tile SpMV, hierarchical dot products, convergence control flow -- into
ONE jitted ``lax.while_loop``.  These tests pin its contract against the
host-driven loop oracle (:mod:`repro.solve.krylov`):

* identical iterations / status / matvec counts, residual histories within a
  per-backend float32-vs-float64 scalar tolerance;
* fused histories **bitwise identical** across all four strategies and the
  barrier/overlap executors (the whole point of deterministic lowering);
* exactly one plan miss and one fused-program compile per solve class;
* wire-codec variants track the host loop at matched tolerance;
* chaos: ``verify=True`` integrity errors surface from inside the compiled
  loop with the same structured fields as the host executor raises;
* the same early-return / breakdown / restart exits, routed through
  ``_finish_status`` exactly like the host solvers.
"""

import numpy as np
import pytest

from repro.comm.topology import PodTopology
from repro.solve import build_numpy, fused_bicgstab, fused_cg, spd_system
from repro.sparse import thermal_like

TOPO = PodTopology(npods=2, ppn=4)


def _system(n=256, seed=5):
    rng = np.random.default_rng(seed)
    A = spd_system(thermal_like(n, rng))
    op = build_numpy(A, TOPO, strategy="two_step")
    b = rng.standard_normal((TOPO.nranks, op.rows_per_rank)).astype(np.float32)
    return op, b


def test_fused_zero_rhs_early_return():
    """The fused solvers mirror the host zero-rhs exit (satellite of the
    ``_finish_status`` routing fix): trivially converged, no device dispatch,
    no matvecs, clean status."""
    op, _ = _system()
    z = np.zeros((TOPO.nranks, op.rows_per_rank), dtype=np.float32)
    for solver in (fused_cg, fused_bicgstab):
        r = solver(op, z)
        assert r.converged and r.iterations == 0 and r.matvecs == 0
        assert r.residuals == (0.0,)
        assert r.status == "converged" and r.restarts == 0


def test_fused_shape_validation():
    op, _ = _system()
    with pytest.raises(ValueError, match="b must be"):
        fused_cg(op, np.zeros((TOPO.nranks, op.rows_per_rank + 1)))


@pytest.mark.slow
def test_fused_matches_host_and_is_bitwise_across_strategies(subproc):
    """The acceptance core: fused CG reproduces the host loop's iterations /
    status / matvecs exactly (history within f32-scalar tolerance), its
    residual histories are BITWISE identical across all 4 strategies x
    barrier/overlap, and each solve class costs exactly one plan miss and
    one fused-program compile."""
    subproc(
        """
import numpy as np
from repro.comm import PodTopology, cache_stats, clear_caches
from repro.sparse import thermal_like, build
from repro.solve import DeviceReductions, bicgstab, cg, fused_bicgstab, fused_cg, shifted_system, spd_system

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
n = 256
b = rng.standard_normal((topo.nranks, n // topo.nranks)).astype(np.float32)
A = spd_system(thermal_like(n, rng))

# --- cache accounting: one plan miss + one fused compile per solve class ---
clear_caches()
op = build(A, topo, strategy="two_step")
red = DeviceReductions(topo, mesh=op.mesh)
f = fused_cg(op, b, tol=1e-6, maxiter=200)
s = cache_stats()
assert s.plan_misses == 1, s
assert s.fused_misses == 1 and s.fused_hits == 0, s
# a second identical solve reuses the compiled program, no new misses
f2 = fused_cg(op, b, tol=1e-6, maxiter=200)
s = cache_stats()
assert s.fused_misses == 1 and s.fused_hits == 1, s
assert s.plan_misses == 1, s
assert f2.residuals == f.residuals

# --- host-loop parity (DeviceReductions host oracle, f32 dots) ---
h = cg(op, b, tol=1e-6, maxiter=200, reductions=red)
assert f.iterations == h.iterations, (f.iterations, h.iterations)
assert f.status == h.status == "converged"
assert f.matvecs == h.matvecs
dr = max(abs(a - c) / max(abs(c), 1e-30) for a, c in zip(f.residuals, h.residuals))
assert dr < 1e-5, dr  # f32 while-loop scalars vs f64 host scalars

# BiCGStab parity on the nonsymmetric workload
B = shifted_system(thermal_like(n, rng))
opb = build(B, topo, strategy="two_step")
hb = bicgstab(opb, b, tol=1e-6, maxiter=200,
              reductions=DeviceReductions(topo, mesh=opb.mesh))
fb = fused_bicgstab(opb, b, tol=1e-6, maxiter=200)
assert fb.iterations == hb.iterations and fb.status == hb.status
assert fb.matvecs == hb.matvecs
drb = max(abs(a - c) / max(abs(c), 1e-30) for a, c in zip(fb.residuals, hb.residuals))
assert drb < 1e-2, drb  # 6 f32 scalar recurrences/iter drift faster than CG's 2

# --- bitwise identical across every strategy and both executors ---
ref = None
for strat in ("standard", "two_step", "three_step", "split"):
    for ov in (False, True):
        r = fused_cg(build(A, topo, strategy=strat, overlap=ov), b,
                     tol=1e-6, maxiter=200)
        if ref is None:
            ref = r
        assert r.residuals == ref.residuals, (strat, ov)
        assert (r.iterations, r.status) == (ref.iterations, ref.status)
print("FUSED PARITY OK", ref.iterations, "iters")
""",
        devices=8,
    )


@pytest.mark.slow
def test_fused_codec_parity_per_dtype_tolerance(subproc):
    """Wire-codec fused solves track the host loop running the SAME codec:
    fixed-horizon comparison (tol below reach, so both run exactly maxiter
    iterations) with a per-codec tolerance matched to the wire's precision."""
    subproc(
        """
import numpy as np
from repro.comm import PodTopology
from repro.sparse import thermal_like, build
from repro.solve import DeviceReductions, cg, fused_cg, spd_system

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
n = 256
b = rng.standard_normal((topo.nranks, n // topo.nranks)).astype(np.float32)
A = spd_system(thermal_like(n, rng))
TOL = {"none": 1e-5, "bf16": 5e-2, "f16": 5e-2, "int8": 2e-1}
for codec, tol in TOL.items():
    op = build(A, topo, strategy="two_step", wire=codec)
    red = DeviceReductions(topo, mesh=op.mesh)
    h = cg(op, b, tol=1e-12, maxiter=12, reductions=red)
    f = fused_cg(op, b, tol=1e-12, maxiter=12)
    assert h.iterations == f.iterations == 12, (codec, h.iterations, f.iterations)
    assert h.status == f.status == "maxiter", (codec, h.status, f.status)
    dr = max(abs(a - c) / max(abs(c), 1e-30) for a, c in zip(f.residuals, h.residuals))
    assert dr < tol, (codec, dr)
    print("CODEC OK", codec, f"{dr:.2e}")
""",
        devices=8,
    )


@pytest.mark.slow
def test_fused_integrity_error_surfaces_from_loop(subproc):
    """Chaos: with ``verify=True`` and a persistent inter-pod perturbation,
    the fused loop's carried violation accumulator must surface the SAME
    structured ``ExchangeIntegrityError`` fields the host executor raises
    (strategy / codec / stage_kind / op_index / round_index / hop_class)."""
    subproc(
        """
import numpy as np
from repro.comm import ExchangeIntegrityError, FaultPlan, FaultSpec, PodTopology
from repro.sparse import thermal_like, partition_csr
from repro.solve import NumpySpMV, cg, fused_cg, spd_system

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
A = spd_system(thermal_like(256, rng))
part = partition_csr(A, topo)
b = rng.standard_normal((topo.nranks, part.rows_per_rank)).astype(np.float32)
fp = FaultPlan(seed=5, specs=(FaultSpec(kind="perturb", prob=1.0, frac=1.0),))

def provoke(solver):
    op = NumpySpMV(part, strategy="two_step", verify=True, faults=fp,
                   max_retries=0, fallback=False)
    try:
        solver(op, b, tol=1e-6, maxiter=10)
    except ExchangeIntegrityError as e:
        return e
    raise SystemExit(f"{solver.__name__} did not raise")

host_err = provoke(cg)
fused_err = provoke(fused_cg)
for field in ("strategy", "codec", "stage_kind", "op_index", "round_index",
              "hop_class"):
    hv, fv = getattr(host_err, field), getattr(fused_err, field)
    assert hv == fv, (field, hv, fv)
assert fused_err.violation > 0
print("CHAOS OK", fused_err.stage_kind, fused_err.hop_class)
""",
        devices=8,
    )


@pytest.mark.slow
def test_fused_exit_paths_match_host(subproc):
    """Breakdown / restart / warm-start parity: CG on an indefinite matrix
    breaks down at the same iteration with the same status; CG on a
    nonsymmetric system stagnates, restarts once from the best iterate and
    reports the same suffixed status; a warm start from the solution exits
    after the single true-residual matvec."""
    subproc(
        """
import numpy as np
from repro.comm import PodTopology
from repro.sparse import thermal_like, partition_csr
from repro.solve import (NumpySpMV, bicgstab, cg, fused_bicgstab, fused_cg,
                         shifted_system, spd_system)

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)

# stagnation + restart: CG on a nonsymmetric (diagonally dominant) matrix
A = shifted_system(thermal_like(256, rng))
part = partition_csr(A, topo)
b = rng.standard_normal((topo.nranks, part.rows_per_rank)).astype(np.float32)
op = NumpySpMV(part, strategy="standard")
h = cg(op, b, tol=1e-10, maxiter=400)
f = fused_cg(op, b, tol=1e-10, maxiter=400)
assert h.status == f.status == "stagnation+restart", (h.status, f.status)
assert (f.iterations, f.restarts, f.matvecs) == (h.iterations, h.restarts, h.matvecs)
assert len(f.residuals) == len(h.residuals) == f.iterations + 2

# indefinite breakdown: flip half the diagonal of an SPD system
S = spd_system(thermal_like(256, rng))
rows = np.repeat(np.arange(S.n), np.diff(S.indptr))
S.data[np.flatnonzero((rows == S.indices) & (rows % 2 == 0))] *= -1.0
parti = partition_csr(S, topo)
bi = rng.standard_normal((topo.nranks, parti.rows_per_rank)).astype(np.float32)
hi = cg(NumpySpMV(parti), bi, tol=1e-8, maxiter=50)
fi = fused_cg(NumpySpMV(parti), bi, tol=1e-8, maxiter=50)
assert fi.status == hi.status == "breakdown:indefinite"
assert (fi.iterations, fi.matvecs) == (hi.iterations, hi.matvecs)
assert np.isfinite(fi.x).all()

# warm start from the exact solution: iterations==0, one matvec
G = spd_system(thermal_like(256, rng))
partg = partition_csr(G, topo)
opg = NumpySpMV(partg, strategy="two_step")
bg = rng.standard_normal((topo.nranks, partg.rows_per_rank)).astype(np.float32)
for hs, fs in ((cg, fused_cg), (bicgstab, fused_bicgstab)):
    exact = hs(opg, bg, tol=1e-6, maxiter=200)
    warm = fs(opg, bg, x0=exact.x, tol=1e-6, maxiter=200)
    assert warm.converged and warm.iterations == 0 and warm.matvecs == 1, warm
print("EXIT PATHS OK", h.iterations, "stall iters")
""",
        devices=8,
    )
