"""Optimizer, data pipeline, checkpoint, watchdog unit tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import SyntheticTokens
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm, warmup_cosine
from repro.runtime.watchdog import StragglerWatchdog


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


@given(step=st.integers(0, 10_000))
def test_schedule_bounds(step):
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=100, total_steps=10_000)
    lr = float(warmup_cosine(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.peak_lr * (1 + 1e-6)


def test_weight_decay_pulls_to_zero():
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=1, total_steps=100, weight_decay=1.0)
    params = {"w": jnp.asarray([5.0])}
    state = adamw_init(params)
    for _ in range(100):
        params, state, _ = adamw_update(cfg, params, {"w": jnp.zeros(1)}, state)
    assert float(jnp.abs(params["w"]).max()) < 1.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_step_addressable():
    d1 = SyntheticTokens(vocab_size=1000, batch=4, seq_len=32, seed=3)
    d2 = SyntheticTokens(vocab_size=1000, batch=4, seq_len=32, seed=3)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(18)["tokens"], b1["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 1000).all()
    # labels are next-token shifted from the same stream
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 3)), "b": jnp.zeros((3,))},
        "nested": {"deep": {"x": jnp.arange(5, dtype=jnp.int32)}},
    }


def test_checkpoint_roundtrip_bitwise():
    with tempfile.TemporaryDirectory() as d:
        state = _state()
        save_checkpoint(d, 7, state, extra={"note": "hi"})
        template = jax.tree.map(lambda x: jnp.zeros_like(x), state)
        restored, manifest = load_checkpoint(d, template)
        assert manifest["step"] == 7 and manifest["extra"]["note"] == "hi"
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save_async(s, _state(s))
        mgr.wait()
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert steps == ["step_00000003", "step_00000004"]
        assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            load_checkpoint(d, {"w": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# straggler watchdog
# ---------------------------------------------------------------------------


def test_watchdog_detects_consecutive_stragglers(monkeypatch):
    times = iter([0.0, 1.0,  # step 0: 1s  (prime EMA)
                  2.0, 3.0,  # step 1: 1s
                  4.0, 9.0,  # step 2: 5s straggler
                  10.0, 15.0,  # step 3: 5s straggler
                  16.0, 21.0])  # step 4: 5s straggler -> escalate
    import repro.runtime.watchdog as W

    monkeypatch.setattr(W.time, "monotonic", lambda: next(times))
    wd = StragglerWatchdog(factor=3.0, budget=3)
    outcomes = []
    for step in range(5):
        wd.start_step()
        outcomes.append(wd.end_step(step))
    assert outcomes == [False, False, False, False, True]
    assert len(wd.events) == 3


def test_watchdog_end_step_without_start_raises():
    wd = StragglerWatchdog()
    with pytest.raises(RuntimeError, match="start_step"):
        wd.end_step(0)
    # a normal step still works afterwards, and consumes its timestamp:
    # a second end_step for the same step is the same clear error, not a
    # TypeError on the None timestamp
    wd.start_step()
    assert wd.end_step(0) is False
    with pytest.raises(RuntimeError, match="start_step"):
        wd.end_step(0)


def test_watchdog_record_external_shares_budget(monkeypatch):
    wd = StragglerWatchdog(budget=3)
    assert wd.record_external("exchange_integrity", {"codec": "bf16"}) is False
    assert wd.record_external("exchange_integrity") is False
    assert wd.record_external("exchange_integrity") is True  # budget hit
    assert len(wd.events) == 3
    assert wd.events[0] == {"kind": "exchange_integrity", "codec": "bf16"}
    # a healthy timed step resets the consecutive count
    times = iter([0.0, 1.0, 2.0, 3.0])
    import repro.runtime.watchdog as W

    monkeypatch.setattr(W.time, "monotonic", lambda: next(times))
    wd.start_step()
    wd.end_step(0)  # primes the EMA
    wd.start_step()
    wd.end_step(1)
    assert wd.consecutive == 0
