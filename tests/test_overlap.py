"""Split-phase (overlap) equivalence: the two-phase exchange and the
overlapped SpMV/SpMM pipeline must be bitwise-compatible with the barrier
path for every strategy -- on the numpy executor in-process, and through
real shard_map collectives in an 8-device subprocess.
"""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.comm.exchange import (
    execute_numpy,
    merge_split_phase,
    plan,
    plan_local,
    random_pattern,
    split_phase,
)
from repro.comm.topology import PodTopology
from repro.core.split_plan import split_rows

ALL_STRATEGIES = ("standard", "two_step", "three_step", "split")


# ---------------------------------------------------------------------------
# Numpy executor: split-phase == barrier, bit for bit, every strategy
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 400),
    npods=st.sampled_from([1, 2, 3]),
    ppn=st.sampled_from([1, 2, 4]),
    strategy=st.sampled_from(list(ALL_STRATEGIES)),
    k=st.sampled_from([0, 2, 4]),
)
@settings(max_examples=40, deadline=None)
def test_split_phase_equals_barrier_numpy(seed, npods, ppn, strategy, k):
    """merge(local phase, remote phase) must equal the unsplit program's
    output exactly, for scalar and batched payloads."""
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=npods, ppn=ppn)
    pat = random_pattern(rng, topo, local_size=5, p_connect=0.5, max_elems=4)
    sp = split_phase(pat)
    lp = plan("local", sp.local)
    rp = plan(strategy, sp.remote, message_cap_bytes=48)
    full = plan(strategy, pat, message_cap_bytes=48)
    shape = (topo.nranks, 5) if k == 0 else (topo.nranks, 5, k)
    local = rng.normal(size=shape).astype(np.float32)
    merged = merge_split_phase(
        sp, execute_numpy(lp, local), execute_numpy(rp, local)
    )
    np.testing.assert_array_equal(merged, execute_numpy(full, local))
    H = pat.max_recv_size()
    np.testing.assert_array_equal(merged[:, :H], pat.reference(local))


@given(seed=st.integers(0, 200), npods=st.sampled_from([2, 3]))
@settings(max_examples=20, deadline=None)
def test_split_phase_partition_is_exact(seed, npods):
    """The local/remote sub-patterns partition the needs, and every merge
    slot routes to exactly one phase."""
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=npods, ppn=3)
    pat = random_pattern(rng, topo, local_size=4, p_connect=0.6, max_elems=3)
    sp = split_phase(pat)
    assert len(sp.local.needs) + len(sp.remote.needs) == len(pat.needs)
    for n in sp.local.needs:
        assert topo.pod_of(n.src) == topo.pod_of(n.dst)
    for n in sp.remote.needs:
        assert topo.pod_of(n.src) != topo.pod_of(n.dst)
    # per-rank: local slots + remote slots == canonical length
    for r in range(topo.nranks):
        n_valid = int(sp.valid[r].sum())
        assert n_valid == len(pat.canonical_tokens(r))
        assert int(sp.from_local[r].sum()) == len(sp.local.canonical_tokens(r))
        assert n_valid - int(sp.from_local[r].sum()) == len(
            sp.remote.canonical_tokens(r)
        )


def test_plan_local_rejects_inter_pod_needs():
    rng = np.random.default_rng(0)
    topo = PodTopology(npods=2, ppn=2)
    # force at least one inter-pod need
    for _ in range(20):
        pat = random_pattern(rng, topo, local_size=4, p_connect=0.9)
        if any(topo.pod_of(n.src) != topo.pod_of(n.dst) for n in pat.needs):
            break
    with pytest.raises(ValueError, match="pod-local"):
        plan_local(pat)


def test_local_phase_moves_no_inter_pod_bytes():
    """The on-node phase must never touch the inter-pod fabric."""
    rng = np.random.default_rng(3)
    topo = PodTopology(npods=3, ppn=4)
    for _ in range(5):
        pat = random_pattern(rng, topo, local_size=6, p_connect=0.6)
        sp = split_phase(pat)
        lp = plan("local", sp.local)
        assert lp.inter_pod_bytes == 0
        assert lp.wire_inter_pod_bytes == 0


# ---------------------------------------------------------------------------
# Interior/boundary row split
# ---------------------------------------------------------------------------


def test_split_rows_tile_granularity():
    dep = np.zeros((2, 10), dtype=bool)
    dep[0, 3] = True  # one boundary row poisons its whole tile
    s = split_rows(dep, tile_rows=4)
    assert s.interior_tiles.shape == (2, 3)  # ceil(10/4)
    np.testing.assert_array_equal(s.interior_tiles[0], [False, True, True])
    np.testing.assert_array_equal(s.interior_tiles[1], [True, True, True])
    np.testing.assert_array_equal(s.interior, ~dep)
    assert s.interior_fraction == pytest.approx(19 / 20)
    assert s.interior_tile_fraction == pytest.approx(5 / 6)
    assert s.interior_tile_fraction <= s.interior_fraction


def test_split_rows_edge_cases():
    # all-boundary and all-interior
    s = split_rows(np.ones((1, 8), dtype=bool), tile_rows=8)
    assert s.interior_fraction == 0.0 and s.interior_tile_fraction == 0.0
    s = split_rows(np.zeros((1, 8), dtype=bool), tile_rows=256)
    assert s.interior_fraction == 1.0 and s.interior_tile_fraction == 1.0
    # padding rows count as interior, boundary property is the complement
    s = split_rows(np.array([[True, False, False]]), tile_rows=2)
    np.testing.assert_array_equal(s.interior_tiles, [[False, True]])
    np.testing.assert_array_equal(s.boundary_tiles, [[True, False]])
    with pytest.raises(ValueError):
        split_rows(np.zeros((3,), dtype=bool), tile_rows=2)
    with pytest.raises(ValueError):
        split_rows(np.zeros((1, 3), dtype=bool), tile_rows=0)


# ---------------------------------------------------------------------------
# 8-device subprocess: real collectives, every strategy, exchange + SpMV
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_split_phase_exchange_on_devices(subproc):
    subproc(
        """
import numpy as np
from repro.comm.topology import PodTopology
from repro.comm.exchange import random_pattern
from repro.comm.strategies import IrregularExchange, STRATEGY_NAMES

rng = np.random.default_rng(11)
topo = PodTopology(npods=2, ppn=4)
for trial in range(2):
    pat = random_pattern(rng, topo, local_size=6, p_connect=0.6, max_elems=4)
    local = rng.normal(size=(topo.nranks, 6)).astype(np.float32)
    loc3 = rng.normal(size=(topo.nranks, 6, 3)).astype(np.float32)
    for strat in STRATEGY_NAMES:
        ex = IrregularExchange(pat, strat, message_cap_bytes=32)
        barrier = np.asarray(ex(local))
        h = ex.start(local)
        np.testing.assert_array_equal(np.asarray(h.finish()), barrier)
        # the fast phase only carries on-pod tokens; spot-check its values
        # against the local sub-pattern's reference
        from repro.comm.exchange import split_phase
        sp = split_phase(pat)
        np.testing.assert_array_equal(
            np.asarray(h.local_halo)[:, : sp.local.max_recv_size()],
            sp.local.reference(local),
        )
        # batched payload through the same handle
        h3 = ex.start(loc3)
        np.testing.assert_array_equal(np.asarray(h3.finish()), np.asarray(ex(loc3)))
print("OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_overlapped_spmv_on_devices(subproc):
    subproc(
        """
import numpy as np
from repro.comm.topology import PodTopology
from repro.sparse import build, thermal_like

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
A = thermal_like(256, rng)
v = rng.normal(size=(A.n,)).astype(np.float32)
vr = v.reshape(topo.nranks, -1)
V = rng.normal(size=(A.n, 3)).astype(np.float32)
Vr = V.reshape(topo.nranks, -1, 3)
for use_pallas in (True, False):
    for strat in ("standard", "two_step", "three_step", "split"):
        sp = build(A, topo, strategy=strat, use_pallas=use_pallas)
        ov = build(A, topo, strategy=strat, use_pallas=use_pallas, overlap=True)
        if use_pallas:
            # pallas kernels are opaque to XLA fusion, so the overlapped
            # diag-pass + off-pass composition is BITWISE equal to the
            # barrier program's fused diag+off (the serving-path guarantee)
            np.testing.assert_array_equal(np.asarray(ov(vr)), np.asarray(sp(vr)))
            np.testing.assert_array_equal(
                np.asarray(ov.matmat(Vr)), np.asarray(sp.matmat(Vr))
            )
        else:
            # the jnp-oracle barrier program fuses its two reductions under
            # one jit and XLA's codegen for that fused form differs from
            # the split two-program form by ~1 ulp; the halo itself is
            # bitwise equal (exchange tests above), so allow ulp-level slack
            np.testing.assert_allclose(
                np.asarray(ov(vr)), np.asarray(sp(vr)), rtol=1e-6, atol=1e-6
            )
            np.testing.assert_allclose(
                np.asarray(ov.matmat(Vr)), np.asarray(sp.matmat(Vr)),
                rtol=1e-6, atol=1e-6,
            )
        np.testing.assert_allclose(
            np.asarray(ov(vr)).reshape(-1), A.spmv(v), rtol=1e-4, atol=1e-4
        )
print("OK")
""",
        devices=8,
    )
