import os
import subprocess
import sys
import textwrap

import pytest

def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device subprocess tests, interpret-mode Pallas sweeps, "
        "and the heaviest property sweeps (solver-vs-dense, kernel oracles). "
        "Run by default -- the full suite is the verify tier; deselect with "
        "-m 'not slow' for a quick inner-loop pass",
    )


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_devices(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with N forced host devices.

    Multi-device tests must not set ``--xla_force_host_platform_device_count``
    in this process (smoke tests and benches should see 1 device), so they
    run in a child interpreter.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture
def subproc():
    return run_devices
