"""Setup-path performance regression guards.

These are deliberately generous budgets: they exist to catch an accidental
return to per-token Python loops (orders of magnitude), not scheduler
noise.
"""

import time

import numpy as np
import pytest

from repro.comm import strategies as comm_strategies
from repro.comm.exchange import plan, random_pattern
from repro.comm.fusion import fuse
from repro.comm.topology import PodTopology

#: generous wall-time budget for planning+fusing one strategy on the fixed
#: 16-rank pattern below (vectorized planner: ~5 ms; legacy: ~70 ms)
PLAN_BUDGET_S = 2.0


def _fixed_pattern():
    rng = np.random.default_rng(1234)
    topo = PodTopology(npods=4, ppn=4)  # 16 ranks
    return random_pattern(rng, topo, local_size=16, p_connect=0.5, max_elems=8)


def test_planning_within_time_budget():
    pat = _fixed_pattern()
    for strategy in ("standard", "two_step", "three_step", "split"):
        t0 = time.perf_counter()
        fuse(plan(strategy, pat, message_cap_bytes=512))
        elapsed = time.perf_counter() - t0
        assert elapsed < PLAN_BUDGET_S, (
            f"{strategy}: planning took {elapsed:.2f}s (budget {PLAN_BUDGET_S}s); "
            "did the planner fall back to per-token Python loops?"
        )


def test_plan_cache_hits_on_second_use():
    """The module plan cache must serve repeated plans of an equal pattern."""
    pat = _fixed_pattern()
    comm_strategies.clear_caches()
    sp1 = comm_strategies.planned(pat, "two_step", message_cap_bytes=512)
    stats = comm_strategies.cache_stats()
    assert stats.plan_misses == 1 and stats.plan_hits == 0
    sp2 = comm_strategies.planned(pat, "two_step", message_cap_bytes=512)
    stats = comm_strategies.cache_stats()
    assert stats.plan_hits == 1
    assert sp2 is sp1
    # different cap is a different exchange: no false sharing
    comm_strategies.planned(pat, "two_step", message_cap_bytes=256)
    stats = comm_strategies.cache_stats()
    assert stats.plan_misses == 2 and stats.plan_hits == 1
    comm_strategies.clear_caches()


def test_plan_cache_eviction_under_many_fingerprints(monkeypatch):
    """The plan LRU must cap at PLAN_CACHE_MAX, evict oldest-first, and keep
    hot entries resident."""
    rng = np.random.default_rng(7)
    topo = PodTopology(npods=2, ppn=2)
    pats = [
        random_pattern(rng, topo, local_size=4, p_connect=0.6, max_elems=2)
        for _ in range(5)
    ]
    assert len({p.fingerprint() for p in pats}) == 5
    comm_strategies.clear_caches()
    monkeypatch.setattr(comm_strategies, "PLAN_CACHE_MAX", 3)
    for p in pats:
        comm_strategies.planned(p, "two_step", message_cap_bytes=64)
    assert len(comm_strategies._PLAN_CACHE) == 3
    stats = comm_strategies.cache_stats()
    assert stats.plan_misses == 5 and stats.plan_hits == 0
    # newest three are resident...
    for p in pats[2:]:
        comm_strategies.planned(p, "two_step", message_cap_bytes=64)
    assert comm_strategies.cache_stats().plan_hits == 3
    # ...oldest two were evicted and re-plan as misses
    comm_strategies.planned(pats[0], "two_step", message_cap_bytes=64)
    stats = comm_strategies.cache_stats()
    assert stats.plan_misses == 6
    comm_strategies.clear_caches()


def test_compute_cache_eviction_under_many_fingerprints(monkeypatch):
    """The local-compute compile LRU evicts by fingerprint but never grows a
    second entry for a repeated (fingerprint, k)."""
    import jax
    from repro.sparse import spmv as spmv_mod

    mesh = jax.make_mesh((1, 1), ("pod", "local"))
    comm_strategies.clear_caches()
    monkeypatch.setattr(spmv_mod, "COMPUTE_CACHE_MAX", 4)
    for fp in ("fp0", "fp1", "fp2", "fp3", "fp4", "fp5"):
        spmv_mod._compute_program(fp, mesh, False, 4)
    assert len(spmv_mod._COMPUTE_CACHE) == 4
    stats = comm_strategies.cache_stats()
    assert stats.compute_misses == 6 and stats.compute_hits == 0
    # distinct k widths of a resident fingerprint are distinct entries ...
    spmv_mod._compute_program("fp5", mesh, False, 8)
    spmv_mod._compute_program("fp5", mesh, False, None)
    # ... repeats are hits, not rebuilds
    spmv_mod._compute_program("fp5", mesh, False, 4)
    spmv_mod._compute_program("fp5", mesh, False, 8)
    stats = comm_strategies.cache_stats()
    assert stats.compute_misses == 8 and stats.compute_hits == 2
    # evicted fingerprint re-misses
    spmv_mod._compute_program("fp0", mesh, False, 4)
    assert comm_strategies.cache_stats().compute_misses == 9
    comm_strategies.clear_caches()
    assert len(spmv_mod._COMPUTE_CACHE) == 0  # registered external cache


def test_split_cache_counts_and_clears(monkeypatch):
    """_SPLIT_CACHE must be visible to cache_stats (split_hits/split_misses),
    evict LRU-style at PLAN_CACHE_MAX, and reset under clear_caches."""
    rng = np.random.default_rng(21)
    topo = PodTopology(npods=2, ppn=2)
    pats = [
        random_pattern(rng, topo, local_size=4, p_connect=0.6, max_elems=2)
        for _ in range(4)
    ]
    assert len({p.fingerprint() for p in pats}) == 4
    comm_strategies.clear_caches()
    monkeypatch.setattr(comm_strategies, "PLAN_CACHE_MAX", 3)
    for p in pats:
        comm_strategies._split_phase_cached(p)
    stats = comm_strategies.cache_stats()
    assert stats.split_misses == 4 and stats.split_hits == 0
    assert len(comm_strategies._SPLIT_CACHE) == 3
    # resident fingerprints hit; the evicted oldest re-misses
    comm_strategies._split_phase_cached(pats[-1])
    assert comm_strategies.cache_stats().split_hits == 1
    comm_strategies._split_phase_cached(pats[0])
    stats = comm_strategies.cache_stats()
    assert stats.split_misses == 5 and stats.split_hits == 1
    # the split cache never bleeds into the plan counters
    assert stats.plan_misses == 0 and stats.plan_hits == 0
    comm_strategies.clear_caches()
    stats = comm_strategies.cache_stats()
    assert stats.split_misses == 0 and stats.split_hits == 0
    assert len(comm_strategies._SPLIT_CACHE) == 0


def _flat_prims(jaxpr, out):
    for e in jaxpr.eqns:
        out[e.primitive.name] = out.get(e.primitive.name, 0) + 1
        for v in e.params.values():
            if hasattr(v, "jaxpr"):
                _flat_prims(v.jaxpr, out)
    return out


@pytest.mark.parametrize("feat", [(), (3,)])
def test_execute_scratch_is_one_fused_pad(feat):
    """The executor's ``ext = [local | buf]`` scratch must be built with a
    single fused pad -- no zeros buffer materialized and concatenated per
    call.  Pinned on a collective-free (gather-only) program so the op
    census is exact: one ``pad``, zero ``concatenate``."""
    import jax

    from repro.comm.strategies import _execute

    topo = PodTopology(npods=2, ppn=2)
    L, w_max, out_size = 4, 6, 5
    ops = (("gather", 6), ("gather", 5))
    i1 = np.zeros((1, 6), np.int32)
    i2 = np.zeros((1, 5), np.int32)
    x = np.zeros((1, L) + feat, np.float32)
    jaxpr = jax.make_jaxpr(
        lambda l, a, b: _execute(ops, topo, L, w_max, out_size, l, (a, b))
    )(x, i1, i2)
    prims = _flat_prims(jaxpr.jaxpr, {})
    assert prims.get("pad", 0) == 1, prims
    assert prims.get("concatenate", 0) == 0, prims


@pytest.mark.slow
def test_batched_plan_cache_keying_on_devices(subproc):
    """Distinct payload widths k must NOT thrash the plan/compile caches:
    one plan + one executor per pattern fingerprint, one local-compute
    compile entry per (fingerprint, k)."""
    subproc(
        """
import numpy as np
from repro.comm import strategies as S
from repro.comm.topology import PodTopology
from repro.sparse import thermal_like, build

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
A = thermal_like(64, rng)
S.clear_caches()
sp = build(A, topo, strategy="two_step", use_pallas=False)
s = S.cache_stats()
assert s.plan_misses == 1 and s.exec_misses == 1, s
assert s.compute_misses == 1, s  # the width=None vector program
V = rng.normal(size=(A.n, 16)).astype(np.float32).reshape(topo.nranks, -1, 16)
for k in (1, 4, 16, 4, 1):
    sp.matmat(V[:, :, :k])
s = S.cache_stats()
# one compile entry per distinct k (1, 4, 16) + the vector program; repeat
# widths are served by the instance memo and never touch the module LRU
assert s.compute_misses == 4, s
assert s.compute_hits == 0, s
# the exchange kept exactly ONE plan/executor for the fingerprint: batched
# widths specialize inside the jitted executor, not the plan cache
assert s.plan_misses == 1 and s.exec_misses == 1, s
# full rebuild for the same matrix is all hits, no recompiles
sp2 = build(A, topo, strategy="two_step", use_pallas=False)
sp2.matmat(V)
s2 = S.cache_stats()
assert s2.plan_misses == 1 and s2.exec_misses == 1, s2
assert s2.compute_misses == 4 and s2.compute_hits == 2, s2
print("BATCHED CACHE OK", s2)
""",
        devices=8,
    )


@pytest.mark.slow
def test_exchange_compile_cache_hits_on_devices(subproc):
    """Second IrregularExchange construction reuses plan AND jitted executor."""
    subproc(
        """
import time
import numpy as np
from repro.comm import strategies as S
from repro.comm.exchange import random_pattern
from repro.comm.topology import PodTopology

rng = np.random.default_rng(1234)
topo = PodTopology(npods=4, ppn=4)
pat = random_pattern(rng, topo, local_size=16, p_connect=0.5, max_elems=8)
S.clear_caches()

t0 = time.perf_counter()
ex1 = S.IrregularExchange(pat, "two_step", message_cap_bytes=512)
cold = time.perf_counter() - t0
s1 = S.cache_stats()
assert s1.plan_misses == 1 and s1.exec_misses == 1, s1
assert s1.plan_hits == 0 and s1.exec_hits == 0, s1

t0 = time.perf_counter()
ex2 = S.IrregularExchange(pat, "two_step", message_cap_bytes=512)
warm = time.perf_counter() - t0
s2 = S.cache_stats()
assert s2.plan_hits >= 1, s2
assert s2.exec_hits >= 1, s2
assert ex2._fn is ex1._fn, "jitted executor was rebuilt"

local = rng.normal(size=(topo.nranks, 16)).astype(np.float32)
ref = pat.reference(local)
H = pat.max_recv_size()
np.testing.assert_array_equal(np.asarray(ex2(local))[:, :H], ref[:, :H])
print(f"CACHE OK cold={cold*1e3:.1f}ms warm={warm*1e3:.1f}ms")
""",
        devices=16,
    )


def test_plan_cache_pressure_under_skewed_stream():
    """A Zipf-skewed fingerprint stream past capacity (the serving regime:
    few hot tenants, long churning tail) must keep the hot classes resident.
    Pins a hit-rate floor and the eviction-counter consistency invariant
    ``evictions == misses - live_entries`` (capacity never shrank)."""
    from repro.testing import make_trace

    rng = np.random.default_rng(31)
    topo = PodTopology(npods=2, ppn=2)
    pats = {
        f"p{i}": random_pattern(rng, topo, local_size=4, p_connect=0.6, max_elems=2)
        for i in range(12)
    }
    assert len({p.fingerprint() for p in pats.values()}) == 12
    trace = make_trace(5, 300, sorted(pats), pattern="poisson", skew=1.4)
    comm_strategies.clear_caches()
    old = comm_strategies.PLAN_CACHE_MAX
    try:
        comm_strategies.set_cache_limits(plan=4)
        for req in trace:
            comm_strategies.planned(pats[req.fp], "two_step", message_cap_bytes=64)
        stats = comm_strategies.cache_stats()
        live = comm_strategies.cache_sizes()
        assert live["plan"] == 4  # pinned at capacity, not unbounded
        assert stats.plan_hits + stats.plan_misses == 300
        hit_rate = stats.plan_hits / 300
        assert hit_rate >= 0.5, f"hot classes not staying resident: {hit_rate:.2f}"
        assert stats.plan_evictions > 0  # the tail really churned
        assert stats.plan_evictions == stats.plan_misses - live["plan"]
    finally:
        comm_strategies.set_cache_limits(plan=old)
        comm_strategies.clear_caches()


def test_compute_cache_pressure_under_skewed_stream(monkeypatch):
    """Same pressure invariants for the registered-external compute LRU."""
    import jax

    from repro.sparse import spmv as spmv_mod
    from repro.testing import make_trace

    mesh = jax.make_mesh((1, 1), ("pod", "local"))
    comm_strategies.clear_caches()
    monkeypatch.setattr(spmv_mod, "COMPUTE_CACHE_MAX", 4)
    trace = make_trace(6, 200, [f"fp{i}" for i in range(10)], skew=1.5)
    for req in trace:
        spmv_mod._compute_program(req.fp, mesh, False, 4)
    stats = comm_strategies.cache_stats()
    assert len(spmv_mod._COMPUTE_CACHE) == 4
    assert stats.compute_hits + stats.compute_misses == 200
    assert stats.compute_hits / 200 >= 0.5
    assert stats.compute_evictions > 0
    assert stats.compute_evictions == stats.compute_misses - len(
        spmv_mod._COMPUTE_CACHE
    )
    comm_strategies.clear_caches()
    stats = comm_strategies.cache_stats()
    assert stats.compute_evictions == 0 and stats.plan_evictions == 0


def test_fused_cache_pressure_under_skewed_stream():
    """The fused whole-solve program cache under the same Zipf-skewed
    stream: PR 8's cache-pressure machinery must govern fused programs too
    -- hot solve classes stay resident, ``cache_sizes()`` reports the live
    count, ``set_cache_limits(fused=...)`` trims LRU-first immediately, and
    the eviction counters keep ``evictions == misses - live``."""
    from repro.testing import make_trace

    comm_strategies.clear_caches()
    old = comm_strategies.FUSED_CACHE_MAX
    try:
        comm_strategies.set_cache_limits(fused=4)
        trace = make_trace(7, 200, [f"fp{i}" for i in range(10)], skew=1.5)
        for req in trace:
            comm_strategies.fused_cached(("fused", "cg", req.fp), object)
        stats = comm_strategies.cache_stats()
        live = comm_strategies.cache_sizes()
        assert live["fused"] == 4  # pinned at capacity, not unbounded
        assert stats.fused_hits + stats.fused_misses == 200
        assert stats.fused_hits / 200 >= 0.5, "hot solves not staying resident"
        assert stats.fused_evictions > 0  # the tail really churned
        assert stats.fused_evictions == stats.fused_misses - live["fused"]
        # shrinking the cap mid-flight evicts LRU-first right away and the
        # counters record the trim without breaking the invariant
        caps = comm_strategies.set_cache_limits(fused=2)
        assert caps["fused"] == 2
        assert comm_strategies.cache_sizes()["fused"] == 2
        stats2 = comm_strategies.cache_stats()
        assert stats2.fused_evictions == stats.fused_evictions + 2
        assert stats2.fused_evictions == stats2.fused_misses - 2
        with pytest.raises(ValueError):
            comm_strategies.set_cache_limits(fused=0)
    finally:
        comm_strategies.set_cache_limits(fused=old)
        comm_strategies.clear_caches()
    stats = comm_strategies.cache_stats()
    assert stats.fused_evictions == 0 and stats.fused_misses == 0


def test_set_cache_limits_trims_immediately():
    """Shrinking a cap mid-flight evicts LRU-first right away (the serving
    memory-budget hook), and the eviction counters record the trim."""
    rng = np.random.default_rng(43)
    topo = PodTopology(npods=2, ppn=2)
    pats = [
        random_pattern(rng, topo, local_size=4, p_connect=0.6, max_elems=2)
        for _ in range(5)
    ]
    comm_strategies.clear_caches()
    old = comm_strategies.PLAN_CACHE_MAX
    try:
        for p in pats:
            comm_strategies.planned(p, "two_step", message_cap_bytes=64)
        assert comm_strategies.cache_sizes()["plan"] == 5
        caps = comm_strategies.set_cache_limits(plan=2)
        assert caps["plan"] == 2
        assert comm_strategies.cache_sizes()["plan"] == 2
        assert comm_strategies.cache_stats().plan_evictions == 3
        # the survivors are the most recently used (LRU-first trim)
        comm_strategies.planned(pats[-1], "two_step", message_cap_bytes=64)
        comm_strategies.planned(pats[-2], "two_step", message_cap_bytes=64)
        assert comm_strategies.cache_stats().plan_hits == 2
        with pytest.raises(ValueError):
            comm_strategies.set_cache_limits(plan=0)
    finally:
        comm_strategies.set_cache_limits(plan=old)
        comm_strategies.clear_caches()


@pytest.mark.slow
def test_exchange_cache_pressure_on_devices(subproc):
    """The exchange front-door LRU under the same skewed stream: hot
    fingerprints stay resident, counters stay consistent."""
    subproc(
        """
import numpy as np
from repro.comm import strategies as S
from repro.comm.exchange import random_pattern
from repro.comm.topology import PodTopology
from repro.testing import make_trace

rng = np.random.default_rng(2)
topo = PodTopology(npods=2, ppn=2)
pats = {
    f"p{i}": random_pattern(rng, topo, local_size=4, p_connect=0.6, max_elems=2)
    for i in range(8)
}
S.clear_caches()
S.set_cache_limits(exchange=3)
trace = make_trace(9, 80, sorted(pats), skew=1.5)
for req in trace:
    S.exchange_for(pats[req.fp], "two_step", message_cap_bytes=64)
s = S.cache_stats()
live = S.cache_sizes()
assert live["exchange"] == 3, live
assert s.exchange_hits + s.exchange_misses == 80, s
assert s.exchange_hits / 80 >= 0.5, s
assert s.exchange_evictions > 0, s
assert s.exchange_evictions == s.exchange_misses - live["exchange"], s
print("EXCHANGE PRESSURE OK", s.exchange_hits, s.exchange_misses, s.exchange_evictions)
""",
        devices=4,
    )
