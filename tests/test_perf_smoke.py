"""Setup-path performance regression guards.

These are deliberately generous budgets: they exist to catch an accidental
return to per-token Python loops (orders of magnitude), not scheduler
noise.
"""

import time

import numpy as np
import pytest

from repro.comm import strategies as comm_strategies
from repro.comm.exchange import plan, random_pattern
from repro.comm.fusion import fuse
from repro.comm.topology import PodTopology

#: generous wall-time budget for planning+fusing one strategy on the fixed
#: 16-rank pattern below (vectorized planner: ~5 ms; legacy: ~70 ms)
PLAN_BUDGET_S = 2.0


def _fixed_pattern():
    rng = np.random.default_rng(1234)
    topo = PodTopology(npods=4, ppn=4)  # 16 ranks
    return random_pattern(rng, topo, local_size=16, p_connect=0.5, max_elems=8)


def test_planning_within_time_budget():
    pat = _fixed_pattern()
    for strategy in ("standard", "two_step", "three_step", "split"):
        t0 = time.perf_counter()
        fuse(plan(strategy, pat, message_cap_bytes=512))
        elapsed = time.perf_counter() - t0
        assert elapsed < PLAN_BUDGET_S, (
            f"{strategy}: planning took {elapsed:.2f}s (budget {PLAN_BUDGET_S}s); "
            "did the planner fall back to per-token Python loops?"
        )


def test_plan_cache_hits_on_second_use():
    """The module plan cache must serve repeated plans of an equal pattern."""
    pat = _fixed_pattern()
    comm_strategies.clear_caches()
    sp1 = comm_strategies.planned(pat, "two_step", message_cap_bytes=512)
    stats = comm_strategies.cache_stats()
    assert stats.plan_misses == 1 and stats.plan_hits == 0
    sp2 = comm_strategies.planned(pat, "two_step", message_cap_bytes=512)
    stats = comm_strategies.cache_stats()
    assert stats.plan_hits == 1
    assert sp2 is sp1
    # different cap is a different exchange: no false sharing
    comm_strategies.planned(pat, "two_step", message_cap_bytes=256)
    stats = comm_strategies.cache_stats()
    assert stats.plan_misses == 2 and stats.plan_hits == 1
    comm_strategies.clear_caches()


@pytest.mark.slow
def test_exchange_compile_cache_hits_on_devices(subproc):
    """Second IrregularExchange construction reuses plan AND jitted executor."""
    subproc(
        """
import time
import numpy as np
from repro.comm import strategies as S
from repro.comm.exchange import random_pattern
from repro.comm.topology import PodTopology

rng = np.random.default_rng(1234)
topo = PodTopology(npods=4, ppn=4)
pat = random_pattern(rng, topo, local_size=16, p_connect=0.5, max_elems=8)
S.clear_caches()

t0 = time.perf_counter()
ex1 = S.IrregularExchange(pat, "two_step", message_cap_bytes=512)
cold = time.perf_counter() - t0
s1 = S.cache_stats()
assert s1.plan_misses == 1 and s1.exec_misses == 1, s1
assert s1.plan_hits == 0 and s1.exec_hits == 0, s1

t0 = time.perf_counter()
ex2 = S.IrregularExchange(pat, "two_step", message_cap_bytes=512)
warm = time.perf_counter() - t0
s2 = S.cache_stats()
assert s2.plan_hits >= 1, s2
assert s2.exec_hits >= 1, s2
assert ex2._fn is ex1._fn, "jitted executor was rebuilt"

local = rng.normal(size=(topo.nranks, 16)).astype(np.float32)
ref = pat.reference(local)
H = pat.max_recv_size()
np.testing.assert_array_equal(np.asarray(ex2(local))[:, :H], ref[:, :H])
print(f"CACHE OK cold={cold*1e3:.1f}ms warm={warm*1e3:.1f}ms")
""",
        devices=16,
    )
