"""Strategy execution tests: every node-aware strategy delivers the
reference exchange (8-device subprocess), plus in-process plan properties."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.comm.exchange import execute_numpy, plan, random_pattern, simulate
from repro.comm.fusion import fuse
from repro.comm.topology import PodTopology


# ---------------------------------------------------------------------------
# In-process: symbolic simulator proves token delivery for random patterns
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 500),
    npods=st.sampled_from([2, 3]),
    ppn=st.sampled_from([2, 4]),
    strategy=st.sampled_from(["standard", "two_step", "three_step", "split"]),
)
@settings(max_examples=40, deadline=None)
def test_all_strategies_deliver_canonical_layout(seed, npods, ppn, strategy):
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=npods, ppn=ppn)
    pat = random_pattern(rng, topo, local_size=6, p_connect=0.5, max_elems=4)
    # plan() runs the symbolic simulator and raises on any mis-delivery
    sp = plan(strategy, pat, message_cap_bytes=48)
    buf = simulate(sp)
    for r in range(topo.nranks):
        want = pat.canonical_tokens(r)
        assert buf[r][: len(want)] == want


@given(seed=st.integers(0, 200))
@settings(max_examples=25, deadline=None)
def test_node_aware_reduces_inter_pod_bytes(seed):
    """The paper's data-redundancy elimination: 2-Step/3-Step/Split move
    fewer inter-pod payload bytes than Standard whenever duplicates exist."""
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=2, ppn=4)
    pat = random_pattern(rng, topo, local_size=5, p_connect=0.7, max_elems=4)
    std = plan("standard", pat)
    for s in ("two_step", "three_step", "split"):
        nodeaware = plan(s, pat, message_cap_bytes=64)
        assert nodeaware.inter_pod_bytes <= std.inter_pod_bytes


@given(
    seed=st.integers(0, 300),
    strategy=st.sampled_from(["standard", "two_step", "three_step", "split"]),
    k=st.sampled_from([2, 3, 5]),
    fused=st.sampled_from([False, True]),
)
@settings(max_examples=30, deadline=None)
def test_batched_exchange_equals_stacked_columns(seed, strategy, k, fused):
    """A batched [nranks, L, k] payload through one plan must equal k stacked
    k=1 exchanges column-for-column (fused and unfused programs)."""
    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=2, ppn=2)
    pat = random_pattern(rng, topo, local_size=5, p_connect=0.5, max_elems=3)
    sp = plan(strategy, pat, message_cap_bytes=48)
    if fused:
        sp = fuse(sp)
    local = rng.normal(size=(topo.nranks, 5, k)).astype(np.float32)
    batched = execute_numpy(sp, local)
    for c in range(k):
        single = execute_numpy(sp, local[:, :, c])
        np.testing.assert_array_equal(batched[:, :, c], single)
    np.testing.assert_array_equal(batched[:, : pat.max_recv_size()], pat.reference(local))


def test_three_step_single_message_per_pod_pair():
    rng = np.random.default_rng(3)
    topo = PodTopology(npods=3, ppn=2)
    pat = random_pattern(rng, topo, local_size=4, p_connect=0.8, max_elems=3)
    sp = plan("three_step", pat)
    # inter-pod messages = PermuteWorld rounds: exactly one per ordered pod pair
    from repro.comm.exchange import PermuteWorld

    perms = [st_ for st_ in sp.stages if isinstance(st_, PermuteWorld)]
    assert len(perms) == 1
    n_msgs = sum(len(r) for r in perms[0].rounds)
    assert n_msgs == topo.npods * (topo.npods - 1)


# ---------------------------------------------------------------------------
# 8-device subprocess: numeric execution through shard_map collectives
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_strategies_numeric_on_devices(subproc):
    subproc(
        """
import numpy as np
from repro.comm.topology import PodTopology
from repro.comm.exchange import random_pattern
from repro.comm.strategies import IrregularExchange, STRATEGY_NAMES

rng = np.random.default_rng(7)
topo = PodTopology(npods=2, ppn=4)
for trial in range(2):
    pat = random_pattern(rng, topo, local_size=7, p_connect=0.6, max_elems=5)
    local = rng.normal(size=(topo.nranks, 7)).astype(np.float32)
    ref = pat.reference(local)
    H = pat.max_recv_size()
    for strat in STRATEGY_NAMES:
        ex = IrregularExchange(pat, strat, message_cap_bytes=32)
        out = np.asarray(ex(local))
        np.testing.assert_allclose(out[:, :H], ref[:, :H])
        # unfused program delivers the same bits through real collectives
        exu = IrregularExchange(pat, strat, message_cap_bytes=32, fuse_program=False)
        np.testing.assert_array_equal(np.asarray(exu(local)), out)
    # batched payload [nranks, L, k]: one plan, k columns, every strategy,
    # fused and unfused -- must equal k stacked k=1 calls column-for-column
    loc3 = rng.normal(size=(topo.nranks, 7, 3)).astype(np.float32)
    ref3 = pat.reference(loc3)
    for strat in STRATEGY_NAMES:
        for fused in (True, False):
            ex = IrregularExchange(pat, strat, message_cap_bytes=32,
                                   fuse_program=fused)
            got = np.asarray(ex(loc3))
            np.testing.assert_array_equal(got[:, :H], ref3[:, :H])
            for c in range(3):
                np.testing.assert_array_equal(
                    got[:, :, c], np.asarray(ex(loc3[:, :, c]))
                )
print("OK")
""",
        devices=8,
    )
