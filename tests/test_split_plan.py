"""Property tests for Algorithm 1 (Split setup)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.core import CommPattern, Message, build_split_plan


def random_pattern(rng, ppn, nnodes, max_msgs=30, max_bytes=5000):
    n = ppn * nnodes
    msgs = []
    for _ in range(rng.integers(1, max_msgs)):
        s, d = rng.integers(0, n, 2)
        if s != d:
            msgs.append(Message(int(s), int(d), int(rng.integers(1, max_bytes))))
    return CommPattern.from_messages(n, ppn, msgs)


@given(
    ppn=st.integers(1, 6),
    nnodes=st.integers(2, 5),
    cap=st.integers(1, 8192),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=80, deadline=None)
def test_algorithm1_invariants(ppn, nnodes, cap, seed):
    rng = np.random.default_rng(seed)
    pat = random_pattern(rng, ppn, nnodes)
    plan = build_split_plan(pat, message_cap=cap)

    inter = pat.inter_node_messages()
    total_inter = sum(m.nbytes for m in inter)

    # 1. byte conservation: every inter-node byte is carried by exactly one chunk
    assert plan.total_inter_node_bytes() == total_inter
    covered = {}
    for c in plan.chunks:
        for msg, off, length in c.parts:
            covered.setdefault(id(msg), 0)
            covered[id(msg)] += length
    for m in inter:
        assert covered.get(id(m), 0) == m.nbytes

    # 2. chunk sizes respect the effective cap (lines 12-17)
    for c in plan.chunks:
        eff = plan.effective_cap[c.dest_node]
        assert c.nbytes <= eff

    # 3. locality: sender on origin node, receiver on destination node
    for c in plan.chunks:
        assert pat.node_of(c.sender) == c.origin_node
        assert pat.node_of(c.receiver) == c.dest_node
        assert c.origin_node != c.dest_node

    # 4. line 18 balance: receive counts per node differ by at most 1
    from collections import Counter

    per_node = {}
    for c in plan.chunks:
        per_node.setdefault(c.dest_node, Counter())[c.receiver] += 1
    for node, counts in per_node.items():
        n_chunks = sum(counts.values())
        expected_max = -(-n_chunks // ppn)
        assert max(counts.values()) <= expected_max

    # 5. on-node messages are untouched (handled by local_comm)
    assert sum(m.nbytes for m in plan.local_messages) == sum(
        m.nbytes for m in pat.messages
    ) - total_inter


def test_conglomeration_when_below_cap():
    """Lines 12-13: if max node->node volume < cap, one chunk per origin."""
    pat = CommPattern.from_messages(
        8, 4, [(0, 4, 10), (1, 5, 20), (2, 6, 30)]
    )
    plan = build_split_plan(pat, message_cap=1000)
    assert len(plan.chunks) == 1  # all three messages fused: same origin/dest node
    assert plan.chunks[0].nbytes == 60


def test_cap_raised_when_exceeding_ppn_chunks():
    """Lines 14-17: cap rises to ceil(total/PPN) when too many chunks."""
    ppn = 2
    msgs = [(0, 2 + (i % 2), 100) for i in range(10)]  # 1000B node0 -> node1
    pat = CommPattern.from_messages(4, ppn, msgs)
    plan = build_split_plan(pat, message_cap=10)  # would need 100 chunks > ppn
    assert plan.effective_cap[1] == 500  # ceil(1000/2)
    assert len(plan.chunks) == 2


def test_invalid_cap_rejected():
    pat = CommPattern.from_messages(4, 2, [(0, 2, 10)])
    with pytest.raises(ValueError):
        build_split_plan(pat, message_cap=0)


# ---------------------------------------------------------------------------
# Cap-resolution edge cases (previously only hit through random patterns)
# ---------------------------------------------------------------------------


def test_cap_larger_than_total_volume():
    """Cap >> everything: lines 12-13 conglomerate to one chunk per origin
    node and the effective cap collapses to the largest origin volume."""
    pat = CommPattern.from_messages(
        12, 4,
        [(0, 4, 100), (1, 5, 50), (8, 6, 30), (9, 7, 20)],  # node0+node2 -> node1
    )
    plan = build_split_plan(pat, message_cap=10**9)
    assert len(plan.chunks) == 2  # one per origin node (0 and 2)
    assert {(c.origin_node, c.nbytes) for c in plan.chunks} == {(0, 150), (2, 50)}
    assert plan.effective_cap[1] == 150  # max origin volume, not the user cap
    # conglomerated chunks need no inter-node splitting of any message
    for c in plan.chunks:
        for msg, off, length in c.parts:
            assert (off, length) == (0, msg.nbytes)


def test_single_node_world_has_no_chunks():
    """All traffic on one node: Algorithm 1 degenerates to local_comm."""
    pat = CommPattern.from_messages(4, 4, [(0, 1, 64), (2, 3, 32), (1, 2, 8)])
    plan = build_split_plan(pat, message_cap=16)
    assert plan.chunks == ()
    assert plan.effective_cap == {}
    assert plan.total_inter_node_bytes() == 0
    assert sum(m.nbytes for m in plan.local_messages) == 104
    assert plan.send_redistribution() == [] and plan.recv_redistribution() == []


def test_ppn1_world_assignment():
    """PPN=1: every node is one rank, so line 18's balancing must pin the
    sender/receiver to the only rank on each node and still split by cap."""
    pat = CommPattern.from_messages(3, 1, [(0, 1, 100), (2, 1, 40)])
    plan = build_split_plan(pat, message_cap=30)
    # total 140 / cap 30 > ppn=1 -> cap raised to ceil(140/1) = 140 (line 16)
    assert plan.effective_cap[1] == 140
    assert all(c.receiver == 1 for c in plan.chunks)
    for c in plan.chunks:
        assert c.sender == c.origin_node  # rank == node when ppn == 1
    assert plan.total_inter_node_bytes() == 140


def test_ppn1_cap_not_raised_when_chunks_fit():
    """PPN=1 with cap >= total: conglomeration branch, one chunk per origin."""
    pat = CommPattern.from_messages(2, 1, [(0, 1, 10)])
    plan = build_split_plan(pat, message_cap=1000)
    assert len(plan.chunks) == 1
    assert plan.chunks[0].sender == 0 and plan.chunks[0].receiver == 1
