"""Property tests for Algorithm 1 (Split setup)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # CI image has no hypothesis; use the vendored shim
    from repro.testing.hypo import given, settings, st

from repro.core import CommPattern, Message, build_split_plan


def random_pattern(rng, ppn, nnodes, max_msgs=30, max_bytes=5000):
    n = ppn * nnodes
    msgs = []
    for _ in range(rng.integers(1, max_msgs)):
        s, d = rng.integers(0, n, 2)
        if s != d:
            msgs.append(Message(int(s), int(d), int(rng.integers(1, max_bytes))))
    return CommPattern.from_messages(n, ppn, msgs)


@given(
    ppn=st.integers(1, 6),
    nnodes=st.integers(2, 5),
    cap=st.integers(1, 8192),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=80, deadline=None)
def test_algorithm1_invariants(ppn, nnodes, cap, seed):
    rng = np.random.default_rng(seed)
    pat = random_pattern(rng, ppn, nnodes)
    plan = build_split_plan(pat, message_cap=cap)

    inter = pat.inter_node_messages()
    total_inter = sum(m.nbytes for m in inter)

    # 1. byte conservation: every inter-node byte is carried by exactly one chunk
    assert plan.total_inter_node_bytes() == total_inter
    covered = {}
    for c in plan.chunks:
        for msg, off, length in c.parts:
            covered.setdefault(id(msg), 0)
            covered[id(msg)] += length
    for m in inter:
        assert covered.get(id(m), 0) == m.nbytes

    # 2. chunk sizes respect the effective cap (lines 12-17)
    for c in plan.chunks:
        eff = plan.effective_cap[c.dest_node]
        assert c.nbytes <= eff

    # 3. locality: sender on origin node, receiver on destination node
    for c in plan.chunks:
        assert pat.node_of(c.sender) == c.origin_node
        assert pat.node_of(c.receiver) == c.dest_node
        assert c.origin_node != c.dest_node

    # 4. line 18 balance: receive counts per node differ by at most 1
    from collections import Counter

    per_node = {}
    for c in plan.chunks:
        per_node.setdefault(c.dest_node, Counter())[c.receiver] += 1
    for node, counts in per_node.items():
        n_chunks = sum(counts.values())
        expected_max = -(-n_chunks // ppn)
        assert max(counts.values()) <= expected_max

    # 5. on-node messages are untouched (handled by local_comm)
    assert sum(m.nbytes for m in plan.local_messages) == sum(
        m.nbytes for m in pat.messages
    ) - total_inter


def test_conglomeration_when_below_cap():
    """Lines 12-13: if max node->node volume < cap, one chunk per origin."""
    pat = CommPattern.from_messages(
        8, 4, [(0, 4, 10), (1, 5, 20), (2, 6, 30)]
    )
    plan = build_split_plan(pat, message_cap=1000)
    assert len(plan.chunks) == 1  # all three messages fused: same origin/dest node
    assert plan.chunks[0].nbytes == 60


def test_cap_raised_when_exceeding_ppn_chunks():
    """Lines 14-17: cap rises to ceil(total/PPN) when too many chunks."""
    ppn = 2
    msgs = [(0, 2 + (i % 2), 100) for i in range(10)]  # 1000B node0 -> node1
    pat = CommPattern.from_messages(4, ppn, msgs)
    plan = build_split_plan(pat, message_cap=10)  # would need 100 chunks > ppn
    assert plan.effective_cap[1] == 500  # ceil(1000/2)
    assert len(plan.chunks) == 2


def test_invalid_cap_rejected():
    pat = CommPattern.from_messages(4, 2, [(0, 2, 10)])
    with pytest.raises(ValueError):
        build_split_plan(pat, message_cap=0)
