"""MoE dispatch through the exchange stack: routing patterns, bucketing,
fingerprint fast path, capacity fill, and 8-device parity with the flat
all-to-all baseline."""

import dataclasses

import numpy as np
import pytest

from repro.comm import (
    ExchangePattern,
    Need,
    PodTopology,
    block_pattern,
    quantize_widths,
    random_pattern,
)
from repro.core import CommPattern, Message, dispatch_stats
from repro.models import ExpertLoadHistogram, RoutingBucketer, recv_maps

TOPO = PodTopology(npods=2, ppn=2)
N = TOPO.nranks


def _counts(seed=0, lo=0, hi=12):
    return np.random.default_rng(seed).integers(lo, hi, size=(N, N))


# ---------------------------------------------------------------------------
# block_pattern / quantize_widths
# ---------------------------------------------------------------------------


def test_block_pattern_full_widths_is_dense_all_to_all():
    block = 4
    pat = block_pattern(TOPO, block)
    assert pat.local_size == N * block
    # every off-diagonal pair ships its full destination block
    assert len(pat.needs) == N * (N - 1)
    for n in pat.needs:
        assert n.idx == tuple(range(n.dst * block, (n.dst + 1) * block))
    # every rank receives (N-1) * block elements
    assert pat.max_recv_size() == (N - 1) * block


def test_block_pattern_widths_ship_only_the_prefix():
    block = 8
    w = quantize_widths(_counts(), 4, block)
    pat = block_pattern(TOPO, block, w)
    for n in pat.needs:
        k = int(w[n.src, n.dst])
        assert k > 0
        assert n.idx == tuple(range(n.dst * block, n.dst * block + k))
    # zero-width pairs drop out of the pattern entirely
    pairs = {(n.src, n.dst) for n in pat.needs}
    for s in range(N):
        for d in range(N):
            if s != d and w[s, d] == 0:
                assert (s, d) not in pairs


def test_block_pattern_validation():
    with pytest.raises(ValueError, match="widths must be"):
        block_pattern(TOPO, 4, np.zeros((N, N + 1), int))
    bad = np.zeros((N, N), int)
    bad[0, 1] = 5
    with pytest.raises(ValueError, match="lie in"):
        block_pattern(TOPO, 4, bad)
    with pytest.raises(ValueError, match="lie in"):
        block_pattern(TOPO, 4, -np.ones((N, N), int))


def test_quantize_widths_rounds_up_and_clips():
    counts = np.array([[0, 1, 8, 9], [15, 16, 17, 100], [0, 0, 0, 0], [3, 7, 8, 12]])
    q = quantize_widths(counts, 8, 16)
    assert (q == np.array([[0, 8, 8, 16], [16, 16, 16, 16], [0, 0, 0, 0], [8, 8, 8, 16]])).all()
    # zero stays zero, quantum 1 is the identity (after the cap clip)
    assert (quantize_widths(counts, 1, 16) == np.minimum(counts, 16)).all()
    with pytest.raises(ValueError, match="quantum"):
        quantize_widths(counts, 0, 16)
    with pytest.raises(ValueError, match="non-negative"):
        quantize_widths(-counts, 8, 16)


# ---------------------------------------------------------------------------
# fingerprint fast path (bugfix satellite)
# ---------------------------------------------------------------------------


def test_fingerprint_equal_patterns_collide():
    rng = np.random.default_rng(3)
    a = random_pattern(rng, TOPO, local_size=6)
    b = ExchangePattern(topo=a.topo, local_size=a.local_size, needs=a.needs)
    assert a is not b and a.fingerprint() == b.fingerprint()


def test_fingerprint_permuted_needs_same_digest():
    rng = np.random.default_rng(4)
    a = random_pattern(rng, TOPO, local_size=6, p_connect=1.0)
    perm = tuple(reversed(a.needs))
    b = ExchangePattern(topo=a.topo, local_size=a.local_size, needs=perm)
    assert a.needs != b.needs
    assert a.fingerprint() == b.fingerprint()


def test_fingerprint_distinguishes_patterns():
    base = block_pattern(TOPO, 4)
    w = np.full((N, N), 4)
    w[0, 1] = 3
    assert base.fingerprint() != block_pattern(TOPO, 4, w).fingerprint()
    # topology changes the digest even for identical needs
    flat = PodTopology(npods=1, ppn=N)
    moved = ExchangePattern(topo=flat, local_size=base.local_size, needs=base.needs)
    assert base.fingerprint() != moved.fingerprint()


def test_fingerprint_memoized_on_instance():
    pat = block_pattern(TOPO, 4)
    assert pat.fingerprint() is pat.fingerprint()
    # a fresh copy re-hashes to the same digest (memo is per instance)
    fresh = dataclasses.replace(pat)
    assert fresh.fingerprint() == pat.fingerprint()


# ---------------------------------------------------------------------------
# recv_maps
# ---------------------------------------------------------------------------


def test_recv_maps_match_canonical_layout():
    block = 8
    w = quantize_widths(_counts(seed=1), 4, block)
    np.fill_diagonal(w, 0)
    pat = block_pattern(TOPO, block, w)
    maps, H = recv_maps(TOPO, block, w)
    assert H == pat.max_recv_size()
    rows = pat.canonical_code_rows()
    for r in range(N):
        off = 0
        for s in range(N):
            base = s * block
            if s == r:  # own block reads the local send buffer in place
                assert (maps[r, base : base + block] == np.arange(base, base + block)).all()
                continue
            k = int(w[s, r])
            for j in range(k):
                # halo index points at the canonical slot holding exactly
                # the element the tiled all-to-all would deliver there
                assert maps[r, base + j] == N * block + off + j
                assert rows[r][off + j] == s * pat.local_size + r * block + j
            # unshipped suffix -> sentinel row
            assert (maps[r, base + k : base + block] == N * block + H).all()
            off += k


def test_recv_maps_validation():
    with pytest.raises(ValueError, match="widths must be"):
        recv_maps(TOPO, 4, np.zeros((N, N + 1), int))
    with pytest.raises(ValueError, match="lie in"):
        recv_maps(TOPO, 4, np.full((N, N), 5))


# ---------------------------------------------------------------------------
# RoutingBucketer: high-water plan reuse
# ---------------------------------------------------------------------------


def test_bucketer_reuses_bundle_under_shrink_and_jitter():
    b = RoutingBucketer(TOPO, block=16, quantum=8)
    counts = _counts(seed=2, lo=4, hi=12)
    bun1, rp1 = b.step(counts)
    assert rp1 and b.replans == 1
    # shrink and small jitter stay under the high-water mark -> same object
    bun2, rp2 = b.step(np.maximum(counts - 3, 0))
    bun3, rp3 = b.step(counts)
    assert bun2 is bun1 and bun3 is bun1
    assert not rp2 and not rp3
    assert b.replans == 1 and b.steps == 3
    assert b.hit_rate == pytest.approx(2 / 3)


def test_bucketer_growth_is_one_incremental_replan():
    b = RoutingBucketer(TOPO, block=16, quantum=8)
    counts = _counts(seed=2, lo=4, hi=12)
    bun1, _ = b.step(counts)
    grown, rp = b.step(counts + 9)  # crosses a quantum boundary somewhere
    assert rp and grown is not bun1
    # the new widths are the union (elementwise max) of what was seen
    assert (grown.widths >= bun1.widths).all()
    # and the grown bundle now absorbs both traffic levels
    again, rp2 = b.step(counts)
    assert again is grown and not rp2


def test_bucketer_bundle_patterns_are_consistent():
    b = RoutingBucketer(TOPO, block=16, quantum=8)
    bun, _ = b.step(_counts(seed=5, lo=0, hi=20))
    assert bun.pattern_dispatch.max_recv_size() == bun.halo_dispatch
    assert bun.pattern_return.max_recv_size() == bun.halo_return
    # return hop ships the transposed widths
    w = bun.widths
    ret_pairs = {(n.src, n.dst): len(n.idx) for n in bun.pattern_return.needs}
    for s in range(N):
        for d in range(N):
            if s != d and w[s, d]:
                assert ret_pairs[(d, s)] == w[s, d]


# ---------------------------------------------------------------------------
# dispatch_stats: histogram -> Table 7 statistics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dispatch_stats_matches_comm_pattern_stats(seed):
    block = 16
    w = quantize_widths(_counts(seed=seed, lo=0, hi=14), 4, block)
    np.fill_diagonal(w, 0)
    ref = block_pattern(TOPO, block, w).to_comm_pattern(elem_bytes=4).stats()
    got = dispatch_stats(w, TOPO.ppn, elem_bytes=4)
    assert got == ref


def test_dispatch_stats_scales_with_elem_bytes():
    w = quantize_widths(_counts(seed=7), 4, 16)
    np.fill_diagonal(w, 0)
    s4 = dispatch_stats(w, TOPO.ppn, elem_bytes=4)
    s8 = dispatch_stats(w, TOPO.ppn, elem_bytes=8)
    assert s8.s_proc == 2 * s4.s_proc and s8.s_node == 2 * s4.s_node
    assert s8.m_proc == s4.m_proc  # message counts don't scale with bytes


# ---------------------------------------------------------------------------
# ExpertLoadHistogram
# ---------------------------------------------------------------------------


def test_histogram_ema_and_advice():
    h = ExpertLoadHistogram(N, decay=0.5)
    a = np.full((N, N), 8.0)
    b = np.zeros((N, N))
    h.update(a)
    assert (h.counts == a).all()  # first update seeds the EMA
    h.update(b)
    assert (h.counts == 4.0).all()
    adv = h.advise(ppn=TOPO.ppn, payload_width=64, machine="lassen")
    assert adv.best.predicted_time <= adv.ranked[-1].predicted_time
    with pytest.raises(ValueError, match="counts must be"):
        h.update(np.zeros((N, N + 1)))
    with pytest.raises(ValueError, match="decay"):
        ExpertLoadHistogram(N, decay=1.0)


# ---------------------------------------------------------------------------
# 8-device subprocess tests
# ---------------------------------------------------------------------------

_SETUP_8DEV = """
import numpy as np, jax, jax.numpy as jnp
from repro.comm import PodTopology, make_exchange_mesh, cache_stats, clear_caches
from repro.configs.base import MoEConfig
from repro.models.moe import MoELayer

topo = PodTopology(npods=2, ppn=4)
mesh = make_exchange_mesh(topo)
cfg = MoEConfig(n_experts=16, top_k=2, d_ff_expert=32)
M = 16
B, S = 8, 16
rng = np.random.default_rng(0)

def make_params(scale=2.0):
    return {
        "router": jnp.asarray(rng.standard_normal((M, cfg.n_experts)) * scale, jnp.float32),
        "w_in": jnp.asarray(rng.standard_normal((cfg.n_experts, M, cfg.d_ff_expert)) * 0.1, jnp.float32),
        "w_gate": jnp.asarray(rng.standard_normal((cfg.n_experts, M, cfg.d_ff_expert)) * 0.1, jnp.float32),
        "w_out": jnp.asarray(rng.standard_normal((cfg.n_experts, cfg.d_ff_expert, M)) * 0.1, jnp.float32),
    }
"""


@pytest.mark.slow
def test_exchange_dispatch_parity_all_strategies(subproc):
    """dispatch="exchange" is bitwise identical to the flat all-to-all
    baseline on 8 devices, for every strategy, uniform and skewed routing."""
    subproc(
        _SETUP_8DEV
        + """
params = make_params()
inputs = {
    "uniform": jnp.asarray(rng.standard_normal((B, S, M)), jnp.float32),
    # a constant bias skews the router's top-k towards a few experts
    "skewed": jnp.asarray(
        rng.standard_normal((B, S, M)) * 0.3 + rng.standard_normal(M), jnp.float32
    ),
}
base = MoELayer(M, cfg, ep_axis=("pod", "local"))
for name, x in inputs.items():
    y0 = np.asarray(base(params, x, mesh))
    assert np.isfinite(y0).all()
    for strat in ("standard", "two_step", "three_step", "split", "auto"):
        layer = MoELayer(M, cfg, dispatch="exchange", strategy=strat)
        y1 = np.asarray(layer(params, x, mesh))
        assert np.array_equal(y0, y1), (name, strat)
        # the dispatcher measured real traffic
        assert layer.dispatcher.histogram.updates == 1
print("PARITY", "OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_divisibility_error_and_valid_path(subproc):
    """Non-divisible expert counts raise instead of silently dropping
    expert parallelism; divisible counts run sharded."""
    subproc(
        _SETUP_8DEV
        + """
x = jnp.asarray(rng.standard_normal((B, S, M)), jnp.float32)

# baseline path: 12 experts on 8 shards must raise, not fall back
bad = MoEConfig(n_experts=12, top_k=2, d_ff_expert=32)
params_bad = {
    "router": jnp.zeros((M, 12), jnp.float32),
    "w_in": jnp.zeros((12, M, 32), jnp.float32),
    "w_gate": jnp.zeros((12, M, 32), jnp.float32),
    "w_out": jnp.zeros((12, 32, M), jnp.float32),
}
try:
    MoELayer(M, bad, ep_axis=("pod", "local"))(params_bad, x, mesh)
    raise SystemExit("baseline: expected ValueError")
except ValueError as e:
    assert "divisible" in str(e) and "12" in str(e), e

# exchange path raises the same contract
try:
    MoELayer(M, bad, dispatch="exchange")(params_bad, x, mesh)
    raise SystemExit("exchange: expected ValueError")
except ValueError as e:
    assert "divisible" in str(e), e

# batch must cover all ranks on the exchange path
try:
    MoELayer(M, cfg, dispatch="exchange")(
        make_params(), x[:4], mesh
    )
    raise SystemExit("expected batch ValueError")
except ValueError as e:
    assert "batch" in str(e), e

# the valid-divisor path actually shards: 16 experts over 8 ranks works
y = MoELayer(M, cfg, ep_axis=("pod", "local"))(make_params(), x, mesh)
assert y.shape == (B, S, M) and np.isfinite(np.asarray(y)).all()
print("ERRORS", "OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_bucketed_plan_cache_hit_rate(subproc):
    """Pinned cache accounting: a saturating uniform load pays exactly ONE
    plan miss across N batches (dispatch and return patterns coincide), and
    a skewed jittering load stays >= 90% exchange-cache hits."""
    subproc(
        """
import numpy as np
from repro.comm import PodTopology, cache_stats, clear_caches
from repro.models import MoEDispatcher

topo = PodTopology(npods=2, ppn=4)
n = topo.nranks
block = 32
N_BATCH = 12

# -- uniform saturating counts: widths == block everywhere, symmetric, so
#    dispatch and return share ONE pattern -> exactly one plan miss total
clear_caches()
disp = MoEDispatcher(topo, strategy="two_step", quantum=8)
full = np.full((n, n), 2 * block, np.int64)
np.fill_diagonal(full, 0)
for _ in range(N_BATCH):
    disp.step(full, block)
st = cache_stats()
assert disp.bucketer(block).replans == 1, disp.bucketer(block).replans
assert st.plan_misses == 1, st
assert st.exchange_misses == 1, st
assert st.exchange_hits == 2 * N_BATCH - 1, st

# -- skewed stationary traffic with jitter: quantization absorbs the noise
clear_caches()
disp = MoEDispatcher(topo, strategy="two_step", quantum=8)
rng = np.random.default_rng(0)
base = np.zeros((n, n), np.int64)
base[:, :3] = 20  # hot experts on ranks 0..2
np.fill_diagonal(base, 0)
for _ in range(N_BATCH):
    jitter = rng.integers(-3, 4, size=(n, n)) * (base > 0)
    disp.step(base + jitter, block)
st = cache_stats()
buck = disp.bucketer(block)
assert buck.replans == 1, buck.replans
assert buck.hit_rate >= 0.9, buck.hit_rate
# asymmetric widths: dispatch and return are distinct patterns
assert st.exchange_misses == 2, st
assert st.exchange_hits == 2 * (N_BATCH - 1), st
rate = st.exchange_hits / (st.exchange_hits + st.exchange_misses)
assert rate >= 0.9, rate
print("CACHE", "OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_exchange_dispatch_end_to_end_cache_stability(subproc):
    """Model-level: repeated batches over the same routing distribution pay
    planning once; the wire codec path runs and stays close to baseline."""
    subproc(
        _SETUP_8DEV
        + """
params = make_params()
x = jnp.asarray(rng.standard_normal((B, S, M)), jnp.float32)
clear_caches()
layer = MoELayer(M, cfg, dispatch="exchange", strategy="three_step")
for i in range(5):
    y = layer(params, x, mesh)
    if i == 0:
        first = cache_stats()
st = cache_stats()
# all planning happened on batch 1; batches 2..5 are pure cache hits
assert st.plan_misses == first.plan_misses, (first, st)
assert st.exchange_misses == first.exchange_misses, (first, st)
assert st.exchange_hits > first.exchange_hits

# lossy wire codec: runs end-to-end, close to the full-precision output
y0 = np.asarray(MoELayer(M, cfg, ep_axis=("pod", "local"))(params, x, mesh))
yw = np.asarray(
    MoELayer(M, cfg, dispatch="exchange", strategy="two_step", wire="bf16")(
        params, x, mesh
    )
)
assert np.allclose(y0, yw, rtol=0.05, atol=0.05), np.abs(y0 - yw).max()
print("E2E", "OK")
""",
        devices=8,
    )
