"""Serving-grade fault tolerance (ISSUE 10).

Four layers under test, all deterministic:

* the :class:`repro.comm.faults.HealthTracker` circuit breaker
  (closed -> open -> half-open, call-count cooldown, doubled cooldown on a
  failed probe) and its capped event ring buffer;
* the resilient executor drain (:meth:`BatchExecutor.execute_resilient` /
  :meth:`run_schedule`): structured :class:`BatchOutcome` per batch,
  per-batch deadline, bounded backoff, shed bookkeeping feeding the shared
  admission/watchdog escalation budget;
* chaos in the traffic simulator (``SimConfig(chaos=FaultPlan(...))``) and
  the ISSUE 10 acceptance storm: >= 99% of admitted requests complete with
  results numerically equal to a fault-free run;
* fused-solve checkpoint/resume (slow, 8 forced host devices): a solve
  interrupted mid-flight resumes losing at most ``checkpoint_every``
  iterations with residual history bitwise equal to the clean run, and the
  fault-free armed program stays bitwise identical to the unarmed one.
"""

import numpy as np
import pytest

from repro.comm import faults as F
from repro.comm.exchange import execute_numpy, plan, random_pattern
from repro.comm.topology import PodTopology
from repro.core import advise, figure43_pattern
from repro.core.advisor import healthy_alternatives
from repro.runtime.watchdog import AdmissionController, StragglerWatchdog
from repro.serving import BatchExecutor, SimConfig, WorkloadClass, simulate
from repro.serving.batcher import Batch
from repro.serving.request import Request
from repro.testing import make_trace


def _err(strategy="two_step", codec="bf16"):
    return F.ExchangeIntegrityError(
        strategy=strategy, codec=codec, stage_kind="a2a_pod",
        op_index=0, round_index=0, violation=1.0,
    )


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


class TestCircuitBreaker:
    def test_full_cycle_closed_open_half_open_closed(self):
        h = F.HealthTracker(cooldown=3)
        key = ("two_step", "bf16")
        assert h.breaker_state(*key) == "closed"
        h.record_call()
        h.record_failure(_err())
        assert h.breaker_state(*key) == "open"
        assert h.penalty(*key) == F.DEGRADED_PENALTY
        for _ in range(2):
            h.record_call()
            assert h.breaker_state(*key) == "open"
        h.record_call()  # cooldown elapsed: one probe earned
        assert h.breaker_state(*key) == "half_open"
        assert h.record_success(*key) is True
        assert h.breaker_state(*key) == "closed"
        assert h.failures == {} and h.penalty(*key) == 1.0
        assert h.probe_recoveries == 1
        # cooldown is back at base after a heal: a fresh trip waits 3 again
        h.record_call()
        h.record_failure(_err())
        for _ in range(3):
            h.record_call()
        assert h.breaker_state(*key) == "half_open"

    def test_failed_probe_doubles_cooldown(self):
        h = F.HealthTracker(cooldown=2, cooldown_growth=2.0)
        key = ("two_step", "bf16")
        h.record_call()
        h.record_failure(_err())
        h.record_call()
        h.record_call()
        assert h.breaker_state(*key) == "half_open"
        h.record_failure(_err())  # the probe itself fails
        assert h.breaker_state(*key) == "open"
        for _ in range(3):  # old cooldown (2) is no longer enough
            h.record_call()
            assert h.breaker_state(*key) == "open"
        h.record_call()  # doubled cooldown (4) elapsed
        assert h.breaker_state(*key) == "half_open"

    def test_directly_set_failures_never_half_open(self):
        h = F.HealthTracker(cooldown=1)
        h.failures[("split", "none")] = 5  # imported degradation, no clock
        for _ in range(10):
            h.record_call()
        assert h.breaker_state("split", "none") == "open"
        assert h.record_success("split", "none") is False

    def test_record_success_noop_unless_half_open(self):
        h = F.HealthTracker(cooldown=4)
        assert h.record_success("two_step", "bf16") is False  # closed
        h.record_call()
        h.record_failure(_err())
        assert h.record_success("two_step", "bf16") is False  # open
        assert h.failures[("two_step", "bf16")] == 1
        assert h.probe_recoveries == 0

    def test_advise_ranking_recovers_after_heal(self):
        pat = figure43_pattern(2048, 256, 16)
        h = F.HealthTracker(cooldown=1)
        baseline = advise(pat, machine="lassen", health=h)
        from repro.core.advisor import EXECUTABLE_STRATEGY

        best = EXECUTABLE_STRATEGY[baseline.best.strategy]
        h.record_call()
        h.record_failure(_err(strategy=best, codec="none"))
        sunk = advise(pat, machine="lassen", health=h)
        assert EXECUTABLE_STRATEGY[sunk.best.strategy] != best
        # the penalty is ranking-only: the sunk ranking still reports the
        # physical model time, not the 1e6x-penalized sort key
        assert sunk.best.predicted_time < 1.0
        h.record_call()
        assert h.breaker_state(best, "none") == "half_open"
        assert h.record_success(best, "none")
        healed = advise(pat, machine="lassen", health=h)
        assert healed.best.key == baseline.best.key

    def test_healthy_alternatives_breaker_aware(self):
        ranked = advise(figure43_pattern(2048, 256, 16), machine="lassen").ranked
        names = list(healthy_alternatives(ranked, None))
        assert names[0] == "two_step" and len(names) == len(set(names))
        # open: skipped entirely
        h = F.HealthTracker()
        h.failures[("two_step", "none")] = 1
        assert "two_step" not in list(healthy_alternatives(ranked, h))
        # half-open: yielded (it has earned exactly one probe)
        hb = F.HealthTracker(cooldown=1)
        hb.record_call()
        hb.record_failure(_err(strategy="two_step", codec="none"))
        hb.record_call()
        assert hb.breaker_state("two_step", "none") == "half_open"
        assert next(healthy_alternatives(ranked, hb)) == "two_step"
        # current is always skipped
        assert "two_step" not in list(
            healthy_alternatives(ranked, None, current="two_step")
        )


class TestEventRingBuffer:
    def test_cap_and_dropped_counter(self):
        h = F.HealthTracker(max_events=8)
        for i in range(30):
            h.record_failure(_err(codec=f"c{i}"))
        assert len(h.events) == 8
        assert h.dropped == 22
        # newest events survive, oldest were dropped
        assert h.events[-1]["codec"] == "c29"
        assert h.events[0]["codec"] == "c22"

    def test_degraded_and_penalty_unaffected_by_eviction(self):
        h = F.HealthTracker(max_events=4)
        for i in range(20):
            h.record_failure(_err(codec=f"c{i}"))
        # every failed pair is still degraded/penalized even though its
        # event left the ring buffer long ago
        assert len(h.degraded()) == 20
        assert h.penalty("two_step", "c0") == F.DEGRADED_PENALTY
        assert h.is_degraded("two_step", "c0")


# ---------------------------------------------------------------------------
# resilient executor drain (jax-free: numpy exchange handlers)
# ---------------------------------------------------------------------------


def _exchange_fixture():
    topo = PodTopology(npods=2, ppn=4)
    rng = np.random.default_rng(0)
    pats = {
        f"t{i}": random_pattern(
            np.random.default_rng(40 + i), topo, local_size=16, max_elems=4
        )
        for i in range(3)
    }
    x = rng.normal(size=(topo.nranks, 16)).astype(np.float32)
    refs = {k: execute_numpy(plan("standard", p), x) for k, p in pats.items()}
    return pats, x, refs


def _batch(fp, rids=(0,), strategy="two_step", wire="none"):
    return Batch(
        fp=fp,
        requests=tuple(Request(arrival=0.0, rid=r, fp=fp) for r in rids),
        payload_width=len(rids),
        resident_bytes=1024,
        strategy=strategy,
        wire=wire,
        key=f"{strategy}/device_aware",
        predicted_time=1e-4,
        kind="spmv",
    )


def _family(pat, faults=None):
    # one fault-call clock per handler family: retries and demotions see
    # fresh call indices, exactly like the real exchange attempt sequence
    counter = {"n": 0}

    def make(strategy, wire):
        def handler(payload):
            idx = counter["n"]
            counter["n"] += 1
            return execute_numpy(
                plan(strategy, pat), payload, wire=wire,
                faults=faults, fault_call=idx, verify=True,
            )

        return handler

    return make


class TestResilientDrain:
    def test_run_schedule_preserves_completed_work_on_keyerror(self):
        pats, x, refs = _exchange_fixture()
        ex = BatchExecutor()
        ex.register_variants("t0", _family(pats["t0"]))
        ex.register_variants("t2", _family(pats["t2"]))
        batches = [_batch("t0", (0,)), _batch("ghost", (1, 2)), _batch("t2", (3,))]
        outcomes = ex.run_schedule(batches, [x, x, x])
        assert [o.ok for o in outcomes] == [True, False, True]
        assert np.array_equal(outcomes[0].value, refs["t0"])
        assert np.array_equal(outcomes[2].value, refs["t2"])
        bad = outcomes[1]
        assert isinstance(bad.error, KeyError)
        assert bad.shed_rids == (1, 2)
        assert ex.shed_batches == 1 and ex.shed_requests == 2

    def test_run_schedule_survives_non_integrity_handler_bug(self):
        pats, x, refs = _exchange_fixture()
        ex = BatchExecutor()
        ex.register_variants("t0", _family(pats["t0"]))

        def buggy(payload):
            raise ValueError("handler bug, not an integrity failure")

        ex.register("t1", buggy)
        outcomes = ex.run_schedule([_batch("t1", (0,)), _batch("t0", (1,))], [x, x])
        assert not outcomes[0].ok and isinstance(outcomes[0].error, ValueError)
        assert outcomes[1].ok and np.array_equal(outcomes[1].value, refs["t0"])

    def test_ladder_recovery_and_outcome_fields(self):
        pats, x, refs = _exchange_fixture()
        storm = F.FaultPlan(
            seed=5,
            specs=(F.FaultSpec(kind="perturb", prob=1.0, frac=0.25,
                               strategies=("two_step",)),),
        )
        ex = BatchExecutor(health=F.HealthTracker())
        ex.register_variants("t0", _family(pats["t0"], faults=storm))
        o = ex.execute_resilient(_batch("t0"), x)
        assert o.ok and o.recovery is not None
        assert o.recovery.startswith(("demote:", "readvise:"))
        assert o.attempts >= 2
        assert np.array_equal(o.value, refs["t0"])
        assert ex.recovered_batches == 1

    def test_transient_fault_cured_by_retry(self):
        pats, x, refs = _exchange_fixture()
        transient = F.FaultPlan(
            seed=7, specs=(F.FaultSpec(kind="corrupt"),), active_calls=(0,)
        )
        ex = BatchExecutor()
        ex.register_variants("t1", _family(pats["t1"], faults=transient))
        o = ex.execute_resilient(_batch("t1", strategy="two_step", wire="none"), x)
        assert o.ok and o.recovery == "retry:two_step/none"
        assert o.attempts == 2
        assert np.array_equal(o.value, refs["t1"])

    def test_deadline_sheds_with_injectable_clock(self):
        pats, x, _ = _exchange_fixture()
        always = F.FaultPlan(seed=3, specs=(F.FaultSpec(kind="corrupt"),))
        t = {"now": 0.0}

        def clock():
            t["now"] += 10.0  # every clock read burns 10 virtual seconds
            return t["now"]

        wd = StragglerWatchdog(budget=1)
        adm = AdmissionController(watchdog=wd)
        ex = BatchExecutor(
            deadline_s=5.0, clock=clock, sleep=lambda s: None,
            watchdog=wd, admission=adm,
        )
        ex.register_variants("t0", _family(pats["t0"], faults=always))
        o = ex.execute_resilient(_batch("t0", rids=(7, 8)), x)
        assert not o.ok and o.deadline_missed
        assert o.shed_rids == (7, 8)
        assert ex.deadline_misses == 1
        # shed pressure reaches the shared escalation budget
        assert adm.shed == 2 and adm.escalations == 1
        assert any(e.get("kind") == "batch_shed" for e in wd.events)

    def test_backoff_is_exponential_and_capped(self):
        pats, x, _ = _exchange_fixture()
        always = F.FaultPlan(seed=3, specs=(F.FaultSpec(kind="corrupt"),))
        pauses = []
        ex = BatchExecutor(
            max_retries=3,
            fallback=False,
            backoff_base_s=0.1,
            backoff_max_s=0.25,
            clock=lambda: 0.0,
            sleep=pauses.append,
        )
        ex.register_variants("t0", _family(pats["t0"], faults=always))
        o = ex.execute_resilient(_batch("t0"), x)
        assert not o.ok
        assert pauses == [0.2, 0.25, 0.25]  # base * 2**failures, capped
        assert o.backoff_s == pytest.approx(sum(pauses))

    def test_fault_free_drain_matches_plain_execute_bitwise(self):
        pats, x, refs = _exchange_fixture()
        ex = BatchExecutor()
        ex.register_variants("t0", _family(pats["t0"]))
        b = _batch("t0")
        o = ex.execute_resilient(b, x)
        assert o.ok and o.recovery is None and o.attempts == 1
        assert np.array_equal(o.value, ex.execute(b, x))
        assert np.array_equal(o.value, refs["t0"])


# ---------------------------------------------------------------------------
# acceptance: seeded fault storm through the serving layer
# ---------------------------------------------------------------------------


class TestFaultStormAcceptance:
    def test_executor_storm_completes_all_with_fault_free_results(self):
        """ISSUE 10 acceptance: >= 99% of admitted requests complete and
        every completed result is numerically equal to a fault-free run."""
        pats, x, refs = _exchange_fixture()
        storm = F.FaultPlan(
            seed=11,
            specs=(
                F.FaultSpec(kind="perturb", prob=0.4, frac=0.2,
                            strategies=("two_step",)),
                F.FaultSpec(kind="corrupt", prob=0.15, codecs=("lossy",)),
            ),
        )
        ex = BatchExecutor(health=F.HealthTracker())
        for k, p in pats.items():
            ex.register_variants(k, _family(p, faults=storm))
        names = sorted(pats)
        batches = [
            _batch(names[i % 3], rids=(i,), strategy="two_step")
            for i in range(48)
        ]
        outcomes = ex.run_schedule(batches, [x] * len(batches))
        admitted = sum(len(o.batch.requests) for o in outcomes)
        done = sum(len(o.batch.requests) for o in outcomes if o.ok)
        assert admitted == 48
        assert done / admitted >= 0.99
        for o in outcomes:
            if o.ok:
                assert np.array_equal(o.value, refs[o.batch.fp]), o.batch.fp
        assert any(o.recovery for o in outcomes)  # the storm actually fired

    def test_sim_storm_deterministic_and_covered_by_trace_hash(self):
        topo = PodTopology(npods=2, ppn=4)
        classes = {
            f"s{i}": WorkloadClass.from_pattern(
                random_pattern(np.random.default_rng(300 + i), topo,
                               local_size=32, max_elems=4),
                fp=f"s{i}",
            )
            for i in range(3)
        }
        trace = make_trace(11, 96, sorted(classes), pattern="burst", rate=4000.0)
        storm = F.FaultPlan(
            seed=11,
            specs=(
                F.FaultSpec(kind="perturb", prob=0.35, frac=0.1,
                            strategies=("two_step",)),
                F.FaultSpec(kind="slow", prob=0.1, delay_s=1e-3),
            ),
        )
        cfg = SimConfig(chaos=storm, deadline_s=0.25, max_width=8,
                        strategy="two_step")
        clean = simulate(classes, trace,
                         SimConfig(max_width=8, strategy="two_step"))
        a = simulate(classes, trace, cfg)
        b = simulate(classes, trace, cfg)
        assert a.trace_hash == b.trace_hash  # chaos is deterministic
        assert a.trace_hash != clean.trace_hash  # ...and covered by the hash
        admitted = a.completed + a.shed
        assert admitted == clean.completed == 96
        assert a.completed / admitted >= 0.99
        assert a.fault_events > 0 and a.recoveries > 0

    def test_chaos_none_leaves_trace_unchanged(self):
        topo = PodTopology(npods=2, ppn=4)
        cls = WorkloadClass.from_pattern(
            random_pattern(np.random.default_rng(100), topo,
                           local_size=32, max_elems=4),
            fp="a",
        )
        trace = make_trace(7, 32, ["a"], pattern="burst", rate=4000.0)
        base = simulate({"a": cls}, trace, SimConfig(max_width=8))
        off = simulate({"a": cls}, trace, SimConfig(max_width=8, chaos=None))
        assert base.trace_hash == off.trace_hash


# ---------------------------------------------------------------------------
# slow: split-phase ladder coverage + fused checkpoint/resume (8 devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_split_phase_ladder_in_executor_drain(subproc):
    """Recovery ladder through the overlap path: seeded faults fire inside
    ``IrregularExchange.start()``/``finish()`` (the inter-pod phase of a
    split-phase exchange) while the *executor's* ladder -- not the
    exchange's own -- does the recovering via a variant handler family."""
    subproc(
        """
import numpy as np
from repro.comm.exchange import random_pattern, PodTopology
from repro.comm.strategies import IrregularExchange
from repro.comm import faults as F
from repro.serving import BatchExecutor
from repro.serving.batcher import Batch
from repro.serving.request import Request

topo = PodTopology(npods=4, ppn=2)
pat = random_pattern(np.random.default_rng(3), topo, local_size=24)
x = np.random.default_rng(0).standard_normal(
    (topo.nranks, pat.local_size)).astype(np.float32)
ref = np.asarray(IrregularExchange(pat, "standard", message_cap_bytes=256)(x))

# persistent per-strategy fault; every variant exchange has its own ladder
# DISABLED (max_retries=0, fallback=False) so recovery can only come from
# the executor's run_ladder around the split-phase handler
fp = F.FaultPlan(seed=7, specs=(F.FaultSpec(strategies=("two_step",)),))

def family(strategy, wire):
    ex = IrregularExchange(pat, strategy, message_cap_bytes=256, wire=wire,
                           faults=fp, verify=True,
                           max_retries=0, fallback=False)
    def handler(payload):
        h = ex.start(payload)           # inter-pod phase dispatches here
        return np.asarray(h.finish())   # ...and merges here
    return handler

bex = BatchExecutor(health=F.HealthTracker())
bex.register_variants("split-phase", family)
batch = Batch(fp="split-phase",
              requests=(Request(arrival=0.0, rid=0, fp="split-phase"),),
              payload_width=1, resident_bytes=x.nbytes,
              strategy="two_step", wire="bf16",
              key="two_step/device_aware+wire:bf16",
              predicted_time=1e-4, kind="spmv")
o = bex.execute_resilient(batch, x)
assert o.ok, o.error
assert o.recovery is not None and o.recovery.startswith("readvise:"), o.recovery
assert o.recovery.split(":")[1].split("/")[0] != "two_step"
assert np.array_equal(o.value, ref)
assert bex.health.is_degraded("two_step")

# fault-free split-phase drain through the same machinery stays clean
def family_clean(strategy, wire):
    ex = IrregularExchange(pat, strategy, message_cap_bytes=256, wire=wire)
    def handler(payload):
        h = ex.start(payload)
        return np.asarray(h.finish())
    return handler

bex2 = BatchExecutor()
bex2.register_variants("split-phase", family_clean)
clean_batch = Batch(fp="split-phase",
                    requests=(Request(arrival=0.0, rid=0, fp="split-phase"),),
                    payload_width=1, resident_bytes=x.nbytes,
                    strategy="two_step", wire="none",
                    key="two_step/device_aware",
                    predicted_time=1e-4, kind="spmv")
o2 = bex2.execute_resilient(clean_batch, x)
assert o2.ok and o2.recovery is None and o2.attempts == 1
assert np.array_equal(o2.value, ref)
print("SPLIT-PHASE LADDER OK")
""",
        devices=8,
    )


@pytest.mark.slow
def test_fused_checkpoint_resume_acceptance(subproc):
    """ISSUE 10 acceptance: a fused solve interrupted mid-solve resumes
    from its in-carry checkpoint, losing at most ``checkpoint_every``
    iterations, with ``+resume`` in the status and residual history /
    solution bitwise equal to the fault-free run -- and an armed but
    fault-free program stays bitwise identical to the unarmed one."""
    subproc(
        """
import numpy as np
from repro.comm import faults as F
from repro.comm.topology import PodTopology
from repro.sparse import thermal_like, partition_csr
from repro.solve import NumpySpMV, fused_bicgstab, fused_cg, spd_system

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
A = spd_system(thermal_like(256, rng))
part = partition_csr(A, topo)
b = rng.standard_normal((topo.nranks, part.rows_per_rank)).astype(np.float32)

clean = fused_cg(NumpySpMV(part, strategy="two_step", verify=True), b,
                 tol=1e-6, maxiter=200)
assert clean.status == "converged", clean.status

# fault-free bitwise pin: arming the checkpoint slots must not perturb
# the solver trajectory in any way
armed = fused_cg(NumpySpMV(part, strategy="two_step", verify=True), b,
                 tol=1e-6, maxiter=200, checkpoint_every=4)
assert armed.status == clean.status
assert armed.iterations == clean.iterations
assert armed.residuals == clean.residuals
assert armed.x.tobytes() == clean.x.tobytes()

# storm: corrupt every DCI hop of call 7, mid-solve
fp = F.FaultPlan(seed=5, specs=(F.FaultSpec(
    kind="perturb", prob=1.0, frac=1.0, strategies=("two_step",)),),
    active_calls=(7,))
op = NumpySpMV(part, strategy="two_step", verify=True, faults=fp)
res = fused_cg(op, b, tol=1e-6, maxiter=200, checkpoint_every=4)
assert res.status.startswith("converged+resume:1"), res.status
assert res.iterations == clean.iterations
assert res.residuals == clean.residuals        # bitwise clean continuation
assert res.x.tobytes() == clean.x.tobytes()
# losing <= checkpoint_every iterations: the resume re-ran at most the
# iterations since the last snapshot, visible in the matvec count
assert res.matvecs <= clean.matvecs + 4 + 1, (res.matvecs, clean.matvecs)

# same contract for BiCGStab
clean_b = fused_bicgstab(NumpySpMV(part, strategy="two_step", verify=True),
                         b, tol=1e-6, maxiter=200)
fpb = F.FaultPlan(seed=5, specs=(F.FaultSpec(
    kind="perturb", prob=1.0, frac=1.0, strategies=("two_step",)),),
    active_calls=(9,))
opb = NumpySpMV(part, strategy="two_step", verify=True, faults=fpb)
res_b = fused_bicgstab(opb, b, tol=1e-6, maxiter=200, checkpoint_every=4)
assert res_b.status.startswith(clean_b.status + "+resume:1"), res_b.status
assert res_b.iterations == clean_b.iterations
assert res_b.residuals == clean_b.residuals
assert res_b.x.tobytes() == clean_b.x.tobytes()
print("FUSED RESUME OK")
""",
        devices=8,
    )
