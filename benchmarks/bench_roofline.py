"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

    compute    = HLO_FLOPs / (chips * 197 TF/s)
    memory     = HLO_bytes / (chips * 819 GB/s)
    collective = per-chip collective bytes / (4 links * 50 GB/s)

HLO_FLOPs / HLO_bytes from ``cost_analysis()`` are whole-program totals;
collective bytes are per-chip (summed operand sizes, trip-count weighted),
so the collective term divides by per-chip link bandwidth directly.
Reports the dominant term, MODEL_FLOPS/HLO_FLOPs utility ratio, and the
roofline fraction = model-flops-time / max(term).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import ARTIFACTS, emit
from repro.core import TPU_V5E_HBM_BW, TPU_V5E_ICI_LINK_BW, TPU_V5E_PEAK_BF16_FLOPS

ICI_LINKS_PER_CHIP = 4


def load_records(mesh: str = "single") -> List[Dict]:
    d = os.path.join(ARTIFACTS, "dryrun")
    out = []
    if not os.path.isdir(d):
        return out
    for f in sorted(os.listdir(d)):
        if f.endswith(f"__{mesh}.json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def terms(rec: Dict) -> Dict[str, float]:
    chips = rec["chips"]
    compute = rec["hlo_flops"] / (chips * TPU_V5E_PEAK_BF16_FLOPS)
    memory = rec["hlo_bytes"] / (chips * TPU_V5E_HBM_BW)
    collective = rec["collective_bytes_per_chip"] / (
        ICI_LINKS_PER_CHIP * TPU_V5E_ICI_LINK_BW
    )
    dominant = max(("compute", compute), ("memory", memory), ("collective", collective),
                   key=lambda kv: kv[1])
    ideal = rec["model_flops"] / (chips * TPU_V5E_PEAK_BF16_FLOPS)
    bound = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant[0],
        "model_flops_ratio": rec["model_flops"] / max(rec["hlo_flops"], 1.0),
        "roofline_fraction": ideal / max(bound, 1e-30),
    }


def main(mesh: str = "single", smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    recs = load_records(mesh)
    if not recs:
        print(f"# no dry-run artifacts for mesh={mesh}; run repro.launch.dryrun first")
        return
    for rec in recs:
        key = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if "skipped" in rec:
            emit(key, 0.0, "skipped")
            continue
        if "error" in rec:
            emit(key, 0.0, "ERROR")
            continue
        t = terms(rec)
        emit(
            key,
            max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6,
            f"dom={t['dominant']} comp={t['compute_s']:.2e} mem={t['memory_s']:.2e} "
            f"coll={t['collective_s']:.2e} util={t['model_flops_ratio']:.2f} "
            f"roofline_frac={t['roofline_fraction']:.3f}",
        )


if __name__ == "__main__":
    import sys

    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    main(args[0] if args else "single", smoke="--smoke" in sys.argv)
