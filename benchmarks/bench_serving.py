"""Serving front-end sweep: arrival pattern x batch window x strategy.

Two views of the continuous batcher (``repro.serving``):

* **simulated sweep** (in-process, jax-free, deterministic) -- the seeded
  virtual-clock simulator replays one fixed skewed-fingerprint trace under
  every (arrival pattern, coalescing window, strategy) cell and reports
  p50/p99 latency, throughput, realized batch width, and the speedup over
  the sequential per-request baseline on the same trace.  Service times
  come from the advisor's performance model, so rows are bit-reproducible
  and the acceptance number (>= 3x at k=8 on the burst trace) is a stable
  regression pin, not a wall-clock measurement.
* **measured replay** (8-device subprocess) -- the executor drains the
  same coalescing decision through real ``DistributedSpMV.matmat`` calls:
  ``n`` right-hand sides dispatched as width-``k`` fused SpMM batches vs.
  one-by-one, with a numerical parity check between the two paths.  Host
  CPU devices don't reproduce DCI latency, so the measured speedup bounds
  dispatch overhead; the simulated rows carry the topology story.

``main(smoke=True)`` shrinks both sweeps so ``benchmarks/run.py --smoke``
keeps the section alive in tier-1.
"""

from __future__ import annotations

from benchmarks.common import run_with_devices

#: the fixed reference serving workload (shared with benchmarks/run.py's
#: schema-4 ``serving`` record): 4 fingerprint classes on a 2x4 topology,
#: Zipf-skewed popularity, seed 7
TRACE_SEED = 7
N_REQUESTS = 256


def reference_classes():
    import numpy as np

    from repro.comm import PodTopology, random_pattern
    from repro.serving import WorkloadClass

    topo = PodTopology(npods=2, ppn=4)
    out = {}
    for i in range(4):
        pat = random_pattern(
            np.random.default_rng(100 + i), topo, local_size=32, max_elems=4
        )
        out[f"c{i}"] = WorkloadClass.from_pattern(pat, fp=f"c{i}")
    return out


def reference_trace(pattern: str = "burst", n: int = N_REQUESTS):
    from repro.testing import make_trace

    return make_trace(
        TRACE_SEED, n, [f"c{i}" for i in range(4)],
        pattern=pattern, rate=200000.0, skew=1.2, burst=32,
    )


def reference_report(n: int = N_REQUESTS) -> dict:
    """The acceptance-criterion cell: burst trace, k<=8, 1 ms window."""
    from repro.serving import SimConfig, serving_report

    return serving_report(
        reference_classes(), reference_trace("burst", n),
        SimConfig(window=1e-3, max_width=8),
    )


def _sim_rows(smoke: bool) -> None:
    from repro.serving import SimConfig, sequential_baseline, simulate

    classes = reference_classes()
    patterns = ("burst", "poisson") if smoke else ("burst", "poisson", "uniform")
    windows = (0.0, 1e-3) if smoke else (0.0, 5e-4, 1e-3, 2e-3)
    strategies = (None, "two_step") if smoke else (
        None, "standard", "two_step", "three_step", "split"
    )
    n = 128 if smoke else N_REQUESTS
    for pattern in patterns:
        trace = reference_trace(pattern, n)
        seq = sequential_baseline(classes, trace, SimConfig(max_width=8))
        for window in windows:
            for strategy in strategies:
                cfg = SimConfig(window=window, max_width=8, strategy=strategy)
                res = simulate(classes, trace, cfg)
                label = strategy or "auto"
                speedup = (
                    res.throughput / seq.throughput if seq.throughput else 0.0
                )
                print(
                    f"serving/{pattern}/w{int(window * 1e6)}us/{label},"
                    f"{res.p50 * 1e6:.1f},"
                    f"p99_us={res.p99 * 1e6:.1f} "
                    f"thr_rps={res.throughput:.0f} "
                    f"width={res.mean_width:.2f} "
                    f"batches={res.batches} "
                    f"speedup={speedup:.2f}x"
                )


REPLAY_CODE = """
import numpy as np
from repro.comm import PodTopology
from repro.serving import measure_spmv_replay
from repro.sparse import build, thermal_like

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
A = thermal_like(N_SIZE, rng)
sp = build(A, topo, strategy="auto", payload_width=WIDTH, use_pallas=False)
rep = measure_spmv_replay(sp, N_REQ, WIDTH, rng, repeats=REPEATS)
assert rep["parity"] <= 1e-4, rep  # coalesced == sequential results
print(
    f"RESULT,serving/replay/{topo.nranks}r/k{WIDTH},"
    f"{rep['coalesced_s'] * 1e6:.1f},"
    f"seq_us={rep['sequential_s'] * 1e6:.1f} "
    f"speedup={rep['speedup']:.2f}x parity=ok n={N_REQ}"
)
"""


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    _sim_rows(smoke)
    rep = reference_report(128 if smoke else N_REQUESTS)
    co = rep["coalesced"]
    print(
        f"serving/acceptance/burst/k8,{co['p50_s'] * 1e6:.1f},"
        f"p99_us={co['p99_s'] * 1e6:.1f} thr_rps={co['throughput_rps']:.0f} "
        f"speedup={rep['speedup']:.2f}x trace_hash={rep['trace_hash'][:12]}"
    )
    n_size, n_req, width, repeats = (
        (64, 8, 4, 1) if smoke else (256, 32, 8, 3)
    )
    out = run_with_devices(
        f"N_SIZE = {n_size}\nN_REQ = {n_req}\nWIDTH = {width}\n"
        f"REPEATS = {repeats}\n" + REPLAY_CODE,
        devices=8,
    )
    for line in out.splitlines():
        if line.startswith("RESULT,"):
            print(line[len("RESULT,"):])


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
