"""Planner setup-time benchmark: vectorized vs legacy pure-Python planner.

The paper's node-aware strategies pay a *setup* cost to restructure the
exchange (communicator construction, Algorithm 1).  This benchmark measures
that setup cost for every strategy as a function of world size, comparing
the vectorized token-code planner (:mod:`repro.comm.exchange`) against the
pre-vectorization token-list baseline
(:mod:`repro.comm._legacy_planner`), which is retained verbatim for this
purpose.  Both planners emit byte-identical stage programs, so the ratio is
pure implementation speedup.

Also times :meth:`ExchangePattern.fingerprint` -- the plan-cache key --
against the pre-bugfix string-join reference: the byte-hash rewrite is
what keeps per-batch cache lookups (the MoE dispatch path fingerprints
every routing pattern) off the planner's critical path.

Runs in-process (planning needs no devices).  CSV columns:

    name,us_per_call,derived
    planning/<nranks>r/<strategy>,<vectorized us>,legacy_us=... speedup=...
    fingerprint/<nranks>r,<bytes-hash us>,strjoin_us=... speedup=... memo_ns=...
"""

from __future__ import annotations

import dataclasses
import hashlib
import time

import numpy as np

from benchmarks.common import emit
from repro.comm import _legacy_planner as legacy
from repro.comm import exchange
from repro.comm.topology import PodTopology

#: (npods, ppn) sweeps; 32 ranks (4x8) is the acceptance configuration
TOPOLOGIES = [(2, 4), (2, 8), (4, 8), (8, 8)]
LOCAL_SIZE = 32
CAP_BYTES = 2048
STRATEGIES = ("standard", "two_step", "three_step", "split")


def _strjoin_fingerprint(pat) -> str:
    """The pre-bugfix reference: per-need Python string formatting.

    Retained verbatim so the fingerprint column measures the rewrite
    against the exact implementation it replaced (same digest family,
    different canonical serialization -- digests are NOT comparable
    across the two, only the costs are)."""
    h = hashlib.sha1()
    h.update(f"{pat.topo.npods},{pat.topo.ppn},{pat.local_size};".encode())
    for n in sorted(pat.needs, key=lambda x: (x.dst, x.src)):
        h.update(f"{n.dst}<{n.src}:{','.join(map(str, n.idx))};".encode())
    return h.hexdigest()


def _time(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    for npods, ppn in TOPOLOGIES[:1] if smoke else TOPOLOGIES:
        topo = PodTopology(npods=npods, ppn=ppn)
        rng = np.random.default_rng(1)
        pat = exchange.random_pattern(
            rng, topo, local_size=LOCAL_SIZE, p_connect=0.5, max_elems=LOCAL_SIZE // 2
        )
        total_new = total_old = 0.0
        for strat in STRATEGIES:
            t_new = _time(
                lambda: exchange.plan(strat, pat, message_cap_bytes=CAP_BYTES), 3
            )
            t_old = _time(
                lambda: legacy.plan(strat, pat, message_cap_bytes=CAP_BYTES), 1
            )
            total_new += t_new
            total_old += t_old
            emit(
                f"planning/{topo.nranks}r/{strat}",
                t_new * 1e6,
                f"legacy_us={t_old * 1e6:.1f} speedup={t_old / t_new:.1f}x",
            )
        emit(
            f"planning/{topo.nranks}r/all",
            total_new * 1e6,
            f"legacy_us={total_old * 1e6:.1f} speedup={total_old / total_new:.1f}x",
        )

        # fingerprint micro-benchmark: bytes-hash vs string-join on fresh
        # copies (dataclasses.replace defeats the per-instance memo), plus
        # the memoized re-read cost the steady-state cache lookups pay
        iters = 5 if smoke else 20
        t_copy = _time(lambda: dataclasses.replace(pat), iters)
        t_hash = max(
            _time(lambda: dataclasses.replace(pat).fingerprint(), iters) - t_copy,
            1e-9,
        )
        t_join = _time(lambda: _strjoin_fingerprint(pat), iters)
        t_memo = _time(pat.fingerprint, iters)
        emit(
            f"fingerprint/{topo.nranks}r",
            t_hash * 1e6,
            f"strjoin_us={t_join * 1e6:.1f} speedup={t_join / t_hash:.1f}x "
            f"memo_ns={t_memo * 1e9:.0f}",
        )


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
