"""Planner setup-time benchmark: vectorized vs legacy pure-Python planner.

The paper's node-aware strategies pay a *setup* cost to restructure the
exchange (communicator construction, Algorithm 1).  This benchmark measures
that setup cost for every strategy as a function of world size, comparing
the vectorized token-code planner (:mod:`repro.comm.exchange`) against the
pre-vectorization token-list baseline
(:mod:`repro.comm._legacy_planner`), which is retained verbatim for this
purpose.  Both planners emit byte-identical stage programs, so the ratio is
pure implementation speedup.

Runs in-process (planning needs no devices).  CSV columns:

    name,us_per_call,derived
    planning/<nranks>r/<strategy>,<vectorized us>,legacy_us=... speedup=...
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.comm import _legacy_planner as legacy
from repro.comm import exchange
from repro.comm.topology import PodTopology

#: (npods, ppn) sweeps; 32 ranks (4x8) is the acceptance configuration
TOPOLOGIES = [(2, 4), (2, 8), (4, 8), (8, 8)]
LOCAL_SIZE = 32
CAP_BYTES = 2048
STRATEGIES = ("standard", "two_step", "three_step", "split")


def _time(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    for npods, ppn in TOPOLOGIES[:1] if smoke else TOPOLOGIES:
        topo = PodTopology(npods=npods, ppn=ppn)
        rng = np.random.default_rng(1)
        pat = exchange.random_pattern(
            rng, topo, local_size=LOCAL_SIZE, p_connect=0.5, max_elems=LOCAL_SIZE // 2
        )
        total_new = total_old = 0.0
        for strat in STRATEGIES:
            t_new = _time(
                lambda: exchange.plan(strat, pat, message_cap_bytes=CAP_BYTES), 3
            )
            t_old = _time(
                lambda: legacy.plan(strat, pat, message_cap_bytes=CAP_BYTES), 1
            )
            total_new += t_new
            total_old += t_old
            emit(
                f"planning/{topo.nranks}r/{strat}",
                t_new * 1e6,
                f"legacy_us={t_old * 1e6:.1f} speedup={t_old / t_new:.1f}x",
            )
        emit(
            f"planning/{topo.nranks}r/all",
            total_new * 1e6,
            f"legacy_us={total_old * 1e6:.1f} speedup={total_old / total_new:.1f}x",
        )


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
