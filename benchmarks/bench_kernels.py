"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle wall-times.

Interpret-mode timing is a correctness-path sanity check, not TPU
performance; the TPU-side performance statement lives in the roofline
analysis.  Emitted anyway so the harness has one benchmark per kernel.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.spmv_ell import spmm_ell, spmv_ell
from repro.kernels.ssd_scan import ssd_scan_kernel
from repro.models.ssd import ssd_chunked

RNG = np.random.default_rng(0)


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    iters = 3 if smoke else 10
    # spmv
    R, N = (128, 512) if smoke else (512, 2048)
    data = jnp.asarray(RNG.normal(size=(R, 32)), jnp.float32)
    cols = jnp.asarray(RNG.integers(0, N, (R, 32)), jnp.int32)
    x = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    t_k = time_fn(lambda: spmv_ell(data, cols, x, interpret=True).block_until_ready(),
                  iters=iters)
    t_r = time_fn(lambda: ref.spmv_ell(data, cols, x).block_until_ready(), iters=iters)
    emit("kernel/spmv_ell/interpret", t_k, f"ref_us={t_r:.1f}")

    # spmm: same ELL block, multi-vector rhs
    for k in (4,) if smoke else (4, 64):
        X = jnp.asarray(RNG.normal(size=(N, k)), jnp.float32)
        t_k = time_fn(lambda: spmm_ell(data, cols, X, interpret=True).block_until_ready(),
                      iters=iters)
        t_r = time_fn(lambda: ref.spmm_ell(data, cols, X).block_until_ready(),
                      iters=iters)
        emit(f"kernel/spmm_ell/interpret/k{k}", t_k, f"ref_us={t_r:.1f}")

    # flash attention
    S = 64 if smoke else 256
    q = jnp.asarray(RNG.normal(size=(1, S, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, S, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, S, 2, 64)), jnp.float32)
    t_k = time_fn(lambda: flash_attention_kernel(q, k, v, block_q=32 if smoke else 128,
                                                 block_k=32 if smoke else 128,
                                                 interpret=True).block_until_ready(),
                  iters=min(iters, 5))
    t_r = time_fn(lambda: ref.attention(q[0], k[0], v[0]).block_until_ready(),
                  iters=iters)
    emit("kernel/flash_attention/interpret", t_k, f"ref_us={t_r:.1f}")

    # ssd
    S = 128 if smoke else 512
    xs = jnp.asarray(RNG.normal(size=(2, S, 4, 32)), jnp.float32)
    loga = jnp.asarray(-np.abs(RNG.normal(size=(2, S, 4))) * 0.2, jnp.float32)
    b = jnp.asarray(RNG.normal(size=(2, S, 32)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(2, S, 32)), jnp.float32)
    t_k = time_fn(lambda: ssd_scan_kernel(xs, loga, b, c, chunk=64 if smoke else 128,
                                          interpret=True).block_until_ready(),
                  iters=min(iters, 5))
    t_r = time_fn(lambda: ssd_chunked(xs, loga, b, c,
                                      chunk=64 if smoke else 128).block_until_ready(),
                  iters=min(iters, 5))
    emit("kernel/ssd_scan/interpret", t_k, f"xla_chunked_us={t_r:.1f}")


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
