"""Benchmark entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run params     # one section
    PYTHONPATH=src python -m benchmarks.run --smoke    # cheap smoke pass

Sections:
  params      -- paper Tables 2/3/4 (+ least-squares fit demo)
  modeled     -- paper Figure 4.3 (strategy predictions)
  validation  -- paper Figure 4.2 (model vs measured SpMV exchange)
  spmv        -- paper Figure 5.1 (SpMV strategies) + SpMM k-sweep
  overlap     -- split-phase overlap sweep (interior fraction x pods x k)
  solver      -- CG workload sweep (regime x strategy x overlap + amortized
                 model, + fused whole-solve vs host-driven loop)
  wire        -- inter-pod wire codec sweep (codec x strategy x k x pods)
  planning    -- planner setup time vs nranks (vectorized vs legacy)
  kernels     -- Pallas kernel micro-benchmarks
  roofline    -- deliverable (g): terms from the dry-run artifacts
  chaos       -- fault-injection recovery rate + verify-mode overhead
  moe_dispatch -- MoE token dispatch via the exchange stack (strategy x
                  codec x skew vs the all-to-all baseline, + plan cache)
  serving     -- multi-tenant continuous batching (arrival pattern x
                 coalescing window x strategy, p50/p99 + throughput, plus
                 a real fused-SpMM replay with parity)

``--smoke`` runs every requested section in a reduced configuration (fewer
matrices/iterations/devices).  It exists so a tier-1 test can execute the
benchmark scripts end to end and catch rot; absolute numbers from a smoke
pass are meaningless.

Every full *passing* run (all sections, no failures) also writes
``BENCH_exchange.json`` at the repo root (single-section runs and runs
with failed sections leave it untouched) -- a
machine-readable record of per-section wall times plus the wire-byte
counters of a fixed reference exchange (the numbers
``IrregularExchange.wire_bytes`` reports, per strategy x codec) and the
chaos-recovery tally (schema 2: which ladder rung cured each seeded fault
scenario, per strategy x codec) and the MoE-dispatch routing counters
(schema 3: bucketed vs uniform plan bytes per strategy, plus the
simulated plan-cache hit rate for a jittering skewed load) and the
serving record (schema 4: coalesced vs sequential p50/p99/throughput and
the >= 3x acceptance speedup on the fixed skewed burst trace, with the
deterministic simulator's trace hash) and the fused-solve record
(schema 5: host-driven CG loop vs the fused whole-solve
``lax.while_loop`` program on the 8-device reference problem at
``maxiter=120``, with the >= 2x acceptance speedup and the
one-plan-miss / one-compile cache pins) and the serving-chaos record
(schema 6: the traffic simulator draining a seeded burst trace through
the executor recovery ladder under a fault storm -- completion /
recovery / shed / deadline-miss rates, breaker probe outcomes, and the
deterministic trace hash) -- so the perf trajectory is
trackable across PRs; schema pinned by ``tests/test_benchmarks_smoke.py``.
"""

from __future__ import annotations

import json
import os
import sys
import time
import traceback

#: bump when the JSON layout changes (tests pin it)
BENCH_SCHEMA = 6
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_exchange.json")


def _wire_byte_counters() -> dict:
    """Wire-byte counters of a fixed reference exchange, per strategy x codec.

    Plan-level and jax-free: :func:`repro.comm.wire.scaled_wire_bytes` on
    the planned (fused) program is exactly what
    ``IrregularExchange.wire_bytes`` returns for the same arguments, so
    these counters track the executor's reporting without needing
    ``nranks`` devices in this process.
    """
    import numpy as np

    from repro.comm import wire
    from repro.comm.exchange import random_pattern
    from repro.comm.strategies import STRATEGY_NAMES, planned
    from repro.comm.topology import PodTopology

    rng = np.random.default_rng(1234)
    topo = PodTopology(npods=2, ppn=4)
    pat = random_pattern(rng, topo, local_size=16, p_connect=0.5, max_elems=8)
    out: dict = {"pattern_fingerprint": pat.fingerprint(), "codecs": {}}
    for strategy in STRATEGY_NAMES:
        sp = planned(pat, strategy, message_cap_bytes=512)
        per_codec = {}
        for codec in wire.WIRE_CODECS:
            intra, inter = wire.scaled_wire_bytes(sp, codec)
            per_codec[codec] = {"intra_pod_bytes": intra, "inter_pod_bytes": inter}
        out["codecs"][strategy] = per_codec
    return out


def _chaos_counters() -> dict:
    """Chaos-recovery tally on the same fixed reference pattern (schema 2).

    Deterministic and jax-free (numpy ladder): for each strategy x lossy
    codec, which ladder rung (retry/demote/readvise) cured each seeded
    fault scenario.  A regression that breaks a recovery path shows up as
    a diff in this committed record before any test names it.
    """
    from benchmarks.bench_chaos import chaos_outcomes

    from repro.comm import wire
    from repro.comm.strategies import STRATEGY_NAMES

    lossy = tuple(c for c in wire.WIRE_CODECS if c != "none")
    return chaos_outcomes(STRATEGY_NAMES, lossy)


def _moe_dispatch_counters() -> dict:
    """MoE routing counters on a fixed skewed load (schema 3).

    Deterministic, plan-level and jax-free: a jittering skewed routing
    stream through :class:`repro.models.RoutingBucketer` (the simulated
    plan-cache hit rate the tentpole pins at >= 90%), plus the planner's
    wire bytes for the bucketed dispatch pattern next to the uniform
    full-block all-to-all it replaces, per strategy.  The byte gap is the
    traffic the quantized prefix shipping avoids sending at all.
    """
    import numpy as np

    from repro.comm import wire
    from repro.comm.exchange import block_pattern
    from repro.comm.strategies import STRATEGY_NAMES, planned
    from repro.comm.topology import PodTopology
    from repro.models import RoutingBucketer

    topo = PodTopology(npods=2, ppn=4)
    n = topo.nranks
    block = 32
    rng = np.random.default_rng(1234)
    base = np.zeros((n, n), np.int64)
    base[:, :3] = 20  # hot experts on ranks 0..2
    np.fill_diagonal(base, 0)
    buck = RoutingBucketer(topo, block=block, quantum=8)
    bundle = None
    for _ in range(24):
        jitter = rng.integers(-3, 4, size=(n, n)) * (base > 0)
        bundle, _ = buck.step(base + jitter)
    out: dict = {
        "batches": buck.steps,
        "replans": buck.replans,
        "hit_rate": round(buck.hit_rate, 4),
        "strategies": {},
    }
    uniform = block_pattern(topo, block)
    for strategy in STRATEGY_NAMES:
        per = {}
        for name, pat in (("uniform", uniform), ("bucketed", bundle.pattern_dispatch)):
            sp = planned(pat, strategy, message_cap_bytes=512)
            intra, inter = wire.scaled_wire_bytes(sp, "none")
            per[name] = {"intra_pod_bytes": intra, "inter_pod_bytes": inter}
        out["strategies"][strategy] = per
    return out


def _serving_counters() -> dict:
    """Continuous-batching acceptance record (schema 4).

    Deterministic and jax-free: the virtual-clock simulator replays the
    fixed skewed burst trace coalesced (k <= 8) and sequentially, with
    service times from the advisor's model.  ``speedup`` is the acceptance
    criterion (>= 3x); ``trace_hash`` pins that the scheduler made the
    same decisions as the committed record -- any diff here is a scheduler
    behavior change, surfaced before any test names it.
    """
    from benchmarks.bench_serving import reference_report

    rep = reference_report()
    co, sq = rep["coalesced"], rep["sequential"]
    return {
        "speedup": round(rep["speedup"], 4),
        "max_width": rep["max_width"],
        "window_s": rep["window_s"],
        "trace_hash": rep["trace_hash"],
        "coalesced": {k: round(v, 9) for k, v in co.items()},
        "sequential": {k: round(v, 9) for k, v in sq.items()},
    }


#: fused-solve acceptance measurement, run on 8 forced host devices.  The
#: reference system is mildly ill-conditioned (shift=1e-2) so the f32
#: trajectory is deterministic and host/fused agree iteration-for-iteration
#: under the maxiter=120 horizon; tol stays above the f32 residual plateau.
_FUSED_SOLVE_CODE = """
import json, time, numpy as np
from repro.comm import cache_stats, clear_caches
from repro.comm.topology import PodTopology
from repro.solve import DeviceReductions, cg, fused_cg, spd_system
from repro.sparse import DistributedSpMV, partition_csr, thermal_like

topo = PodTopology(npods=2, ppn=4)
rng = np.random.default_rng(7)
A = spd_system(thermal_like(144, rng), shift=1e-2)
part = partition_csr(A, topo)
b = rng.normal(size=(topo.nranks, part.rows_per_rank)).astype(np.float32)
red = DeviceReductions(topo)
op = DistributedSpMV(part, strategy="two_step", use_pallas=False)
tol, maxiter = 1e-5, 120

host = cg(op, b, tol=tol, maxiter=maxiter, reductions=red)  # warm jits
t0 = time.perf_counter()
host = cg(op, b, tol=tol, maxiter=maxiter, reductions=red)
t_host = time.perf_counter() - t0

clear_caches()
# fresh op: the fused solve must plan from scratch (one plan miss)
opf = DistributedSpMV(part, strategy="two_step", use_pallas=False)
fres = fused_cg(opf, b, tol=tol, maxiter=maxiter)  # plan + trace exactly once
s = cache_stats()
assert (s.plan_misses, s.fused_misses, s.fused_hits) == (1, 1, 0), s
t0 = time.perf_counter()
fres = fused_cg(opf, b, tol=tol, maxiter=maxiter)
t_fused = time.perf_counter() - t0
s = cache_stats()
assert s.fused_hits == 1, s
assert (fres.iterations, fres.status) == (host.iterations, host.status), (
    fres.iterations, fres.status, host.iterations, host.status)
assert t_host / t_fused >= 2.0, (t_host, t_fused)  # the acceptance bar

rec = {
    "problem": {"n": A.n, "nnz": A.nnz, "shift": 1e-2, "strategy": "two_step",
                "tol": tol, "maxiter": maxiter, "devices": topo.nranks},
    "host": {"iterations": host.iterations, "status": host.status,
             "total_s": round(t_host, 6),
             "us_per_iter": round(t_host / max(host.iterations, 1) * 1e6, 1)},
    "fused": {"iterations": fres.iterations, "status": fres.status,
              "total_s": round(t_fused, 6),
              "us_per_iter": round(t_fused / max(fres.iterations, 1) * 1e6, 1)},
    "speedup": round(t_host / t_fused, 2),
    "cache": {"plan_misses": s.plan_misses, "fused_misses": s.fused_misses,
              "fused_hits": s.fused_hits},
}
print("FUSED_RECORD," + json.dumps(rec))
"""


def _serving_chaos_record() -> dict:
    """Serving-chaos acceptance record (schema 6).

    Deterministic and jax-free (:func:`benchmarks.bench_chaos.
    serving_chaos`): the traffic simulator drains a seeded burst trace
    through the executor recovery ladder under a fault storm.  The
    committed record pins the completion / recovery / shed /
    deadline-miss rates, the breaker probe outcomes, and the trace hash,
    so a regression in fault handling shows up as a diff before any test
    names it.
    """
    from benchmarks.bench_chaos import serving_chaos

    return serving_chaos()


def _fused_solve_record() -> dict:
    """Fused whole-solve acceptance record (schema 5).

    Unlike the other counters this one needs devices: it times the
    host-driven CG loop against the fused ``lax.while_loop`` program
    (:func:`repro.solve.fused_cg`) on the 8-device smoke reference
    problem at ``maxiter=120``.  ``speedup`` is the acceptance criterion
    (>= 2x, asserted in the subprocess so a regression blocks the
    write); the cache counters pin the exactly-one-plan-miss /
    one-fused-compile contract.
    """
    from benchmarks.common import run_with_devices

    out = run_with_devices(_FUSED_SOLVE_CODE, devices=8)
    line = next(l for l in out.splitlines() if l.startswith("FUSED_RECORD,"))
    return json.loads(line[len("FUSED_RECORD,"):])


def maybe_write_record(report: dict, wanted, section_names, path: str = BENCH_JSON,
                       fused_record: "dict | None" = None) -> bool:
    """Write the tracked record iff this was a FULL, PASSING run.

    The record's contract (``tests/test_benchmarks_smoke.py``) is
    ``failures == []`` with every section ok, so a broken environment must
    never clobber the healthy committed trajectory file; likewise a
    single-section iteration must not replace the cross-PR record (and only
    a full run pays for the wire counters it would otherwise discard).

    ``fused_record`` is a test seam: the fused-solve measurement spawns an
    8-device subprocess, so hermetic unit tests inject a synthetic record
    instead of paying for (and depending on) the real one.
    """
    failures = report["failures"]
    not_ok = [n for n, s in report["sections"].items() if not s["ok"]]
    if failures or not_ok:
        print(f"\n### sections failed ({failures or not_ok}); {path} left untouched")
        return False
    if set(wanted) != set(section_names):
        print(f"\n### partial run ({wanted}); {path} left untouched")
        return False
    report["wire_bytes"] = _wire_byte_counters()
    report["chaos_recovery"] = _chaos_counters()
    report["moe_dispatch"] = _moe_dispatch_counters()
    report["serving"] = _serving_counters()
    report["fused_solve"] = _fused_solve_record() if fused_record is None else fused_record
    report["serving_chaos"] = _serving_chaos_record()
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"\n### wrote {path}")
    return True


def main() -> None:
    from benchmarks import (
        bench_chaos,
        bench_kernels,
        bench_model_validation,
        bench_modeled_performance,
        bench_moe_dispatch,
        bench_overlap,
        bench_params,
        bench_planning,
        bench_roofline,
        bench_serving,
        bench_solver,
        bench_spmv,
        bench_wire,
    )

    sections = {
        "params": bench_params.main,
        "modeled": bench_modeled_performance.main,
        "validation": bench_model_validation.main,
        "spmv": bench_spmv.main,
        "overlap": bench_overlap.main,
        "solver": bench_solver.main,
        "wire": bench_wire.main,
        "planning": bench_planning.main,
        "kernels": bench_kernels.main,
        "roofline": bench_roofline.main,
        "chaos": bench_chaos.main,
        "moe_dispatch": bench_moe_dispatch.main,
        "serving": bench_serving.main,
    }
    args = sys.argv[1:]
    smoke = "--smoke" in args
    wanted = [a for a in args if not a.startswith("--")] or list(sections)
    failures = []
    report = {
        "schema": BENCH_SCHEMA,
        "smoke": smoke,
        "sections": {},
    }
    for name in wanted:
        print(f"\n### section: {name}")
        t0 = time.perf_counter()
        try:
            sections[name](smoke=smoke)
            ok = True
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            ok = False
            traceback.print_exc()
            print(f"### section {name} FAILED: {e}")
        report["sections"][name] = {
            "elapsed_s": round(time.perf_counter() - t0, 3),
            "ok": ok,
        }
    report["failures"] = failures
    maybe_write_record(report, wanted, sections)
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
