"""Benchmark entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run params     # one section
    PYTHONPATH=src python -m benchmarks.run --smoke    # cheap smoke pass

Sections:
  params      -- paper Tables 2/3/4 (+ least-squares fit demo)
  modeled     -- paper Figure 4.3 (strategy predictions)
  validation  -- paper Figure 4.2 (model vs measured SpMV exchange)
  spmv        -- paper Figure 5.1 (SpMV strategies) + SpMM k-sweep
  overlap     -- split-phase overlap sweep (interior fraction x pods x k)
  solver      -- CG workload sweep (regime x strategy x overlap + amortized model)
  planning    -- planner setup time vs nranks (vectorized vs legacy)
  kernels     -- Pallas kernel micro-benchmarks
  roofline    -- deliverable (g): terms from the dry-run artifacts

``--smoke`` runs every requested section in a reduced configuration (fewer
matrices/iterations/devices).  It exists so a tier-1 test can execute the
benchmark scripts end to end and catch rot; absolute numbers from a smoke
pass are meaningless.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_kernels,
        bench_model_validation,
        bench_modeled_performance,
        bench_overlap,
        bench_params,
        bench_planning,
        bench_roofline,
        bench_solver,
        bench_spmv,
    )

    sections = {
        "params": bench_params.main,
        "modeled": bench_modeled_performance.main,
        "validation": bench_model_validation.main,
        "spmv": bench_spmv.main,
        "overlap": bench_overlap.main,
        "solver": bench_solver.main,
        "planning": bench_planning.main,
        "kernels": bench_kernels.main,
        "roofline": bench_roofline.main,
    }
    args = sys.argv[1:]
    smoke = "--smoke" in args
    wanted = [a for a in args if not a.startswith("--")] or list(sections)
    failures = []
    for name in wanted:
        print(f"\n### section: {name}")
        try:
            sections[name](smoke=smoke)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"### section {name} FAILED: {e}")
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
