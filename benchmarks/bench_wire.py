"""Wire-codec sweep: codec x strategy x payload width x pods.

Two views of the same lever (ISSUE 5's acceptance numbers):

* **byte counters** (in-process, deterministic) -- for each (pods,
  strategy, codec) the planner's padding-inclusive inter-pod wire bytes
  next to the codec-scaled number `IrregularExchange.wire_bytes` reports.
  ``reduction=`` is the acceptance metric: >= 2x for the 16-bit wires on
  f32 payloads (the >= 1.8x bar with margin), ~3.9x for int8 (the float32
  scales cost a little back).
* **model crossovers** -- ``advise(..., wire="auto")`` per (pods, k):
  which (strategy, transport, codec) the overlap-unaware model picks as k
  widens the payload.  Latency-bound small-k points keep ``none``; byte
  bound points flip to a codec.
* **measured execution** (device subprocess) -- median wall time per
  exchange for each codec on host devices, with parity checked before
  timing: ``codec="none"`` must be bitwise identical to the codec-free
  executor, lossy codecs must stay inside their pinned error bound
  (``repro.comm.wire.REL_ERROR_BOUND``) and match the numpy oracle bit for
  bit.  Host CPU collectives don't traverse a real DCI, so the timings
  bound codec overhead rather than showing the bandwidth win -- the byte
  counters are the reproduction target.

``main(smoke=True)`` shrinks the sweep (2 pods, k <= 4, fewer iters) so
``benchmarks/run.py --smoke`` keeps this section alive in tier-1.
"""

from __future__ import annotations

from benchmarks.common import run_with_devices

CODE = """
import time, numpy as np
from repro.comm import wire
from repro.comm.exchange import execute_numpy, random_pattern
from repro.comm.strategies import IrregularExchange, STRATEGY_NAMES
from repro.comm.topology import PodTopology

def med_us(fn, iters):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter(); fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts)//2] * 1e6

rng = np.random.default_rng(42)
iters = 3 if SMOKE else 7
topo = PodTopology(npods=NPODS, ppn=4)
pat = random_pattern(rng, topo, local_size=8, p_connect=0.6, max_elems=5)
local = rng.normal(size=(topo.nranks, 8)).astype(np.float32)
ref = pat.reference(local)
H = pat.max_recv_size()
for strat in STRATEGY_NAMES:
    ex0 = IrregularExchange(pat, strat, message_cap_bytes=64)
    base = np.asarray(ex0(local))
    _, inter0 = ex0.wire_bytes
    for codec in wire.WIRE_CODECS:
        ex = IrregularExchange(pat, strat, message_cap_bytes=64, wire=codec)
        out = np.asarray(ex(local))
        if codec == "none":
            np.testing.assert_array_equal(out, base)  # bitwise acceptance
        else:
            np.testing.assert_array_equal(
                out, execute_numpy(ex.plan, local, wire=codec)
            )
            bound = wire.REL_ERROR_BOUND[codec] * np.abs(local).max()
            assert np.abs(out[:, :H] - ref[:, :H]).max() <= bound * (1 + 1e-6)
        us = med_us(lambda: ex(local).block_until_ready(), iters)
        _, inter = ex.wire_bytes
        red = inter0 / inter if inter else 1.0
        print(
            f"RESULT,wire/{NPODS}p/{strat}/{codec},{us:.1f},"
            f"inter_none_B={inter0} inter_wire_B={inter} "
            f"reduction={red:.2f}x parity=ok"
        )
"""


#: model-crossover scenarios: figure 4.3 generator args chosen so the k
#: sweep exposes both a none-wins regime and a codec-wins regime (the same
#: physics pinned in tests/test_advisor_regression.py::WIRE_PINS)
MODEL_SCENARIOS = [
    ("tiny", "tpu_v5e_pod", (256, 32, 4)),
    ("mid", "lassen", (2048, 256, 16)),
    ("big", "tpu_v5e_pod", (65536, 32, 4)),
]


def _emit_model_rows(ks) -> None:
    from repro.core import advise, figure43_pattern

    for name, machine, scenario in MODEL_SCENARIOS:
        pat = figure43_pattern(*scenario)
        for k in ks:
            adv = advise(pat, machine=machine, payload_width=k, wire="auto")
            best_none = min(
                (r for r in adv.ranked if r.wire == "none"),
                key=lambda r: r.predicted_time,
            )
            win = best_none.predicted_time / adv.best.predicted_time
            print(
                f"wiremodel/{name}/k{k},0.000,"
                f"advised={adv.best.key} best_none={best_none.key} "
                f"model_win={win:.2f}x"
            )


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    pods = (2,) if smoke else (2, 4)
    _emit_model_rows((1, 64) if smoke else (1, 8, 64))
    for npods in pods:
        out = run_with_devices(
            f"SMOKE = {smoke!r}\nNPODS = {npods}\n" + CODE,
            devices=npods * 4,
        )
        for line in out.splitlines():
            if line.startswith("RESULT,"):
                print(line[len("RESULT,"):])


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
