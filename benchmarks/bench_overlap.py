"""Split-phase overlap sweep: interior fraction x pods x payload width.

For a synthetic block-stencil matrix whose *boundary fraction* (rows that
read halo data) is an exact knob, this section compares the barrier pipeline
(``exchange -> compute``) against the split-phase pipeline
(``start -> interior tiles -> finish -> boundary tiles``,
``DistributedSpMV(overlap=True)``) for each (pods, interior fraction, k)
point:

* ``barrier_us`` / ``overlap_us`` -- measured wall time per step on host
  devices.  Host CPU collectives complete synchronously, so the measured
  numbers bound the overhead of the split pipeline (two phase programs plus
  the merge) rather than showing the latency hiding itself;
* ``parity=ok`` -- the overlapped result was verified bitwise-equal to the
  barrier result before timing (the acceptance property);
* ``model_barrier_s`` / ``model_overlap_s`` / ``advised`` -- the
  overlap-aware model terms (paper-style prediction:
  ``T = T_local + max(T_inter, T_interior) + T_boundary``) evaluated with a
  compute profile *at the scale of the modeled communication* (interior
  compute = best barrier comm time, split by the interior tile fraction), so
  the sweep exposes the reproduction target: the modeled overlap win grows
  with the interior fraction and vanishes at fraction 0.

``main(smoke=True)`` shrinks the sweep (one topology, 8 devices, k <= 4) so
``benchmarks/run.py --smoke`` keeps this section alive in tier-1.
"""

from __future__ import annotations

from benchmarks.common import run_with_devices

CODE = """
import time, numpy as np
from repro.comm.topology import PodTopology
from repro.core import ComputeProfile, advise
from repro.sparse import build
from repro.sparse.matrices import _from_coo

def med_us(fn, iters):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter(); fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts)//2] * 1e6

def halo_frac_matrix(nranks, L, boundary_frac, rng):
    '''Block stencil with an exact boundary-row knob: every row has a
    diagonal + an in-block neighbour; the first round(boundary_frac * L)
    rows of each rank block also read one element of the previous block.'''
    n = nranks * L
    nb = int(round(boundary_frac * L))
    rows_l, cols_l = [], []
    for r in range(nranks):
        base = r * L
        idx = base + np.arange(L)
        rows_l += [idx, idx[:-1]]
        cols_l += [idx, idx[:-1] + 1]
        if nb and nranks > 1:
            src = (r - 1) % nranks
            rows_l.append(base + np.arange(nb))
            cols_l.append(src * L + np.arange(nb))
    rows = np.concatenate(rows_l); cols = np.concatenate(cols_l)
    return _from_coo(n, rows, cols, rng.normal(size=rows.size))

rng = np.random.default_rng(0)
L = 256 if SMOKE else 512
iters = 3 if SMOKE else 5
pods = (2,) if SMOKE else (2, 4)
fracs = (0.25, 0.75) if SMOKE else (0.125, 0.5, 0.875)
ks = (1, 4) if SMOKE else (1, 8)
for npods in pods:
    topo = PodTopology(npods=npods, ppn=4)
    for frac in fracs:
        A = halo_frac_matrix(topo.nranks, L, 1.0 - frac, rng)
        sp = build(A, topo, strategy="two_step", use_pallas=False)
        ov = build(A, topo, strategy="two_step", use_pallas=False, overlap=True)
        for k in ks:
            V = rng.normal(size=(A.n, k)).astype(np.float32)
            Vr = V.reshape(topo.nranks, L, k)
            vr = Vr[:, :, 0]
            bar = np.asarray(sp(vr) if k == 1 else sp.matmat(Vr))
            ovl = np.asarray(ov(vr) if k == 1 else ov.matmat(Vr))
            # ulp-level slack: the jnp-oracle barrier program fuses both
            # reductions under one jit (the pallas path is bitwise equal;
            # see tests/test_overlap.py)
            np.testing.assert_allclose(ovl, bar, rtol=1e-6, atol=1e-6)
            b_us = med_us(lambda: (sp(vr) if k == 1 else sp.matmat(Vr)).block_until_ready(), iters)
            o_us = med_us(lambda: (ov(vr) if k == 1 else ov.matmat(Vr)).block_until_ready(), iters)
            # the tile granularity actually executed: SpMV tiles at k=1,
            # SpMM tiles otherwise
            itf = (ov.row_split if k == 1 else ov.row_split_mm).interior_tile_fraction
            # overlap-aware model at comm scale: interior compute sized to
            # the best barrier comm time, split by the interior tile fraction
            pat = sp.partition.pattern.to_comm_pattern()
            t_comm = advise(pat, machine="tpu_v5e_pod", payload_width=k).best.predicted_time
            prof = ComputeProfile.from_fraction(t_comm, itf)
            adv = advise(pat, machine="tpu_v5e_pod", payload_width=k, compute=prof)
            best_bar = min(r.predicted_time for r in adv.ranked if not r.overlap)
            best_ovl = min(r.predicted_time for r in adv.ranked if r.overlap)
            win = best_bar / best_ovl if best_ovl > 0 else 1.0
            print(
                f"RESULT,overlap/{npods}p/f{frac:g}/k{k},{o_us:.1f},"
                f"barrier_us={b_us:.1f} overlap_us={o_us:.1f} "
                f"int_tile_frac={itf:.3f} "
                f"model_barrier_s={best_bar:.3e} model_overlap_s={best_ovl:.3e} "
                f"model_win={win:.2f}x "
                f"advised={adv.best.key} parity=ok"
            )
"""


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    devices = 8 if smoke else 16
    out = run_with_devices(f"SMOKE = {smoke!r}\n" + CODE, devices=devices)
    for line in out.splitlines():
        if line.startswith("RESULT,"):
            print(line[len("RESULT,"):])


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
