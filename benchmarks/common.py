"""Shared benchmark helpers: timing, CSV output, subprocess devices."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
from typing import Callable, List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
ARTIFACTS = os.path.join(REPO, "artifacts")


def time_fn(fn: Callable, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def run_with_devices(code: str, devices: int, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-3000:]}")
    return proc.stdout
