"""Paper Figure 5.1: SpMV communication benchmark per strategy per matrix,
plus the multi-vector SpMM k-sweep.

Runs the distributed SpMV exchange for each synthetic SuiteSparse-analogue
matrix under every strategy on an 8-host-device mesh (2 pods x 4), timing the
exchange and reporting wire bytes (intra/inter-pod) plus the advisor's pick.
Absolute times are CPU-host numbers; the *ranking* and byte counts are the
reproduction target (DESIGN.md section 10).

Per strategy the CSV also reports the setup path PR 1 optimizes:

* ``plan_ms``      -- cold planning+fusion wall time (plan cache cleared),
* ``replan_ms``    -- the same construction again (plan/compile cache hit),
* ``fused_us`` / ``unfused_us`` -- median exchange time with and without
  the stage-fusion rewrites.

The k-sweep (``kswp`` rows) compares, for k in {1, 4, 16, 64} on a 32-rank
(8 pods x 4) stencil pattern, the three multi-vector paths:

* ``looped_us`` -- k independent exchanges + k local SpMVs
  (:meth:`DistributedSpMV.matmat_looped`, the pre-SpMM behaviour),
* ``fused_us``  -- ONE batched exchange + one blocked-ELL SpMM
  (:meth:`DistributedSpMV.matmat`),
* ``oracle_us`` -- the sequential numpy ``CSRMatrix.spmm`` oracle, which the
  fused output is verified against before timing.

``main(smoke=True)`` shrinks both sections (one matrix, 8 devices, k <= 4)
so ``benchmarks/run.py --smoke`` can exercise the script in tier-1 tests.
"""

from __future__ import annotations

from benchmarks.common import run_with_devices

CODE = """
import time, numpy as np
from repro.comm import strategies as comm_strategies
from repro.comm.topology import PodTopology
from repro.sparse import audikw_like, thermal_like, random_block, build

def med_us(fn, iters=10):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter(); fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts)//2] * 1e6

ITERS = 3 if SMOKE else 10
rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
mats = {
    "audikw_like": audikw_like(128, rng),
    "thermal_like": thermal_like(256, rng),
    "random_block": random_block(128, 0.05, rng),
}
if SMOKE:
    mats = {"thermal_like": mats["thermal_like"]}
strategies = ("standard", "two_step") if SMOKE else (
    "standard", "two_step", "three_step", "split")
for name, A in mats.items():
    v = rng.normal(size=(A.n,)).astype(np.float32)
    vr = v.reshape(topo.nranks, -1)
    for strat in strategies:
        comm_strategies.clear_caches()
        t0 = time.perf_counter()
        sp = build(A, topo, strategy=strat, use_pallas=False)
        plan_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        build(A, topo, strategy=strat, use_pallas=False)
        replan_ms = (time.perf_counter() - t0) * 1e3
        out = sp(vr); out.block_until_ready()
        fused_us = med_us(lambda: sp.exchange(vr).block_until_ready(), ITERS)
        spu = build(A, topo, strategy=strat, use_pallas=False, fuse_program=False)
        spu(vr).block_until_ready()
        unfused_us = med_us(lambda: spu.exchange(vr).block_until_ready(), ITERS)
        wi, we = sp.wire_bytes
        print(
            f"RESULT,fig5.1/{name}/{strat},{fused_us:.1f},"
            f"intra={wi}B inter={we}B plan_ms={plan_ms:.1f} "
            f"replan_ms={replan_ms:.1f} fused_us={fused_us:.1f} "
            f"unfused_us={unfused_us:.1f}"
        )
    adv = build(A, topo, strategy="auto", use_pallas=False)
    print(f"RESULT,fig5.1/{name}/advisor,0.0,chose={adv.strategy}")
"""

KSWEEP_CODE = """
import time, numpy as np
from repro.comm.topology import PodTopology
from repro.core import advise
from repro.sparse import thermal_like, build

def med_us(fn, iters):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter(); fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts)//2] * 1e6

rng = np.random.default_rng(0)
topo = PodTopology(npods=2 if SMOKE else 8, ppn=4)
A = thermal_like(256 if SMOKE else 1024, rng)
ks = (1, 4) if SMOKE else (1, 4, 16, 64)
iters = 3 if SMOKE else 5
sp = build(A, topo, strategy="two_step", use_pallas=False)
for k in ks:
    V = rng.normal(size=(A.n, k)).astype(np.float32)
    Vr = V.reshape(topo.nranks, -1, k)
    out = np.asarray(sp.matmat(Vr)).reshape(A.n, k)
    want = A.spmm(V)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
    fused_us = med_us(lambda: sp.matmat(Vr).block_until_ready(), iters)
    looped_us = med_us(lambda: sp.matmat_looped(Vr).block_until_ready(), iters)
    t0 = time.perf_counter(); A.spmm(V)
    oracle_us = (time.perf_counter() - t0) * 1e6
    adv = advise(sp.partition.pattern.to_comm_pattern(), machine="tpu_v5e_pod",
                 payload_width=k)
    print(
        f"RESULT,kswp/{topo.nranks}r/k{k},{fused_us:.1f},"
        f"looped_us={looped_us:.1f} fused_us={fused_us:.1f} "
        f"oracle_us={oracle_us:.1f} speedup={looped_us/fused_us:.2f}x "
        f"advised={adv.best.key} parity=ok"
    )
"""


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    prefix = f"SMOKE = {smoke!r}\n"
    for code, devices in ((CODE, 8), (KSWEEP_CODE, 8 if smoke else 32)):
        out = run_with_devices(prefix + code, devices=devices)
        for line in out.splitlines():
            if line.startswith("RESULT,"):
                print(line[len("RESULT,"):])


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
