"""Paper Figure 5.1: SpMV communication benchmark per strategy per matrix.

Runs the distributed SpMV exchange for each synthetic SuiteSparse-analogue
matrix under every strategy on an 8-host-device mesh (2 pods x 4), timing the
exchange and reporting wire bytes (intra/inter-pod) plus the advisor's pick.
Absolute times are CPU-host numbers; the *ranking* and byte counts are the
reproduction target (DESIGN.md section 10).

Per strategy the CSV also reports the setup path this PR optimizes:

* ``plan_ms``      -- cold planning+fusion wall time (plan cache cleared),
* ``replan_ms``    -- the same construction again (plan/compile cache hit),
* ``fused_us`` / ``unfused_us`` -- median exchange time with and without
  the stage-fusion rewrites.
"""

from __future__ import annotations

from benchmarks.common import emit, run_with_devices

CODE = """
import time, numpy as np
from repro.comm import strategies as comm_strategies
from repro.comm.topology import PodTopology
from repro.sparse import audikw_like, thermal_like, random_block, build

def med_us(fn, iters=10):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter(); fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts)//2] * 1e6

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
mats = {
    "audikw_like": audikw_like(128, rng),
    "thermal_like": thermal_like(256, rng),
    "random_block": random_block(128, 0.05, rng),
}
for name, A in mats.items():
    v = rng.normal(size=(A.n,)).astype(np.float32)
    vr = v.reshape(topo.nranks, -1)
    for strat in ("standard", "two_step", "three_step", "split"):
        comm_strategies.clear_caches()
        t0 = time.perf_counter()
        sp = build(A, topo, strategy=strat, use_pallas=False)
        plan_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        build(A, topo, strategy=strat, use_pallas=False)
        replan_ms = (time.perf_counter() - t0) * 1e3
        out = sp(vr); out.block_until_ready()
        fused_us = med_us(lambda: sp.exchange(vr).block_until_ready())
        spu = build(A, topo, strategy=strat, use_pallas=False, fuse_program=False)
        spu(vr).block_until_ready()
        unfused_us = med_us(lambda: spu.exchange(vr).block_until_ready())
        wi, we = sp.wire_bytes
        print(
            f"RESULT,fig5.1/{name}/{strat},{fused_us:.1f},"
            f"intra={wi}B inter={we}B plan_ms={plan_ms:.1f} "
            f"replan_ms={replan_ms:.1f} fused_us={fused_us:.1f} "
            f"unfused_us={unfused_us:.1f}"
        )
    adv = build(A, topo, strategy="auto", use_pallas=False)
    print(f"RESULT,fig5.1/{name}/advisor,0.0,chose={adv.strategy}")
"""


def main() -> None:
    print("name,us_per_call,derived")
    out = run_with_devices(CODE, devices=8)
    for line in out.splitlines():
        if line.startswith("RESULT,"):
            print(line[len("RESULT,"):])


if __name__ == "__main__":
    main()
