"""Paper Figure 4.3: modeled strategy performance across scenarios.

For 32/256 inter-node messages x 4/16 destination nodes x message sizes
2^4..2^20 B, evaluates every Table 6 composite on the Lassen registry (exact
reproduction of the paper's prediction curves) and on the TPU registry (the
adapted machine), including the 25%-duplicate-data variants.  Emits the
winning strategy per scenario -- the paper's headline observations are
asserted in tests/test_perfmodel.py.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import Strategy, Transport, advise, figure43_pattern


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    for machine in ("lassen", "tpu_v5e_pod"):
        for nmsgs in (32, 256):
            for nodes in (4, 16):
                for dup in ((0.0,) if smoke else (0.0, 0.25)):
                    wins = {}
                    for logs in range(4, 13 if smoke else 21):
                        size = 2**logs
                        pat = figure43_pattern(size, nmsgs, nodes)
                        adv = advise(pat, machine=machine, duplicate_fraction=dup)
                        best = adv.best
                        emit(
                            f"fig4.3/{machine}/m{nmsgs}/n{nodes}/dup{int(dup*100)}/s{size}",
                            best.predicted_time * 1e6,
                            best.key,
                        )
                        wins[best.key] = wins.get(best.key, 0) + 1
                    top = max(wins, key=wins.get)
                    emit(
                        f"fig4.3/{machine}/m{nmsgs}/n{nodes}/dup{int(dup*100)}/winner",
                        0.0,
                        f"{top}({wins[top]}of{sum(wins.values())})",
                    )
        # payload-width sweep: how the advised winner moves as the batched
        # column count k scales the byte terms under fixed message counts
        pat = figure43_pattern(2048, 256, 16)
        for k in (1, 4, 16, 64):
            best = advise(pat, machine=machine, payload_width=k).best
            emit(
                f"fig4.3/{machine}/payload_width/k{k}",
                best.predicted_time * 1e6,
                best.key,
            )


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
