"""Paper Figure 4.2: model predictions vs measured SpMV communication.

For the audikw_1-analogue matrix, compares each strategy's *predicted* time
(Table 6 composites on the TPU registry, byte counts from the actual
exchange plan) against the *measured* exchange time on the 8-device host
mesh.  The paper's validation criterion -- node-aware model predictions form
a tight upper bound of the same order of magnitude, standard's prediction is
loose -- is what we report (absolute CPU-host numbers differ from TPU).
"""

from __future__ import annotations

from benchmarks.common import emit, run_with_devices

CODE = """
import time, numpy as np
from repro.comm.topology import PodTopology
from repro.core import advise, Strategy, Transport
from repro.sparse import audikw_like, build, partition_csr

rng = np.random.default_rng(0)
topo = PodTopology(npods=2, ppn=4)
A = audikw_like(64 if SMOKE else 128, rng)
part = partition_csr(A, topo)
adv = advise(part.pattern.to_comm_pattern(), machine="tpu_v5e_pod", include_two_step_one=False)
pred = {
    "standard": adv.time_for(Strategy.STANDARD, Transport.STAGED_HOST),
    "two_step": adv.time_for(Strategy.TWO_STEP, Transport.STAGED_HOST),
    "three_step": adv.time_for(Strategy.THREE_STEP, Transport.STAGED_HOST),
    "split": adv.time_for(Strategy.SPLIT_MD, Transport.STAGED_HOST),
}
v = rng.normal(size=(A.n,)).astype(np.float32).reshape(topo.nranks, -1)
for strat in pred:
    sp = build(A, topo, strategy=strat, use_pallas=False)
    sp.exchange(v).block_until_ready()
    ts = []
    for _ in range(3 if SMOKE else 10):
        t0 = time.perf_counter(); sp.exchange(v).block_until_ready()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    meas = ts[len(ts)//2]
    print(f"RESULT,fig4.2/audikw_like/{strat},{meas*1e6:.1f},predicted_tpu_us={pred[strat]*1e6:.2f}")
"""


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    out = run_with_devices(f"SMOKE = {smoke!r}\n" + CODE, devices=8)
    for line in out.splitlines():
        if line.startswith("RESULT,"):
            print(line[len("RESULT,"):])


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
