"""MoE token-dispatch sweep: strategy x wire codec x routing skew.

The tentpole claim (ISSUE 7): routing MoE expert dispatch through the
node-aware exchange stack is a drop-in for the flat ``all_to_all``
baseline -- bitwise identical outputs -- while exposing the paper's
strategy/codec levers on the dispatch hop.  Three views:

* **measured execution** (8-device subprocess) -- median wall time per
  MoE layer call for the baseline all-to-all column next to every
  (strategy, codec) pair, on uniform and skewed router inputs.  Parity is
  checked before timing: ``codec="none"`` must match the baseline
  bitwise, lossy codecs must stay within their error envelope.  Host CPU
  collectives don't traverse a real DCI, so timings bound dispatch-path
  overhead; the plan-level byte counters in ``benchmarks/run.py`` carry
  the bandwidth story.
* **plan-cache behaviour** -- a jittering skewed load stream through
  ``MoEDispatcher``: capacity-slot quantization plus high-water
  bucketing must hold the exchange-cache hit rate at >= 90% (the
  acceptance number, pinned again in tier-1).
* **routing economics** (in-process, jax-free) -- ``dispatch_stats``
  Table-7 statistics for uniform vs skewed quantized width matrices, the
  numbers the advisor ranks strategies with.

``main(smoke=True)`` shrinks the sweep (2 strategies, 2 codecs, fewer
iters) so ``benchmarks/run.py --smoke`` keeps this section alive in
tier-1.
"""

from __future__ import annotations

from benchmarks.common import run_with_devices

CODE = """
import time, numpy as np, jax, jax.numpy as jnp
from repro.comm import PodTopology, make_exchange_mesh, cache_stats, clear_caches
from repro.configs.base import MoEConfig
from repro.models import MoEDispatcher
from repro.models.moe import MoELayer

def med_us(fn, iters):
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter(); fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts)//2] * 1e6

topo = PodTopology(npods=2, ppn=4)
n = topo.nranks
mesh = make_exchange_mesh(topo)
cfg = MoEConfig(n_experts=16, top_k=2, d_ff_expert=32)
M = 16
B, S = 8, 16
iters = 2 if SMOKE else 5
rng = np.random.default_rng(0)
params = {
    "router": jnp.asarray(rng.standard_normal((M, cfg.n_experts)) * 2.0, jnp.float32),
    "w_in": jnp.asarray(rng.standard_normal((cfg.n_experts, M, cfg.d_ff_expert)) * 0.1, jnp.float32),
    "w_gate": jnp.asarray(rng.standard_normal((cfg.n_experts, M, cfg.d_ff_expert)) * 0.1, jnp.float32),
    "w_out": jnp.asarray(rng.standard_normal((cfg.n_experts, cfg.d_ff_expert, M)) * 0.1, jnp.float32),
}
inputs = {
    "uniform": jnp.asarray(rng.standard_normal((B, S, M)), jnp.float32),
    # a constant bias skews the router's top-k towards a few hot experts
    "skewed": jnp.asarray(
        rng.standard_normal((B, S, M)) * 0.3 + rng.standard_normal(M), jnp.float32
    ),
}
base = MoELayer(M, cfg, ep_axis=("pod", "local"))
# the eager layer re-traces its shard_map every call; jit once so the
# baseline column measures execution, not repeated tracing
base_jit = jax.jit(lambda p, xx: base(p, xx, mesh))
for skew, x in inputs.items():
    y0 = np.asarray(base(params, x, mesh))
    base_us = med_us(lambda: jax.block_until_ready(base_jit(params, x)), iters)
    print(f"RESULT,moe/{n}r/{skew}/all_to_all/none,{base_us:.1f},baseline parity=ok")
    for strat in STRATEGIES:
        for codec in CODECS:
            layer = MoELayer(M, cfg, dispatch="exchange", strategy=strat, wire=codec)
            y1 = np.asarray(layer(params, x, mesh))
            if codec == "none":
                assert np.array_equal(y0, y1), (skew, strat)  # bitwise acceptance
            else:
                assert np.allclose(y0, y1, rtol=0.05, atol=0.05), (skew, strat, codec)
            us = med_us(lambda: jax.block_until_ready(layer(params, x, mesh)), iters)
            print(
                f"RESULT,moe/{n}r/{skew}/{strat}/{codec},{us:.1f},"
                f"base_us={base_us:.1f} overhead={us/base_us:.2f}x parity=ok"
            )

# plan-cache behaviour: stationary skewed traffic with jitter must stay
# >= 90% exchange-cache hits (bucketing + quantization absorb the noise)
block = 32
clear_caches()
disp = MoEDispatcher(topo, strategy="two_step", quantum=8)
basec = np.zeros((n, n), np.int64)
basec[:, :3] = 20
np.fill_diagonal(basec, 0)
for _ in range(N_BATCH):
    jitter = rng.integers(-3, 4, size=(n, n)) * (basec > 0)
    disp.step(basec + jitter, block)
st = cache_stats()
buck = disp.bucketer(block)
ex_rate = st.exchange_hits / max(st.exchange_hits + st.exchange_misses, 1)
print(
    f"RESULT,moeplan/{n}r/skewed,0.000,"
    f"batches={N_BATCH} replans={buck.replans} bucket_hit_rate={buck.hit_rate:.3f} "
    f"exchange_hit_rate={ex_rate:.3f} plan_misses={st.plan_misses}"
)
"""


def _emit_stats_rows() -> None:
    """Jax-free Table-7 routing economics for uniform vs skewed widths."""
    import numpy as np

    from repro.comm import PodTopology, quantize_widths
    from repro.core import dispatch_stats

    topo = PodTopology(npods=2, ppn=4)
    n = topo.nranks
    block = 32
    rng = np.random.default_rng(0)
    uniform = np.full((n, n), 20, np.int64)
    skewed = np.zeros((n, n), np.int64)
    skewed[:, :3] = 20
    skewed += rng.integers(0, 3, size=(n, n))
    for name, counts in (("uniform", uniform), ("skewed", skewed)):
        w = quantize_widths(counts, 8, block)
        np.fill_diagonal(w, 0)
        s = dispatch_stats(w, topo.ppn, elem_bytes=4)
        print(
            f"moestats/{n}r/{name},0.000,"
            f"m_proc={s.m_proc} m_proc_node={s.m_proc_node} "
            f"s_proc_B={s.s_proc:.0f} s_node_B={s.s_node:.0f}"
        )


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    _emit_stats_rows()
    strategies = ("standard", "two_step") if smoke else (
        "standard", "two_step", "three_step", "split"
    )
    codecs = ("none", "bf16") if smoke else ("none", "bf16", "int8")
    n_batch = 12 if smoke else 24
    out = run_with_devices(
        f"SMOKE = {smoke!r}\nSTRATEGIES = {strategies!r}\n"
        f"CODECS = {codecs!r}\nN_BATCH = {n_batch}\n" + CODE,
        devices=8,
    )
    for line in out.splitlines():
        if line.startswith("RESULT,"):
            print(line[len("RESULT,"):])


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
