"""Chaos sweep: recovery rate + verify-mode overhead per strategy x codec.

Three views of the fault-hardening layer (ISSUE 6 + ISSUE 10 acceptance
numbers):

* **recovery rate** (deterministic, jax-free) -- for each (strategy, codec)
  a bank of seeded :class:`repro.comm.faults.FaultPlan` scenarios (transient
  corruption, persistent lossy-codec corruption, persistent per-strategy
  corruption, dropped blocks) runs through the retry -> demote -> re-advise
  ladder on the numpy executor.  ``recovered=N/N`` is the acceptance
  metric: every scenario must end in a correct halo buffer, and the row
  records which rung cured what (``retry=/demote=/readvise=``).
* **serving chaos** (deterministic, jax-free) -- the traffic simulator
  drains a seeded burst trace through the executor recovery ladder under a
  :class:`~repro.comm.faults.FaultPlan` storm (:func:`serving_chaos`);
  the row records completion / recovery / shed / probe / deadline-miss
  counts and the trace hash.
* **verify overhead** (numpy timings) -- median wall time per exchange with
  ``verify=False`` vs ``verify=True``.  Host numpy timings bound the check
  arithmetic's cost, not DCI wire time; the acceptance property is that the
  fault-free ``verify=False`` path is byte-identical to the unguarded
  executor (asserted before timing).

``main(smoke=True)`` shrinks the sweep (two strategies, one lossy codec,
fewer timing iters) so ``benchmarks/run.py --smoke`` keeps this section
alive in tier-1.
"""

from __future__ import annotations

import time

import numpy as np

#: the seeded chaos scenarios each (strategy, codec) pair must survive;
#: (name, FaultSpec kwargs, active_calls) -- see chaos_outcomes()
SCENARIOS = (
    ("transient_nan", {"kind": "corrupt"}, (0,)),
    ("lossy_bits", {"kind": "perturb", "codecs": ("lossy",)}, None),
    ("sticky_strategy", {"kind": "corrupt", "strategies": None}, None),
    ("dropped_block", {"kind": "zero", "prob": 0.5}, (0,)),
)


def _reference(seed=1234):
    from repro.comm.exchange import random_pattern
    from repro.comm.topology import PodTopology

    rng = np.random.default_rng(seed)
    topo = PodTopology(npods=2, ppn=4)
    pat = random_pattern(rng, topo, local_size=16, p_connect=0.5, max_elems=8)
    local = rng.normal(size=(topo.nranks, 16)).astype(np.float32)
    return pat, local


def chaos_outcomes(strategies, codecs, seeds=(7,)) -> dict:
    """Run the scenario bank through the numpy ladder; returns the
    per-(strategy, codec) recovery tally.  Deterministic and jax-free --
    run.py records this dict in ``BENCH_exchange.json``."""
    from repro.comm import faults as F
    from repro.comm.exchange import execute_numpy, plan

    pat, local = _reference()
    out: dict = {}
    for strategy in strategies:
        clean = execute_numpy(plan(strategy, pat, message_cap_bytes=512), local)
        for codec in codecs:
            tally = {"retry": 0, "demote": 0, "readvise": 0, "clean_pass": 0}
            attempts, recovered = 0, 0
            for seed in seeds:
                for name, spec_kw, calls in SCENARIOS:
                    kw = dict(spec_kw)
                    if kw.get("strategies", "unset") is None:
                        kw["strategies"] = (strategy,)
                    fp = F.FaultPlan(
                        seed=seed, specs=(F.FaultSpec(**kw),), active_calls=calls
                    )
                    counter = {"n": 0}

                    def attempt(s, w):
                        idx = counter["n"]
                        counter["n"] += 1
                        sp = plan(s, pat, message_cap_bytes=512)
                        return execute_numpy(
                            sp, local, wire=w, faults=fp,
                            fault_call=idx, verify=True,
                        )

                    attempts += 1
                    try:
                        value, path = F.run_ladder(
                            attempt,
                            strategy=strategy,
                            wire=codec,
                            health=F.HealthTracker(),
                            choose_alternative=F.advise_alternative(pat),
                        )
                    except F.ExchangeIntegrityError:
                        continue
                    # a recovery only counts if the healed buffer is right:
                    # bitwise vs the clean full-precision exchange whenever
                    # the ladder landed on wire="none"
                    landed_wire = path.wire if path is not None else codec
                    if landed_wire == "none" and not np.array_equal(value, clean):
                        continue
                    recovered += 1
                    tally["clean_pass" if path is None else path.action] += 1
            out[f"{strategy}/{codec}"] = {
                "attempts": attempts,
                "recovered": recovered,
                **tally,
            }
    return out


def serving_chaos(n_requests: int = 96, seed: int = 11) -> dict:
    """Serving front-end under a seeded fault storm (deterministic,
    jax-free): the traffic simulator drains a Zipf burst trace through the
    executor recovery ladder with a :class:`FaultPlan` attached.  Returns
    the acceptance numbers run.py records in ``BENCH_exchange.json``:
    recovery / shed / deadline-miss rates, breaker probe outcomes, and the
    trace hash (equal hashes = bit-identical fault handling)."""
    from repro.comm.exchange import random_pattern
    from repro.comm.faults import FaultPlan, FaultSpec
    from repro.comm.topology import PodTopology
    from repro.serving import SimConfig, WorkloadClass, simulate
    from repro.testing import make_trace

    topo = PodTopology(npods=2, ppn=4)
    classes = {}
    for i in range(3):
        pat = random_pattern(
            np.random.default_rng(300 + i), topo, local_size=32, max_elems=4
        )
        classes[f"s{i}"] = WorkloadClass.from_pattern(pat, fp=f"s{i}")
    trace = make_trace(seed, n_requests, sorted(classes), pattern="burst",
                       rate=4000.0)
    plan = FaultPlan(
        seed=seed,
        specs=(
            # a degraded inter-pod link under the pinned strategy: retries
            # may refire, but the re-advise rung moves off two_step and
            # reliably cures it, so the ladder saves nearly every batch
            FaultSpec(kind="perturb", prob=0.35, frac=0.1,
                      strategies=("two_step",)),
            FaultSpec(kind="slow", prob=0.1, delay_s=1e-3),
        ),
    )
    res = simulate(
        classes, trace,
        SimConfig(chaos=plan, deadline_s=0.25, max_width=8,
                  strategy="two_step"),
    )
    admitted = res.completed + res.shed
    return {
        "n_requests": n_requests,
        "admitted": admitted,
        "completed": res.completed,
        "completion_rate": res.completed / admitted if admitted else 1.0,
        "fault_events": res.fault_events,
        "recoveries": res.recoveries,
        "recovery_rate": (
            res.recoveries / res.fault_events if res.fault_events else 1.0
        ),
        "shed": res.shed,
        "shed_rate": res.shed / admitted if admitted else 0.0,
        "probes": res.probes,
        "probe_recoveries": res.probe_recoveries,
        "deadline_misses": res.deadline_misses,
        "deadline_miss_rate": (
            res.deadline_misses / res.completed if res.completed else 0.0
        ),
        "trace_hash": res.trace_hash,
    }


def _med_us(fn, iters: int) -> float:
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def main(smoke: bool = False) -> None:
    from repro.comm import wire
    from repro.comm.exchange import execute_numpy, plan
    from repro.comm.strategies import STRATEGY_NAMES

    print("name,us_per_call,derived")
    strategies = ("two_step", "split") if smoke else STRATEGY_NAMES
    codecs = ("bf16",) if smoke else tuple(c for c in wire.WIRE_CODECS if c != "none")
    iters = 3 if smoke else 9

    outcomes = chaos_outcomes(strategies, codecs)
    for key, o in outcomes.items():
        assert o["recovered"] == o["attempts"], (key, o)
        print(
            f"chaos/{key},0.000,"
            f"recovered={o['recovered']}/{o['attempts']} "
            f"retry={o['retry']} demote={o['demote']} "
            f"readvise={o['readvise']} clean={o['clean_pass']}"
        )

    storm = serving_chaos()
    print(
        f"chaosserve/storm,0.000,"
        f"completed={storm['completed']}/{storm['admitted']} "
        f"faults={storm['fault_events']} recoveries={storm['recoveries']} "
        f"shed={storm['shed']} probes={storm['probes']} "
        f"probe_recoveries={storm['probe_recoveries']} "
        f"deadline_misses={storm['deadline_misses']} "
        f"trace={storm['trace_hash'][:12]}"
    )

    pat, local = _reference()
    for strategy in strategies:
        sp = plan(strategy, pat, message_cap_bytes=512)
        for codec in codecs:
            base = execute_numpy(sp, local, wire=codec)
            checked = execute_numpy(sp, local, wire=codec, verify=True)
            np.testing.assert_array_equal(base, checked)  # bitwise acceptance
            t_base = _med_us(lambda: execute_numpy(sp, local, wire=codec), iters)
            t_ver = _med_us(
                lambda: execute_numpy(sp, local, wire=codec, verify=True), iters
            )
            over = (t_ver / t_base - 1.0) * 100.0 if t_base else 0.0
            print(
                f"chaosverify/{strategy}/{codec},{t_ver:.1f},"
                f"base_us={t_base:.1f} verify_us={t_ver:.1f} "
                f"overhead={over:.0f}% parity=ok"
            )


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
