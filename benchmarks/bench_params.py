"""Paper Tables 2-4: path parameter tables + the fitting machinery.

Emits (a) the Lassen measured parameters verbatim (the paper's tables, used
by every model-reproduction benchmark), (b) the TPU-adapted registry, and
(c) a demonstration of the BenchPress-style least-squares alpha/beta fit on
*this* host: ping-pong style buffer copies at varying sizes, fitted with the
same estimator the paper uses -- showing the measurement pipeline works even
though this container has no fabric to measure.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import LASSEN, TPU_V5E_POD, Locality, Protocol, Space


def fit_postal(sizes: np.ndarray, times_s: np.ndarray) -> tuple:
    """Least-squares fit of T = alpha + beta * s (the paper's estimator)."""
    A = np.stack([np.ones_like(sizes, dtype=np.float64), sizes.astype(np.float64)], axis=1)
    coef, *_ = np.linalg.lstsq(A, times_s.astype(np.float64), rcond=None)
    return float(coef[0]), float(coef[1])


def table_2_3_4() -> None:
    for machine in (LASSEN, TPU_V5E_POD):
        for (space, proto, loc), p in sorted(
            machine.paths.items(), key=lambda kv: (kv[0][0].value, kv[0][1].value, kv[0][2].value)
        ):
            emit(
                f"table2/{machine.name}/{space.value}/{proto.value}/{loc.value}",
                p.alpha * 1e6,
                f"beta={p.beta:.3e}s_per_B",
            )
        for nproc, cp in sorted(machine.copy.items()):
            emit(f"table3/{machine.name}/copy_{nproc}proc/h2d", cp.h2d.alpha * 1e6,
                 f"beta={cp.h2d.beta:.3e}")
            emit(f"table3/{machine.name}/copy_{nproc}proc/d2h", cp.d2h.alpha * 1e6,
                 f"beta={cp.d2h.beta:.3e}")
        emit(f"table4/{machine.name}/rn_inv", machine.rn_inv * 1e6, "s_per_B*1e6")


def host_pingpong_fit(smoke: bool = False) -> None:
    """Measure host memcpy 'ping-pong' and fit alpha/beta (demonstrates the
    paper's parameter-measurement methodology end to end)."""
    import jax.numpy as jnp
    import jax

    sizes = np.array([2**k for k in range(10, 18 if smoke else 22)])
    med = []
    for s in sizes:
        x = jnp.zeros((int(s) // 4,), jnp.float32)

        def copy():
            jnp.array(x, copy=True).block_until_ready()

        med.append(time_fn(copy, warmup=1, iters=3 if smoke else 5) * 1e-6)
    alpha, beta = fit_postal(sizes, np.array(med))
    emit("fit/host_copy/alpha_us", alpha * 1e6, f"beta={beta:.3e}s_per_B "
         f"bw={1e-9/max(beta,1e-30):.2f}GB_s")


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    table_2_3_4()
    host_pingpong_fit(smoke=smoke)


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
