"""Krylov solver sweep: matrix regime x strategy x overlap (paper §5 as a
workload, §4.6 closing discussion as the model).

For each of the three communication regimes (`audikw_like` /
`thermal_like` / `random_block`, SPD-ified by
``repro.solve.problems.spd_system``), runs CG on the device executor
(:class:`repro.sparse.spmv.DistributedSpMV`) under every strategy, barrier
and split-phase, with dot products through the node-aware hierarchical
reductions (:class:`repro.solve.DeviceReductions`).  Reported per row:

* ``us_per_iter`` -- measured wall time per CG iteration (host-device
  collectives complete synchronously, so this bounds pipeline overhead, not
  latency hiding);
* ``iters`` / ``relres`` -- convergence trajectory (identical iteration
  counts across strategies is the correctness property; asserted before
  timing within each overlap mode, where results are bitwise equal);
* ``setup_s`` / ``periter_s`` / ``total_s`` -- the iteration-amortized model
  (:func:`repro.core.advisor.advise_solver`) for this strategy at the
  measured iteration count;
* one ``.../advisor`` row per regime showing the amortization flip: the
  modeled best strategy for a 1-iteration exchange vs the full solve;
* ``solver/fused/<strategy>`` rows comparing the host-driven CG loop
  against the fused whole-solve program (:func:`repro.solve.fused_cg`,
  one jitted ``lax.while_loop``) on a mildly ill-conditioned reference
  system at ``maxiter=120`` -- the ``T_launch`` amortization the
  ``LaunchModel`` prices, with ``speedup`` the measured win.

``main(smoke=True)`` shrinks matrices and the strategy set so
``benchmarks/run.py --smoke`` keeps the section alive in tier-1.
"""

from __future__ import annotations

from benchmarks.common import run_with_devices

CODE = """
import time, numpy as np
from repro.comm.topology import PodTopology
from repro.core import Strategy, Transport, advise_solver
from repro.solve import DeviceReductions, REDUCTIONS_PER_ITER, cg, spd_system
from repro.sparse import DistributedSpMV, partition_csr
from repro.sparse.matrices import GENERATORS

EXEC_TO_MODEL = {
    "standard": Strategy.STANDARD, "two_step": Strategy.TWO_STEP,
    "three_step": Strategy.THREE_STEP, "split": Strategy.SPLIT_DD,
}

topo = PodTopology(npods=2, ppn=4) if SMOKE else PodTopology(npods=4, ppn=4)
n = 144 if SMOKE else 1024
strategies = ("standard", "two_step", "split") if SMOKE else (
    "standard", "two_step", "three_step", "split")
tol = 1e-6
rng = np.random.default_rng(0)
red = DeviceReductions(topo)  # one jitted dot program serves every regime

for regime in ("audikw_like", "thermal_like", "random_block"):
    A = spd_system(GENERATORS[regime](n, rng))
    part = partition_csr(A, topo)
    b = rng.normal(size=(topo.nranks, part.rows_per_rank)).astype(np.float32)
    pat = part.pattern.to_comm_pattern()
    rows = []
    for strat in strategies:
        for ov in (False, True):
            op = DistributedSpMV(part, strategy=strat, use_pallas=False, overlap=ov)
            res = cg(op, b, tol=tol, reductions=red)  # warm caches + jits
            t0 = time.perf_counter()
            res = cg(op, b, tol=tol, reductions=red)
            wall = time.perf_counter() - t0
            rows.append((strat, ov, res))
            us = wall / max(res.iterations, 1) * 1e6
            adv = advise_solver(
                pat, max(res.iterations, 1), machine="tpu_v5e_pod",
                reductions_per_iter=REDUCTIONS_PER_ITER["cg"],
            )
            model = next(
                r for r in adv.ranked
                if r.strategy is EXEC_TO_MODEL[strat]
                and r.transport is Transport.STAGED_HOST and not r.overlap
            )
            print(
                f"RESULT,solver/{regime}/{strat}/{'ov1' if ov else 'ov0'},"
                f"{us:.1f},iters={res.iterations} conv={int(res.converged)} "
                f"relres={res.final_residual:.2e} "
                f"setup_s={model.setup_time:.3e} periter_s={model.iter_time:.3e} "
                f"total_s={model.total_time:.3e}"
            )
    # parity: within one overlap mode every strategy's trajectory is
    # bitwise equal (the halo buffer is canonical); assert it
    for mode in (False, True):
        group = [r for s, o, r in rows if o is mode]
        assert all(r.converged for r in group), f"{regime} non-convergence"
        assert all(r.residuals == group[0].residuals for r in group), (
            f"{regime} history drift across strategies (overlap={mode})")
    iters = rows[0][2].iterations
    best1 = advise_solver(pat, 1, machine="tpu_v5e_pod").best.key
    bestN = advise_solver(
        pat, iters, machine="tpu_v5e_pod",
        reductions_per_iter=REDUCTIONS_PER_ITER["cg"]).best.key
    print(
        f"RESULT,solver/{regime}/advisor,0.0,"
        f"best@1={best1} best@{iters}={bestN} parity=ok"
    )

# fused whole-solve front-end vs the host-driven loop: the T_launch
# amortization the LaunchModel prices.  A mildly ill-conditioned
# reference system (shift=1e-2) keeps the f32 trajectory deterministic
# so host and fused agree iteration-for-iteration under the same
# maxiter=120 horizon; tol stays above the f32 residual plateau.
from repro.comm import cache_stats, clear_caches
from repro.solve import fused_cg

rngf = np.random.default_rng(7)
A = spd_system(GENERATORS["thermal_like"](n, rngf), shift=1e-2)
part = partition_csr(A, topo)
b = rngf.normal(size=(topo.nranks, part.rows_per_rank)).astype(np.float32)
maxiter = 120
for strat in (("two_step",) if SMOKE else ("standard", "two_step", "split")):
    op = DistributedSpMV(part, strategy=strat, use_pallas=False)
    host = cg(op, b, tol=1e-5, maxiter=maxiter, reductions=red)  # warm
    t0 = time.perf_counter()
    host = cg(op, b, tol=1e-5, maxiter=maxiter, reductions=red)
    t_host = time.perf_counter() - t0
    clear_caches()
    # fresh op: the fused solve must plan from scratch (one plan miss)
    opf = DistributedSpMV(part, strategy=strat, use_pallas=False)
    fres = fused_cg(opf, b, tol=1e-5, maxiter=maxiter)  # plan + trace once
    s = cache_stats()
    if strat == "two_step":
        assert (s.plan_misses, s.fused_misses, s.fused_hits) == (1, 1, 0), s
    else:
        assert (s.fused_misses, s.fused_hits) == (1, 0), s
    t0 = time.perf_counter()
    fres = fused_cg(opf, b, tol=1e-5, maxiter=maxiter)
    t_fused = time.perf_counter() - t0
    assert cache_stats().fused_hits == 1, cache_stats()
    parity = (fres.iterations, fres.status) == (host.iterations, host.status)
    if SMOKE:
        assert parity, (fres.iterations, fres.status, host.iterations, host.status)
    print(
        f"RESULT,solver/fused/{strat},"
        f"{t_fused / max(fres.iterations, 1) * 1e6:.1f},"
        f"iters={fres.iterations} conv={int(fres.converged)} "
        f"host_us_per_iter={t_host / max(host.iterations, 1) * 1e6:.1f} "
        f"speedup={t_host / t_fused:.2f}x "
        f"parity={'ok' if parity else 'drift'}"
    )
"""


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    devices = 8 if smoke else 16
    out = run_with_devices(f"SMOKE = {smoke!r}\n" + CODE, devices=devices)
    for line in out.splitlines():
        if line.startswith("RESULT,"):
            print(line[len("RESULT,"):])


if __name__ == "__main__":
    import sys

    main(smoke="--smoke" in sys.argv)
