"""Quickstart: the paper's pipeline in ~80 lines.

1. Build an irregular communication pattern (a distributed SpMV halo).
2. Ask the model-driven advisor (paper §4.6) which node-aware strategy wins
   -- including the payload-width effect: batched ``k``-column payloads scale
   the byte terms while message counts stay fixed, which can flip the winner.
3. Execute every strategy and verify identical results: single-vector SpMV,
   the fused multi-vector ``matmat`` (ONE exchange for all ``k`` columns),
   and the split-phase ``overlap=True`` pipeline.

Runs on 1 CPU device (the strategies need >= nranks devices, so the
execution step self-relaunches with 8 forced host devices).

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import subprocess
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

K = 8  # multi-vector payload width for the SpMM demo


def main() -> None:
    from repro.comm.topology import PodTopology
    from repro.core import advise
    from repro.sparse import audikw_like, partition_csr

    rng = np.random.default_rng(0)
    topo = PodTopology(npods=2, ppn=4)

    # 1. the paper's case study: a row-partitioned sparse matrix induces an
    #    irregular point-to-point pattern
    A = audikw_like(128, rng)
    part = partition_csr(A, topo)
    pattern = part.pattern.to_comm_pattern()
    print(f"matrix n={A.n} nnz={A.nnz}; irregular pattern: "
          f"{len(pattern.messages)} messages, stats={pattern.stats()}\n")

    # 2. model-driven strategy selection (Table 6 composites), and how the
    #    batched payload width k moves the ranking (PatternStats.widened)
    for k in (1, K):
        advice = advise(pattern, machine="tpu_v5e_pod", payload_width=k)
        print(f"advisor ranking (TPU registry, payload_width={k}):")
        print(advice.table())
        print(f"-> best at k={k}: {advice.best.key}\n")

    # 3. execute all strategies on 8 host devices and verify
    if os.environ.get("_QS_CHILD") != "1":
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["_QS_CHILD"] = "1"
        env["PYTHONPATH"] = os.pathsep.join(sys.path)
        print("executing strategies on 8 host devices...")
        out = subprocess.run([sys.executable, __file__], env=env,
                             capture_output=True, text=True)
        print(out.stdout[out.stdout.find("EXECUTION"):] or out.stderr[-2000:])
        return

    print("EXECUTION")
    from repro.sparse import build

    v = rng.normal(size=(A.n,)).astype(np.float32)
    V = rng.normal(size=(A.n, K)).astype(np.float32)
    want_v, want_V = A.spmv(v), A.spmm(V)
    for strat in ("standard", "two_step", "three_step", "split"):
        # single vector, barrier exchange
        sp = build(A, topo, strategy=strat, use_pallas=True, payload_width=K)
        out = np.asarray(sp(v.reshape(topo.nranks, -1))).reshape(-1)
        np.testing.assert_allclose(out, want_v, rtol=1e-4, atol=1e-4)
        # multi-vector: matmat runs ONE exchange + one fused blocked-ELL SpMM
        W = np.asarray(sp.matmat(V.reshape(topo.nranks, -1, K)))
        np.testing.assert_allclose(W.reshape(A.n, K), want_V, rtol=1e-4, atol=1e-4)
        # split-phase overlap: interior tiles compute during the inter-node
        # phase; results are bitwise-identical to the barrier path
        ov = build(A, topo, strategy=strat, use_pallas=True, overlap=True)
        np.testing.assert_array_equal(
            np.asarray(ov.matmat(V.reshape(topo.nranks, -1, K))), W
        )
        wi, we = sp.wire_bytes
        print(f"  {strat:11s} OK (spmv + matmat k={K} + overlap)   "
              f"intra-pod {wi:6d} B   inter-pod {we:6d} B")


if __name__ == "__main__":
    main()
