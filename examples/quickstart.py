"""Quickstart: the paper's pipeline in 60 lines.

1. Build an irregular communication pattern (a distributed SpMV halo).
2. Ask the model-driven advisor (paper §4.6) which node-aware strategy wins.
3. Execute the exchange with each strategy and verify identical results.

Runs on 1 CPU device (the strategies need >= nranks devices, so the
execution step self-relaunches with 8 forced host devices).

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import subprocess
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from repro.comm.topology import PodTopology
    from repro.core import Strategy, advise
    from repro.sparse import audikw_like, partition_csr

    rng = np.random.default_rng(0)
    topo = PodTopology(npods=2, ppn=4)

    # 1. the paper's case study: a row-partitioned sparse matrix induces an
    #    irregular point-to-point pattern
    A = audikw_like(128, rng)
    part = partition_csr(A, topo)
    pattern = part.pattern.to_comm_pattern()
    print(f"matrix n={A.n} nnz={A.nnz}; irregular pattern: "
          f"{len(pattern.messages)} messages, stats={pattern.stats()}\n")

    # 2. model-driven strategy selection (Table 6 composites)
    advice = advise(pattern, machine="tpu_v5e_pod")
    print("advisor ranking (TPU registry):")
    print(advice.table())
    print(f"\n-> best: {advice.best.key}\n")

    # 3. execute all strategies on 8 host devices and verify
    if os.environ.get("_QS_CHILD") != "1":
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["_QS_CHILD"] = "1"
        env["PYTHONPATH"] = os.pathsep.join(sys.path)
        print("executing strategies on 8 host devices...")
        out = subprocess.run([sys.executable, __file__], env=env,
                             capture_output=True, text=True)
        print(out.stdout[out.stdout.find("EXECUTION"):] or out.stderr[-2000:])
        return

    print("EXECUTION")
    from repro.sparse import build

    v = rng.normal(size=(A.n,)).astype(np.float32)
    want = A.spmv(v)
    for strat in ("standard", "two_step", "three_step", "split"):
        sp = build(A, topo, strategy=strat, use_pallas=True)
        out = np.asarray(sp(v.reshape(topo.nranks, -1))).reshape(-1)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
        wi, we = sp.wire_bytes
        print(f"  {strat:11s} OK   intra-pod {wi:6d} B   inter-pod {we:6d} B")


if __name__ == "__main__":
    main()
