"""Reproduce the paper's Figure 4.3 analysis as a planning tool.

Given a scenario (message count, destination nodes, message sizes), print the
per-size strategy ranking on both machine registries -- the exact exercise of
paper §4.6, usable for planning a real deployment's exchange strategy.

    PYTHONPATH=src python examples/strategy_advisor.py --messages 256 --nodes 16
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--messages", type=int, default=256)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--machine", default="lassen", choices=("lassen", "tpu_v5e_pod"))
    ap.add_argument("--duplicate", type=float, default=0.0,
                    help="fraction of duplicate data removable by node-aware schemes")
    args = ap.parse_args()

    from repro.core import advise, figure43_pattern

    print(f"machine={args.machine}  inter-node messages={args.messages}  "
          f"destination nodes={args.nodes}  duplicates={args.duplicate:.0%}\n")
    print(f"{'msg size':>10} | best strategy             | predicted | runner-up")
    print("-" * 78)
    for logs in range(4, 21):
        size = 2 ** logs
        pat = figure43_pattern(size, args.messages, args.nodes)
        adv = advise(pat, machine=args.machine, duplicate_fraction=args.duplicate)
        b, r = adv.ranked[0], adv.ranked[1]
        print(f"{size:>10} | {b.key:<25} | {b.predicted_time:.3e}s | "
              f"{r.key} ({r.predicted_time:.2e}s)")


if __name__ == "__main__":
    main()
