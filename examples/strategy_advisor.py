"""Reproduce the paper's Figure 4.3 analysis as a planning tool.

Given a scenario (message count, destination nodes, message sizes), print the
per-size strategy ranking on both machine registries -- the exact exercise of
paper §4.6, usable for planning a real deployment's exchange strategy.

``--payload-width k`` widens the byte terms for batched ``k``-column payloads
(the multi-vector SpMM / batched-serving lever: message counts stay fixed, so
big ``k`` pushes every model toward the bandwidth-bound regime and can flip
the winner -- compare ``--payload-width 1`` with ``--payload-width 64``).

``--compute-us t --interior-frac f`` adds overlap-aware ranking: a per-step
local compute of ``t`` microseconds, ``f`` of it halo-independent, lets the
split-phase pipeline hide the inter-node phase and ``+overlap`` variants
enter the ranking.

``--wire auto`` (or a codec name / comma list, e.g. ``none,bf16``) adds
inter-pod wire-format variants: ``+wire:<codec>`` entries scale the
inter-node byte terms by the codec's compression ratio and pay its
encode+decode term, so bandwidth-bound sizes flip to a compressed wire.

    PYTHONPATH=src python examples/strategy_advisor.py --messages 256 --nodes 16
    PYTHONPATH=src python examples/strategy_advisor.py --payload-width 64
    PYTHONPATH=src python examples/strategy_advisor.py --compute-us 50 --interior-frac 0.9
    PYTHONPATH=src python examples/strategy_advisor.py --wire auto
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--messages", type=int, default=256)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--machine", default="lassen", choices=("lassen", "tpu_v5e_pod"))
    ap.add_argument("--duplicate", type=float, default=0.0,
                    help="fraction of duplicate data removable by node-aware schemes")
    ap.add_argument("--payload-width", type=int, default=1,
                    help="batched payload columns k (PatternStats.widened)")
    ap.add_argument("--compute-us", type=float, default=0.0,
                    help="per-step local compute in us; enables overlap ranking")
    ap.add_argument("--interior-frac", type=float, default=0.0,
                    help="fraction of compute that is halo-independent")
    ap.add_argument("--wire", default=None,
                    help="wire codec candidates: 'auto', a codec name, or a "
                         "comma list like 'none,bf16'")
    args = ap.parse_args()

    from repro.core import ComputeProfile, advise, figure43_pattern

    wire = args.wire
    if wire and "," in wire:
        wire = tuple(wire.split(","))

    compute = None
    if args.compute_us > 0.0:
        compute = ComputeProfile.from_fraction(
            args.compute_us * 1e-6, args.interior_frac
        )

    print(f"machine={args.machine}  inter-node messages={args.messages}  "
          f"destination nodes={args.nodes}  duplicates={args.duplicate:.0%}  "
          f"payload_width={args.payload_width}"
          + (f"  compute={args.compute_us}us"
             f" interior={args.interior_frac:.0%}" if compute else "")
          + (f"  wire={args.wire}" if wire else "") + "\n")
    print(f"{'msg size':>10} | best strategy                     | predicted | runner-up")
    print("-" * 90)
    for logs in range(4, 21):
        size = 2 ** logs
        pat = figure43_pattern(size, args.messages, args.nodes)
        adv = advise(pat, machine=args.machine,
                     duplicate_fraction=args.duplicate,
                     payload_width=args.payload_width,
                     compute=compute,
                     wire=wire)
        b, r = adv.ranked[0], adv.ranked[1]
        print(f"{size:>10} | {b.key:<33} | {b.predicted_time:.3e}s | "
              f"{r.key} ({r.predicted_time:.2e}s)")


if __name__ == "__main__":
    main()
