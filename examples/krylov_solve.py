"""Distributed Krylov solve: the iterative workload the paper's models
amortize over.

1. Build an SPD system with thermal2-like communication structure and ask
   the iteration-amortized advisor (`repro.core.advise_solver`) which
   strategy wins a whole solve -- setup cost paid once, per-iteration
   exchange + hierarchical-reduction cost multiplied by the iteration count.
   Note the flip: a 1-iteration "solve" favours standard communication
   (no communicator construction), a real solve favours the node-aware
   winner.
2. Solve with CG on the jax-free numpy executor (`repro.solve.NumpySpMV`)
   under every strategy, barrier and split-phase: one cached exchange plan
   serves all iterations (shown via `repro.comm.cache_stats()`) and the
   residual histories are bitwise identical across all configurations.
3. Re-run on real devices (`repro.sparse.DistributedSpMV`, 8 forced host
   chips) with dot products through the node-aware hierarchical collectives
   (`repro.solve.DeviceReductions`), including an int8-compressed
   inter-pod reduction variant.
4. With ``--fused``: compare the host-driven loop against the fused
   whole-solve program (`repro.solve.fused_cg`) -- one jitted
   ``lax.while_loop`` per solve, cached in the fused-program LRU -- and ask
   the advisor's `LaunchModel` accounting (`advise_solver(fused="auto")`)
   at which horizon the one-time trace cost beats the per-iteration host
   dispatches.

    PYTHONPATH=src python examples/krylov_solve.py [--fused]
"""

import os
import subprocess
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from repro.comm import cache_stats, clear_caches
    from repro.comm.topology import PodTopology
    from repro.core import advise_solver, figure43_pattern
    from repro.solve import NumpySpMV, REDUCTIONS_PER_ITER, cg, spd_system
    from repro.sparse import partition_csr, thermal_like

    fused = "--fused" in sys.argv[1:]
    rng = np.random.default_rng(0)
    topo = PodTopology(npods=2, ppn=4)
    A = spd_system(thermal_like(1024, rng))
    part = partition_csr(A, topo)
    pattern = part.pattern.to_comm_pattern()
    b = rng.normal(size=(topo.nranks, part.rows_per_rank))

    if os.environ.get("_KS_CHILD") == "1":
        # the 8-device re-launch only runs the device solves (steps 3/4)
        _device_execution(topo, part, b, fused=os.environ.get("_KS_FUSED") == "1")
        return

    print(f"SPD system n={A.n} nnz={A.nnz} on {topo.nranks} ranks\n")

    # 1. iteration-amortized strategy selection.  On the paper's flagship
    #    pattern (256 x 2 KiB messages to 16 nodes, Fig 4.3) the winner
    #    FLIPS with the horizon: standard wins a 1-iteration "solve" (no
    #    communicator construction), 2-Step wins once its setup amortizes.
    flagship = figure43_pattern(2048, 256, 16)
    for iters in (1, 200):
        adv = advise_solver(
            flagship, iters, machine="lassen",
            reductions_per_iter=REDUCTIONS_PER_ITER["cg"],
        )
        print(f"amortized advisor on the Fig 4.3 pattern, iters={iters}:")
        print(adv.table())
        print(f"-> best for a {iters}-iteration solve: {adv.best.key}\n")
    #    ... while this small stencil system is latency-bound at every
    #    horizon: node-aware setup never pays for itself (also the paper's
    #    conclusion for small per-message volumes).
    adv = advise_solver(pattern, 200, machine="tpu_v5e_pod",
                        reductions_per_iter=REDUCTIONS_PER_ITER["cg"])
    print(f"this matrix's own pattern, iters=200 -> {adv.best.key} "
          f"(latency-bound: no flip)\n")

    # 2. CG on the numpy executor: every strategy, barrier + split-phase
    clear_caches()
    histories = {}
    for strategy in ("standard", "two_step", "three_step", "split"):
        for overlap in (False, True):
            op = NumpySpMV(part, strategy=strategy, overlap=overlap)
            res = cg(op, b, tol=1e-6)
            histories[(strategy, overlap)] = res.residuals
            assert res.converged
    ref = histories[("standard", False)]
    assert all(h == ref for h in histories.values())
    s = cache_stats()
    print(f"numpy executor: {len(histories)} strategy/overlap configs, "
          f"all converged in {len(ref) - 1} iterations with bitwise-identical "
          f"residual histories")
    print(f"plan cache over all solves: {s.plan_misses} misses "
          f"(one per distinct sub-pattern), {s.plan_hits} hits; "
          f"split decompositions: {s.split_misses} miss, {s.split_hits} hits\n")

    if fused:
        # 2b. where does the fused front-end win?  The LaunchModel charges
        #     the host loop t_launch per dispatch and the fused program one
        #     t_trace up front; the ranking flips to +fused once the trace
        #     amortizes (~t_trace / (launches_per_iter * t_launch) iters).
        for iters in (50, 400):
            adv = advise_solver(
                flagship, iters, machine="lassen", fused="auto",
                reductions_per_iter=REDUCTIONS_PER_ITER["cg"],
            )
            print(f"fused-aware advisor, iters={iters} -> {adv.best.key}")
        print()

    # 3. device executor + hierarchical reductions (8 forced host chips;
    #    XLA_FLAGS must be set before jax import, hence the re-launch)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["_KS_CHILD"] = "1"
    if fused:
        env["_KS_FUSED"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(sys.path)
    print("re-running the solve on 8 host devices...")
    out = subprocess.run([sys.executable, __file__], env=env,
                         capture_output=True, text=True)
    start = out.stdout.find("DEVICE EXECUTION")
    print(out.stdout[start:] if start >= 0 else out.stderr[-2000:])


def _device_execution(topo, part, b, fused=False) -> None:
    from repro.comm import Compressor, cache_stats
    from repro.solve import DeviceReductions, cg, fused_cg
    from repro.sparse import DistributedSpMV

    print("DEVICE EXECUTION")
    bf = b.astype(np.float32)
    red = DeviceReductions(topo)
    for strategy, overlap in (("two_step", False), ("two_step", True)):
        op = DistributedSpMV(part, strategy=strategy, use_pallas=False,
                             overlap=overlap)
        res = cg(op, bf, tol=1e-6, reductions=red)
        mode = "overlap" if overlap else "barrier"
        print(f"  {strategy:9s} {mode:8s} converged={res.converged} "
              f"iters={res.iterations} relres={res.final_residual:.2e}")
    comp = DeviceReductions(topo, compressor=Compressor())
    res = cg(DistributedSpMV(part, strategy="two_step", use_pallas=False),
             bf, tol=1e-4, maxiter=200, reductions=comp)
    print(f"  two_step  int8-compressed inter-pod reductions: "
          f"converged={res.converged} iters={res.iterations} "
          f"relres={res.final_residual:.2e}")
    if not fused:
        return
    # 4. fused whole-solve program: same SolveResult contract, ONE compiled
    #    lax.while_loop instead of per-iteration host dispatches
    op = DistributedSpMV(part, strategy="two_step", use_pallas=False)
    host = cg(op, bf, tol=1e-6, reductions=red)
    fres = fused_cg(op, bf, tol=1e-6)
    s = cache_stats()
    drift = max(
        abs(a - c) / max(abs(c), 1e-30)
        for a, c in zip(fres.residuals, host.residuals)
    )
    print(f"  two_step  fused whole-solve: converged={fres.converged} "
          f"iters={fres.iterations} (host {host.iterations}), "
          f"history drift {drift:.1e}, "
          f"{s.fused_misses} program compile / {s.fused_hits} cache hits")


if __name__ == "__main__":
    main()
