"""Chaos serving demo: the recovery ladder keeping a fault storm invisible.

Everything here is jax-free and seeded, so every number reprints bit-for-bit:

1. Run the continuous-batching traffic simulator twice on one trace --
   fault-free, then under a seeded ``FaultPlan`` storm -- and compare:
   the storm costs latency (every ladder attempt charges a service
   quantum) but not answers (completion stays ~100%, shed only when the
   whole retry -> demote -> re-advise ladder is exhausted).  Identical
   seeds give identical ``trace_hash`` values: fault handling is part of
   the deterministic schedule, not noise on top of it.
2. Drain real batches through :class:`repro.serving.BatchExecutor` on the
   numpy exchange executor with a *variant* handler family, so the
   demote/re-advise rungs genuinely run a different (strategy, codec) --
   and assert the recovered halo buffers are bitwise equal to a
   fault-free exchange.
3. Heal: walk the :class:`repro.comm.faults.HealthTracker` circuit
   breaker through closed -> open -> half-open -> closed and show the
   advisor ranking sinking the degraded pair, then restoring it after
   one successful probe.

    PYTHONPATH=src python examples/chaos_serving.py
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from repro.comm.exchange import execute_numpy, plan, random_pattern
    from repro.comm.faults import FaultPlan, FaultSpec, HealthTracker
    from repro.comm.topology import PodTopology
    from repro.core.advisor import EXECUTABLE_STRATEGY, advise_stats
    from repro.serving import BatchExecutor, SimConfig, WorkloadClass, simulate
    from repro.testing import make_trace

    topo = PodTopology(npods=2, ppn=4)
    rng = np.random.default_rng(0)

    # -- 1. simulated storm -------------------------------------------------
    classes = {}
    patterns = {}
    for i in range(3):
        pat = random_pattern(
            np.random.default_rng(300 + i), topo, local_size=32, max_elems=4
        )
        patterns[f"s{i}"] = pat
        classes[f"s{i}"] = WorkloadClass.from_pattern(pat, fp=f"s{i}")
    trace = make_trace(11, 96, sorted(classes), pattern="burst", rate=4000.0)
    storm_plan = FaultPlan(
        seed=11,
        specs=(
            FaultSpec(kind="perturb", prob=0.35, frac=0.1,
                      strategies=("two_step",)),
            FaultSpec(kind="slow", prob=0.1, delay_s=1e-3),
        ),
    )
    clean = simulate(classes, trace, SimConfig(max_width=8, strategy="two_step"))
    cfg = SimConfig(max_width=8, strategy="two_step", chaos=storm_plan,
                    deadline_s=0.25)
    storm = simulate(classes, trace, cfg)
    again = simulate(classes, trace, cfg)
    print("chaos serving: fault storm vs fault-free on one trace")
    print(f"  fault-free: {clean.completed} completed, p99 {clean.p99*1e3:.2f}ms,"
          f" trace {clean.trace_hash[:12]}")
    print(f"  storm:      {storm.completed} completed, p99 {storm.p99*1e3:.2f}ms,"
          f" {storm.fault_events} faults, {storm.recoveries} ladder recoveries,"
          f" {storm.shed} shed, {storm.probes} probes, trace {storm.trace_hash[:12]}")
    assert storm.trace_hash == again.trace_hash, "chaos must be deterministic"
    assert storm.completed + storm.shed == clean.completed

    # -- 2. a real executor drain with variant handlers ---------------------
    # one fingerprint's exchanges are hit by a persistent per-strategy fault;
    # the re-advise rung moves the batch off two_step and the healed halo is
    # bitwise what a fault-free exchange produces
    fp = FaultPlan(seed=5, specs=(
        FaultSpec(kind="perturb", prob=1.0, frac=0.25, strategies=("two_step",)),
    ))
    local = rng.normal(size=(topo.nranks, 32)).astype(np.float32)
    reference = {
        name: execute_numpy(plan("standard", pat), local)
        for name, pat in patterns.items()
    }

    def make_family(name):
        pat = patterns[name]

        def make(strategy, wire):
            def handler(payload):
                return execute_numpy(
                    plan(strategy, pat), payload, wire=wire,
                    faults=fp, verify=True,
                )
            return handler

        return make

    ex = BatchExecutor(health=HealthTracker())
    from repro.serving.batcher import Batch
    from repro.serving.request import Request

    outcomes = []
    for i, name in enumerate(sorted(patterns)):
        ex.register_variants(name, make_family(name))
        batch = Batch(
            fp=name, requests=(Request(arrival=0.0, rid=i, fp=name),),
            payload_width=1, resident_bytes=local.nbytes,
            strategy="two_step", wire="none", key="two_step/device_aware",
            predicted_time=1e-4, kind="spmv",
        )
        outcomes.append(ex.execute_resilient(batch, local))
    for o in outcomes:
        assert o.ok, o.error
        healed = np.asarray(o.value)
        assert np.array_equal(healed, reference[o.batch.fp]), o.batch.fp
    recovered = [o for o in outcomes if o.recovery]
    print(f"  executor drain: {len(outcomes)} batches, "
          f"{len(recovered)} recovered "
          f"({', '.join(sorted({o.recovery for o in recovered}))}), "
          f"0 shed, healed halos bitwise correct")

    # -- 3. breaker heal: rankings sink, probe, recover ---------------------
    health = HealthTracker(cooldown=3)
    stats = classes["s0"].stats
    baseline = advise_stats(stats, machine="tpu_v5e_pod", health=health)
    best = EXECUTABLE_STRATEGY[baseline.best.strategy]
    for _ in range(2):  # trip the breaker on the clean winner
        health.record_call()
        health.failures[(best, "none")] = health.failures.get((best, "none"), 0) + 1
        health._opened_at[(best, "none")] = health.calls
        health._cooldowns.setdefault((best, "none"), health.cooldown)
    sunk = advise_stats(stats, machine="tpu_v5e_pod", health=health)
    for _ in range(health.cooldown):  # cooldown passes in breaker ticks
        health.record_call()
    state = health.breaker_state(best, "none")
    healed_now = health.record_success(best, "none")  # the probe succeeds
    recovered_rank = advise_stats(stats, machine="tpu_v5e_pod", health=health)
    print(f"  breaker: clean winner {best!r} sank to "
          f"{EXECUTABLE_STRATEGY[sunk.best.strategy]!r} when degraded; "
          f"state {state!r} after cooldown; probe success -> "
          f"{EXECUTABLE_STRATEGY[recovered_rank.best.strategy]!r} restored "
          f"(probe_recoveries={health.probe_recoveries}, healed={healed_now})")
    assert state == "half_open" and healed_now
    assert recovered_rank.best.key == baseline.best.key


if __name__ == "__main__":
    main()
