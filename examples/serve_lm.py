"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-32b --batch 4
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--advise-dispatch", action="store_true",
                    help="rank exchange strategies for the measured MoE "
                         "routing histogram (MoE archs only)")
    ap.add_argument("--npods", type=int, default=2)
    ap.add_argument("--ppn", type=int, default=4)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.launch.train import tiny
    from repro.models import LMModel

    cfg = tiny(get_config(args.arch))
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    ctx = (
        jnp.asarray(rng.normal(size=(args.batch, model.ctx_len(), cfg.d_model)), jnp.float32)
        if model.ctx_len()
        else None
    )
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    logits, cache = model.prefill(params, prompts, ctx)
    grown = model.init_cache(args.batch, max_len, model.dtype)
    cache = jax.tree.map(
        lambda dst, src: dst.at[tuple(slice(0, s) for s in src.shape)].set(src.astype(dst.dtype))
        if dst.shape != src.shape else src.astype(dst.dtype),
        grown, cache,
    )
    t1 = time.time()
    decode = jax.jit(model.decode_step)
    token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    toks = [token]
    for t in range(args.gen - 1):
        logits, cache = decode(params, token, cache, jnp.int32(args.prompt_len + t))
        token = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        toks.append(token)
    jax.block_until_ready(token)
    t2 = time.time()
    gen = np.asarray(jnp.concatenate(toks, axis=1))
    tput = args.batch * (args.gen - 1) / (t2 - t1)
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in {t1-t0:.2f}s; "
          f"decode {args.gen} steps in {t2-t1:.2f}s ({tput:.1f} tok/s incl. 1st-step compile)")
    print("sample:", gen[0][:16])

    if args.advise_dispatch:
        from repro.launch.serve import dispatch_advice

        served = np.concatenate([np.asarray(prompts), gen], axis=1)
        counts, advice = dispatch_advice(params, cfg, served, args.npods, args.ppn)
        print(f"dispatch advice ({args.npods} pods x {args.ppn}, "
              f"{int(counts.sum())} routed tokens):")
        print(advice.table())


if __name__ == "__main__":
    main()
