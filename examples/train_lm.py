"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Full stack: synthetic sharded data pipeline -> scanned transformer ->
AdamW -> async checkpointing -> straggler watchdog, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import logging
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import small_100m
    from repro.optim import AdamWConfig
    from repro.runtime import Trainer, TrainerConfig

    cfg = small_100m(get_config(args.arch))
    mesh = make_host_mesh(1, 1)
    trainer = Trainer(
        cfg,
        mesh,
        TrainerConfig(
            steps=args.steps,
            batch=8,
            seq_len=256,
            log_every=20,
            checkpoint_every=100,
            checkpoint_dir=args.ckpt,
            impl="chunked",
        ),
        AdamWConfig(peak_lr=1e-3, warmup_steps=30, total_steps=args.steps),
    )
    print(f"model: {cfg.name} ~{trainer.model.param_count()/1e6:.0f}M params")
    out = trainer.run(resume=args.resume)
    h = out["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over {args.steps} steps")
    if args.steps >= 100:  # short smoke runs are too noisy to assert on
        assert h[-1]["loss"] < h[0]["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
