"""Synthetic sparse matrices with SuiteSparse-like communication structure.

The paper benchmarks against large SuiteSparse matrices (audikw_1, thermal2,
Serena, ldoor, bone010, Geo_1438).  This container has no network access, so
we generate synthetic matrices that induce the same three *communication
regimes* the paper exercises:

* ``audikw_like``  -- banded FEM matrix with dense top rows / left columns
  ("high numbers of on-node and inter-node communication", paper §4.5).
* ``thermal_like`` -- 2D 5-point stencil: narrow band, many small neighbour
  messages (thermal2's "high inter-node message volume" regime).
* ``random_block`` -- uniformly random coupling: every rank talks to every
  rank (worst-case message count).

Matrices are CSR (``indptr``, ``indices``, ``data``) in plain numpy; no scipy
dependency.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    n: int
    indptr: np.ndarray  # [n+1] int64
    indices: np.ndarray  # [nnz] int32, column ids, sorted per row
    data: np.ndarray  # [nnz] float32

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def validate(self) -> "CSRMatrix":
        """Enforce the documented invariants; raises ``ValueError`` on a
        malformed matrix, returns ``self`` otherwise.

        Checked: ``indptr`` is ``[n+1]`` starting at 0 and non-decreasing,
        ``indices``/``data`` lengths match ``indptr[-1]``, column ids are in
        ``[0, n)``, and -- the invariant downstream code leans on
        (:func:`repro.sparse.partition.partition_csr` canonical orders,
        bisection over rows) -- indices are strictly increasing within each
        row (sorted, no duplicates).  Generators call this under
        ``__debug__``; run ``python -O`` to skip the O(nnz) check.
        """
        indptr, indices, data = self.indptr, self.indices, self.data
        if indptr.shape != (self.n + 1,):
            raise ValueError(f"indptr shape {indptr.shape} != ({self.n + 1},)")
        if indptr[0] != 0 or (np.diff(indptr) < 0).any():
            raise ValueError("indptr must start at 0 and be non-decreasing")
        if indices.shape != (int(indptr[-1]),) or data.shape != indices.shape:
            raise ValueError(
                f"indices/data length {indices.shape}/{data.shape} "
                f"!= nnz {int(indptr[-1])}"
            )
        if indices.size and (indices.min() < 0 or indices.max() >= self.n):
            raise ValueError("column ids out of range [0, n)")
        # strictly increasing per row: every adjacent pair must increase
        # unless it straddles a row boundary
        d = np.diff(indices.astype(np.int64))
        within_row = np.ones(d.shape, dtype=bool)
        boundary = indptr[1:-1]
        boundary = boundary[(boundary > 0) & (boundary < indices.size)]
        within_row[boundary - 1] = False
        if (d[within_row] <= 0).any():
            bad = int(np.flatnonzero(within_row & (d <= 0))[0])
            row = int(np.searchsorted(indptr, bad, side="right")) - 1
            raise ValueError(
                f"indices not strictly sorted within row {row} "
                f"(positions {bad}, {bad + 1}: {indices[bad]}, {indices[bad + 1]})"
            )
        return self

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.data[s:e]

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n), dtype=np.float32)
        for i in range(self.n):
            cols, vals = self.row(i)
            out[i, cols] = vals
        return out

    def spmv(self, v: np.ndarray) -> np.ndarray:
        """Reference sequential SpMV."""
        out = np.zeros(self.n, dtype=np.result_type(self.data, v))
        for i in range(self.n):
            cols, vals = self.row(i)
            out[i] = (vals * v[cols]).sum()
        return out

    def spmm(self, V: np.ndarray) -> np.ndarray:
        """Reference sequential SpMM for a ``[n, k]`` right-hand side."""
        out = np.zeros((self.n, V.shape[1]), dtype=np.result_type(self.data, V))
        for i in range(self.n):
            cols, vals = self.row(i)
            out[i] = vals @ V[cols]
        return out


def _from_coo(
    n: int,
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    duplicates: str = "first",
) -> CSRMatrix:
    """COO triplets -> CSR (rows lexsorted, per-row columns sorted).

    ``duplicates`` resolves repeated ``(row, col)`` entries: ``"first"``
    keeps the earliest occurrence in the input order (the generators'
    historical behavior), ``"sum"`` accumulates them (what matrix algebra
    like :func:`repro.solve.problems.spd_system` needs).  Empty input is
    valid and yields an all-empty-rows matrix.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    key = rows * n + cols
    keep = np.ones(key.shape, dtype=bool)
    keep[1:] = key[1:] != key[:-1]
    if duplicates == "sum":
        group = np.cumsum(keep) - 1
        summed = np.zeros(int(keep.sum()), dtype=np.float64)
        np.add.at(summed, group, vals.astype(np.float64))
        vals = summed
    elif duplicates == "first":
        vals = vals[keep]
    else:
        raise ValueError(f"duplicates must be 'first' or 'sum', got {duplicates!r}")
    rows, cols = rows[keep], cols[keep]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    out = CSRMatrix(
        n=n,
        indptr=indptr,
        indices=cols.astype(np.int32),
        data=vals.astype(np.float32),
    )
    if __debug__:
        out.validate()
    return out


def banded(n: int, bandwidth: int, rng: np.random.Generator, fill: float = 0.6) -> CSRMatrix:
    """Random banded matrix: |i-j| <= bandwidth with density ``fill``."""
    rows_l, cols_l = [], []
    for i in range(n):
        lo, hi = max(0, i - bandwidth), min(n, i + bandwidth + 1)
        js = np.arange(lo, hi)
        mask = rng.random(js.size) < fill
        mask[js == i] = True  # keep the diagonal
        js = js[mask]
        rows_l.append(np.full(js.size, i))
        cols_l.append(js)
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    vals = rng.normal(size=rows.size)
    return _from_coo(n, rows, cols, vals)


def audikw_like(
    n: int, rng: np.random.Generator, bandwidth: int | None = None, dense_frac: float = 0.05
) -> CSRMatrix:
    """Banded + dense top rows and left columns (audikw_1's pattern, Fig 4.1)."""
    bandwidth = bandwidth or max(2, n // 32)
    base = banded(n, bandwidth, rng)
    k = max(1, int(n * dense_frac))
    extra_rows, extra_cols = [], []
    # dense top rows
    for i in range(k):
        js = np.where(rng.random(n) < 0.5)[0]
        extra_rows.append(np.full(js.size, i))
        extra_cols.append(js)
        # symmetric: dense left columns
        extra_rows.append(js)
        extra_cols.append(np.full(js.size, i))
    rows = np.concatenate(
        [np.repeat(np.arange(n), np.diff(base.indptr))] + extra_rows
    )
    cols = np.concatenate([base.indices] + extra_cols)
    vals = np.concatenate([base.data, rng.normal(size=rows.size - base.nnz)])
    return _from_coo(n, rows, cols, vals.astype(np.float32))


def thermal_like(n: int, rng: np.random.Generator) -> CSRMatrix:
    """2D 5-point stencil on a sqrt(n) x sqrt(n) grid (thermal2 regime)."""
    side = int(np.floor(np.sqrt(n)))
    n = side * side
    idx = np.arange(n)
    x, y = idx % side, idx // side
    rows_l, cols_l = [idx], [idx]
    for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        nx, ny = x + dx, y + dy
        ok = (0 <= nx) & (nx < side) & (0 <= ny) & (ny < side)
        rows_l.append(idx[ok])
        cols_l.append((ny * side + nx)[ok])
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    vals = rng.normal(size=rows.size)
    return _from_coo(n, rows, cols, vals)


def random_block(n: int, density: float, rng: np.random.Generator) -> CSRMatrix:
    """Uniform random sparsity (all-to-all communication regime)."""
    nnz = max(n, int(n * n * density))
    rows = rng.integers(0, n, size=nnz)
    cols = rng.integers(0, n, size=nnz)
    diag = np.arange(n)
    rows = np.concatenate([rows, diag])
    cols = np.concatenate([cols, diag])
    vals = rng.normal(size=rows.size)
    return _from_coo(n, rows, cols, vals)


GENERATORS: Dict[str, Callable[..., CSRMatrix]] = {
    "audikw_like": audikw_like,
    "thermal_like": thermal_like,
    "random_block": lambda n, rng: random_block(n, 16.0 / n, rng),
}
