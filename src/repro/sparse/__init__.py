"""Distributed sparse matrix substrate (the paper's SpMV case study)."""

from repro.sparse.matrices import (
    GENERATORS,
    CSRMatrix,
    audikw_like,
    banded,
    random_block,
    thermal_like,
)
from repro.sparse.partition import EllBlock, SpmvPartition, partition_csr
from repro.sparse.spmv import DistributedSpMV, build, reference, reference_mm

__all__ = [
    "GENERATORS",
    "CSRMatrix",
    "audikw_like",
    "banded",
    "random_block",
    "thermal_like",
    "EllBlock",
    "SpmvPartition",
    "partition_csr",
    "DistributedSpMV",
    "build",
    "reference",
    "reference_mm",
]
