"""Row-wise partitioning of a sparse matrix for distributed SpMV (paper §2.4.1).

``A``, ``v``, ``w`` are partitioned row-wise across ``g`` ranks with
contiguous rows per rank.  Each rank's rows split into the **on-rank block**
(columns it owns) and the **off-rank block** (columns owned elsewhere); the
off-rank column set induces the irregular point-to-point pattern
(:class:`repro.comm.exchange.ExchangePattern`) the paper studies.

Local storage is blocked-ELL (rows x max_nnz_per_row), the TPU-friendly
layout consumed by :mod:`repro.kernels.spmv_ell`: column ids of the off-rank
block are rewritten to positions in the canonical halo buffer produced by the
exchange.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

from repro.comm.exchange import ExchangePattern, Need
from repro.comm.topology import PodTopology
from repro.sparse.matrices import CSRMatrix


@dataclasses.dataclass(frozen=True)
class EllBlock:
    """Padded ELL block: ``w[i] += sum_k data[i,k] * x[cols[i,k]]``.

    Padding entries have ``data == 0`` and ``cols == 0``.
    """

    data: np.ndarray  # [rows, K] float32
    cols: np.ndarray  # [rows, K] int32


@dataclasses.dataclass(frozen=True)
class SpmvPartition:
    """Everything each rank needs, stacked over ranks (leading dim nranks)."""

    topo: PodTopology
    rows_per_rank: int
    pattern: ExchangePattern
    # stacked blocked-ELL storage, one slice per rank:
    diag: EllBlock  # cols index into the rank's own v slice [0, L)
    off: EllBlock  # cols index into the canonical halo buffer [0, H)
    halo_width: int
    #: structural off-rank nonzeros per row ``[nranks * L]`` -- the
    #: interior/boundary classifier for split-phase compute (a row with 0
    #: has a pure-padding off-ELL row, including explicitly stored zeros)
    off_row_nnz: np.ndarray

    @property
    def n(self) -> int:
        return self.topo.nranks * self.rows_per_rank


def partition_csr(matrix: CSRMatrix, topo: PodTopology) -> SpmvPartition:
    """Partition ``matrix`` row-wise over ``topo.nranks`` ranks."""
    g = topo.nranks
    if matrix.n % g:
        raise ValueError(f"matrix dim {matrix.n} not divisible by {g} ranks")
    L = matrix.n // g

    def owner(col: int) -> int:
        return col // L

    # 1. per-rank column dependencies -> exchange pattern
    needs_by_pair: Dict[Tuple[int, int], set] = defaultdict(set)
    for r in range(g):
        for i in range(r * L, (r + 1) * L):
            cols, _ = matrix.row(i)
            for c in cols:
                o = owner(int(c))
                if o != r:
                    needs_by_pair[(r, o)].add(int(c) - o * L)
    needs = tuple(
        Need(dst=dst, src=src, idx=tuple(sorted(elems)))
        for (dst, src), elems in sorted(needs_by_pair.items())
    )
    pattern = ExchangePattern(topo=topo, local_size=L, needs=needs)

    # 2. canonical halo layout: position of (owner, elem) in dst's recv buffer
    halo_pos: List[Dict[Tuple[int, int], int]] = []
    for r in range(g):
        pos = {tok: k for k, tok in enumerate(pattern.canonical_tokens(r))}
        halo_pos.append(pos)
    H = max(pattern.max_recv_size(), 1)

    # 3. per-rank ELL blocks with rewritten column ids
    kd = ko = 1
    for r in range(g):
        for i in range(r * L, (r + 1) * L):
            cols, _ = matrix.row(i)
            on = sum(owner(int(c)) == r for c in cols)
            kd = max(kd, on)
            ko = max(ko, len(cols) - on)

    diag_data = np.zeros((g, L, kd), dtype=np.float32)
    diag_cols = np.zeros((g, L, kd), dtype=np.int32)
    off_data = np.zeros((g, L, ko), dtype=np.float32)
    off_cols = np.zeros((g, L, ko), dtype=np.int32)
    off_row_nnz = np.zeros(g * L, dtype=np.int64)
    for r in range(g):
        for li in range(L):
            cols, vals = matrix.row(r * L + li)
            di = oi = 0
            for c, vv in zip(cols, vals):
                o = owner(int(c))
                if o == r:
                    diag_data[r, li, di] = vv
                    diag_cols[r, li, di] = int(c) - r * L
                    di += 1
                else:
                    off_data[r, li, oi] = vv
                    off_cols[r, li, oi] = halo_pos[r][(o, int(c) - o * L)]
                    oi += 1
            off_row_nnz[r * L + li] = oi

    return SpmvPartition(
        topo=topo,
        rows_per_rank=L,
        pattern=pattern,
        diag=EllBlock(data=diag_data.reshape(g * L, kd), cols=diag_cols.reshape(g * L, kd)),
        off=EllBlock(data=off_data.reshape(g * L, ko), cols=off_cols.reshape(g * L, ko)),
        halo_width=H,
        off_row_nnz=off_row_nnz,
    )
