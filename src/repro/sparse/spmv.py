"""Distributed SpMV with pluggable node-aware communication (paper §2.4, §5).

``A`` is row-partitioned over the mesh; each step is

    halo = exchange(v)                      # irregular p2p, chosen strategy
    w    = A_diag @ v_local + A_off @ halo  # local blocked-ELL SpMV

The exchange is an :class:`repro.comm.strategies.IrregularExchange` planned by
the selected strategy; ``strategy="auto"`` asks the model-driven advisor
(paper §4.6) to pick.  The local SpMV runs the Pallas blocked-ELL kernel
(interpret mode on CPU) or its jnp oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm.strategies import IrregularExchange
from repro.compat import shard_map
from repro.comm.topology import WORLD_AXES, PodTopology, make_exchange_mesh
from repro.core.advisor import advise
from repro.core.perfmodel import Strategy, Transport
from repro.kernels import ref as kref
from repro.kernels.spmv_ell import spmv_ell as spmv_ell_kernel
from repro.sparse.matrices import CSRMatrix
from repro.sparse.partition import SpmvPartition, partition_csr

#: advisor Strategy -> executable strategy name
_ADVISED = {
    Strategy.STANDARD: "standard",
    Strategy.TWO_STEP: "two_step",
    Strategy.TWO_STEP_ONE: "two_step",
    Strategy.THREE_STEP: "three_step",
    Strategy.SPLIT_MD: "split",
    Strategy.SPLIT_DD: "split",
}


@dataclasses.dataclass
class DistributedSpMV:
    """A compiled distributed SpMV for one matrix, topology and strategy."""

    partition: SpmvPartition
    strategy: str = "auto"
    message_cap_bytes: int = 16384
    use_pallas: bool = True
    mesh: Optional[jax.sharding.Mesh] = None
    fuse_program: bool = True

    def __post_init__(self) -> None:
        topo = self.partition.topo
        if self.strategy == "auto":
            advice = advise(
                self.partition.pattern.to_comm_pattern(), machine="tpu_v5e_pod"
            )
            self.advice = advice
            self.strategy = _ADVISED[advice.best.strategy]
        else:
            self.advice = None
        if self.mesh is None:
            self.mesh = make_exchange_mesh(topo)
        # The exchange's plan + jitted executor come from the module-level
        # caches in repro.comm.strategies, so rebuilding for the same matrix
        # partition skips planning and the exchange jit.  The local-SpMV
        # _compute below is still re-jitted per construction.
        self.exchange = IrregularExchange(
            self.partition.pattern,
            self.strategy,
            mesh=self.mesh,
            message_cap_bytes=self.message_cap_bytes,
            fuse_program=self.fuse_program,
        )
        L = self.partition.rows_per_rank
        g = topo.nranks
        use_pallas = self.use_pallas

        diag_d = jnp.asarray(self.partition.diag.data.reshape(g, L, -1))
        diag_c = jnp.asarray(self.partition.diag.cols.reshape(g, L, -1))
        off_d = jnp.asarray(self.partition.off.data.reshape(g, L, -1))
        off_c = jnp.asarray(self.partition.off.cols.reshape(g, L, -1))

        def local_spmv(data, cols, x):
            if use_pallas:
                return spmv_ell_kernel(data, cols, x, interpret=True)
            return kref.spmv_ell(data, cols, x)

        def compute(v_local, halo, dd, dc, od, oc):
            # leading rank dim is 1 inside shard_map
            v_local, halo = v_local[0], halo[0]
            w = local_spmv(dd[0], dc[0], v_local) + local_spmv(od[0], oc[0], halo)
            return w[None]

        self._compute = jax.jit(
            shard_map(
                compute,
                mesh=self.mesh,
                in_specs=(P(WORLD_AXES),) * 6,
                out_specs=P(WORLD_AXES),
                check_vma=False,  # pallas_call does not yet annotate vma
            )
        )
        self._blocks = (diag_d, diag_c, off_d, off_c)

    # ------------------------------------------------------------------
    def __call__(self, v: jax.Array) -> jax.Array:
        """``v [nranks, L] -> w [nranks, L]``."""
        halo = self.exchange(v)
        return self._compute(v, halo, *self._blocks)

    def halo(self, v: jax.Array) -> jax.Array:
        """Exchange-only entry point.

        Accepts batched payloads ``[nranks, L, k]`` (multi-vector SpMM /
        batched serving) under the same plan; see
        :meth:`repro.comm.strategies.IrregularExchange.__call__`.
        """
        return self.exchange(v)

    # ------------------------------------------------------------------
    @property
    def wire_bytes(self) -> Tuple[int, int]:
        return self.exchange.wire_bytes


def build(
    matrix: CSRMatrix,
    topo: PodTopology,
    strategy: str = "auto",
    **kw,
) -> DistributedSpMV:
    return DistributedSpMV(partition_csr(matrix, topo), strategy=strategy, **kw)


def reference(matrix: CSRMatrix, v_flat: np.ndarray) -> np.ndarray:
    """Sequential oracle on the unpartitioned matrix."""
    return matrix.spmv(v_flat)
