"""Distributed SpMV/SpMM with pluggable node-aware communication (paper §2.4, §5).

``A`` is row-partitioned over the mesh; each step is

    halo = exchange(v)                      # irregular p2p, chosen strategy
    w    = A_diag @ v_local + A_off @ halo  # local blocked-ELL SpMV

The exchange is an :class:`repro.comm.strategies.IrregularExchange` planned by
the selected strategy; ``strategy="auto"`` asks the model-driven advisor
(paper §4.6) to pick, with ``payload_width`` feeding the advisor's batched
byte terms.  The local compute runs the Pallas blocked-ELL kernels
(interpret mode on CPU) or their jnp oracles.

Multi-vector products (``V: [nranks, L, k]``) are first-class: one exchange
moves all ``k`` columns under the single cached plan and one fused blocked-ELL
SpMM replaces the per-column Python loop (:meth:`DistributedSpMV.matmat`).

``overlap=True`` replaces the barrier step with the split-phase pipeline
(paper §4.6 closing discussion: hide inter-node latency behind on-node work):

    handle = exchange.start(v)   # inter-pod phase in flight; on-pod done
    w_diag = A_diag @ v_local    # halo-independent: every row tile overlaps
    halo   = handle.finish()
    w_off  = A_off @ halo        # boundary row tiles only
    w      = w_diag + w_off

The boundary row set -- rows whose off-rank ELL row holds a *stored* entry
(structural ``off_row_nnz``, value-independent) -- comes from
:func:`repro.core.split_plan.split_rows` at kernel row-tile granularity;
interior tiles' off-block is pure padding and is skipped outright.  (Note
the off-rank block covers *all* non-owned columns, on-pod and inter-pod
alike, so even rows that only read on-pod neighbours count as boundary and
wait for ``finish()``.)  Both passes run the same tile-masked blocked-ELL
kernel, so with the Pallas kernels (the default) the overlapped result is
bit-identical to the barrier result for every strategy; the jnp-oracle
flavor (``use_pallas=False``) agrees to ~1 ulp because XLA fuses the
barrier program's two reductions.  Finite inputs are assumed, as everywhere
in the ELL layout: a padding slot computes ``0 * x[0]``, so a non-finite
value in slot 0 would poison padded rows in the barrier path but not in
the skipped interior tiles.

The local-compute programs are compiled once per
``(pattern fingerprint, payload width k, kernel flavor, mesh)`` into a
module-level LRU shared with the exchange plan/executor caches -- inspect via
``repro.comm.cache_stats()`` (``compute_hits`` / ``compute_misses``): distinct
``k`` widths get distinct compile entries while the exchange keeps exactly one
plan entry per pattern fingerprint.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import strategies as comm_strategies
from repro.comm.strategies import IrregularExchange
from repro.compat import shard_map
from repro.comm.topology import WORLD_AXES, PodTopology, make_exchange_mesh
from repro.core.advisor import EXECUTABLE_STRATEGY, advise
from repro.core.perfmodel import Strategy, Transport
from repro.core.split_plan import RowPhaseSplit, split_rows
from repro.kernels import ref as kref
from repro.kernels.spmv_ell import TILE_R, TILE_R_MM
from repro.kernels.spmv_ell import spmm_ell as spmm_ell_kernel
from repro.kernels.spmv_ell import spmv_ell as spmv_ell_kernel
from repro.sparse.matrices import CSRMatrix
from repro.sparse.partition import SpmvPartition, partition_csr

#: advisor Strategy -> executable strategy name (canonical copy lives with
#: the advisor so the fault ladder's re-advising shares one mapping)
_ADVISED = EXECUTABLE_STRATEGY

# ---------------------------------------------------------------------------
# Local-compute compile cache
# ---------------------------------------------------------------------------

#: jitted local-compute programs keyed by
#: ``(pattern fingerprint, width, use_pallas, mesh)`` where ``width`` is the
#: payload column count ``k`` (``None`` = the unbatched SpMV program).  One
#: entry per (fingerprint, k): repeated construction / repeated ``matmat(k)``
#: calls reuse the jitted program, and new widths never evict the exchange's
#: single per-fingerprint plan entry.
_COMPUTE_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
COMPUTE_CACHE_MAX = 64
comm_strategies.register_cache(_COMPUTE_CACHE)


def _compute_program(
    fingerprint: str,
    mesh: jax.sharding.Mesh,
    use_pallas: bool,
    width: Optional[int],
):
    """Build (or fetch) the jitted shard_map local-compute program.

    ``width=None`` is the vector program (``v: [nranks, L]``); ``width=k``
    is the fused SpMM program (``V: [nranks, L, k]``).
    """
    key = (fingerprint, width, use_pallas, comm_strategies._mesh_key(mesh))

    def build():
        if width is None:
            def local(data, cols, x):
                if use_pallas:
                    return spmv_ell_kernel(data, cols, x, interpret=True)
                return kref.spmv_ell(data, cols, x)
        else:
            def local(data, cols, x):
                if use_pallas:
                    return spmm_ell_kernel(data, cols, x, interpret=True)
                return kref.spmm_ell(data, cols, x)

        def compute(v_local, halo, dd, dc, od, oc):
            # leading rank dim is 1 inside shard_map
            v_local, halo = v_local[0], halo[0]
            w = local(dd[0], dc[0], v_local) + local(od[0], oc[0], halo)
            return w[None]

        return jax.jit(
            shard_map(
                compute,
                mesh=mesh,
                in_specs=(P(WORLD_AXES),) * 6,
                out_specs=P(WORLD_AXES),
                check_vma=False,  # pallas_call does not yet annotate vma
            )
        )

    return comm_strategies.compute_cached(
        _COMPUTE_CACHE, key, COMPUTE_CACHE_MAX, build
    )


def _phase_program(
    fingerprint: str,
    mesh: jax.sharding.Mesh,
    use_pallas: bool,
    width: Optional[int],
):
    """Build (or fetch) the tile-masked one-block program of the overlapped
    local compute: ``x, (data, cols), masks -> block @ x`` on active tiles.

    The split-phase pipeline runs it twice per step: once for the
    halo-independent diag block (every row tile, while the inter-node
    exchange is in flight) and once for the halo-dependent off block after
    ``handle.finish()``, masked to the boundary row tiles (an interior
    tile's off-block rows are pure padding, so skipping them changes
    nothing).  Both runs use the SAME blocked-ELL kernel as the barrier
    path and the final ``diag + off`` add matches the barrier program's
    summation, so the overlapped result is bit-identical to it with the
    Pallas kernels (the jnp oracle agrees to ~1 ulp; see module docstring).
    """
    key = (fingerprint, width, use_pallas, "phase", comm_strategies._mesh_key(mesh))

    def build():
        if width is None:
            def local(data, cols, x, tiles, rows):
                if use_pallas:
                    return spmv_ell_kernel(data, cols, x, interpret=True, tile_mask=tiles)
                return kref.spmv_ell_masked(data, cols, x, rows)
        else:
            def local(data, cols, x, tiles, rows):
                if use_pallas:
                    return spmm_ell_kernel(data, cols, x, interpret=True, tile_mask=tiles)
                return kref.spmm_ell_masked(data, cols, x, rows)

        def compute(x, data, cols, tiles, rows):
            return local(data[0], cols[0], x[0], tiles[0], rows[0])[None]

        return jax.jit(
            shard_map(
                compute,
                mesh=mesh,
                in_specs=(P(WORLD_AXES),) * 5,
                out_specs=P(WORLD_AXES),
                check_vma=False,
            )
        )

    return comm_strategies.compute_cached(
        _COMPUTE_CACHE, key, COMPUTE_CACHE_MAX, build
    )


@dataclasses.dataclass
class DistributedSpMV:
    """A compiled distributed SpMV/SpMM for one matrix, topology and strategy.

    ``payload_width`` is the expected multi-vector column count ``k`` fed to
    the advisor when ``strategy="auto"`` -- larger widths amortize per-message
    latency and can flip the advised strategy into the bandwidth-bound regime.
    Any width can still be executed regardless of the advised-time value.

    ``overlap=True`` switches ``__call__``/:meth:`matmat` to the split-phase
    pipeline: the exchange runs as ``start()``/``finish()``
    (:meth:`repro.comm.strategies.IrregularExchange.start`), the whole
    halo-independent diag-block product computes while the inter-node phase
    is in flight, and only the boundary row tiles' off-block product (see
    :func:`repro.core.split_plan.split_rows`) runs after ``finish()``.
    Results are bit-compatible with the barrier path for every strategy.

    ``wire`` selects the exchange's inter-pod codec
    (:data:`repro.comm.wire.WIRE_CODECS`): halo values arriving from other
    pods carry the codec's pinned error bound while on-pod halo values stay
    full precision; ``wire="none"`` (the default) is bitwise identical to
    the codec-free path.  ``wire="auto"`` lets the advisor rank
    ``+wire:<codec>`` variants and picks the codec jointly with the
    strategy (``strategy="auto"``) or the fastest codec for a fixed
    strategy.

    Example (needs >= ``topo.nranks`` devices, e.g. via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)::

        import numpy as np
        from repro.comm import PodTopology
        from repro.sparse import build, thermal_like

        A = thermal_like(256, np.random.default_rng(0))
        topo = PodTopology(npods=2, ppn=4)
        sp = build(A, topo, strategy="auto", payload_width=8, overlap=True)

        V = np.ones((A.n, 8), np.float32)          # 8 right-hand sides
        W = sp.matmat(V.reshape(topo.nranks, -1, 8))  # ONE exchange, overlapped
    """

    partition: SpmvPartition
    strategy: str = "auto"
    message_cap_bytes: int = 16384
    use_pallas: bool = True
    mesh: Optional[jax.sharding.Mesh] = None
    fuse_program: bool = True
    payload_width: int = 1
    overlap: bool = False
    wire: str = "none"
    #: opt-in wire integrity verification on the exchange (see
    #: :class:`repro.comm.strategies.IrregularExchange`)
    verify: bool = False
    #: seeded deterministic fault injection (repro.comm.faults.FaultPlan)
    faults: Optional[object] = None
    #: shared health tracker for the recovery ladder / watchdog
    health: Optional[object] = None

    def __post_init__(self) -> None:
        topo = self.partition.topo
        if self.strategy == "auto" or self.wire == "auto":
            advice = advise(
                self.partition.pattern.to_comm_pattern(),
                machine="tpu_v5e_pod",
                payload_width=self.payload_width,
                # "auto" ranks every codec; a fixed codec constrains the
                # candidate set; "none" keeps the paper's ranking
                wire="auto" if self.wire == "auto" else (
                    None if self.wire == "none" else self.wire
                ),
            )
            self.advice = advice
            best = advice.best
            if self.strategy != "auto":
                # wire="auto" with a pinned strategy: fastest codec among
                # this strategy's own variants
                best = next(
                    (
                        r for r in advice.ranked
                        if _ADVISED[r.strategy] == self.strategy
                    ),
                    None,
                )
                if best is None:
                    raise ValueError(
                        f"unknown strategy {self.strategy!r}; known: "
                        f"{sorted(set(_ADVISED.values()))}"
                    )
            self.strategy = _ADVISED[best.strategy]
            if self.wire == "auto":
                self.wire = best.wire
        else:
            self.advice = None
        if self.mesh is None:
            self.mesh = make_exchange_mesh(topo)
        # The exchange's plan + jitted executor and the local-compute programs
        # all come from module-level caches (repro.comm.strategies plus
        # _COMPUTE_CACHE above), so rebuilding for the same matrix partition
        # skips planning and every jit.
        self.exchange = IrregularExchange(
            self.partition.pattern,
            self.strategy,
            mesh=self.mesh,
            message_cap_bytes=self.message_cap_bytes,
            fuse_program=self.fuse_program,
            wire=self.wire,
            verify=self.verify,
            faults=self.faults,
            health=self.health,
        )
        # the exchange owns (and may have created) the shared tracker
        self.health = self.exchange.health
        L = self.partition.rows_per_rank
        g = topo.nranks

        diag_d = jnp.asarray(self.partition.diag.data.reshape(g, L, -1))
        diag_c = jnp.asarray(self.partition.diag.cols.reshape(g, L, -1))
        off_d = jnp.asarray(self.partition.off.data.reshape(g, L, -1))
        off_c = jnp.asarray(self.partition.off.cols.reshape(g, L, -1))

        self._fingerprint = self.partition.pattern.fingerprint()
        self._compute = _compute_program(
            self._fingerprint, self.mesh, self.use_pallas, None
        )
        self._blocks = (diag_d, diag_c, off_d, off_c)
        # per-instance memo over the module LRU: matmat's hot path must not
        # re-derive the (fingerprint, k, mesh) key per call
        self._mm_programs: dict = {}

        self._row_splits: dict = {}
        if self.overlap:
            self._masks_v = self._phase_masks(self.row_split, L)
            self._masks_mm = self._phase_masks(self.row_split_mm, L)
            self._phase_fn = _phase_program(
                self._fingerprint, self.mesh, self.use_pallas, None
            )
            self._mm_phase_programs: dict = {}

    def _row_split(self, tile_rows: int) -> RowPhaseSplit:
        """Interior/boundary row split (the overlap enabler), lazily built.

        Classification is *structural*: a row is boundary iff its off-rank
        ELL row holds at least one stored entry (``off_row_nnz > 0``), so an
        explicitly stored zero still counts as a halo dependency and the
        split never depends on matrix values.
        """
        split = self._row_splits.get(tile_rows)
        if split is None:
            g, L = self.partition.topo.nranks, self.partition.rows_per_rank
            halo_dep = self.partition.off_row_nnz.reshape(g, L) > 0
            split = self._row_splits[tile_rows] = split_rows(halo_dep, tile_rows)
        return split

    @property
    def row_split(self) -> RowPhaseSplit:
        """Row split at the SpMV kernel's tile size."""
        return self._row_split(TILE_R)

    @property
    def row_split_mm(self) -> RowPhaseSplit:
        """Row split at the SpMM kernel's tile size."""
        return self._row_split(TILE_R_MM)

    @staticmethod
    def _phase_masks(split: RowPhaseSplit, L: int):
        """Device arrays for one tile size: the all-tiles mask pair (the
        diag pass) and the boundary mask pair (the off pass), each as
        (tile mask, tile-expanded row mask)."""
        g, ntiles = split.interior_tiles.shape
        bnd = split.boundary_tiles
        bnd_rows = np.repeat(bnd, split.tile_rows, axis=1)[:, :L]
        return (
            jnp.ones((g, ntiles), np.int32),
            jnp.ones((g, L), bool),
            jnp.asarray(bnd.astype(np.int32)),
            jnp.asarray(bnd_rows),
        )

    # ------------------------------------------------------------------
    def __call__(self, v: jax.Array) -> jax.Array:
        """``v [nranks, L] -> w [nranks, L]``; a trailing feature dim
        (``[nranks, L, k]``) dispatches to :meth:`matmat`."""
        if v.ndim == 3:
            return self.matmat(v)
        if not self.overlap:
            halo = self.exchange(v)
            return self._compute(v, halo, *self._blocks)
        all_tiles, all_rows, bnd_tiles, bnd_rows = self._masks_v
        handle = self.exchange.start(v)
        # the whole halo-independent diag block runs while the inter-pod
        # phase is in flight; only boundary tiles' off-block waits on it
        w_diag = self._phase_fn(v, *self._blocks[:2], all_tiles, all_rows)
        halo = handle.finish()
        w_off = self._phase_fn(halo, *self._blocks[2:], bnd_tiles, bnd_rows)
        return w_diag + w_off

    def matmat(self, V: jax.Array) -> jax.Array:
        """``V [nranks, L, k] -> W [nranks, L, k]`` under ONE exchange.

        All ``k`` columns ride the single cached plan
        (:meth:`repro.comm.strategies.IrregularExchange.__call__`) and the
        local compute is one fused blocked-ELL SpMM per block -- no Python
        loop over columns.  The compiled program is cached per
        ``(pattern fingerprint, k)``.  With ``overlap=True`` the exchange is
        split-phase and the diag-block SpMM computes during the inter-node
        phase.
        """
        if V.ndim != 3:
            raise ValueError(f"matmat expects [nranks, L, k], got {tuple(V.shape)}")
        k = int(V.shape[2])
        if not self.overlap:
            halo = self.exchange(V)
            fn = self._mm_programs.get(k)
            if fn is None:
                fn = self._mm_programs[k] = _compute_program(
                    self._fingerprint, self.mesh, self.use_pallas, k
                )
            return fn(V, halo, *self._blocks)
        fn = self._mm_phase_programs.get(k)
        if fn is None:
            fn = self._mm_phase_programs[k] = _phase_program(
                self._fingerprint, self.mesh, self.use_pallas, k
            )
        all_tiles, all_rows, bnd_tiles, bnd_rows = self._masks_mm
        handle = self.exchange.start(V)
        w_diag = fn(V, *self._blocks[:2], all_tiles, all_rows)
        halo = handle.finish()
        w_off = fn(halo, *self._blocks[2:], bnd_tiles, bnd_rows)
        return w_diag + w_off

    def matmat_looped(self, V: jax.Array) -> jax.Array:
        """Per-column baseline: ``k`` exchanges + ``k`` local SpMVs.

        Kept as the comparison path for benchmarks/tests; :meth:`matmat` is
        the serving path.
        """
        if V.ndim != 3:
            raise ValueError(f"matmat_looped expects [nranks, L, k], got {tuple(V.shape)}")
        cols = [self(V[:, :, c]) for c in range(V.shape[2])]
        return jnp.stack(cols, axis=-1)

    def halo(self, v: jax.Array) -> jax.Array:
        """Exchange-only entry point.

        Accepts batched payloads ``[nranks, L, k]`` (multi-vector SpMM /
        batched serving) under the same plan; see
        :meth:`repro.comm.strategies.IrregularExchange.__call__`.
        """
        return self.exchange(v)

    # ------------------------------------------------------------------
    @property
    def topo(self) -> PodTopology:
        """The pod topology (the solver-facing operator contract shared
        with :class:`repro.solve.operator.NumpySpMV`)."""
        return self.partition.topo

    @property
    def rows_per_rank(self) -> int:
        return self.partition.rows_per_rank

    @property
    def wire_bytes(self) -> Tuple[int, int]:
        return self.exchange.wire_bytes


def build(
    matrix: CSRMatrix,
    topo: PodTopology,
    strategy: str = "auto",
    **kw,
) -> DistributedSpMV:
    return DistributedSpMV(partition_csr(matrix, topo), strategy=strategy, **kw)


def reference(matrix: CSRMatrix, v_flat: np.ndarray) -> np.ndarray:
    """Sequential oracle on the unpartitioned matrix."""
    return matrix.spmv(v_flat)


def reference_mm(matrix: CSRMatrix, V_flat: np.ndarray) -> np.ndarray:
    """Sequential multi-vector oracle on the unpartitioned matrix."""
    return matrix.spmm(V_flat)
