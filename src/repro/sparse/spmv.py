"""Distributed SpMV/SpMM with pluggable node-aware communication (paper §2.4, §5).

``A`` is row-partitioned over the mesh; each step is

    halo = exchange(v)                      # irregular p2p, chosen strategy
    w    = A_diag @ v_local + A_off @ halo  # local blocked-ELL SpMV

The exchange is an :class:`repro.comm.strategies.IrregularExchange` planned by
the selected strategy; ``strategy="auto"`` asks the model-driven advisor
(paper §4.6) to pick, with ``payload_width`` feeding the advisor's batched
byte terms.  The local compute runs the Pallas blocked-ELL kernels
(interpret mode on CPU) or their jnp oracles.

Multi-vector products (``V: [nranks, L, k]``) are first-class: one exchange
moves all ``k`` columns under the single cached plan and one fused blocked-ELL
SpMM replaces the per-column Python loop (:meth:`DistributedSpMV.matmat`).

The local-compute programs are compiled once per
``(pattern fingerprint, payload width k, kernel flavor, mesh)`` into a
module-level LRU shared with the exchange plan/executor caches -- inspect via
``repro.comm.cache_stats()`` (``compute_hits`` / ``compute_misses``): distinct
``k`` widths get distinct compile entries while the exchange keeps exactly one
plan entry per pattern fingerprint.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import strategies as comm_strategies
from repro.comm.strategies import IrregularExchange
from repro.compat import shard_map
from repro.comm.topology import WORLD_AXES, PodTopology, make_exchange_mesh
from repro.core.advisor import advise
from repro.core.perfmodel import Strategy, Transport
from repro.kernels import ref as kref
from repro.kernels.spmv_ell import spmm_ell as spmm_ell_kernel
from repro.kernels.spmv_ell import spmv_ell as spmv_ell_kernel
from repro.sparse.matrices import CSRMatrix
from repro.sparse.partition import SpmvPartition, partition_csr

#: advisor Strategy -> executable strategy name
_ADVISED = {
    Strategy.STANDARD: "standard",
    Strategy.TWO_STEP: "two_step",
    Strategy.TWO_STEP_ONE: "two_step",
    Strategy.THREE_STEP: "three_step",
    Strategy.SPLIT_MD: "split",
    Strategy.SPLIT_DD: "split",
}

# ---------------------------------------------------------------------------
# Local-compute compile cache
# ---------------------------------------------------------------------------

#: jitted local-compute programs keyed by
#: ``(pattern fingerprint, width, use_pallas, mesh)`` where ``width`` is the
#: payload column count ``k`` (``None`` = the unbatched SpMV program).  One
#: entry per (fingerprint, k): repeated construction / repeated ``matmat(k)``
#: calls reuse the jitted program, and new widths never evict the exchange's
#: single per-fingerprint plan entry.
_COMPUTE_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
COMPUTE_CACHE_MAX = 64
comm_strategies.register_cache(_COMPUTE_CACHE)


def _compute_program(
    fingerprint: str,
    mesh: jax.sharding.Mesh,
    use_pallas: bool,
    width: Optional[int],
):
    """Build (or fetch) the jitted shard_map local-compute program.

    ``width=None`` is the vector program (``v: [nranks, L]``); ``width=k``
    is the fused SpMM program (``V: [nranks, L, k]``).
    """
    key = (fingerprint, width, use_pallas, comm_strategies._mesh_key(mesh))

    def build():
        if width is None:
            def local(data, cols, x):
                if use_pallas:
                    return spmv_ell_kernel(data, cols, x, interpret=True)
                return kref.spmv_ell(data, cols, x)
        else:
            def local(data, cols, x):
                if use_pallas:
                    return spmm_ell_kernel(data, cols, x, interpret=True)
                return kref.spmm_ell(data, cols, x)

        def compute(v_local, halo, dd, dc, od, oc):
            # leading rank dim is 1 inside shard_map
            v_local, halo = v_local[0], halo[0]
            w = local(dd[0], dc[0], v_local) + local(od[0], oc[0], halo)
            return w[None]

        return jax.jit(
            shard_map(
                compute,
                mesh=mesh,
                in_specs=(P(WORLD_AXES),) * 6,
                out_specs=P(WORLD_AXES),
                check_vma=False,  # pallas_call does not yet annotate vma
            )
        )

    return comm_strategies.compute_cached(
        _COMPUTE_CACHE, key, COMPUTE_CACHE_MAX, build
    )


@dataclasses.dataclass
class DistributedSpMV:
    """A compiled distributed SpMV/SpMM for one matrix, topology and strategy.

    ``payload_width`` is the expected multi-vector column count ``k`` fed to
    the advisor when ``strategy="auto"`` -- larger widths amortize per-message
    latency and can flip the advised strategy into the bandwidth-bound regime.
    Any width can still be executed regardless of the advised-time value.
    """

    partition: SpmvPartition
    strategy: str = "auto"
    message_cap_bytes: int = 16384
    use_pallas: bool = True
    mesh: Optional[jax.sharding.Mesh] = None
    fuse_program: bool = True
    payload_width: int = 1

    def __post_init__(self) -> None:
        topo = self.partition.topo
        if self.strategy == "auto":
            advice = advise(
                self.partition.pattern.to_comm_pattern(),
                machine="tpu_v5e_pod",
                payload_width=self.payload_width,
            )
            self.advice = advice
            self.strategy = _ADVISED[advice.best.strategy]
        else:
            self.advice = None
        if self.mesh is None:
            self.mesh = make_exchange_mesh(topo)
        # The exchange's plan + jitted executor and the local-compute programs
        # all come from module-level caches (repro.comm.strategies plus
        # _COMPUTE_CACHE above), so rebuilding for the same matrix partition
        # skips planning and every jit.
        self.exchange = IrregularExchange(
            self.partition.pattern,
            self.strategy,
            mesh=self.mesh,
            message_cap_bytes=self.message_cap_bytes,
            fuse_program=self.fuse_program,
        )
        L = self.partition.rows_per_rank
        g = topo.nranks

        diag_d = jnp.asarray(self.partition.diag.data.reshape(g, L, -1))
        diag_c = jnp.asarray(self.partition.diag.cols.reshape(g, L, -1))
        off_d = jnp.asarray(self.partition.off.data.reshape(g, L, -1))
        off_c = jnp.asarray(self.partition.off.cols.reshape(g, L, -1))

        self._fingerprint = self.partition.pattern.fingerprint()
        self._compute = _compute_program(
            self._fingerprint, self.mesh, self.use_pallas, None
        )
        self._blocks = (diag_d, diag_c, off_d, off_c)
        # per-instance memo over the module LRU: matmat's hot path must not
        # re-derive the (fingerprint, k, mesh) key per call
        self._mm_programs: dict = {}

    # ------------------------------------------------------------------
    def __call__(self, v: jax.Array) -> jax.Array:
        """``v [nranks, L] -> w [nranks, L]``; a trailing feature dim
        (``[nranks, L, k]``) dispatches to :meth:`matmat`."""
        if v.ndim == 3:
            return self.matmat(v)
        halo = self.exchange(v)
        return self._compute(v, halo, *self._blocks)

    def matmat(self, V: jax.Array) -> jax.Array:
        """``V [nranks, L, k] -> W [nranks, L, k]`` under ONE exchange.

        All ``k`` columns ride the single cached plan
        (:meth:`repro.comm.strategies.IrregularExchange.__call__`) and the
        local compute is one fused blocked-ELL SpMM per block -- no Python
        loop over columns.  The compiled program is cached per
        ``(pattern fingerprint, k)``.
        """
        if V.ndim != 3:
            raise ValueError(f"matmat expects [nranks, L, k], got {tuple(V.shape)}")
        halo = self.exchange(V)
        k = int(V.shape[2])
        fn = self._mm_programs.get(k)
        if fn is None:
            fn = self._mm_programs[k] = _compute_program(
                self._fingerprint, self.mesh, self.use_pallas, k
            )
        return fn(V, halo, *self._blocks)

    def matmat_looped(self, V: jax.Array) -> jax.Array:
        """Per-column baseline: ``k`` exchanges + ``k`` local SpMVs.

        Kept as the comparison path for benchmarks/tests; :meth:`matmat` is
        the serving path.
        """
        if V.ndim != 3:
            raise ValueError(f"matmat_looped expects [nranks, L, k], got {tuple(V.shape)}")
        cols = [self(V[:, :, c]) for c in range(V.shape[2])]
        return jnp.stack(cols, axis=-1)

    def halo(self, v: jax.Array) -> jax.Array:
        """Exchange-only entry point.

        Accepts batched payloads ``[nranks, L, k]`` (multi-vector SpMM /
        batched serving) under the same plan; see
        :meth:`repro.comm.strategies.IrregularExchange.__call__`.
        """
        return self.exchange(v)

    # ------------------------------------------------------------------
    @property
    def wire_bytes(self) -> Tuple[int, int]:
        return self.exchange.wire_bytes


def build(
    matrix: CSRMatrix,
    topo: PodTopology,
    strategy: str = "auto",
    **kw,
) -> DistributedSpMV:
    return DistributedSpMV(partition_csr(matrix, topo), strategy=strategy, **kw)


def reference(matrix: CSRMatrix, v_flat: np.ndarray) -> np.ndarray:
    """Sequential oracle on the unpartitioned matrix."""
    return matrix.spmv(v_flat)


def reference_mm(matrix: CSRMatrix, V_flat: np.ndarray) -> np.ndarray:
    """Sequential multi-vector oracle on the unpartitioned matrix."""
    return matrix.spmm(V_flat)
