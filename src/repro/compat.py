"""Version-tolerance shims for the small jax API surface this repo relies on.

The repo targets the newest jax spellings (``jax.shard_map`` with
``check_vma``, ``jax.tree.flatten_with_path``); older runtimes (e.g. the
0.4.x series in the CI image) expose the same functionality under
``jax.experimental.shard_map`` / ``jax.tree_util``.  Route every use through
here so call sites stay on the modern spelling.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax < 0.5: experimental module, `check_rep` keyword
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` with the replication-check kwarg name papered over."""
    kwargs = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


try:
    tree_flatten_with_path = jax.tree.flatten_with_path
except AttributeError:
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size``; on older jax, ``psum(1, axis)`` constant-folds
    to the same static size inside shard_map/pmap bodies."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
