"""Batched serving launcher: prefill a prompt batch, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --preset tiny \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.train import PRESETS
from repro.models import ExpertLoadHistogram, LMModel


def routing_counts(params, cfg, tokens, nranks: int) -> np.ndarray:
    """Measured (src rank -> dst rank) routed-token counts for served tokens.

    Replays the first MoE layer's router over the embedded token ids (the
    layer-0 approximation: later layers see residual-mixed activations, but
    the first routing decision is exact) and bins the top-k assignments by
    source shard (batch rows block-sharded over ranks, matching the dispatch
    hop's token splice) and destination shard (experts block-sharded over
    ranks).  This is the traffic matrix the dispatch hop would carry -- the
    advisor's measured histogram.
    """
    if cfg.family != "moe":
        raise ValueError(f"--advise-dispatch needs a MoE arch, got {cfg.family!r}")
    emb = np.asarray(params["embed"])  # [V, M]
    router = np.asarray(params["seg_moe"]["moe"]["router"])[0]  # [M, E]
    toks2 = np.asarray(tokens)  # [B, S] (a flat [N] is treated as B=N, S=1)
    toks = toks2.reshape(-1)
    logits = emb[toks] @ router
    k = cfg.moe.top_k
    top = np.argsort(-logits, axis=-1)[:, :k]  # [N, k]
    e_per = max(cfg.moe.n_experts // nranks, 1)
    # Source shard = block-sharded owner of the token's batch ROW, the
    # np.array_split convention the dispatch hop splices by (first B % nranks
    # ranks carry one extra row).  Flat-index binning (arange(N) * nranks // N)
    # agrees only when B % nranks == 0; on ragged batches it splits a row
    # across ranks and misattributes its traffic.
    rows = toks2.shape[0] if toks2.ndim > 1 else toks.size
    sizes = np.full(nranks, rows // nranks, dtype=np.int64)
    sizes[: rows % nranks] += 1
    owner = np.repeat(np.arange(nranks), sizes)  # [rows]
    src = np.repeat(np.repeat(owner, toks.size // rows), k)
    dst = np.minimum(top.reshape(-1) // e_per, nranks - 1)
    counts = np.zeros((nranks, nranks), dtype=np.int64)
    np.add.at(counts, (src, dst), 1)
    return counts


def dispatch_advice(params, cfg, tokens, npods: int, ppn: int,
                    machine: str = "tpu_v5e_pod"):
    """Rank exchange strategies for the traffic this serving run produced.

    Returns ``(counts, advice)``: the measured ``[nranks, nranks]`` routing
    histogram and the :class:`repro.core.Advice` ranking for it, with byte
    terms scaled by ``d_model`` (each routed token ships a d_model-wide
    activation row).
    """
    nranks = npods * ppn
    counts = routing_counts(params, cfg, tokens, nranks)
    hist = ExpertLoadHistogram(nranks)
    hist.update(counts)
    advice = hist.advise(ppn=ppn, payload_width=cfg.d_model, machine=machine)
    return counts, advice


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--advise-dispatch", action="store_true",
                    help="after serving, rank exchange strategies for the "
                         "measured MoE routing histogram (MoE archs only)")
    ap.add_argument("--npods", type=int, default=2,
                    help="pods assumed for --advise-dispatch")
    ap.add_argument("--ppn", type=int, default=4,
                    help="chips per pod assumed for --advise-dispatch")
    ap.add_argument("--simulate-serving", type=int, default=0, metavar="N",
                    help="with --advise-dispatch: replay N concurrent dispatch "
                         "requests of the measured routing pattern through the "
                         "continuous-batching simulator (repro.serving) and "
                         "report coalesced vs sequential p50/p99/throughput")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="with --simulate-serving: re-run the simulation under "
                         "a seeded fault storm (FaultPlan(SEED)) and report the "
                         "recovery-ladder outcome: faults, recoveries, sheds, "
                         "breaker probes, deadline misses, trace hash")
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d, m)
    cfg = PRESETS[args.preset](get_config(args.arch))
    model = LMModel(cfg, tp=m)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    ctx = (
        jnp.asarray(rng.normal(size=(args.batch, model.ctx_len(), cfg.d_model)), jnp.float32)
        if model.ctx_len()
        else None
    )
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    logits, cache = model.prefill(params, prompts, ctx, mesh=mesh)
    # re-home the prefill cache into max_len-deep buffers
    full = model.init_cache(args.batch, max_len, model.dtype)

    def blend(dst, src):
        if dst.shape != src.shape:
            return dst.at[tuple(slice(0, s) for s in src.shape)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    cache = jax.tree.map(blend, full, cache)
    t1 = time.time()

    decode = jax.jit(model.decode_step, static_argnames=())
    token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = [token]
    for t in range(args.gen - 1):
        logits, cache = decode(params, token, cache, jnp.int32(args.prompt_len + t))
        token = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        outs.append(token)
    gen = jnp.concatenate(outs, axis=1)
    t2 = time.time()
    print(f"prefill {args.batch}x{args.prompt_len} in {t1-t0:.2f}s; "
          f"decoded {args.gen} tokens/seq in {t2-t1:.2f}s")
    print("generated:", np.asarray(gen)[:, :10])

    if args.advise_dispatch:
        served = np.concatenate([np.asarray(prompts), np.asarray(gen)], axis=1)
        counts, advice = dispatch_advice(params, cfg, served, args.npods, args.ppn)
        print(f"dispatch advice ({args.npods} pods x {args.ppn}, "
              f"{int(counts.sum())} routed tokens):")
        print(advice.table())
        if args.simulate_serving:
            from repro.serving import SimConfig, WorkloadClass, serving_report
            from repro.testing import make_trace

            cls = WorkloadClass.from_routing(
                counts, ppn=args.ppn, d_model=cfg.d_model, fp="moe"
            )
            trace = make_trace(
                0, args.simulate_serving, ["moe"], pattern="burst",
                rate=50 * args.simulate_serving, kinds={"moe": "moe"},
            )
            rep = serving_report({"moe": cls}, trace, SimConfig(max_width=8))
            co, sq = rep["coalesced"], rep["sequential"]
            print(f"serving sim ({args.simulate_serving} requests, k<=8): "
                  f"coalesced p50={co['p50_s']*1e3:.2f}ms p99={co['p99_s']*1e3:.2f}ms "
                  f"{co['throughput_rps']:.0f} rps | sequential "
                  f"{sq['throughput_rps']:.0f} rps | speedup {rep['speedup']:.2f}x")
            if args.chaos is not None:
                from repro.comm.faults import FaultPlan, FaultSpec
                from repro.serving import simulate

                plan = FaultPlan(
                    seed=args.chaos,
                    specs=(
                        FaultSpec(kind="perturb", prob=0.25, frac=0.1),
                        FaultSpec(kind="slow", prob=0.1, delay_s=2e-3),
                    ),
                )
                storm = simulate(
                    {"moe": cls}, trace,
                    SimConfig(max_width=8, chaos=plan, deadline_s=0.05),
                )
                total = storm.completed + storm.shed
                rate = storm.completed / total if total else 1.0
                print(f"chaos storm (seed {args.chaos}): "
                      f"{storm.fault_events} faults, "
                      f"{storm.recoveries} ladder recoveries, "
                      f"{storm.shed} shed, {storm.probes} probes "
                      f"({storm.probe_recoveries} closed breakers), "
                      f"{storm.deadline_misses} deadline misses | "
                      f"completion {rate:.1%} | trace {storm.trace_hash[:12]}")


if __name__ == "__main__":
    main()
