"""Batched serving launcher: prefill a prompt batch, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --preset tiny \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.train import PRESETS
from repro.models import LMModel


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d, m)
    cfg = PRESETS[args.preset](get_config(args.arch))
    model = LMModel(cfg, tp=m)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    ctx = (
        jnp.asarray(rng.normal(size=(args.batch, model.ctx_len(), cfg.d_model)), jnp.float32)
        if model.ctx_len()
        else None
    )
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    logits, cache = model.prefill(params, prompts, ctx, mesh=mesh)
    # re-home the prefill cache into max_len-deep buffers
    full = model.init_cache(args.batch, max_len, model.dtype)

    def blend(dst, src):
        if dst.shape != src.shape:
            return dst.at[tuple(slice(0, s) for s in src.shape)].set(src.astype(dst.dtype))
        return src.astype(dst.dtype)

    cache = jax.tree.map(blend, full, cache)
    t1 = time.time()

    decode = jax.jit(model.decode_step, static_argnames=())
    token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = [token]
    for t in range(args.gen - 1):
        logits, cache = decode(params, token, cache, jnp.int32(args.prompt_len + t))
        token = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        outs.append(token)
    gen = jnp.concatenate(outs, axis=1)
    t2 = time.time()
    print(f"prefill {args.batch}x{args.prompt_len} in {t1-t0:.2f}s; "
          f"decoded {args.gen} tokens/seq in {t2-t1:.2f}s")
    print("generated:", np.asarray(gen)[:, :10])


if __name__ == "__main__":
    main()
