import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: every cell's
``train_step`` / ``prefill`` / ``serve_step`` is lowered with full-size
``ShapeDtypeStruct`` inputs (no allocation), compiled for the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh, and its
``memory_analysis()`` / ``cost_analysis()`` / collective schedule recorded to
``artifacts/dryrun/``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, shape_applicable, ARCH_IDS
from repro.compat import tree_flatten_with_path
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.models import LMModel, param_shardings, rules_for_mesh, spec_for
from repro.models.sharding import ParamSpec, named_sharding
from repro.optim import AdamWConfig, OptState, adamw_init
from repro.runtime.trainer import build_train_step

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (no allocation)
# ---------------------------------------------------------------------------


def _sds_tree(spec_tree, dtype):
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: LMModel) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["batch"] = {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if model.ctx_len():
            out["batch"]["ctx"] = jax.ShapeDtypeStruct(
                (B, model.ctx_len(), cfg.d_model), jnp.bfloat16
            )
    elif shape.kind == "prefill":
        out["tokens"] = tok
        if model.ctx_len():
            out["ctx"] = jax.ShapeDtypeStruct((B, model.ctx_len(), cfg.d_model), jnp.bfloat16)
    else:  # decode: one new token against a seq_len-deep cache
        out["token"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        out["cache"] = jax.eval_shape(lambda: model.init_cache(B, S, jnp.bfloat16))
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


def cache_shardings(cache_tree, mesh: Mesh, rules) -> Any:
    """Heuristic logical mapping for cache leaves by their key name."""

    def one(path, leaf):
        key = str(getattr(path[-1], "key", path[-1]))
        nd = len(leaf.shape)
        if key in ("k", "v", "cross_k", "cross_v"):
            logical = ("layers", "batch", "cache_seq", None, None)
        elif key in ("c_kv", "k_rope"):
            logical = ("layers", "batch", "cache_seq", None)
        elif key == "ssm":
            logical = ("layers", "batch", "ssm_heads", None, None)
        elif key == "conv":
            logical = ("layers", "batch", None, "ssm_heads", None)
        else:
            logical = (None,) * nd
        logical = logical[:nd] + (None,) * (nd - len(logical))
        return named_sharding(mesh, rules, logical, leaf.shape)

    flat, treedef = tree_flatten_with_path(cache_tree)
    return jax.tree.unflatten(treedef, [one(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def attn_impl() -> str:
    """REPRO_ATTN_IMPL knob: "chunked" (XLA online-softmax, default) or
    "fused" (Pallas-kernel surrogate + analytic kernel terms, §Perf)."""
    return os.environ.get("REPRO_ATTN_IMPL", "chunked")


def attention_kernel_terms(cfg: ModelConfig, model: LMModel, shape: ShapeConfig) -> Dict[str, float]:
    """Analytic per-chip FLOPs/HBM-bytes of the Pallas flash kernel calls
    that the fused-attention dry-run variant replaces with a stub.

    fwd FLOPs = 4*B*H*S*Sk*D (QK^T + PV), x2.5 more for the flash backward;
    HBM bytes = Q+K+V+O traffic (x3 for fwd+bwd).  Causality halves the
    effective Sk; sliding windows clamp it.  Divided by chip count (batch,
    heads and sequence are sharded across the mesh).
    """
    from repro.models.transformer import pad_heads

    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}  # decode path uses the dot impl
    hp, kvp = pad_heads(cfg.n_heads, cfg.n_kv_heads, model.tp)
    D = cfg.resolved_head_dim
    flops = 0.0
    byts = 0.0

    def add(layers, H, KV, sq, sk, causal=True, window=None):
        nonlocal flops, byts
        eff = min(window, sk) if window else sk
        factor = 0.5 if (causal and not window) else 1.0
        flops_l = 4.0 * B * H * sq * eff * D * factor
        bytes_l = 2.0 * B * D * (sq * H + 2 * sk * KV + sq * H)  # q,k,v,o bf16
        mult_f = 3.5 if shape.kind == "train" else 1.0
        mult_b = 3.0 if shape.kind == "train" else 1.0
        flops += layers * flops_l * mult_f
        byts += layers * bytes_l * mult_b

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if cfg.mla is None:
            n_self = cfg.n_layers if fam != "vlm" else cfg.n_layers - cfg.n_layers // cfg.cross_attn_every
            add(n_self, hp, kvp, S, S, causal=True, window=cfg.window)
        else:
            add(cfg.n_layers, hp, hp, S, S, causal=True)  # MLA expands per-head K
        if fam == "vlm":
            add(cfg.n_layers // cfg.cross_attn_every, hp, kvp, S, cfg.cross_context, causal=False)
    elif fam == "hybrid":
        add(cfg.n_layers, hp, kvp, S, S, causal=True, window=cfg.window)
    elif fam == "enc_dec":
        add(cfg.n_layers, hp, kvp, S, S, causal=True)
        add(cfg.n_layers, hp, kvp, S, cfg.encoder.context, causal=False)  # cross
        add(cfg.encoder.n_layers, hp, kvp, cfg.encoder.context, cfg.encoder.context, causal=False)
    # ssm family: no attention
    return {"flops": flops, "bytes": byts}


def lower_cell(
    arch: str, shape_name: str, mesh: Mesh
) -> Tuple[Any, LMModel]:
    """Returns (lowered computation, model) for one (arch, shape) cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules_for_mesh(mesh)
    tp = mesh.shape.get("model", 1)
    model = LMModel(cfg, tp=tp)
    specs = model.param_specs()
    p_shard = param_shardings(specs, mesh, rules)
    ins = input_specs(cfg, shape, model)
    bspec = lambda shp: NamedSharding(mesh, spec_for(mesh, rules, ("batch",) + (None,) * (len(shp) - 1), shp))

    if shape.kind == "train":
        params_sds = _sds_tree(specs, jnp.float32)
        state_sds = {
            "params": params_sds,
            "opt": jax.eval_shape(adamw_init, params_sds),
        }
        step = build_train_step(
            model, mesh, AdamWConfig(), impl=attn_impl(), remat=True
        )
        batch_sh = {k: bspec(v.shape) for k, v in ins["batch"].items()}
        lowered = step.lower(state_sds, jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), ins["batch"], batch_sh))
        return lowered, model

    params_sds = _sds_tree(specs, jnp.bfloat16)
    if shape.kind == "prefill":
        def prefill_fn(params, tokens, ctx=None):
            return model.prefill(params, tokens, ctx, impl=attn_impl(), mesh=mesh)

        args = [params_sds, ins["tokens"]]
        in_sh = [p_shard, bspec(ins["tokens"].shape)]
        if "ctx" in ins:
            args.append(ins["ctx"])
            in_sh.append(bspec(ins["ctx"].shape))
        out_shape = jax.eval_shape(prefill_fn, *args)
        out_sh = (bspec(out_shape[0].shape), cache_shardings(out_shape[1], mesh, rules))
        lowered = jax.jit(prefill_fn, in_shardings=tuple(in_sh), out_shardings=out_sh).lower(*args)
        return lowered, model

    # decode
    cache_sds = ins["cache"]
    cache_sh = cache_shardings(cache_sds, mesh, rules)

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, token, cache, pos, mesh=mesh)

    lowered = jax.jit(
        serve_step,
        in_shardings=(p_shard, cache_sh, bspec(ins["token"].shape), NamedSharding(mesh, P())),
        out_shardings=(bspec((ins["token"].shape[0], 1, model.vocab)), cache_sh),
        donate_argnums=(1,),
    ).lower(params_sds, cache_sds, ins["token"], ins["pos"])
    return lowered, model


# ---------------------------------------------------------------------------
# FLOPs accounting
# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, model: LMModel, shape: ShapeConfig) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params (MoE-aware)."""
    specs = model.param_specs()
    total = active = 0
    for path, ps in tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )[0]:
        n = int(np.prod(ps.shape))
        total += n
        keys = [str(getattr(p, "key", p)) for p in path]
        if "moe" in keys and any(k in ("w_in", "w_gate", "w_out") for k in keys):
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        active += n
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens, total, active


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "skipped": why}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    lowered, model = lower_cell(arch, shape_name, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    print(mem)
    print({k: v for k, v in cost.items() if k in ("flops", "bytes accessed")})
    hlo = analyze(compiled.as_text())
    mf, n_total, n_active = model_flops(cfg, model, shape)
    nchips = int(np.prod(list(mesh.shape.values())))
    kern_flops = kern_bytes = 0.0
    if attn_impl() == "fused":
        kt = attention_kernel_terms(cfg, model, shape)
        kern_flops = kt["flops"] / nchips
        kern_bytes = kt["bytes"] / nchips
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": nchips,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        # trip-count-weighted, per-chip (see hlo_analysis docstring); the raw
        # cost_analysis numbers (loop bodies counted once) kept for reference
        "hlo_flops_per_chip": hlo.flops + kern_flops,
        "hlo_bytes_per_chip": hlo.mem_bytes + kern_bytes,
        "hlo_flops": (hlo.flops + kern_flops) * nchips,
        "hlo_bytes": (hlo.mem_bytes + kern_bytes) * nchips,
        "analytic_kernel_flops_per_chip": kern_flops,
        "analytic_kernel_bytes_per_chip": kern_bytes,
        "knobs": {"attn_impl": attn_impl(),
                  "remat": os.environ.get("REPRO_REMAT_POLICY", "full")},
        "xla_cost_flops_raw": float(cost.get("flops", 0.0)),
        "xla_cost_bytes_raw": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_chip": hlo.collective_bytes,
        "collective_by_kind": hlo.collective_by_kind,
        "collective_ops": hlo.collective_ops,
        "model_flops": mf,
        "params_total": n_total,
        "params_active": n_active,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACTS))
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                key = f"{arch} x {shape_name} x {mesh_kind}"
                try:
                    rec = run_cell(arch, shape_name, mesh_kind, args.out)
                except Exception as e:  # noqa: BLE001 - report and continue
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                           "error": f"{type(e).__name__}: {e}"}
                    with open(os.path.join(args.out, f"{arch}__{shape_name}__{mesh_kind}.json"), "w") as f:
                        json.dump(rec, f, indent=1)
                if "error" in rec:
                    print(f"[FAIL] {key}: {rec['error'][:300]}")
                elif "skipped" in rec:
                    print(f"[SKIP] {key}: {rec['skipped']}")
                else:
                    print(
                        f"[ OK ] {key}: compile={rec['compile_s']}s "
                        f"flops={rec['hlo_flops']:.3e} coll={rec['collective_bytes_per_chip']:.3e}B/chip "
                        f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB"
                    )
                cells.append(rec)
    n_ok = sum(1 for c in cells if "error" not in c and "skipped" not in c)
    n_skip = sum(1 for c in cells if "skipped" in c)
    n_fail = sum(1 for c in cells if "error" in c)
    print(f"\nDRY-RUN SUMMARY: {n_ok} ok, {n_skip} skipped (documented), {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
