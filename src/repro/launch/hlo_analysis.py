"""Trip-count-aware analysis of compiled HLO (roofline inputs).

``compiled.cost_analysis()`` on the CPU backend counts each while-loop body
once, but our models scan over layers (and SSD chunks / attention blocks), so
FLOPs, HBM bytes and collective bytes must be weighted by each loop's
``known_trip_count``.  This module parses the post-optimization HLO text and
computes, per chip (HLO shapes are per-device after SPMD partitioning):

* ``flops``            -- 2*M*N*K summed over every ``dot`` (matmul FLOPs
  dominate all our models; elementwise FLOPs are not counted, documented).
* ``mem_bytes``        -- operand + result bytes of every instruction at
  fusion *boundaries* (fusion-internal values never touch HBM).
* ``collective bytes`` -- summed operand sizes of every all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute, by kind.

Ops inside ``while`` bodies are multiplied by the loop trip count,
recursively.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)
_SKIP_MEM_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _parse_shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shapes_bytes(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class HloStats:
    flops: float
    mem_bytes: float
    collective_by_kind: Dict[str, float]
    collective_ops: int

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective_by_kind.values())


# kept for backward compatibility with earlier callers
@dataclasses.dataclass
class CollectiveStats:
    by_kind: Dict[str, float]
    op_count: int

    @property
    def total_bytes(self) -> float:
        return sum(self.by_kind.values())


def analyze(hlo_text: str) -> HloStats:
    # ---- split into computations --------------------------------------
    lines = hlo_text.splitlines()
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for ln in lines:
        stripped = ln.strip()
        if stripped.endswith("{") and "->" in stripped and not stripped.startswith("%param"):
            toks = stripped.split()
            name = (toks[1] if toks[0] == "ENTRY" else toks[0]).lstrip("%")
            cur = name
            comps[cur] = []
            if toks[0] == "ENTRY":
                entry = cur
            continue
        if cur is not None:
            comps[cur].append(ln)

    inst_re = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
    call_re = re.compile(r"(?:body=|calls=)%?([\w\.\-]+)")
    trip_re = re.compile(r'known_trip_count[\\"]*:\{[\\"]*n[\\"]*:[\\"]*(\d+)')
    operand_re = re.compile(r"%([\w\.\-]+)")
    op_re = re.compile(r"(?:^|\s)([a-z][\w\-]*)\(")
    cdims_re = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

    comp_flops: Dict[str, float] = defaultdict(float)
    comp_mem: Dict[str, float] = defaultdict(float)
    comp_coll: Dict[str, Dict[str, float]] = {}
    comp_calls: Dict[str, List[Tuple[str, float]]] = {}
    comp_ops: Dict[str, int] = {}
    fusion_bodies: set = set()

    for name, body in comps.items():
        shapes: Dict[str, List[Tuple[str, List[int]]]] = {}
        colls: Dict[str, float] = defaultdict(float)
        calls: List[Tuple[str, float]] = []
        nops = 0
        for ln in body:
            m = inst_re.match(ln)
            if not m:
                continue
            iname, rest = m.groups()
            opm = op_re.search(rest)
            opname = opm.group(1) if opm else None
            head = rest[: opm.start()] if opm else rest
            res_shapes = _parse_shapes(head)
            shapes[iname] = res_shapes
            args = ""
            if "(" in rest:
                args = rest.split("(", 1)[1].split(")", 1)[0]
            operands = [om.group(1) for om in operand_re.finditer(args)]

            # calls / loops
            if opname == "while":
                cm = call_re.search(rest)
                tm = trip_re.search(ln)
                trips = float(tm.group(1)) if tm else 1.0
                if cm:
                    calls.append((cm.group(1), trips))
            elif opname in ("call", "conditional", "async-start", "custom-call"):
                for cm in call_re.finditer(rest):
                    calls.append((cm.group(1), 1.0))
            elif opname == "fusion":
                for cm in call_re.finditer(rest):
                    calls.append((cm.group(1), 1.0))
                    fusion_bodies.add(cm.group(1))

            # dot FLOPs: 2 * result_elems * contracted_elems
            if opname == "dot":
                cm = cdims_re.search(rest)
                if cm and operands:
                    lhs_shapes = shapes.get(operands[0], [])
                    if lhs_shapes:
                        lhs_dims = lhs_shapes[0][1]
                        cdims = [int(x) for x in cm.group(1).split(",") if x]
                        contract = 1
                        for ci in cdims:
                            if ci < len(lhs_dims):
                                contract *= lhs_dims[ci]
                        res_elems = 1
                        for _, dims in res_shapes[:1]:
                            for d in dims:
                                res_elems *= d
                        comp_flops[name] += 2.0 * res_elems * contract

            # memory traffic at fusion boundaries: each produced tensor is
            # written once and (amortized) read once downstream -> 2x result
            # bytes.  Counting operand reads per-consumer would double-count
            # every producer/consumer edge and overstate HBM traffic badly on
            # the CPU backend, whose fusion is much weaker than TPU's.
            if opname and opname not in _SKIP_MEM_OPS:
                comp_mem[name] += 2.0 * _shapes_bytes(res_shapes)

            # collectives
            if opname and any(opname.startswith(c) for c in _COLLECTIVES):
                if opname.endswith("-done"):
                    continue
                nops += 1
                kind = next(c for c in _COLLECTIVES if opname.startswith(c))
                b = 0
                for op in operands:
                    b += _shapes_bytes(shapes.get(op, []))
                if b == 0:
                    b = _shapes_bytes(res_shapes)
                colls[kind] += float(b)
        comp_coll[name] = dict(colls)
        comp_calls[name] = calls
        comp_ops[name] = nops

    # ---- bottom-up totals from ENTRY -----------------------------------
    memo: Dict[str, Tuple[float, float, Dict[str, float], int]] = {}

    def total(name: str, seen=()) -> Tuple[float, float, Dict[str, float], int]:
        if name in memo:
            return memo[name]
        if name not in comp_coll or name in seen:
            return 0.0, 0.0, {}, 0
        flops = comp_flops[name]
        mem = 0.0 if name in fusion_bodies else comp_mem[name]
        agg = defaultdict(float, comp_coll[name])
        nops = comp_ops[name]
        for callee, mult in comp_calls.get(name, []):
            f, mm, sub, sub_ops = total(callee, seen + (name,))
            flops += f * mult
            mem += mm * mult
            for k, v in sub.items():
                agg[k] += v * mult
            nops += sub_ops
        memo[name] = (flops, mem, dict(agg), nops)
        return memo[name]

    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return HloStats(0.0, 0.0, {}, 0)
    flops, mem, agg, nops = total(entry)
    return HloStats(flops=flops, mem_bytes=mem, collective_by_kind=agg, collective_ops=nops)


def analyze_collectives(hlo_text: str) -> CollectiveStats:
    st = analyze(hlo_text)
    return CollectiveStats(by_kind=st.collective_by_kind, op_count=st.collective_ops)


def top_contributors(hlo_text: str, k: int = 12) -> List[Tuple[float, float, str, str]]:
    """Top trip-weighted memory contributors: (bytes, trips, op, shape)."""
    lines = hlo_text.splitlines()
    comps: Dict[str, List[str]] = {}
    entry = cur = None
    for ln in lines:
        s = ln.strip()
        if s.endswith("{") and "->" in s and not s.startswith("%param"):
            t = s.split()
            name = (t[1] if t[0] == "ENTRY" else t[0]).lstrip("%")
            cur = name
            comps[cur] = []
            if t[0] == "ENTRY":
                entry = cur
            continue
        if cur is not None:
            comps[cur].append(ln)

    call_re = re.compile(r"(?:body=|calls=)%?([\w\.\-]+)")
    trip_re = re.compile(r'known_trip_count[\\"]*:\{[\\"]*n[\\"]*:[\\"]*(\d+)')
    inst_re = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
    op_re = re.compile(r"(?:^|\s)([a-z][\w\-]*)\(")

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    i = 0
    fusion_bodies = set()
    while i < len(order):
        c = order[i]
        i += 1
        for ln in comps.get(c, []):
            if " while(" in ln:
                m = call_re.search(ln)
                t = trip_re.search(ln)
                if m:
                    mult[m.group(1)] += mult[c] * (float(t.group(1)) if t else 1.0)
                    order.append(m.group(1))
            elif "calls=" in ln:
                for m in call_re.finditer(ln):
                    mult[m.group(1)] += mult[c]
                    order.append(m.group(1))
                    if "fusion(" in ln:
                        fusion_bodies.add(m.group(1))
    out = []
    for c, body in comps.items():
        if c in fusion_bodies:
            continue
        for ln in body:
            m = inst_re.match(ln)
            if not m:
                continue
            iname, rest = m.groups()
            opm = op_re.search(rest)
            opname = opm.group(1) if opm else None
            if not opname or opname in _SKIP_MEM_OPS:
                continue
            head = rest[: opm.start()]
            b = 2.0 * _shapes_bytes(_parse_shapes(head)) * mult.get(c, 0.0)
            if b > 0:
                out.append((b, mult.get(c, 0.0), opname, head.strip()[:70]))
    out.sort(reverse=True)
    return out[:k]
