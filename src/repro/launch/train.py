"""Training launcher.

CPU-runnable with ``--preset tiny`` (reduced width, real arch family); the
full configs are exercised by ``dryrun.py``.  Supports checkpoint/restart
(``--resume``), fault injection (``--fail-at``), and elastic resharding
(resume the same checkpoint with a different ``--mesh``).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --preset tiny \
        --steps 50 --mesh 1x1 --ckpt /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def tiny(cfg):
    kw = dict(
        n_layers=2, d_model=128, d_ff=256 if cfg.d_ff else 0, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=32, vocab_size=1024,
        dtype="float32", cross_context=16 if cfg.cross_context else 0,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2, d_ff_expert=64,
                                        first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.mla:
        kw["mla"] = dataclasses.replace(cfg.mla, kv_lora_rank=32, rope_head_dim=16,
                                        nope_head_dim=32, v_head_dim=32)
        kw["head_dim"] = 48
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16, chunk=16)
    if cfg.encoder:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=2, context=16)
    if cfg.window:
        kw["window"] = 32
    return dataclasses.replace(cfg, **kw)


def small_100m(cfg):
    """~100M-parameter config for the end-to-end example run."""
    kw = dict(n_layers=8, d_model=512, d_ff=1536 if cfg.d_ff else 0, n_heads=8,
              n_kv_heads=min(cfg.n_kv_heads, 4), head_dim=64, vocab_size=32768,
              dtype="float32")
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=64, head_dim=32, chunk=64)
    return dataclasses.replace(cfg, **kw)


PRESETS = {"tiny": tiny, "100m": small_100m, "full": lambda c: c}


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d, m)
    cfg = PRESETS[args.preset](get_config(args.arch))
    trainer = Trainer(
        cfg,
        mesh,
        TrainerConfig(
            steps=args.steps, batch=args.batch, seq_len=args.seq,
            checkpoint_dir=args.ckpt, fail_at_step=args.fail_at,
            log_every=max(args.steps // 10, 1),
            checkpoint_every=max(args.steps // 4, 1),
        ),
        AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps),
    )
    out = trainer.run(resume=args.resume)
    losses = out["history"]
    print(f"first loss {losses[0]['loss']:.4f} -> last loss {losses[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
