"""Production mesh builders.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches JAX device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` *before* any JAX import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod mesh, or 2 pods x 16 x 16 = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh for CPU tests/examples (requires enough host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
