"""Mixture-of-Experts with expert-parallel all-to-all dispatch.

Token -> expert routing is the modern LM incarnation of the paper's irregular
point-to-point pattern: per-step, every data shard sends a data-dependent
subset of its tokens to the shards owning their experts.  Placement follows
the paper's pod-aware guidance (DESIGN.md section 4):

* experts are sharded over the **data** axis (expert parallelism), so the
  dispatch/return all-to-alls run entirely over intra-pod ICI;
* across **pods** experts are replicated -- the DCI carries only gradient
  reduction, never token traffic;
* each expert's FFN dim is sharded over **model** (TP within the expert).

Dispatch is capacity-based (tokens beyond ``capacity_factor`` per
(src shard, dst shard) slot are dropped, standard GShard/Switch practice) and
runs inside ``shard_map`` so the all-to-all is explicit -- the dry-run HLO
shows it, and the hierarchical variant can replace it on multi-pod meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.compat import shard_map
from repro.models.layers import MLP
from repro.models.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class MoELayer:
    d_model: int
    cfg: MoEConfig
    act: str = "silu"
    ep_axis: str = "data"  # expert-parallel mesh axis (intra-pod!)

    def params(self) -> dict:
        E, M, F = self.cfg.n_experts, self.d_model, self.cfg.d_ff_expert
        p = {
            "router": ParamSpec((M, E), ("fsdp", None)),
            "w_in": ParamSpec((E, M, F), ("experts", None, "mlp")),
            "w_gate": ParamSpec((E, M, F), ("experts", None, "mlp")),
            "w_out": ParamSpec((E, F, M), ("experts", "mlp", None)),
        }
        if self.cfg.n_shared:
            shared = MLP(self.d_model, self.cfg.d_ff_expert * self.cfg.n_shared, self.act)
            p["shared"] = shared.params()
        return p

    # ------------------------------------------------------------------
    def __call__(self, params, x: jnp.ndarray, mesh=None) -> jnp.ndarray:
        """x: [B, S, M].  Routed experts + optional shared experts."""
        cfg = self.cfg
        B, S, M = x.shape
        logits = jnp.einsum("bsm,me->bse", x, params["router"].astype(x.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        top_p, top_e = jax.lax.top_k(probs, cfg.top_k)  # [B,S,k]
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        if mesh is not None and self.ep_axis in mesh.axis_names and mesh.shape[self.ep_axis] > 1:
            routed = self._dispatch_shard_map(params, x, top_p, top_e, mesh)
        else:
            routed = self._dispatch_local(params, x, top_p, top_e)

        if cfg.n_shared:
            shared = MLP(self.d_model, cfg.d_ff_expert * cfg.n_shared, self.act)
            routed = routed + shared(params["shared"], x)
        return routed

    # ------------------------------------------------------------------
    def _expert_ffn(self, w_in, w_gate, w_out, xe: jnp.ndarray) -> jnp.ndarray:
        """Batched per-expert FFN. xe: [E, C, M] -> [E, C, M]."""
        h = jnp.einsum("ecm,emf->ecf", xe, w_in.astype(xe.dtype))
        g = jnp.einsum("ecm,emf->ecf", xe, w_gate.astype(xe.dtype))
        h = jax.nn.silu(g) * h
        return jnp.einsum("ecf,efm->ecm", h, w_out.astype(xe.dtype))

    @staticmethod
    def _fill_capacity(eid: jnp.ndarray, n_bins: int, cap: int):
        """Position of each assignment within its bin; >= cap means dropped.

        eid: [T] bin ids. Returns (pos_in_bin [T], keep mask [T]).
        """
        onehot = jax.nn.one_hot(eid, n_bins, dtype=jnp.int32)  # [T, E]
        pos = jnp.cumsum(onehot, axis=0) - 1  # position within bin
        pos = jnp.take_along_axis(pos, eid[:, None], axis=1)[:, 0]
        return pos, pos < cap

    # -- single-device / replicated fallback ----------------------------
    def _dispatch_local(self, params, x, top_p, top_e) -> jnp.ndarray:
        cfg = self.cfg
        B, S, M = x.shape
        T = B * S * cfg.top_k
        xt = jnp.repeat(x.reshape(B * S, M), cfg.top_k, axis=0)  # [T, M]
        eid = top_e.reshape(T)
        w = top_p.reshape(T).astype(x.dtype)
        cap = max(int(T / cfg.n_experts * cfg.capacity_factor), 1)
        pos, keep = self._fill_capacity(eid, cfg.n_experts, cap)
        slot = jnp.where(keep, eid * cap + pos, cfg.n_experts * cap)  # drop slot
        buf = jnp.zeros((cfg.n_experts * cap + 1, M), x.dtype).at[slot].set(xt)
        ye = self._expert_ffn(
            params["w_in"], params["w_gate"], params["w_out"],
            buf[:-1].reshape(cfg.n_experts, cap, M),
        ).reshape(cfg.n_experts * cap, M)
        yt = jnp.concatenate([ye, jnp.zeros((1, M), x.dtype)])[slot] * w[:, None]
        return yt.reshape(B * S, cfg.top_k, M).sum(1).reshape(B, S, M)

    # -- expert-parallel all-to-all over the data axis -------------------
    def _dispatch_shard_map(self, params, x, top_p, top_e, mesh) -> jnp.ndarray:
        cfg = self.cfg
        B, S, M = x.shape
        ep = self.ep_axis
        nd = mesh.shape[ep]
        if cfg.n_experts % nd:
            return self._dispatch_local(params, x, top_p, top_e)
        e_local = cfg.n_experts // nd
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

        def body(xl, pl, el, w_in, w_gate, w_out):
            # xl: [b, S, M] local batch; experts local: [e_local, M, F_shard].
            #
            # (A bf16 pin of this whole path was tried and refuted in
            # EXPERIMENTS.md §Perf iter 3: the f32 buffers come from XLA's
            # scatter-add backward, not from a castable leaf here.)
            in_dtype = xl.dtype
            #
            # Routing is GATHER-based: the only scatters are 1-D int32
            # inverse-permutation builds.  A 2-D `.at[slot].set(tokens)`
            # scatter materializes several full-width [slots, M] index/temp
            # buffers (measured: ~12 x 4 GiB per layer on deepseek-v2-lite,
            # dominating the memory roofline -- EXPERIMENTS.md §Perf iter 2).
            b = xl.shape[0]
            t = b * S * cfg.top_k
            xt = jnp.repeat(xl.reshape(b * S, M), cfg.top_k, axis=0)
            eid = el.reshape(t)
            w = pl.reshape(t).astype(xl.dtype)
            dst = eid // e_local  # destination data-shard
            # capacity per (src shard -> dst shard) slot; floor of 8 keeps
            # decode-time (tiny t) routing essentially drop-free
            cap = max(int(t / nd * cfg.capacity_factor), 8)
            pos, keep = self._fill_capacity(dst, nd, cap)
            slot = jnp.where(keep, dst * cap + pos, nd * cap)
            # inverse permutation: which token fills each send slot (1-D)
            inv = jnp.full((nd * cap + 1,), t, jnp.int32).at[slot].set(
                jnp.arange(t, dtype=jnp.int32)
            )[:-1]
            xt_pad = jnp.concatenate([xt, jnp.zeros((1, M), xl.dtype)])
            send = xt_pad[inv]  # [nd*cap, M] gather, no wide scatter
            send_e = jnp.concatenate([eid % e_local, jnp.full((1,), e_local, jnp.int32)])[inv]
            # all-to-all over the EP axis (intra-pod ICI by construction)
            recv = jax.lax.all_to_all(
                send.reshape(nd, cap, M), ep, 0, 0, tiled=True
            ).reshape(nd * cap, M)
            recv_e = jax.lax.all_to_all(
                send_e.reshape(nd, cap), ep, 0, 0, tiled=True
            ).reshape(nd * cap)
            # bin received tokens into local experts (second capacity stage)
            cap2 = max(int(nd * cap / e_local), 1)
            bin_id = jnp.minimum(recv_e, e_local)  # dead slots -> drop bin
            pos2, keep2 = self._fill_capacity(bin_id, e_local + 1, cap2)
            keep2 &= recv_e < e_local
            slot2 = jnp.where(keep2, bin_id * cap2 + pos2, e_local * cap2)
            inv2 = jnp.full((e_local * cap2 + 1,), nd * cap, jnp.int32).at[slot2].set(
                jnp.arange(nd * cap, dtype=jnp.int32)
            )[:-1]
            recv_pad = jnp.concatenate([recv, jnp.zeros((1, M), xl.dtype)])
            buf = recv_pad[inv2]
            ye = self._expert_ffn(
                w_in, w_gate, w_out, buf.reshape(e_local, cap2, M)
            ).reshape(e_local * cap2, M)
            # NOTE: with F sharded over "model", ye is a partial sum.  The
            # psum is deferred to the *combined* [b, S, M] output (7.5x fewer
            # bytes than psumming the dispatch-width buffer); every routing
            # op in between is linear, so the result is identical.
            back = jnp.concatenate([ye, jnp.zeros((1, M), ye.dtype)])[slot2]
            ret = jax.lax.all_to_all(
                back.reshape(nd, cap, M), ep, 0, 0, tiled=True
            ).reshape(nd * cap, M)
            yt = jnp.concatenate([ret, jnp.zeros((1, M), ret.dtype)])[slot]
            yt = yt * w[:, None]
            out = yt.reshape(b * S, cfg.top_k, M).sum(1).reshape(b, S, M)
            if "model" in mesh.axis_names and mesh.shape["model"] > 1:
                out = jax.lax.psum(out, "model")
            return out.astype(in_dtype)

        x_spec = P(batch_axes or None, None, None)
        r_spec = P(batch_axes or None, None, None)
        w_spec = P(ep, None, "model" if "model" in mesh.axis_names else None)
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(x_spec, r_spec, r_spec, w_spec, w_spec,
                      P(ep, "model" if "model" in mesh.axis_names else None, None)),
            out_specs=x_spec,
            check_vma=False,
        )(x, top_p, top_e, params["w_in"], params["w_gate"], params["w_out"])
