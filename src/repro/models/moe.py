"""Mixture-of-Experts with expert-parallel all-to-all dispatch.

Token -> expert routing is the modern LM incarnation of the paper's irregular
point-to-point pattern: per-step, every data shard sends a data-dependent
subset of its tokens to the shards owning their experts.  Placement follows
the paper's pod-aware guidance (DESIGN.md section 4):

* experts are sharded over the **data** axis (expert parallelism), so the
  dispatch/return all-to-alls run entirely over intra-pod ICI;
* across **pods** experts are replicated -- the DCI carries only gradient
  reduction, never token traffic;
* each expert's FFN dim is sharded over **model** (TP within the expert).

Dispatch is capacity-based (tokens beyond ``capacity_factor`` per
(src shard, dst shard) slot are dropped, standard GShard/Switch practice) and
runs inside ``shard_map`` so the all-to-all is explicit -- the dry-run HLO
shows it, and the hierarchical variant can replace it on multi-pod meshes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm.topology import WORLD_AXES, PodTopology
from repro.configs.base import MoEConfig
from repro.compat import shard_map
from repro.models.layers import MLP
from repro.models.moe_dispatch import MoEDispatcher
from repro.models.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class MoELayer:
    d_model: int
    cfg: MoEConfig
    act: str = "silu"
    #: expert-parallel mesh axis (or tuple of axes, e.g. ``("pod", "local")``
    #: to run dispatch over the full exchange mesh)
    ep_axis: Union[str, Tuple[str, ...]] = "data"
    #: "all_to_all" (flat ``jax.lax.all_to_all``, the parity baseline) or
    #: "exchange" (node-aware :class:`~repro.comm.IrregularExchange` hops,
    #: planned per measured routing pattern -- see repro.models.moe_dispatch)
    dispatch: str = "all_to_all"
    #: exchange strategy: "auto" (advisor-picked from the measured routing
    #: histogram) or one of repro.comm.STRATEGY_NAMES
    strategy: str = "auto"
    #: inter-pod wire codec for the exchange path ("none" = full precision)
    wire: str = "none"
    #: slot granularity for routing-count bucketing (plan-cache stability)
    route_quantum: int = 8
    #: lazily-created per-layer dispatcher; not part of identity
    dispatcher: Optional[MoEDispatcher] = dataclasses.field(
        default=None, compare=False
    )

    def __post_init__(self) -> None:
        if self.dispatch not in ("all_to_all", "exchange"):
            raise ValueError(
                f"dispatch must be 'all_to_all' or 'exchange', got {self.dispatch!r}"
            )
        if self.dispatch == "exchange" and self.ep_axis == "data":
            # exchange dispatch runs over the ("pod", "local") exchange mesh
            object.__setattr__(self, "ep_axis", WORLD_AXES)

    def params(self) -> dict:
        E, M, F = self.cfg.n_experts, self.d_model, self.cfg.d_ff_expert
        p = {
            "router": ParamSpec((M, E), ("fsdp", None)),
            "w_in": ParamSpec((E, M, F), ("experts", None, "mlp")),
            "w_gate": ParamSpec((E, M, F), ("experts", None, "mlp")),
            "w_out": ParamSpec((E, F, M), ("experts", "mlp", None)),
        }
        if self.cfg.n_shared:
            shared = MLP(self.d_model, self.cfg.d_ff_expert * self.cfg.n_shared, self.act)
            p["shared"] = shared.params()
        return p

    # ------------------------------------------------------------------
    def _ep_axes(self) -> Tuple[str, ...]:
        return self.ep_axis if isinstance(self.ep_axis, tuple) else (self.ep_axis,)

    def _ep_size(self, mesh) -> int:
        """Expert-parallel degree; 1 when any ep axis is absent."""
        axes = self._ep_axes()
        if mesh is None or any(a not in mesh.axis_names for a in axes):
            return 1
        return math.prod(mesh.shape[a] for a in axes)

    def __call__(self, params, x: jnp.ndarray, mesh=None) -> jnp.ndarray:
        """x: [B, S, M].  Routed experts + optional shared experts."""
        cfg = self.cfg
        B, S, M = x.shape
        logits = jnp.einsum("bsm,me->bse", x, params["router"].astype(x.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        top_p, top_e = jax.lax.top_k(probs, cfg.top_k)  # [B,S,k]
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        if self._ep_size(mesh) > 1:
            if self.dispatch == "exchange":
                routed = self._dispatch_exchange(params, x, top_p, top_e, mesh)
            else:
                routed = self._dispatch_shard_map(params, x, top_p, top_e, mesh)
        else:
            routed = self._dispatch_local(params, x, top_p, top_e)

        if cfg.n_shared:
            shared = MLP(self.d_model, cfg.d_ff_expert * cfg.n_shared, self.act)
            routed = routed + shared(params["shared"], x)
        return routed

    # ------------------------------------------------------------------
    def _expert_ffn(self, w_in, w_gate, w_out, xe: jnp.ndarray) -> jnp.ndarray:
        """Batched per-expert FFN. xe: [E, C, M] -> [E, C, M]."""
        h = jnp.einsum("ecm,emf->ecf", xe, w_in.astype(xe.dtype))
        g = jnp.einsum("ecm,emf->ecf", xe, w_gate.astype(xe.dtype))
        h = jax.nn.silu(g) * h
        return jnp.einsum("ecf,efm->ecm", h, w_out.astype(xe.dtype))

    @staticmethod
    def _fill_capacity(eid: jnp.ndarray, n_bins: int, cap: int):
        """Position of each assignment within its bin; >= cap means dropped.

        eid: [T] bin ids. Returns (pos_in_bin [T], keep mask [T]).

        Sort-based: a stable argsort groups each bin's assignments in
        original order, the position within the run is ``index - run start``
        (a ``cummax`` over run-start indices), and a 1-D inverse scatter
        restores token order.  O(T log T) time and O(T) memory -- the
        previous one-hot cumsum materialized a ``[T, n_bins]`` int32 buffer,
        O(T*E) at serving batch sizes -- and bitwise-equal to it, since the
        stable sort preserves the arrival order the cumsum counted.
        """
        t = eid.shape[0]
        order = jnp.argsort(eid, stable=True)
        idx = jnp.arange(t, dtype=jnp.int32)
        sorted_eid = eid[order]
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), sorted_eid[1:] != sorted_eid[:-1]]
        )
        start = jax.lax.cummax(jnp.where(is_start, idx, 0), axis=0)
        pos = jnp.zeros((t,), jnp.int32).at[order].set(idx - start)
        return pos, pos < cap

    # -- single-device / replicated fallback ----------------------------
    def _dispatch_local(self, params, x, top_p, top_e) -> jnp.ndarray:
        cfg = self.cfg
        B, S, M = x.shape
        T = B * S * cfg.top_k
        xt = jnp.repeat(x.reshape(B * S, M), cfg.top_k, axis=0)  # [T, M]
        eid = top_e.reshape(T)
        w = top_p.reshape(T).astype(x.dtype)
        cap = max(int(T / cfg.n_experts * cfg.capacity_factor), 1)
        pos, keep = self._fill_capacity(eid, cfg.n_experts, cap)
        slot = jnp.where(keep, eid * cap + pos, cfg.n_experts * cap)  # drop slot
        buf = jnp.zeros((cfg.n_experts * cap + 1, M), x.dtype).at[slot].set(xt)
        ye = self._expert_ffn(
            params["w_in"], params["w_gate"], params["w_out"],
            buf[:-1].reshape(cfg.n_experts, cap, M),
        ).reshape(cfg.n_experts * cap, M)
        yt = jnp.concatenate([ye, jnp.zeros((1, M), x.dtype)])[slot] * w[:, None]
        return yt.reshape(B * S, cfg.top_k, M).sum(1).reshape(B, S, M)

    # -- expert-parallel all-to-all over the data axis -------------------
    def _dispatch_shard_map(self, params, x, top_p, top_e, mesh) -> jnp.ndarray:
        cfg = self.cfg
        B, S, M = x.shape
        ep = self.ep_axis  # a mesh axis name, or a tuple of them
        nd = self._ep_size(mesh)
        if cfg.n_experts % nd:
            # Silently falling back to the replicated local path here would
            # quietly drop expert parallelism on a sharded model.
            raise ValueError(
                f"n_experts={cfg.n_experts} is not divisible by the "
                f"expert-parallel degree {nd} (mesh axis {ep!r}); choose "
                f"n_experts as a multiple of {nd}, or drop ep_axis from the "
                "mesh to run the replicated local path"
            )
        e_local = cfg.n_experts // nd
        if isinstance(ep, tuple):
            batch_axes = ep  # tokens sharded over the full exchange mesh
        else:
            batch_axes = tuple(a for a in ("pod", ep) if a in mesh.axis_names)

        def body(xl, pl, el, w_in, w_gate, w_out):
            # xl: [b, S, M] local batch; experts local: [e_local, M, F_shard].
            #
            # (A bf16 pin of this whole path was tried and refuted in
            # EXPERIMENTS.md §Perf iter 3: the f32 buffers come from XLA's
            # scatter-add backward, not from a castable leaf here.)
            in_dtype = xl.dtype
            #
            # Routing is GATHER-based: the only scatters are 1-D int32
            # inverse-permutation builds.  A 2-D `.at[slot].set(tokens)`
            # scatter materializes several full-width [slots, M] index/temp
            # buffers (measured: ~12 x 4 GiB per layer on deepseek-v2-lite,
            # dominating the memory roofline -- EXPERIMENTS.md §Perf iter 2).
            b = xl.shape[0]
            t = b * S * cfg.top_k
            xt = jnp.repeat(xl.reshape(b * S, M), cfg.top_k, axis=0)
            eid = el.reshape(t)
            w = pl.reshape(t).astype(xl.dtype)
            dst = eid // e_local  # destination data-shard
            # capacity per (src shard -> dst shard) slot; floor of 8 keeps
            # decode-time (tiny t) routing essentially drop-free
            cap = max(int(t / nd * cfg.capacity_factor), 8)
            pos, keep = self._fill_capacity(dst, nd, cap)
            slot = jnp.where(keep, dst * cap + pos, nd * cap)
            # inverse permutation: which token fills each send slot (1-D)
            inv = jnp.full((nd * cap + 1,), t, jnp.int32).at[slot].set(
                jnp.arange(t, dtype=jnp.int32)
            )[:-1]
            xt_pad = jnp.concatenate([xt, jnp.zeros((1, M), xl.dtype)])
            send = xt_pad[inv]  # [nd*cap, M] gather, no wide scatter
            send_e = jnp.concatenate([eid % e_local, jnp.full((1,), e_local, jnp.int32)])[inv]
            # all-to-all over the EP axis (intra-pod ICI by construction)
            recv = jax.lax.all_to_all(
                send.reshape(nd, cap, M), ep, 0, 0, tiled=True
            ).reshape(nd * cap, M)
            recv_e = jax.lax.all_to_all(
                send_e.reshape(nd, cap), ep, 0, 0, tiled=True
            ).reshape(nd * cap)
            # bin received tokens into local experts (second capacity stage)
            cap2 = max(int(nd * cap / e_local), 1)
            bin_id = jnp.minimum(recv_e, e_local)  # dead slots -> drop bin
            pos2, keep2 = self._fill_capacity(bin_id, e_local + 1, cap2)
            keep2 &= recv_e < e_local
            slot2 = jnp.where(keep2, bin_id * cap2 + pos2, e_local * cap2)
            inv2 = jnp.full((e_local * cap2 + 1,), nd * cap, jnp.int32).at[slot2].set(
                jnp.arange(nd * cap, dtype=jnp.int32)
            )[:-1]
            recv_pad = jnp.concatenate([recv, jnp.zeros((1, M), xl.dtype)])
            buf = recv_pad[inv2]
            ye = self._expert_ffn(
                w_in, w_gate, w_out, buf.reshape(e_local, cap2, M)
            ).reshape(e_local * cap2, M)
            # NOTE: with F sharded over "model", ye is a partial sum.  The
            # psum is deferred to the *combined* [b, S, M] output (7.5x fewer
            # bytes than psumming the dispatch-width buffer); every routing
            # op in between is linear, so the result is identical.
            back = jnp.concatenate([ye, jnp.zeros((1, M), ye.dtype)])[slot2]
            ret = jax.lax.all_to_all(
                back.reshape(nd, cap, M), ep, 0, 0, tiled=True
            ).reshape(nd * cap, M)
            yt = jnp.concatenate([ret, jnp.zeros((1, M), ret.dtype)])[slot]
            yt = yt * w[:, None]
            out = yt.reshape(b * S, cfg.top_k, M).sum(1).reshape(b, S, M)
            if "model" in mesh.axis_names and mesh.shape["model"] > 1:
                out = jax.lax.psum(out, "model")
            return out.astype(in_dtype)

        x_spec = P(batch_axes or None, None, None)
        r_spec = P(batch_axes or None, None, None)
        w_spec = P(ep, None, "model" if "model" in mesh.axis_names else None)
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(x_spec, r_spec, r_spec, w_spec, w_spec,
                      P(ep, "model" if "model" in mesh.axis_names else None, None)),
            out_specs=x_spec,
            check_vma=False,
        )(x, top_p, top_e, params["w_in"], params["w_gate"], params["w_out"])

    # -- node-aware exchange dispatch over the ("pod", "local") mesh -----
    def _get_dispatcher(self, mesh) -> MoEDispatcher:
        if self.dispatcher is not None:
            return self.dispatcher
        topo = PodTopology(npods=mesh.shape["pod"], ppn=mesh.shape["local"])
        disp = MoEDispatcher(
            topo,
            strategy=self.strategy,
            wire=self.wire,
            quantum=self.route_quantum,
            mesh=mesh,
        )
        object.__setattr__(self, "dispatcher", disp)
        return disp

    def _dispatch_exchange(self, params, x, top_p, top_e, mesh) -> jnp.ndarray:
        """Capacity dispatch with both hops on the node-aware exchange stack.

        Same routing math as :meth:`_dispatch_shard_map`, restructured into
        three ``shard_map`` stages with the collectives lifted out between
        them: the flat ``jax.lax.all_to_all`` calls become planned
        :class:`~repro.comm.IrregularExchange` hops over the measured
        (bucketed) routing pattern, so skewed traffic ships only the
        occupied slot prefix per pair, the advisor can pick the strategy per
        pattern, and wire codecs apply to the DCI-crossing segments.  The
        per-pair count matrix is synced to the host each batch (a tiny
        ``[n, n]`` int32 transfer) -- that measured histogram both keys the
        bucketer and feeds the dispatcher's load histogram.

        Bitwise identical to the baseline for ``wire="none"``: kept tokens
        occupy the block prefix (at most the quantized width), and every
        slot the baseline would carry as dead (zero row / sentinel expert
        id) is reproduced by the splice maps' sentinel row.
        """
        cfg = self.cfg
        B, S, M = x.shape
        if tuple(mesh.axis_names) != WORLD_AXES:
            raise ValueError(
                f'dispatch="exchange" needs the ("pod", "local") exchange '
                f"mesh, got axes {tuple(mesh.axis_names)}"
            )
        n = mesh.shape["pod"] * mesh.shape["local"]
        if cfg.n_experts % n:
            raise ValueError(
                f"n_experts={cfg.n_experts} is not divisible by the "
                f"expert-parallel degree {n} (mesh axes {WORLD_AXES!r}); "
                f"choose n_experts as a multiple of {n}"
            )
        if B % n:
            raise ValueError(
                f'dispatch="exchange" shards the batch over all {n} ranks; '
                f"batch {B} is not divisible by {n}"
            )
        e_local = cfg.n_experts // n
        k = cfg.top_k
        b = B // n
        t = b * S * k
        cap = max(int(t / n * cfg.capacity_factor), 8)

        stages = self._exchange_stages(mesh, b, S, M, jnp.dtype(x.dtype))
        stage_send, stage_expert, stage_combine = stages

        send, send_e, slot, w, counts = stage_send(x, top_p, top_e)

        # host sync on the measured [n, n] histogram: the price of planning
        # communication for the traffic we actually have
        step = self._get_dispatcher(mesh).step(
            np.asarray(jax.device_get(counts), dtype=np.int64), cap, payload_width=M
        )
        bundle = step.bundle
        ex_d, ex_r = step.exchange_dispatch, step.exchange_return

        if ex_d is not None:
            halo_x = ex_d(send)
            halo_e = ex_d(send_e)
        else:
            halo_x = jnp.zeros((n, 0, M), send.dtype)
            halo_e = jnp.zeros((n, 0), send_e.dtype)
        map_d = jnp.asarray(bundle.map_dispatch)
        map_r = jnp.asarray(bundle.map_return)

        back = stage_expert(
            send, send_e, halo_x, halo_e, map_d,
            params["w_in"], params["w_gate"], params["w_out"],
        )

        if ex_r is not None:
            halo_b = ex_r(back)
        else:
            halo_b = jnp.zeros((n, 0, M), back.dtype)

        return stage_combine(back, halo_b, map_r, slot, w)

    def _exchange_stages(self, mesh, b: int, S: int, M: int, dtype):
        """Build (once per shape signature) the three jitted shard_map
        stages of the exchange dispatch.  Re-creating the ``shard_map``
        callables per batch would re-trace every call; wrapping them in a
        memoized ``jax.jit`` makes a steady-state batch pure cache hits
        (the only re-specialization is a halo-width change on re-plan)."""
        memo = self.__dict__.get("_stage_memo")
        if memo is None:
            memo = {}
            object.__setattr__(self, "_stage_memo", memo)
        key = (mesh, b, S, M, str(dtype))
        if key in memo:
            return memo[key]

        cfg = self.cfg
        n = mesh.shape["pod"] * mesh.shape["local"]
        e_local = cfg.n_experts // n
        k = cfg.top_k
        t = b * S * k
        cap = max(int(t / n * cfg.capacity_factor), 8)

        vec = P(WORLD_AXES, None)
        mat = P(WORLD_AXES, None, None)

        def stage_send(xl, pl, el):
            xt = jnp.repeat(xl.reshape(b * S, M), k, axis=0)  # [t, M]
            eid = el.reshape(t)
            w = pl.reshape(t).astype(xl.dtype)
            dst = eid // e_local
            pos, keep = self._fill_capacity(dst, n, cap)
            slot = jnp.where(keep, dst * cap + pos, n * cap)
            inv = jnp.full((n * cap + 1,), t, jnp.int32).at[slot].set(
                jnp.arange(t, dtype=jnp.int32)
            )[:-1]
            xt_pad = jnp.concatenate([xt, jnp.zeros((1, M), xl.dtype)])
            send = xt_pad[inv]
            send_e = jnp.concatenate(
                [eid % e_local, jnp.full((1,), e_local, jnp.int32)]
            )[inv]
            counts = jnp.zeros((n,), jnp.int32).at[dst].add(1)
            return send[None], send_e[None], slot[None], w[None], counts[None]

        def stage_expert(sd, se, hx, he, mp, w_in, w_gate, w_out):
            sd, se, hx, he, mp = sd[0], se[0], hx[0], he[0], mp[0]
            # splice canonical exchange recv back into the [n*cap] layout;
            # the sentinel row reproduces the baseline's dead slots exactly
            comb_x = jnp.concatenate([sd, hx, jnp.zeros((1, M), sd.dtype)])
            comb_e = jnp.concatenate(
                [se, he, jnp.full((1,), e_local, jnp.int32)]
            )
            recv = comb_x[mp]
            recv_e = comb_e[mp]
            cap2 = max(int(n * cap / e_local), 1)
            bin_id = jnp.minimum(recv_e, e_local)
            pos2, keep2 = self._fill_capacity(bin_id, e_local + 1, cap2)
            keep2 &= recv_e < e_local
            slot2 = jnp.where(keep2, bin_id * cap2 + pos2, e_local * cap2)
            inv2 = jnp.full((e_local * cap2 + 1,), n * cap, jnp.int32).at[
                slot2
            ].set(jnp.arange(n * cap, dtype=jnp.int32))[:-1]
            recv_pad = jnp.concatenate([recv, jnp.zeros((1, M), sd.dtype)])
            buf = recv_pad[inv2]
            ye = self._expert_ffn(
                w_in, w_gate, w_out, buf.reshape(e_local, cap2, M)
            ).reshape(e_local * cap2, M)
            back = jnp.concatenate([ye, jnp.zeros((1, M), ye.dtype)])[slot2]
            return back[None]

        def stage_combine(bk, hb, mp, sl, wl):
            bk, hb, mp, sl, wl = bk[0], hb[0], mp[0], sl[0], wl[0]
            comb = jnp.concatenate([bk, hb, jnp.zeros((1, M), bk.dtype)])
            ret = comb[mp]
            yt = jnp.concatenate([ret, jnp.zeros((1, M), ret.dtype)])[sl]
            yt = yt * wl[:, None]
            out = yt.reshape(b * S, k, M).sum(1).reshape(b, S, M)
            return out.astype(dtype)

        fns = (
            jax.jit(shard_map(
                stage_send,
                mesh=mesh,
                in_specs=(mat, mat, mat),
                out_specs=(mat, vec, vec, vec, vec),
                check_vma=False,
            )),
            jax.jit(shard_map(
                stage_expert,
                mesh=mesh,
                in_specs=(mat, vec, mat, vec, vec, mat, mat, mat),
                out_specs=mat,
                check_vma=False,
            )),
            jax.jit(shard_map(
                stage_combine,
                mesh=mesh,
                in_specs=(mat, mat, vec, vec, vec),
                out_specs=mat,
                check_vma=False,
            )),
        )
        memo[key] = fns
        return fns
