"""Shared transformer layers: norms, RoPE, GQA attention, MLP.

All layers are pure functions over (params dict, inputs); parameter
declaration returns a matching tree of :class:`repro.models.sharding.ParamSpec`.

Attention ships two interchangeable implementations:

* ``dot``     -- materialized scores (smoke tests, short sequences)
* ``chunked`` -- online-softmax over key blocks via ``lax.scan`` (flash
  attention in pure XLA ops; O(S * block) memory, used for the 32k dry-run
  shapes and as the CPU-runnable stand-in for the Pallas kernel)

plus the Pallas flash kernel in :mod:`repro.kernels.flash_attention` for the
real TPU target (selected by ``impl="pallas"``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_params(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float = 1e4,
    fraction: float = 1.0,
) -> jnp.ndarray:
    """Rotary embedding. x: [..., S, H, D]; positions: [..., S].

    ``fraction < 1`` rotates only the leading ``fraction * D`` dims
    (ChatGLM's 2D/partial RoPE).
    """
    D = x.shape[-1]
    rot = int(D * fraction) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = xr[..., :half].astype(jnp.float32), xr[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Attention cores (batched; q: [B, Sq, H, D], k/v: [B, Sk, Hkv, D])
# ---------------------------------------------------------------------------


def _repeat_kv(k: jnp.ndarray, h: int) -> jnp.ndarray:
    rep = h // k.shape[-2]
    return jnp.repeat(k, rep, axis=-2) if rep > 1 else k


def attend_dot(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Materialized-scores attention."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attend_chunked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block: int = 1024,
) -> jnp.ndarray:
    """Online-softmax (flash) attention over key blocks, pure XLA.

    Memory is O(Sq * block) per head instead of O(Sq * Sk): the 32k-sequence
    shapes would need ~4 GiB of scores *per head* with ``attend_dot``.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]  # may differ from D (MLA)
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    nblk = -(-Sk // block)
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, H, Dv).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(Sq) + (Sk - Sq)  # absolute query positions
    qf = q.astype(jnp.float32)

    def step(carry, blk):
        acc, m, denom, b_idx = carry
        kblk, vblk = blk
        kpos = b_idx * block + jnp.arange(block)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk.astype(jnp.float32)) * scale
        mask = jnp.ones((Sq, block), dtype=bool)
        mask &= kpos[None, :] < Sk  # padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        denom = denom * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32)
        )
        return (acc, m_new, denom, b_idx + 1), None

    acc0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, _, denom, _), _ = jax.lax.scan(step, (acc0, m0, d0, 0), (kb, vb))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attend_fused_stub(q, k, v) -> jnp.ndarray:
    """Shape/dependency-correct surrogate for the Pallas flash kernel.

    Used ONLY by the dry-run's fused-attention variant: the Pallas kernel
    cannot be compiled by the CPU backend, so the graph carries this cheap
    stand-in and the dry-run adds the kernel's FLOPs/HBM-bytes analytically
    (see ``repro.launch.dryrun.attention_kernel_terms``).  On real TPU,
    ``impl="pallas"`` runs the actual kernel.
    """
    H = q.shape[-2]
    Dv = v.shape[-1]  # MLA: value head dim < qk head dim
    km = _repeat_kv(k.mean(axis=1, keepdims=True), H)
    vm = _repeat_kv(v.mean(axis=1, keepdims=True), H)
    return q[..., :Dv] * km[..., :Dv] + vm


def attend(
    q, k, v, *, impl: str = "dot", causal: bool = True, window=None, scale=None
) -> jnp.ndarray:
    if impl == "dot":
        return attend_dot(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "chunked":
        return attend_chunked(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "fused":
        return attend_fused_stub(q, k, v)
    if impl == "pallas":
        from repro.kernels.ops import flash_attention

        return flash_attention(q, k, v, causal=causal, window=window, scale=scale)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionLayer:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    window: Optional[int] = None
    cross: bool = False  # cross-attention (kv from encoder/image context)

    def params(self) -> dict:
        H, KV, D, M = self.n_heads, self.n_kv_heads, self.head_dim, self.d_model
        p = {
            "wq": ParamSpec((M, H, D), ("fsdp", "heads", None)),
            "wk": ParamSpec((M, KV, D), ("fsdp", "kv_heads", None)),
            "wv": ParamSpec((M, KV, D), ("fsdp", "kv_heads", None)),
            "wo": ParamSpec((H, D, M), ("heads", None, "fsdp")),
        }
        if self.qk_norm:
            p["q_norm"] = rmsnorm_params(D)
            p["k_norm"] = rmsnorm_params(D)
        return p

    # -- projections ---------------------------------------------------
    def qkv(self, params, x, positions, kv_x=None):
        """x: [B, S, M] -> q [B,S,H,D], k/v [B,Skv,KV,D] (rotated, normed)."""
        kv_x = x if kv_x is None else kv_x
        q = jnp.einsum("bsm,mhd->bshd", x, params["wq"].astype(x.dtype))
        k = jnp.einsum("bsm,mhd->bshd", kv_x, params["wk"].astype(x.dtype))
        v = jnp.einsum("bsm,mhd->bshd", kv_x, params["wv"].astype(x.dtype))
        if self.qk_norm:
            q = rmsnorm(params["q_norm"], q)
            k = rmsnorm(params["k_norm"], k)
        if not self.cross:
            q = rope(q, positions, self.rope_theta, self.rope_fraction)
            k = rope(
                k,
                positions[..., -k.shape[1] :] if k.shape[1] != q.shape[1] else positions,
                self.rope_theta,
                self.rope_fraction,
            )
        return q, k, v

    def out(self, params, attn_out):
        return jnp.einsum("bshd,hdm->bsm", attn_out, params["wo"].astype(attn_out.dtype))

    def __call__(self, params, x, positions, impl="dot", kv_x=None, causal=None):
        q, k, v = self.qkv(params, x, positions, kv_x=kv_x)
        causal = (not self.cross) if causal is None else causal
        o = attend(q, k, v, impl=impl, causal=causal, window=self.window)
        return self.out(params, o)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLP:
    d_model: int
    d_ff: int
    act: str = "silu"  # silu (-> SwiGLU) | gelu

    def params(self) -> dict:
        p = {
            "w_in": ParamSpec((self.d_model, self.d_ff), ("fsdp", "mlp")),
            "w_out": ParamSpec((self.d_ff, self.d_model), ("mlp", "fsdp")),
        }
        if self.act == "silu":
            p["w_gate"] = ParamSpec((self.d_model, self.d_ff), ("fsdp", "mlp"))
        return p

    def __call__(self, params, x):
        h = jnp.einsum("bsm,mf->bsf", x, params["w_in"].astype(x.dtype))
        if self.act == "silu":
            g = jnp.einsum("bsm,mf->bsf", x, params["w_gate"].astype(x.dtype))
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        return jnp.einsum("bsf,fm->bsm", h, params["w_out"].astype(x.dtype))
