"""Composable transformer blocks and scanned segments.

A model is a sequence of **segments**; each segment is ``count`` copies of one
homogeneous **block**, executed under ``jax.lax.scan`` with per-segment
stacked parameters ``[count, ...]`` (MaxText-style: keeps the HLO small and
compile times bounded at 100-layer scale) and rematerialization.

Block kinds (built from :mod:`repro.models.layers` / :mod:`moe` / :mod:`mla` /
:mod:`mamba2`):

* ``dense``   -- self-attention (GQA or MLA) + MLP or MoE
* ``ssm``     -- Mamba-2 mixer only
* ``hybrid``  -- parallel attention + SSM heads (Hymba), then MLP
* ``cross``   -- cross-attention to a fixed context (VLM image layers,
  encoder-decoder), optionally fused with self-attention
* ``encoder`` -- bidirectional self-attention + MLP

Every block implements ``apply`` (full sequence, no cache), ``prefill``
(full sequence, returns its cache slice) and ``decode`` (single token +
cache).  Head counts are padded per DESIGN.md section 6 when the
tensor-parallel size does not divide them.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.mamba2 import Mamba2Mixer
from repro.models.mla import MLAttention
from repro.models.moe import MoELayer
from repro.models.sharding import ParamSpec


def pad_heads(n_heads: int, n_kv: int, tp: int) -> Tuple[int, int]:
    """Pad (q heads, kv heads) so q % tp == 0 and q % kv == 0 (DESIGN §6)."""
    hp = -(-n_heads // tp) * tp
    kv = n_kv
    while hp % kv:
        kv += 1
    return hp, kv


def kv_store_heads(kv: int, tp: int) -> int:
    """KV heads as stored in the decode cache.

    We store the *true* (grouping-padded) KV head count and shard the cache
    on ``head_dim`` over the ``model`` axis instead (rule ``cache_dim`` in
    :mod:`repro.models.sharding`): repeating KV heads up to the TP size would
    double the 32k cache (llama-3.2-vision-90b would not fit a single pod),
    while head_dim (64/128) always divides the 16-way model axis and the
    decode-time partial-dot psum is tiny (Sq == 1).
    """
    del tp
    return kv


# ---------------------------------------------------------------------------
# Attention with cache (shared by all attention-bearing blocks)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CachedAttention:
    """GQA attention + ring/linear KV cache."""

    attn: L.AttentionLayer
    kv_store: int  # stored (possibly repeated) kv heads
    window: Optional[int] = None

    def params(self) -> dict:
        return self.attn.params()

    def _store(self, k: jnp.ndarray) -> jnp.ndarray:
        rep = self.kv_store // k.shape[-2]
        return jnp.repeat(k, rep, axis=-2) if rep > 1 else k

    def apply(self, params, x, positions, impl):
        return self.attn(params, x, positions, impl=impl)

    def prefill(self, params, x, positions, impl):
        q, k, v = self.attn.qkv(params, x, positions)
        o = L.attend(q, k, v, impl=impl, causal=True, window=self.window)
        out = self.attn.out(params, o)
        ks, vs = self._store(k), self._store(v)
        if self.window is not None:
            W = self.window
            S = ks.shape[1]
            if S >= W:
                # ring holds the last W keys at slot = pos % W
                idx = (jnp.arange(S - W, S)) % W
                ks = jnp.zeros((ks.shape[0], W, *ks.shape[2:]), ks.dtype).at[:, idx].set(ks[:, -W:])
                vs = jnp.zeros((vs.shape[0], W, *vs.shape[2:]), vs.dtype).at[:, idx].set(vs[:, -W:])
            else:
                pad = W - S
                ks = jnp.pad(ks, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vs = jnp.pad(vs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return out, {"k": ks, "v": vs}

    def decode(self, params, x, positions, cache, pos, impl):
        """Single-token decode WITHOUT touching the cache tensors.

        Attention runs over the *existing* cache entries (masked to
        ``< pos``) plus the current token's K/V as an explicit extra term;
        the cache append happens once per step *outside* the layer scan
        (:meth:`Segment.decode`).  Carrying the updated cache through the
        scan instead forced a full stacked-cache copy per layer iteration
        and a replicated->sharded resharding gather -- together these
        dominated the decode memory roofline (EXPERIMENTS.md §Perf,
        vision-90b iterations 2-3).
        """
        q, k, v = self.attn.qkv(params, x, positions)  # S == 1
        k, v = self._store(k), self._store(v)
        ks, vs = cache["k"], cache["v"]
        if self.window is not None:
            W = self.window
            slots = jnp.arange(W)
            # ring slots hold positions pos-W..pos-1 except the slot about to
            # be overwritten; all written slots are < pos by construction
            valid = jnp.where(pos >= W, slots != pos % W, slots < pos)
        else:
            valid = jnp.arange(ks.shape[1]) < pos
        o = self._decode_attend(q, k, v, ks, vs, valid)
        return self.attn.out(params, o), {"k_new": k, "v_new": v}

    def _decode_attend(self, q, k_new, v_new, ks, vs, valid):
        """Grouped-GQA single-query attention over cache + current token.

        The grouped einsum avoids materializing KV heads repeated to the
        query head count (up to 8x the whole cache per layer -- §Perf,
        vision-90b iteration 1); dots run in the cache dtype, softmax in f32.
        """
        B, _, H, D = q.shape
        KV = ks.shape[-2]
        rep = H // KV
        q5 = q.reshape(B, 1, KV, rep, D).transpose(0, 2, 3, 1, 4)  # [B,KV,rep,1,D]
        scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
        lc = jnp.einsum("bkrqd,bskd->bkrqs", q5, ks.astype(q.dtype)).astype(jnp.float32) * scale
        lc = jnp.where(valid[None, None, None, None, :], lc, L.NEG_INF)
        lnew = jnp.einsum("bkrqd,bskd->bkrqs", q5, k_new.astype(q.dtype)).astype(jnp.float32) * scale
        # online-softmax composition of the (seq-sharded) cache term and the
        # current-token term: concatenating along the sharded seq dim made
        # the partitioner gather the whole cache (§Perf vision-90b iter 5)
        m = jnp.maximum(lc.max(axis=-1, keepdims=True), lnew)
        pc = jnp.exp(lc - m)
        pn = jnp.exp(lnew - m)
        denom = pc.sum(axis=-1, keepdims=True) + pn
        o = jnp.einsum("bkrqs,bskd->bkrqd", pc.astype(vs.dtype), vs) + pn.astype(
            v_new.dtype
        ) * v_new.transpose(0, 2, 1, 3)[:, :, None]
        o = o / denom.astype(o.dtype)
        return o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, D).astype(q.dtype)

    def init_cache(self, batch, max_len, dtype):
        S = self.window if self.window is not None else max_len
        D = self.attn.head_dim
        return {
            "k": jnp.zeros((batch, S, self.kv_store, D), dtype),
            "v": jnp.zeros((batch, S, self.kv_store, D), dtype),
        }


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Block:
    """One transformer block; which sub-layers exist depends on the config."""

    cfg: ModelConfig
    tp: int = 1
    self_attn: Optional[CachedAttention] = None
    mla: Optional[MLAttention] = None
    ssm: Optional[Mamba2Mixer] = None
    cross: Optional[L.AttentionLayer] = None
    mlp: Optional[L.MLP] = None
    moe: Optional[MoELayer] = None
    causal: bool = True

    # -- construction ----------------------------------------------------
    @staticmethod
    def make(cfg: ModelConfig, kind: str, tp: int = 1, use_moe: bool = False) -> "Block":
        hp, kvp = pad_heads(cfg.n_heads, cfg.n_kv_heads, tp)
        d = cfg.resolved_head_dim
        attn = L.AttentionLayer(
            d_model=cfg.d_model, n_heads=hp, n_kv_heads=kvp, head_dim=d,
            qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
            rope_fraction=cfg.rope_fraction, window=cfg.window,
        )
        cached = CachedAttention(attn, kv_store_heads(kvp, tp), window=cfg.window)
        mlp = L.MLP(cfg.d_model, cfg.d_ff, cfg.act) if cfg.d_ff else None
        moe = MoELayer(cfg.d_model, cfg.moe, cfg.act) if (use_moe and cfg.moe) else None
        kw: Dict[str, Any] = dict(cfg=cfg, tp=tp, mlp=None if moe else mlp, moe=moe)
        if kind == "dense":
            if cfg.mla is not None:
                return Block(self_attn=None, mla=MLAttention(cfg.d_model, hp, cfg.mla, cfg.rope_theta), **kw)
            return Block(self_attn=cached, **kw)
        if kind == "ssm":
            return Block(ssm=Mamba2Mixer(cfg.d_model, cfg.ssm), mlp=None, moe=None,
                         cfg=cfg, tp=tp)
        if kind == "hybrid":
            return Block(self_attn=cached, ssm=Mamba2Mixer(cfg.d_model, cfg.ssm), **kw)
        if kind == "cross":
            xattn = L.AttentionLayer(
                d_model=cfg.d_model, n_heads=hp, n_kv_heads=kvp, head_dim=d,
                cross=True,
            )
            return Block(cross=xattn, **kw)
        if kind == "decoder":  # enc-dec decoder layer: self + cross + mlp
            xattn = L.AttentionLayer(
                d_model=cfg.d_model, n_heads=hp, n_kv_heads=kvp, head_dim=d, cross=True,
            )
            return Block(self_attn=cached, cross=xattn, **kw)
        if kind == "encoder":
            return Block(self_attn=cached, causal=False, **kw)
        raise ValueError(f"unknown block kind {kind!r}")

    # -- params ----------------------------------------------------------
    def params(self) -> dict:
        p: Dict[str, Any] = {}
        eps = self.cfg.norm_eps
        if self.self_attn is not None:
            p["attn"] = self.self_attn.params()
            p["attn_norm"] = L.rmsnorm_params(self.cfg.d_model)
        if self.mla is not None:
            p["attn"] = self.mla.params()
            p["attn_norm"] = L.rmsnorm_params(self.cfg.d_model)
        if self.ssm is not None:
            p["ssm"] = self.ssm.params()
            if self.self_attn is None:
                p["ssm_norm"] = L.rmsnorm_params(self.cfg.d_model)
        if self.cross is not None:
            p["cross"] = self.cross.params()
            p["cross_norm"] = L.rmsnorm_params(self.cfg.d_model)
        if self.mlp is not None:
            p["mlp"] = self.mlp.params()
            p["mlp_norm"] = L.rmsnorm_params(self.cfg.d_model)
        if self.moe is not None:
            p["moe"] = self.moe.params()
            p["mlp_norm"] = L.rmsnorm_params(self.cfg.d_model)
        return p

    # -- mixing sub-layer (attention and/or SSM), full sequence -----------
    def _mix(self, p, x, positions, impl, mode, cache=None, pos=None):
        """Returns (delta, new_cache_pieces)."""
        new_cache: Dict[str, Any] = {}
        parts = []
        eps = self.cfg.norm_eps
        if self.self_attn is not None or self.mla is not None:
            h = L.rmsnorm(p["attn_norm"], x, eps)
            if self.mla is not None:
                if mode == "decode":
                    o, new_cache["mla"] = self.mla.decode(p["attn"], h, positions, cache["mla"], pos)
                else:
                    o = self.mla(p["attn"], h, positions, impl=impl)
                    if mode == "prefill":
                        # cache the latent directly (absorbed decode reads it)
                        c_kv, k_rope = self.mla.latent(p["attn"], h, positions)
                        new_cache["mla"] = {"c_kv": c_kv, "k_rope": k_rope}
            else:
                if mode == "apply":
                    q, k, v = self.self_attn.attn.qkv(p["attn"], h, positions)
                    o = L.attend(q, k, v, impl=impl, causal=self.causal, window=self.self_attn.window)
                    o = self.self_attn.attn.out(p["attn"], o)
                elif mode == "prefill":
                    o, new_cache["attn"] = self.self_attn.prefill(p["attn"], h, positions, impl)
                else:
                    o, new_cache["attn"] = self.self_attn.decode(
                        p["attn"], h, positions, cache["attn"], pos, impl
                    )
            parts.append(o)
        if self.ssm is not None:
            hs = L.rmsnorm(p.get("ssm_norm", p.get("attn_norm")), x, eps)
            if mode == "decode":
                o, new_cache["ssm"] = self.ssm.decode(p["ssm"], hs, cache["ssm"])
            else:
                o = self.ssm(p["ssm"], hs, impl="chunked" if impl != "dot" else "chunked")
                if mode == "prefill":
                    new_cache["ssm"] = self._ssm_prefill_state(p, hs)
            parts.append(o)
        delta = parts[0] if len(parts) == 1 else 0.5 * (parts[0] + parts[1])
        return delta, new_cache

    def _ssm_prefill_state(self, p, hs):
        """Final SSM state after a prefill (recompute via chunked scan end)."""
        # run the mixer's projections and fold the sequence into the state
        m = self.ssm
        xh, z, b, c, dt = m._project(p["ssm"], hs)
        xh, conv_state = m._conv(p["ssm"], xh)
        a = -jnp.exp(p["ssm"]["a_log"].astype(jnp.float32))
        loga = a[None, None, :] * dt
        xdt = xh.astype(jnp.float32) * dt[..., None]
        # state = sum_j exp(sum_{k>j} loga_k) b_j xdt_j
        la = jnp.cumsum(loga, axis=1)
        w = jnp.exp(la[:, -1:, :] - la)  # [B,S,H]
        h = jnp.einsum("bsn,bsh,bshp->bhnp", b.astype(jnp.float32), w, xdt)
        return {"ssm": h, "conv": conv_state[:, -(m.cfg.conv_width - 1):]}

    # -- full block ------------------------------------------------------
    def run(self, p, x, positions, *, impl, mode, cache=None, pos=None,
            ctx=None, ctx_cache=None, mesh=None):
        """mode: apply | prefill | decode. Returns (x, new_cache)."""
        new_cache: Dict[str, Any] = {}
        if self.self_attn is not None or self.mla is not None or self.ssm is not None:
            delta, nc = self._mix(p, x, positions, impl, mode, cache, pos)
            x = x + delta
            new_cache.update(nc)
        if self.cross is not None:
            h = L.rmsnorm(p["cross_norm"], x, self.cfg.norm_eps)
            if mode == "decode":
                # cross K/V are immutable after prefill: read, never re-emit
                # (returning them as scan ys copied the full context cache
                # once per decode step)
                kc, vc = cache["cross_k"], cache["cross_v"]
                q = jnp.einsum("bsm,mhd->bshd", h, p["cross"]["wq"].astype(h.dtype))
                o = L.attend(q, kc, vc, impl="dot", causal=False)
                o = self.cross.out(p["cross"], o)
            else:
                q, k, v = self.cross.qkv(p["cross"], h, positions, kv_x=ctx)
                o = L.attend(q, k, v, impl=impl, causal=False)
                o = self.cross.out(p["cross"], o)
                if mode == "prefill":
                    new_cache["cross_k"], new_cache["cross_v"] = k, v
            x = x + o
        if self.mlp is not None or self.moe is not None:
            h = L.rmsnorm(p["mlp_norm"], x, self.cfg.norm_eps)
            if self.moe is not None:
                x = x + self.moe(p["moe"], h, mesh=mesh)
            else:
                x = x + self.mlp(p["mlp"], h)
        return x, new_cache

    # -- cache template ----------------------------------------------------
    def init_cache(self, batch, max_len, dtype, ctx_len: int = 0):
        c: Dict[str, Any] = {}
        if self.self_attn is not None:
            c["attn"] = self.self_attn.init_cache(batch, max_len, dtype)
        if self.mla is not None:
            c["mla"] = self.mla.init_cache(batch, max_len, dtype)
        if self.ssm is not None:
            c["ssm"] = self.ssm.init_cache(batch, dtype)
        if self.cross is not None:
            D = self.cross.head_dim
            c["cross_k"] = jnp.zeros((batch, ctx_len, self.cross.n_kv_heads, D), dtype)
            c["cross_v"] = jnp.zeros((batch, ctx_len, self.cross.n_kv_heads, D), dtype)
        return c


# ---------------------------------------------------------------------------
# Segments: scan over stacked homogeneous blocks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    block: Block
    count: int

    def params(self) -> dict:
        """Stacked ParamSpec tree: every leaf gains a leading 'layers' dim."""
        tree = self.block.params()

        def stack(ps: ParamSpec) -> ParamSpec:
            return ParamSpec(
                (self.count, *ps.shape), ("layers", *ps.logical), ps.init, ps.scale
            )

        return jax.tree.map(stack, tree, is_leaf=lambda v: isinstance(v, ParamSpec))

    @staticmethod
    def _checkpoint(body):
        """Remat policy knob (read at trace time): REPRO_REMAT_POLICY in
        {"full" (default: save only the carry), "dots" (save matmul outputs,
        trading memory for recompute FLOPs), "none" (no remat)}."""
        import os

        policy = os.environ.get("REPRO_REMAT_POLICY", "full")
        if policy == "none":
            return body
        if policy == "dots":
            return jax.checkpoint(
                body,
                prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        return jax.checkpoint(body, prevent_cse=False)

    @staticmethod
    def _anchor(x, mesh):
        """Constrain the scan carry to the canonical activation sharding so
        GSPMD cannot flip to parameter-side layouts inside the loop."""
        if mesh is None:
            return x
        from repro.models.sharding import constrain, rules_for_mesh

        return constrain(x, mesh, rules_for_mesh(mesh), ("batch", "seq_sp", "embed"))

    # ------------------------------------------------------------------
    def apply(self, params, x, positions, *, impl, ctx=None, mesh=None, remat=True):
        block = self.block

        def body(carry, layer_p):
            carry = Segment._anchor(carry, mesh)
            y, _ = block.run(layer_p, carry, positions, impl=impl, mode="apply",
                             ctx=ctx, mesh=mesh)
            return y, None

        if remat:
            body = Segment._checkpoint(body)
        x, _ = jax.lax.scan(body, x, params)
        return x

    def prefill(self, params, x, positions, *, impl, ctx=None, mesh=None, remat=True):
        block = self.block

        def body(carry, layer_p):
            carry = Segment._anchor(carry, mesh)
            y, cache = block.run(layer_p, carry, positions, impl=impl,
                                 mode="prefill", ctx=ctx, mesh=mesh)
            return y, cache

        if remat:
            body = Segment._checkpoint(body)
        x, caches = jax.lax.scan(body, x, params)
        return x, caches  # cache leaves stacked [count, ...]

    def decode(self, params, x, positions, caches, pos, *, ctx=None, mesh=None):
        """One decode step for all layers of this segment.

        Blocks never return updated cache tensors: the scan emits only the
        per-layer *new entries* ([count, B, 1, ...]), which are appended with
        a single dynamic_update_slice per tensor after the scan.  Carrying
        the caches through the scan ys copied the full stacked cache once per
        layer iteration and forced a replicated->sharded resharding of every
        update (EXPERIMENTS.md §Perf, vision-90b decode iterations 2-3).
        """
        block = self.block

        def body(carry, inp):
            layer_p, cache = inp
            carry = Segment._anchor(carry, mesh)
            y, upd = block.run(layer_p, carry, positions, impl="dot",
                               mode="decode", cache=cache, pos=pos,
                               ctx=ctx, mesh=mesh)
            return y, upd

        x, updates = jax.lax.scan(body, x, (params, caches))
        new_caches = dict(caches)

        def _append(old, new, slot):
            # old: [count, B, S, ...]; new: [count, B, 1, ...]
            if mesh is not None:
                from repro.models.sharding import constrain, rules_for_mesh

                logical = ("layers", "batch") + (None,) * (old.ndim - 2)
                new = constrain(new, mesh, rules_for_mesh(mesh), logical)
            start = (0, 0, slot) + (0,) * (old.ndim - 3)
            return jax.lax.dynamic_update_slice(old, new.astype(old.dtype), start)

        if "attn" in updates:
            W = block.self_attn.window
            slot = pos % W if W is not None else pos
            new_caches["attn"] = {
                "k": _append(caches["attn"]["k"], updates["attn"]["k_new"], slot),
                "v": _append(caches["attn"]["v"], updates["attn"]["v_new"], slot),
            }
        if "mla" in updates:
            new_caches["mla"] = {
                "c_kv": _append(caches["mla"]["c_kv"], updates["mla"]["c_kv_new"], pos),
                "k_rope": _append(caches["mla"]["k_rope"], updates["mla"]["k_rope_new"], pos),
            }
        if "ssm" in updates:
            new_caches["ssm"] = updates["ssm"]  # full replacement (O(1) state)
        return x, new_caches

    def init_cache(self, batch, max_len, dtype, ctx_len=0):
        one = self.block.init_cache(batch, max_len, dtype, ctx_len)
        return jax.tree.map(
            lambda a: jnp.zeros((self.count, *a.shape), a.dtype), one
        )
