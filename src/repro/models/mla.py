"""Multi-head Latent Attention (DeepSeek-V2) with absorbed decode.

Keys/values are compressed into a per-token latent ``c_kv`` of rank
``kv_lora_rank`` plus a shared rotary key ``k_rope``; the decode path uses the
weight-absorption identity so the KV cache stores only
``[B, S, kv_lora_rank + rope_head_dim]`` -- the reason MLA's 32k cache is
~50x smaller than GQA's:

    q^T k   = (q_nope^T W_uk) c_kv + q_rope^T k_rope
    out_h   = (probs_h @ c_kv) W_uv[h]
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.layers import attend, rmsnorm, rmsnorm_params, rope
from repro.models.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class MLAttention:
    d_model: int
    n_heads: int
    cfg: MLAConfig
    rope_theta: float = 1e4

    @property
    def qk_dim(self) -> int:
        return self.cfg.nope_head_dim + self.cfg.rope_head_dim

    def params(self) -> dict:
        c, M, H = self.cfg, self.d_model, self.n_heads
        return {
            "wq": ParamSpec((M, H, self.qk_dim), ("fsdp", "heads", None)),
            "w_kv_a": ParamSpec(
                (M, c.kv_lora_rank + c.rope_head_dim), ("fsdp", None)
            ),
            "kv_norm": rmsnorm_params(c.kv_lora_rank),
            "w_uk": ParamSpec((c.kv_lora_rank, H, c.nope_head_dim), (None, "heads", None)),
            "w_uv": ParamSpec((c.kv_lora_rank, H, c.v_head_dim), (None, "heads", None)),
            "wo": ParamSpec((H, c.v_head_dim, M), ("heads", None, "fsdp")),
        }

    # ------------------------------------------------------------------
    def latent(self, params, x, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """x -> (c_kv [B,S,lora], k_rope [B,S,rope_dim]) -- the cache entry."""
        c = self.cfg
        kv_a = jnp.einsum("bsm,mr->bsr", x, params["w_kv_a"].astype(x.dtype))
        c_kv = rmsnorm(params["kv_norm"], kv_a[..., : c.kv_lora_rank])
        k_rope = rope(
            kv_a[..., c.kv_lora_rank :][:, :, None, :], positions, self.rope_theta
        )[:, :, 0, :]
        return c_kv, k_rope

    def queries(self, params, x, positions):
        c = self.cfg
        q = jnp.einsum("bsm,mhd->bshd", x, params["wq"].astype(x.dtype))
        q_nope, q_rope = q[..., : c.nope_head_dim], q[..., c.nope_head_dim :]
        q_rope = rope(q_rope, positions, self.rope_theta)
        return q_nope, q_rope

    # ------------------------------------------------------------------
    def __call__(self, params, x, positions, impl="dot"):
        """Train/prefill path: expand the latent into per-head K/V."""
        c = self.cfg
        q_nope, q_rope = self.queries(params, x, positions)
        c_kv, k_rope = self.latent(params, x, positions)
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uk"].astype(x.dtype))
        v = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uv"].astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_nope.shape[:3], c.rope_head_dim))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = attend(q, k, v, impl=impl, causal=True, scale=1.0 / math.sqrt(self.qk_dim))
        return jnp.einsum("bshd,hdm->bsm", o, params["wo"].astype(x.dtype))

    # ------------------------------------------------------------------
    def decode(self, params, x, positions, cache, pos: jnp.ndarray):
        """Absorbed single-token decode.

        cache: dict(c_kv [B, Smax, lora], k_rope [B, Smax, rope]); ``pos`` is
        the current write index.  Attention runs over the *existing* entries
        (masked to ``< pos``) plus the current latent as an explicit extra
        term; the cache append happens outside the layer scan (see
        ``transformer.Segment.decode``).  Returns (out, update dict).
        """
        c = self.cfg
        B = x.shape[0]
        q_nope, q_rope = self.queries(params, x, positions)  # [B,1,H,*]
        c_new, kr_new = self.latent(params, x, positions)  # [B,1,lora],[B,1,rope]
        c_kv, k_rope = cache["c_kv"], cache["k_rope"]
        # absorb: q' = q_nope @ W_uk -> latent space
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, params["w_uk"].astype(x.dtype))
        sc = (
            jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32), c_kv.astype(jnp.float32))
            + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
        ) / math.sqrt(self.qk_dim)
        spos = jnp.arange(c_kv.shape[1])
        sc = jnp.where(spos[None, None, None, :] < pos, sc, -1e30)
        sn = (
            jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32), c_new.astype(jnp.float32))
            + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32), kr_new.astype(jnp.float32))
        ) / math.sqrt(self.qk_dim)
        probs = jax.nn.softmax(jnp.concatenate([sc, sn], axis=-1), axis=-1)
        ctx = jnp.einsum("bhqs,bsr->bqhr", probs[..., :-1], c_kv.astype(jnp.float32)) + jnp.einsum(
            "bhqs,bsr->bqhr", probs[..., -1:], c_new.astype(jnp.float32)
        )
        o = jnp.einsum("bqhr,rhd->bqhd", ctx.astype(x.dtype), params["w_uv"].astype(x.dtype))
        out = jnp.einsum("bqhd,hdm->bqm", o, params["wo"].astype(x.dtype))
        return out, {"c_kv_new": c_new, "k_rope_new": kr_new}

    def init_cache(self, batch: int, max_len: int, dtype) -> dict:
        c = self.cfg
        return {
            "c_kv": jnp.zeros((batch, max_len, c.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, c.rope_head_dim), dtype),
        }
