"""Logical-axis sharding rules (MaxText-style) for the model zoo.

Every parameter and boundary activation carries a tuple of *logical* axis
names; :func:`spec_for` resolves them to mesh axes through a rules table.
The same model code therefore runs on the single-pod ``("data", "model")``
mesh, the multi-pod ``("pod", "data", "model")`` mesh, or a 1-device CPU
mesh (where every rule resolves to None).

Default placement (see DESIGN.md section 6):

* tensor-parallel dims (heads / mlp / vocab / experts / state) -> ``model``
* weight-FSDP dim (the non-TP dim of each matrix)              -> ``data``
* parameters are *replicated* across ``pod`` (keeps steady-state DCI traffic
  to gradient reduction only -- the paper-guided choice); optimizer state
  follows the parameters.
* activation batch -> ``("pod", "data")`` (falls back to fewer axes when the
  batch is too small to split).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

LogicalAxes = Tuple[Optional[str], ...]

#: logical axis -> mesh axes (tuple) or None (replicated)
Rules = Dict[str, Optional[Tuple[str, ...]]]

DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    # experts ride the *data* axis (EP inside the pod; pods replicate experts
    # so token all-to-alls never cross DCI -- DESIGN.md section 4)
    "experts": ("data",),
    "ssm_heads": ("model",),
    # decode-cache sharding: sequence dim over `model` (context parallelism).
    # head_dim sharding ("cache_dim") made XLA all-gather the full K cache in
    # f32 per layer instead of partial-dotting (§Perf vision-90b iter 5);
    # sequence sharding keeps all cache reads local -- scores are s-sharded,
    # and only the tiny softmax reduction + output psum cross chips.
    "cache_seq": ("model",),
    "cache_dim": ("model",),
    # sequence-parallel residual/norm regions (Megatron-SP): activations
    # between blocks are sharded over `model` on the *sequence* dim, cutting
    # per-chip activation memory by the TP degree.  Falls back to replicated
    # when seq is too short (decode) via spec_for's divisibility check.
    "seq_sp": ("model",),
    "embed": None,
    "seq": None,
    "layers": None,
    "state": None,
    "head_dim": None,
    None: None,
}


def rules_for_mesh(mesh: Mesh, overrides: Optional[Rules] = None) -> Rules:
    """Drop mesh axes that do not exist (e.g. no ``pod`` on single-pod)."""
    import os

    present = set(mesh.axis_names)
    out: Rules = {}
    base = dict(DEFAULT_RULES)
    # §Perf knob: sharding the activations' d_model dim over `data` aligns it
    # with the weights' FSDP dim, so projections become partial-dots + tiny
    # activation all-reduces instead of per-layer weight all-gathers
    # (weight-stationary decode).
    if os.environ.get("REPRO_EMBED_SHARD") == "data":
        base["embed"] = ("data",)
    if overrides:
        base.update(overrides)
    for logical, axes in base.items():
        if axes is None:
            out[logical] = None
        else:
            kept = tuple(a for a in axes if a in present)
            out[logical] = kept or None
    return out


def _axis_size(mesh: Mesh, axes: Optional[Tuple[str, ...]]) -> int:
    if not axes:
        return 1
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for(
    mesh: Mesh,
    rules: Rules,
    logical: LogicalAxes,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Resolve logical axes to a PartitionSpec, dropping non-divisible dims.

    If ``shape`` is given, a dim whose size is not divisible by the resolved
    axis-product falls back to replication (e.g. 25 heads on a 16-way
    ``model`` axis -- hymba/whisper/llama4 attention).  For the ``batch``
    logical axis, a *prefix* of the mesh axes that divides the dim is kept
    (batch 32 on pod x data = 2 x 16 keeps both; batch 1 keeps none).
    """
    parts = []
    for d, name in enumerate(logical):
        axes = rules.get(name) if name is not None else None
        if not axes:
            parts.append(None)
            continue
        if shape is not None:
            dim = shape[d]
            if name == "batch":
                kept = []
                prod = 1
                for a in axes:
                    if dim % (prod * mesh.shape[a]) == 0:
                        kept.append(a)
                        prod *= mesh.shape[a]
                    else:
                        break
                parts.append(tuple(kept) if kept else None)
                continue
            if dim % _axis_size(mesh, axes) != 0:
                parts.append(None)
                continue
        parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def named_sharding(mesh: Mesh, rules: Rules, logical: LogicalAxes, shape=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, rules, logical, shape))


def constrain(x: jax.Array, mesh: Mesh, rules: Rules, logical: LogicalAxes) -> jax.Array:
    """``with_sharding_constraint`` from logical axes (no-op off-mesh)."""
    if mesh.empty or math.prod(mesh.devices.shape) == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(mesh, rules, logical, x.shape)
    )


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declared parameter: shape + logical axes + initializer family."""

    shape: Tuple[int, ...]
    logical: LogicalAxes
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0

    def initialize(self, key: jax.Array, dtype) -> jax.Array:
        if self.init == "zeros":
            return jax.numpy.zeros(self.shape, dtype)
        if self.init == "ones":
            return jax.numpy.ones(self.shape, dtype)
        # fan-in from the first non-stacked dim ("layers" is a batch of
        # independent layer weights, not an input dimension)
        start = 1 if (self.logical and self.logical[0] == "layers") else 0
        dims = self.shape[start:]
        fan_in = dims[0] if len(dims) > 1 else max(dims[0] if dims else 1, 1)
        std = self.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape) * std).astype(dtype)


def init_params(tree, key: jax.Array, dtype) -> dict:
    """Initialize a (nested dict) tree of ParamSpec into arrays."""
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    arrs = [l.initialize(k, dtype) for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def param_shardings(tree, mesh: Mesh, rules: Rules):
    """NamedSharding tree matching a ParamSpec tree."""
    return jax.tree.map(
        lambda ps: named_sharding(mesh, rules, ps.logical, ps.shape),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(int(np.prod(l.shape)) for l in leaves)
