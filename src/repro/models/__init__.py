"""Model zoo: composable transformer/SSM stacks for the assigned archs."""

from repro.models.lm import LMModel
from repro.models.moe import MoELayer
from repro.models.moe_dispatch import (
    DispatchStep,
    ExpertLoadHistogram,
    MoEDispatcher,
    RoutingBucketer,
    RoutingBundle,
    recv_maps,
)
from repro.models.sharding import (
    DEFAULT_RULES,
    ParamSpec,
    constrain,
    init_params,
    named_sharding,
    param_count,
    param_shardings,
    rules_for_mesh,
    spec_for,
)
from repro.models.transformer import Block, Segment

__all__ = [
    "LMModel",
    "MoELayer",
    "DispatchStep",
    "ExpertLoadHistogram",
    "MoEDispatcher",
    "RoutingBucketer",
    "RoutingBundle",
    "recv_maps",
    "DEFAULT_RULES",
    "ParamSpec",
    "constrain",
    "init_params",
    "named_sharding",
    "param_count",
    "param_shardings",
    "rules_for_mesh",
    "spec_for",
    "Block",
    "Segment",
]
