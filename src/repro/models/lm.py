"""LMModel: the unified train/serve interface over all assigned architectures.

Responsibilities: token/frontend embeddings, segment construction per family
(dense / moe / ssm / hybrid / vlm / enc_dec), final norm + LM head, loss,
prefill and single-token decode with a cache pytree, and ParamSpec trees for
sharded initialization.

Modality frontends are STUBS per the assignment: ``[audio]`` / ``[vlm]``
inputs arrive as precomputed frame/patch embeddings (see ``input_specs`` in
:mod:`repro.launch.dryrun`) and pass through a linear adapter.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import (
    ParamSpec,
    constrain,
    init_params,
    param_count as _pc,
    rules_for_mesh,
)
from repro.models.transformer import Block, Segment


@dataclasses.dataclass
class LMModel:
    cfg: ModelConfig
    tp: int = 1  # tensor-parallel size (for head padding); 1 = exact arch

    def __post_init__(self) -> None:
        cfg = self.cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.vocab = cfg.padded_vocab(max(self.tp, 16))
        self.segments: List[Segment] = self._build_segments()
        self.enc_segments: List[Segment] = self._build_encoder()

    # ------------------------------------------------------------------
    def _build_segments(self) -> List[Segment]:
        cfg, tp = self.cfg, self.tp
        segs: List[Segment] = []
        if cfg.family in ("dense",):
            segs.append(Segment("dec", Block.make(cfg, "dense", tp), cfg.n_layers))
        elif cfg.family == "moe":
            fd = cfg.moe.first_dense_layers
            if fd:
                segs.append(Segment("dense0", Block.make(cfg, "dense", tp), fd))
            segs.append(
                Segment("moe", Block.make(cfg, "dense", tp, use_moe=True), cfg.n_layers - fd)
            )
        elif cfg.family == "ssm":
            segs.append(Segment("ssm", Block.make(cfg, "ssm", tp), cfg.n_layers))
        elif cfg.family == "hybrid":
            segs.append(Segment("hyb", Block.make(cfg, "hybrid", tp), cfg.n_layers))
        elif cfg.family == "vlm":
            every = cfg.cross_attn_every
            n_groups = cfg.n_layers // every
            segs.append(Segment("self", Block.make(cfg, "dense", tp), cfg.n_layers - n_groups))
            # cross layers are hoisted into their own scanned segment; the
            # interleaving is approximated as [selfs..., crosses...] per scan
            # friendliness (same op mix and comm pattern; DESIGN.md §5)
            segs.append(Segment("cross", Block.make(cfg, "cross", tp), n_groups))
        elif cfg.family == "enc_dec":
            segs.append(Segment("dec", Block.make(cfg, "decoder", tp), cfg.n_layers))
        else:
            raise ValueError(f"unknown family {cfg.family}")
        return segs

    def _build_encoder(self) -> List[Segment]:
        cfg = self.cfg
        if cfg.family != "enc_dec" or cfg.encoder is None:
            return []
        return [Segment("enc", Block.make(cfg, "encoder", self.tp), cfg.encoder.n_layers)]

    # ------------------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        p: Dict[str, Any] = {
            "embed": ParamSpec((self.vocab, cfg.d_model), ("vocab", "fsdp")),
            "final_norm": L.rmsnorm_params(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = ParamSpec((cfg.d_model, self.vocab), ("fsdp", "vocab"))
        for s in self.segments:
            p[f"seg_{s.name}"] = s.params()
        for s in self.enc_segments:
            p[f"enc_{s.name}"] = s.params()
        if cfg.frontend or cfg.family == "enc_dec":
            p["adapter"] = ParamSpec((cfg.d_model, cfg.d_model), ("fsdp", None))
        return p

    def init(self, rng: jax.Array, dtype=None) -> dict:
        return init_params(self.param_specs(), rng, dtype or jnp.float32)

    def param_count(self) -> int:
        return _pc(self.param_specs())

    # ------------------------------------------------------------------
    def _c(self, x, mesh, logical):
        """Anchor GSPMD propagation at activation boundaries: without these,
        the partitioner may prefer parameter-side shardings (replicated
        batch, d_model split over 'data') through the layer scan."""
        if mesh is None:
            return x
        return constrain(x, mesh, rules_for_mesh(mesh), logical)

    def _embed(self, params, tokens: jnp.ndarray) -> jnp.ndarray:
        return params["embed"].astype(self.dtype)[tokens]

    def _context(self, params, ctx_emb, positions, impl, mesh) -> Optional[jnp.ndarray]:
        """Run frontend adapter (+ encoder for enc_dec) on stub embeddings."""
        if ctx_emb is None:
            return None
        ctx = jnp.einsum(
            "bsm,mn->bsn", ctx_emb.astype(self.dtype), params["adapter"].astype(self.dtype)
        )
        if self.enc_segments:
            epos = jnp.arange(ctx.shape[1])[None, :]
            for s in self.enc_segments:
                ctx = s.apply(params[f"enc_{s.name}"], ctx, epos, impl=impl, mesh=mesh)
        return ctx

    def _head(self, params, x: jnp.ndarray) -> jnp.ndarray:
        x = L.rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        w = (
            params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        ).astype(self.dtype)
        return jnp.einsum("bsm,mv->bsv", x, w)

    # ------------------------------------------------------------------
    def apply(self, params, tokens, ctx_emb=None, impl="dot", mesh=None, remat=True):
        """Full-sequence logits (training / eval)."""
        B, S = tokens.shape
        positions = jnp.arange(S)[None, :]
        x = self._c(self._embed(params, tokens), mesh, ("batch", "seq_sp", "embed"))
        ctx = self._context(params, ctx_emb, positions, impl, mesh)
        for s in self.segments:
            x = s.apply(params[f"seg_{s.name}"], x, positions, impl=impl, ctx=ctx,
                        mesh=mesh, remat=remat)
            x = self._c(x, mesh, ("batch", "seq_sp", "embed"))
        return self._c(self._head(params, x), mesh, ("batch", None, "vocab"))

    def loss(self, params, batch: dict, impl="dot", mesh=None, remat=True):
        """Mean next-token cross-entropy. batch: tokens/labels [B,S] (+ctx)."""
        logits = self.apply(
            params, batch["tokens"], batch.get("ctx"), impl=impl, mesh=mesh, remat=remat
        ).astype(jnp.float32)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return (logz - gold).mean()

    # ------------------------------------------------------------------
    def prefill(self, params, tokens, ctx_emb=None, impl="chunked", mesh=None):
        """Returns (last-position logits, cache pytree)."""
        B, S = tokens.shape
        positions = jnp.arange(S)[None, :]
        x = self._c(self._embed(params, tokens), mesh, ("batch", "seq_sp", "embed"))
        ctx = self._context(params, ctx_emb, positions, impl, mesh)
        caches = {}
        for s in self.segments:
            x, caches[f"seg_{s.name}"] = s.prefill(
                params[f"seg_{s.name}"], x, positions, impl=impl, ctx=ctx, mesh=mesh
            )
            x = self._c(x, mesh, ("batch", "seq_sp", "embed"))
        return self._head(params, x[:, -1:]), caches

    def decode_step(self, params, token, caches, pos, ctx_emb=None, mesh=None):
        """One token for every sequence. token: [B, 1] int32; pos: scalar."""
        positions = jnp.full((token.shape[0], 1), pos, dtype=jnp.int32)
        x = self._c(self._embed(params, token), mesh, ("batch", None, "embed"))
        ctx = None  # cross-attention reads cached K/V from the prefill
        new_caches = {}
        for s in self.segments:
            x, new_caches[f"seg_{s.name}"] = s.decode(
                params[f"seg_{s.name}"], x, positions, caches[f"seg_{s.name}"], pos,
                ctx=ctx, mesh=mesh,
            )
            x = self._c(x, mesh, ("batch", "seq_sp", "embed"))
        return self._head(params, x), new_caches

    def init_cache(self, batch: int, max_len: int, dtype=None):
        dtype = dtype or self.dtype
        ctx_len = self.ctx_len()
        return {
            f"seg_{s.name}": s.init_cache(batch, max_len, dtype, ctx_len)
            for s in self.segments
        }

    def ctx_len(self) -> int:
        cfg = self.cfg
        if cfg.family == "enc_dec" and cfg.encoder:
            return cfg.encoder.context
        return cfg.cross_context or 0
