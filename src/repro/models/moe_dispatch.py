"""Node-aware MoE token dispatch: routing histograms -> exchange patterns.

The router's per-batch (src shard -> dst shard, token count) assignment *is*
the paper's irregular point-to-point pattern, regenerated every step.  This
module is the bridge between that dynamic traffic and the static exchange
planner:

* :func:`repro.comm.block_pattern` turns a per-pair width matrix into the
  element-level :class:`~repro.comm.ExchangePattern` of a ragged tiled
  all-to-all (capacity-based dispatch makes the communication *shape* a pure
  function of the counts, independent of token values);
* :class:`RoutingBucketer` quantizes measured counts to capacity-slot
  granularity and keeps a high-water width matrix, so fluctuating-but-
  stationary load skew maps onto ONE pattern object -- its memoized
  ``fingerprint()`` keys the plan / executor caches, and growth beyond the
  high-water mark is an *incremental* re-plan (widen to the union) instead
  of a cold plan per batch;
* :func:`recv_maps` precomputes, on the host, the per-rank gather that
  splices the exchange's canonical receive layout back into the dense
  ``[nranks * cap]`` slot layout the capacity dispatch math expects --
  making the exchange-backed path bitwise identical to the flat
  ``jax.lax.all_to_all`` baseline;
* :class:`ExpertLoadHistogram` accumulates the measured count matrices and
  feeds them to :func:`repro.core.advise_routing` (the paper's model-driven
  strategy selection, driven by real traffic instead of assumed-uniform);
* :class:`MoEDispatcher` ties it together for ``MoELayer``: per-step it
  buckets the counts, resolves the strategy (fixed or ``"auto"`` via the
  advisor), and hands back memoized :class:`~repro.comm.IrregularExchange`
  instances for the dispatch and return hops.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.comm import (
    ExchangePattern,
    IrregularExchange,
    PodTopology,
    STRATEGY_NAMES,
    block_pattern,
    exchange_for,
    quantize_widths,
)
from repro.core import EXECUTABLE_STRATEGY, advise_routing


def recv_maps(
    topo: PodTopology, block: int, widths: np.ndarray
) -> Tuple[np.ndarray, int]:
    """Per-rank splice maps from canonical exchange recv to slot layout.

    For the :func:`~repro.comm.block_pattern` with width matrix ``widths``
    (``widths[s, d]`` = slots ``s`` ships to ``d``), rank ``r``'s exchange
    output is the src-major concatenation of the shipped prefixes, padded to
    the pattern-wide halo width ``H``.  The dispatch math instead wants the
    dense tiled-all-to-all layout ``recv[s * block + j] =`` slot ``j`` of
    ``s``'s block for ``r``.  Returns ``(maps, H)`` where ``maps[r]`` is an
    ``[nranks * block]`` int32 gather into the concatenation
    ``[own send buffer | halo | one sentinel row]``:

    * own block (``s == r``): index ``s * block + j`` into the send buffer
      (the all-to-all diagonal never leaves the device);
    * shipped slots (``j < widths[s, r]``): ``nranks * block + offset`` into
      the halo;
    * unshipped slots: ``nranks * block + H`` -- the sentinel row, which the
      caller fills with the same dead-slot value (zero row / sentinel expert
      id) the baseline's send buffer carries there, keeping the two paths
      bitwise identical.
    """
    n = topo.nranks
    w = np.asarray(widths, dtype=np.int64)
    if w.shape != (n, n):
        raise ValueError(f"widths must be [{n}, {n}], got {w.shape}")
    if (w < 0).any() or (w > block).any():
        raise ValueError(f"widths must lie in [0, {block}]")
    recv_sizes = w.sum(axis=0) - np.diag(w)
    H = int(recv_sizes.max(initial=0))
    maps = np.full((n, n * block), n * block + H, dtype=np.int32)
    for r in range(n):
        off = 0
        for s in range(n):
            base = s * block
            if s == r:
                maps[r, base : base + block] = np.arange(
                    base, base + block, dtype=np.int32
                )
                continue
            k = int(w[s, r])
            maps[r, base : base + k] = n * block + off + np.arange(k, dtype=np.int32)
            off += k
    return maps, H


@dataclasses.dataclass(frozen=True)
class RoutingBundle:
    """One bucketed routing pattern: both hops plus their splice maps."""

    widths: np.ndarray  # [n, n] high-water slot widths (diagonal zeroed)
    pattern_dispatch: ExchangePattern
    pattern_return: ExchangePattern
    map_dispatch: np.ndarray  # [n, n*block] int32 (see recv_maps)
    map_return: np.ndarray
    halo_dispatch: int
    halo_return: int


class RoutingBucketer:
    """High-water width bucketing for per-batch routing counts.

    ``step(counts)`` quantizes the measured per-pair counts to ``quantum``
    slots and compares against the running high-water width matrix.  Counts
    at or under the mark reuse the cached :class:`RoutingBundle` -- the SAME
    pattern objects, so their memoized fingerprints hit the module-level
    plan / executor / exchange caches.  Growth widens the mark to the union
    and rebuilds once (the incremental re-plan).  Shrinkage never re-plans:
    a superset pattern is always safe because unshipped-but-planned slots
    carry the dead-slot sentinel values, which the splice maps reproduce.
    """

    def __init__(self, topo: PodTopology, block: int, quantum: int = 8) -> None:
        if block < 1:
            raise ValueError(f"block must be >= 1, got {block}")
        self.topo = topo
        self.block = block
        self.quantum = quantum
        self.high_water = np.zeros((topo.nranks, topo.nranks), dtype=np.int64)
        self.bundle: Optional[RoutingBundle] = None
        self.steps = 0
        self.replans = 0

    @property
    def hit_rate(self) -> float:
        """Fraction of steps served by the cached bundle."""
        return 1.0 - self.replans / self.steps if self.steps else 0.0

    def step(self, counts: np.ndarray) -> Tuple[RoutingBundle, bool]:
        """Bucket one batch's counts; returns ``(bundle, replanned)``."""
        self.steps += 1
        q = quantize_widths(counts, self.quantum, self.block)
        np.fill_diagonal(q, 0)  # own block never leaves the device
        if self.bundle is not None and (q <= self.high_water).all():
            return self.bundle, False
        self.high_water = np.maximum(self.high_water, q)
        w = self.high_water.copy()
        map_d, halo_d = recv_maps(self.topo, self.block, w)
        map_r, halo_r = recv_maps(self.topo, self.block, w.T)
        self.bundle = RoutingBundle(
            widths=w,
            pattern_dispatch=block_pattern(self.topo, self.block, w),
            pattern_return=block_pattern(self.topo, self.block, w.T),
            map_dispatch=map_d,
            map_return=map_r,
            halo_dispatch=halo_d,
            halo_return=halo_r,
        )
        self.replans += 1
        return self.bundle, True


class ExpertLoadHistogram:
    """EMA of measured per-pair routed-token counts (the advisor's input).

    The paper's performance models are only as good as the traffic estimate
    they are fed; *Improving Performance Models for Irregular Point-to-Point
    Communication* motivates measuring it.  ``update`` folds one batch's
    ``[nranks, nranks]`` count matrix into an exponential moving average;
    ``advise`` ranks strategies for the smoothed histogram.
    """

    def __init__(self, nranks: int, decay: float = 0.9) -> None:
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        self.nranks = nranks
        self.decay = decay
        self.counts = np.zeros((nranks, nranks), dtype=np.float64)
        self.updates = 0

    def update(self, counts: np.ndarray) -> None:
        c = np.asarray(counts, dtype=np.float64)
        if c.shape != (self.nranks, self.nranks):
            raise ValueError(
                f"counts must be [{self.nranks}, {self.nranks}], got {c.shape}"
            )
        if self.updates == 0:
            self.counts = c.copy()
        else:
            self.counts = self.decay * self.counts + (1.0 - self.decay) * c
        self.updates += 1

    def advise(
        self,
        ppn: int,
        payload_width: int = 1,
        machine: str = "tpu_v5e_pod",
        wire=None,
    ):
        """Rank strategies for the smoothed histogram (see ``advise_routing``)."""
        counts = np.rint(self.counts).astype(np.int64)
        return advise_routing(
            counts, ppn=ppn, payload_width=payload_width, machine=machine, wire=wire
        )


@dataclasses.dataclass(frozen=True)
class DispatchStep:
    """Everything one MoE batch needs to run its two exchange hops.

    ``exchange_dispatch`` / ``exchange_return`` are ``None`` when the hop's
    pattern has no cross-device needs (e.g. every token routed to its own
    shard): the splice maps then read only the local send buffer and the
    sentinel row, and no collective runs at all.
    """

    bundle: RoutingBundle
    strategy: str
    exchange_dispatch: Optional[IrregularExchange]
    exchange_return: Optional[IrregularExchange]


class MoEDispatcher:
    """Per-layer routing-aware exchange front-end for ``MoELayer``.

    Holds one :class:`RoutingBucketer` per capacity (decode and prefill
    batches bucket separately), the :class:`ExpertLoadHistogram`, and the
    strategy / wire configuration.  ``step(counts, block)`` is the per-batch
    entry point; everything it returns is memoized so a stationary routing
    distribution costs one quantize + one dict hit per batch.

    ``strategy="auto"`` re-runs the advisor on the bucketed width matrix
    whenever the bucketer re-plans (traffic changed enough to matter) and
    keeps the previous choice otherwise.
    """

    def __init__(
        self,
        topo: PodTopology,
        strategy: str = "auto",
        wire: str = "none",
        quantum: int = 8,
        mesh=None,
        message_cap_bytes: int = 16384,
        machine: str = "tpu_v5e_pod",
        decay: float = 0.9,
    ) -> None:
        if strategy != "auto" and strategy not in STRATEGY_NAMES:
            raise ValueError(
                f"strategy must be 'auto' or one of {STRATEGY_NAMES}, got {strategy!r}"
            )
        self.topo = topo
        self.strategy = strategy
        self.wire = wire
        self.quantum = quantum
        self.mesh = mesh
        self.message_cap_bytes = message_cap_bytes
        self.machine = machine
        self.histogram = ExpertLoadHistogram(topo.nranks, decay=decay)
        self._bucketers: Dict[int, RoutingBucketer] = {}
        self._strategies: Dict[int, str] = {}

    def bucketer(self, block: int) -> RoutingBucketer:
        if block not in self._bucketers:
            self._bucketers[block] = RoutingBucketer(
                self.topo, block, quantum=min(self.quantum, block)
            )
        return self._bucketers[block]

    def _resolve_strategy(self, widths: np.ndarray, payload_width: int) -> str:
        if self.strategy != "auto":
            return self.strategy
        adv = advise_routing(
            widths,
            ppn=self.topo.ppn,
            payload_width=payload_width,
            machine=self.machine,
        )
        return EXECUTABLE_STRATEGY[adv.best.strategy]

    def _exchange(self, pattern: ExchangePattern, strategy: str):
        if not pattern.needs:
            return None
        return exchange_for(
            pattern,
            strategy,
            mesh=self.mesh,
            message_cap_bytes=self.message_cap_bytes,
            wire=self.wire,
        )

    def step(
        self, counts: np.ndarray, block: int, payload_width: int = 1
    ) -> DispatchStep:
        """Bucket one batch's measured counts and return its exchanges.

        Exchange instances come from :func:`repro.comm.exchange_for` every
        step, so the module-level cache counters (``exchange_hits`` /
        ``exchange_misses`` in :func:`repro.comm.cache_stats`) directly
        measure the bucketing's plan-cache effectiveness: a reused bundle's
        memoized fingerprints make both lookups O(1) dict hits.  The
        advisor (``strategy="auto"``) only re-runs when the bucketer
        re-planned -- i.e. when the traffic actually changed.
        """
        counts = np.asarray(counts)
        self.histogram.update(counts)
        bundle, replanned = self.bucketer(block).step(counts)
        strategy = self._strategies.get(block)
        if replanned or strategy is None:
            strategy = self._resolve_strategy(bundle.widths, payload_width)
            self._strategies[block] = strategy
        return DispatchStep(
            bundle=bundle,
            strategy=strategy,
            exchange_dispatch=self._exchange(bundle.pattern_dispatch, strategy),
            exchange_return=self._exchange(bundle.pattern_return, strategy),
        )
