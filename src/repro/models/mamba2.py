"""Mamba-2 SSD (state-space duality) mixer.

Train/prefill uses the chunked SSD algorithm: quadratic attention-like math
*within* chunks of length Q, a linear recurrence *across* chunks -- O(S*Q)
instead of O(S^2), and the intra-chunk part is a dense matmul (MXU-friendly;
the Pallas kernel in :mod:`repro.kernels.ssd_scan` implements that hot loop).
Decode keeps a constant-size state ``[B, H, N, P]`` -- why the SSM archs run
the 500k-token shape.

Simplifications vs. the reference implementation (documented in DESIGN.md):
single B/C group, depthwise conv applied to x only, no learned D skip scaling
beyond a per-head scalar.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import rmsnorm, rmsnorm_params
from repro.models.sharding import ParamSpec


@dataclasses.dataclass(frozen=True)
class Mamba2Mixer:
    d_model: int
    cfg: SSMConfig

    @property
    def d_inner(self) -> int:
        return self.cfg.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.cfg.head_dim

    def params(self) -> dict:
        M, H, P, N = self.d_model, self.n_heads, self.cfg.head_dim, self.cfg.state_dim
        return {
            "w_x": ParamSpec((M, H, P), ("fsdp", "ssm_heads", None)),
            "w_z": ParamSpec((M, H, P), ("fsdp", "ssm_heads", None)),
            "w_b": ParamSpec((M, N), ("fsdp", None)),
            "w_c": ParamSpec((M, N), ("fsdp", None)),
            "w_dt": ParamSpec((M, H), ("fsdp", "ssm_heads")),
            "dt_bias": ParamSpec((H,), ("ssm_heads",), init="zeros"),
            "a_log": ParamSpec((H,), ("ssm_heads",), init="ones"),
            "d_skip": ParamSpec((H,), ("ssm_heads",), init="ones"),
            "conv_w": ParamSpec(
                (self.cfg.conv_width, H, P), (None, "ssm_heads", None), scale=0.5
            ),
            "norm": rmsnorm_params(H * P),
            "w_out": ParamSpec((H, P, M), ("ssm_heads", None, "fsdp")),
        }

    # ------------------------------------------------------------------
    def _project(self, params, x):
        """x [B,S,M] -> (xh [B,S,H,P], z, b [B,S,N], c [B,S,N], dt [B,S,H])."""
        xh = jnp.einsum("bsm,mhp->bshp", x, params["w_x"].astype(x.dtype))
        z = jnp.einsum("bsm,mhp->bshp", x, params["w_z"].astype(x.dtype))
        b = jnp.einsum("bsm,mn->bsn", x, params["w_b"].astype(x.dtype))
        c = jnp.einsum("bsm,mn->bsn", x, params["w_c"].astype(x.dtype))
        dt = jax.nn.softplus(
            jnp.einsum("bsm,mh->bsh", x, params["w_dt"].astype(x.dtype)).astype(jnp.float32)
            + params["dt_bias"].astype(jnp.float32)
        )
        return xh, z, b, c, dt

    def _conv(self, params, xh, conv_state=None):
        """Depthwise causal conv over sequence. xh: [B,S,H,P]."""
        W = self.cfg.conv_width
        if conv_state is None:
            pad = jnp.zeros((xh.shape[0], W - 1, *xh.shape[2:]), xh.dtype)
        else:
            pad = conv_state
        xp = jnp.concatenate([pad, xh], axis=1)
        out = jnp.zeros_like(xh)
        for i in range(W):
            out = out + xp[:, i : i + xh.shape[1]] * params["conv_w"][i].astype(xh.dtype)
        new_state = xp[:, -(W - 1) :] if W > 1 else pad
        return jax.nn.silu(out), new_state

    def _gate_out(self, params, y, z):
        B, S, H, P = y.shape
        y = y * jax.nn.silu(z)
        y = rmsnorm(params["norm"], y.reshape(B, S, H * P)).reshape(B, S, H, P)
        return jnp.einsum("bshp,hpm->bsm", y, params["w_out"].astype(y.dtype))

    # ------------------------------------------------------------------
    def __call__(self, params, x, impl: str = "chunked"):
        """Full-sequence forward (train/prefill)."""
        xh, z, b, c, dt = self._project(params, x)
        xh, _ = self._conv(params, xh)
        a = -jnp.exp(params["a_log"].astype(jnp.float32))  # [H], negative
        loga = a[None, None, :] * dt  # [B,S,H]  log decay
        xdt = xh.astype(jnp.float32) * dt[..., None]
        if impl == "pallas":
            from repro.kernels.ops import ssd_chunked as ssd_fn
        else:
            from repro.models.ssd import ssd_chunked as ssd_fn
        y = ssd_fn(xdt, loga, b.astype(jnp.float32), c.astype(jnp.float32), self.cfg.chunk)
        y = y + xh.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[None, None, :, None]
        return self._gate_out(params, y.astype(x.dtype), z)

    # ------------------------------------------------------------------
    def decode(self, params, x, cache) -> Tuple[jnp.ndarray, dict]:
        """Single-token step. cache: {ssm [B,H,N,P] f32, conv [B,W-1,H,P]}."""
        xh, z, b, c, dt = self._project(params, x)  # S == 1
        xh, conv_state = self._conv(params, xh, cache["conv"])
        a = -jnp.exp(params["a_log"].astype(jnp.float32))
        decay = jnp.exp(a[None, :] * dt[:, 0])  # [B,H]
        xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]  # [B,H,P]
        h = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", b[:, 0].astype(jnp.float32), xdt
        )
        y = jnp.einsum("bn,bhnp->bhp", c[:, 0].astype(jnp.float32), h)
        y = y + xh[:, 0].astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[None, :, None]
        out = self._gate_out(params, y[:, None].astype(x.dtype), z)
        return out, {"ssm": h, "conv": conv_state}

    def init_cache(self, batch: int, dtype) -> dict:
        H, P, N, W = self.n_heads, self.cfg.head_dim, self.cfg.state_dim, self.cfg.conv_width
        return {
            "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((batch, max(W - 1, 1), H, P), dtype),
        }
