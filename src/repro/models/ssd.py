"""Chunked SSD algorithm (Mamba-2) in pure XLA ops.

Sequential-scan over chunks keeps the quadratic intra-chunk tensors bounded
to one chunk at a time (O(B*Q^2*H) live memory), while the cross-chunk state
``h [B,H,N,P]`` carries the recurrence.  This is the CPU-runnable twin of the
Pallas kernel in :mod:`repro.kernels.ssd_scan` and the implementation the
dry-run shapes compile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked(
    xdt: jnp.ndarray,  # [B, S, H, P] dt-scaled inputs (float32)
    loga: jnp.ndarray,  # [B, S, H]   log decay per step (<= 0)
    b: jnp.ndarray,  # [B, S, N]
    c: jnp.ndarray,  # [B, S, N]
    chunk: int = 128,
) -> jnp.ndarray:
    """Returns y [B, S, H, P] with h_t = exp(loga_t) h_{t-1} + b_t (x)xdt_t,
    y_t = c_t . h_t  (all per head)."""
    B, S, H, P = xdt.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // Q

    # chunk-major for scan: [nc, B, Q, ...]
    xc = xdt.reshape(B, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    lc = loga.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
    bc = b.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)
    cc = c.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)

    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))

    def step(h, inp):
        xq, lq, bq, cq = inp  # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        la = jnp.cumsum(lq, axis=1)  # inclusive log-decay prefix [B,Q,H]
        # intra-chunk (attention-like, masked).  The mask is applied to the
        # *exponent*: masked (j > i) entries have positive log-decay sums that
        # overflow exp, and inf * 0 poisons the backward pass.
        scores = jnp.einsum("bin,bjn->bij", cq, bq)
        diff = la[:, :, None, :] - la[:, None, :, :]  # [B,Q,Q,H]
        diff = jnp.where(mask[None, :, :, None], diff, -jnp.inf)
        decay = jnp.exp(diff)
        y = jnp.einsum("bij,bijh,bjhp->bihp", scores, decay, xq)
        # inter-chunk: state entering the chunk, decayed through position i
        y = y + jnp.einsum("bin,bhnp->bihp", cq, h) * jnp.exp(la)[..., None]
        # state at chunk end
        la_end = la[:, -1]  # [B,H]
        w = jnp.exp(la_end[:, None, :] - la)  # [B,Q,H] decay from j to end
        s_end = jnp.einsum("bjn,bjh,bjhp->bhnp", bq, w, xq)
        h = h * jnp.exp(la_end)[:, :, None, None] + s_end
        return h, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xc, lc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * Q, H, P)
    return y[:, :S]
