"""Blocked-ELL SpMV Pallas kernel for TPU.

Computes ``w[i] = sum_k data[i, k] * x[cols[i, k]]`` for an ELL-padded sparse
block (the local on-rank / off-rank SpMV of the paper's distributed SpMV,
§2.4).

TPU adaptation (vs. a CUDA CSR kernel):

* CSR's per-row variable nnz maps badly onto the VPU's (8, 128) vregs; we use
  ELL padding so every row tile is a dense ``[TILE_R, K]`` rectangle -- the
  padding slots carry ``data == 0`` so they contribute nothing.
* The row dimension is tiled with a ``BlockSpec`` grid so each step's working
  set (``TILE_R x K`` data/cols plus the gathered values) sits in VMEM.
* The source vector ``x`` is kept whole in VMEM (halo buffers in this system
  are << VMEM; a multi-megarow vector would need a two-phase
  gather-then-reduce kernel instead).
* The inner gather uses ``jnp.take`` which lowers to Mosaic's dynamic-gather;
  K is padded to a multiple of 128 so the multiply-accumulate is lane-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256  # rows per grid step
LANE = 128  # TPU lane width


def _spmv_ell_kernel(data_ref, cols_ref, x_ref, out_ref):
    data = data_ref[...]  # [TILE_R, K]
    cols = cols_ref[...]  # [TILE_R, K]
    x = x_ref[...]  # [N]
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
    out_ref[...] = (data * gathered).sum(axis=1)


def _pad_to(a: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-a.shape[axis]) % mult
    if not pad:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_ell(
    data: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """``w = A @ x`` for an ELL block. data/cols: [R, K]; x: [N] -> w: [R]."""
    R, K = data.shape
    data_p = _pad_to(_pad_to(data, LANE, 1), TILE_R, 0)
    cols_p = _pad_to(_pad_to(cols, LANE, 1), TILE_R, 0)
    x_p = _pad_to(x, LANE, 0)
    Rp, Kp = data_p.shape
    grid = (Rp // TILE_R,)
    out = pl.pallas_call(
        _spmv_ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_R, Kp), lambda i: (i, 0)),
            pl.BlockSpec((TILE_R, Kp), lambda i: (i, 0)),
            pl.BlockSpec((x_p.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_R,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Rp,), data.dtype),
        interpret=interpret,
    )(data_p, cols_p, x_p)
    return out[:R]
