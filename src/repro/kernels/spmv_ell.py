"""Blocked-ELL SpMV / SpMM Pallas kernels for TPU.

:func:`spmv_ell` computes ``w[i] = sum_k data[i, k] * x[cols[i, k]]`` for an
ELL-padded sparse block (the local on-rank / off-rank SpMV of the paper's
distributed SpMV, §2.4); :func:`spmm_ell` is its multi-vector generalization
``W[i, c] = sum_k data[i, k] * X[cols[i, k], c]`` for a ``[N, C]`` right-hand
side (the fused local compute paired with the batched ``[nranks, L, k]``
halo exchange).

TPU adaptation (vs. a CUDA CSR kernel):

* CSR's per-row variable nnz maps badly onto the VPU's (8, 128) vregs; we use
  ELL padding so every row tile is a dense ``[TILE_R, K]`` rectangle -- the
  padding slots carry ``data == 0`` so they contribute nothing.
* The row dimension is tiled with a ``BlockSpec`` grid so each step's working
  set (``TILE_R x K`` data/cols plus the gathered values) sits in VMEM.
* The source vector ``x`` is kept whole in VMEM (halo buffers in this system
  are << VMEM; a multi-megarow vector would need a two-phase
  gather-then-reduce kernel instead).
* The inner gather uses ``jnp.take`` which lowers to Mosaic's dynamic-gather;
  K is padded to a multiple of 128 so the multiply-accumulate is lane-aligned.

SpMM column-tiling design (why a second grid axis instead of a wider SpMV):

* The grid is ``row tiles x column tiles`` of the rhs: step ``(i, c)``
  gathers ``X[cols, c-tile]`` and contracts ``[TILE_R, K] @ gather`` into one
  ``[TILE_R, TILE_C]`` output tile.  ``TILE_C = 128`` makes every rhs tile
  exactly one lane tile wide, so each gathered row of ``X`` is a full vreg
  row and the broadcast-multiply-reduce stays lane-aligned for any ``k``.
* ``TILE_R`` shrinks from 256 (SpMV) to 64: the gathered operand is now
  ``[TILE_R, K, TILE_C]`` rather than ``[TILE_R, K]``, and the VMEM budget
  that held one row-tile's vector gather must hold a full lane tile per
  matrix slot (64 x 128 x 128 x 4B = 4 MiB at K = 128).
* Column tiles are *independent grid steps*, not an inner loop: the same
  ``data``/``cols`` row tile is re-streamed once per column tile instead of
  keeping a ``[TILE_R, k]`` accumulator live across the sweep.  That bounds
  VMEM independently of ``k`` (k = 64 costs the ELL block being re-read
  ``ceil(k/128)`` times, i.e. once) and keeps the k = 1 path numerically
  identical to :func:`spmv_ell`: same ``K`` padding, same reduction order,
  one degenerate column tile.

Row-tile masking (the split-phase/overlap hook):

* Both kernels accept an optional ``tile_mask`` -- one int per row tile.
  Inactive tiles (mask 0) are *skipped* via ``pl.when`` (zero-filled output,
  no gather, no multiply-accumulate), so both passes of the overlapped
  distributed SpMV reuse ONE kernel: the diag pass runs every row tile while
  the inter-node exchange is in flight, and the off pass afterwards runs
  only the boundary tiles (interior tiles' off-block rows are pure padding).
  An active tile's compute is instruction-identical to the unmasked kernel,
  which is what makes the overlapped path bit-compatible with the barrier
  path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256  # rows per SpMV grid step
TILE_R_MM = 64  # rows per SpMM grid step (gather working set is TILE_C x wider)
TILE_C = 128  # rhs columns per SpMM grid step = one lane tile
LANE = 128  # TPU lane width


def _spmv_ell_kernel(data_ref, cols_ref, x_ref, out_ref):
    data = data_ref[...]  # [TILE_R, K]
    cols = cols_ref[...]  # [TILE_R, K]
    x = x_ref[...]  # [N]
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
    out_ref[...] = (data * gathered).sum(axis=1)


def _spmv_ell_masked_kernel(mask_ref, data_ref, cols_ref, x_ref, out_ref):
    @pl.when(mask_ref[0] != 0)
    def _active():
        _spmv_ell_kernel(data_ref, cols_ref, x_ref, out_ref)

    @pl.when(mask_ref[0] == 0)
    def _inactive():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)


def _spmm_ell_kernel(data_ref, cols_ref, x_ref, out_ref):
    data = data_ref[...]  # [TILE_R_MM, K]
    cols = cols_ref[...]  # [TILE_R_MM, K]
    x = x_ref[...]  # [N, TILE_C]
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(
        cols.shape + (x.shape[-1],)
    )  # [TILE_R_MM, K, TILE_C]
    out_ref[...] = (data[..., None] * gathered).sum(axis=1)


def _spmm_ell_masked_kernel(mask_ref, data_ref, cols_ref, x_ref, out_ref):
    @pl.when(mask_ref[0] != 0)
    def _active():
        _spmm_ell_kernel(data_ref, cols_ref, x_ref, out_ref)

    @pl.when(mask_ref[0] == 0)
    def _inactive():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)


def _pad_to(a: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-a.shape[axis]) % mult
    if not pad:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def num_row_tiles(rows: int, tile_rows: int) -> int:
    """Grid length (= ``tile_mask`` length) for ``rows`` ELL rows."""
    return -(-rows // tile_rows)


def _check_mask(tile_mask: jnp.ndarray, ntiles: int) -> jnp.ndarray:
    if tile_mask.shape != (ntiles,):
        raise ValueError(
            f"tile_mask must have shape ({ntiles},) for this row count, "
            f"got {tuple(tile_mask.shape)}"
        )
    return tile_mask.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_ell(
    data: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    interpret: bool = True,
    tile_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """``w = A @ x`` for an ELL block. data/cols: [R, K]; x: [N] -> w: [R].

    ``tile_mask`` (optional ``[num_row_tiles(R, TILE_R)]`` ints) selects
    which row tiles compute; inactive tiles are skipped and deliver zeros.
    """
    R, K = data.shape
    data_p = _pad_to(_pad_to(data, LANE, 1), TILE_R, 0)
    cols_p = _pad_to(_pad_to(cols, LANE, 1), TILE_R, 0)
    x_p = _pad_to(x, LANE, 0)
    Rp, Kp = data_p.shape
    grid = (num_row_tiles(R, TILE_R),)
    in_specs = [
        pl.BlockSpec((TILE_R, Kp), lambda i: (i, 0)),
        pl.BlockSpec((TILE_R, Kp), lambda i: (i, 0)),
        pl.BlockSpec((x_p.shape[0],), lambda i: (0,)),
    ]
    if tile_mask is None:
        kernel, args = _spmv_ell_kernel, (data_p, cols_p, x_p)
    else:
        mask = _check_mask(tile_mask, grid[0])
        kernel = _spmv_ell_masked_kernel
        in_specs = [pl.BlockSpec((1,), lambda i: (i,))] + in_specs
        args = (mask, data_p, cols_p, x_p)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((TILE_R,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Rp,), data.dtype),
        interpret=interpret,
    )(*args)
    return out[:R]


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmm_ell(
    data: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    interpret: bool = True,
    tile_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """``W = A @ X`` for an ELL block. data/cols: [R, K]; x: [N, C] -> [R, C].

    ``tile_mask`` (optional ``[num_row_tiles(R, TILE_R_MM)]`` ints) selects
    which row tiles compute; inactive tiles are skipped and deliver zeros.
    """
    R, K = data.shape
    N, C = x.shape
    data_p = _pad_to(_pad_to(data, LANE, 1), TILE_R_MM, 0)
    cols_p = _pad_to(_pad_to(cols, LANE, 1), TILE_R_MM, 0)
    x_p = _pad_to(_pad_to(x, TILE_C, 1), 8, 0)
    Rp, Kp = data_p.shape
    Np, Cp = x_p.shape
    grid = (num_row_tiles(R, TILE_R_MM), Cp // TILE_C)
    in_specs = [
        pl.BlockSpec((TILE_R_MM, Kp), lambda i, c: (i, 0)),
        pl.BlockSpec((TILE_R_MM, Kp), lambda i, c: (i, 0)),
        pl.BlockSpec((Np, TILE_C), lambda i, c: (0, c)),
    ]
    if tile_mask is None:
        kernel, args = _spmm_ell_kernel, (data_p, cols_p, x_p)
    else:
        mask = _check_mask(tile_mask, grid[0])
        kernel = _spmm_ell_masked_kernel
        in_specs = [pl.BlockSpec((1,), lambda i, c: (i,))] + in_specs
        args = (mask, data_p, cols_p, x_p)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((TILE_R_MM, TILE_C), lambda i, c: (i, c)),
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), data.dtype),
        interpret=interpret,
    )(*args)
    return out[:R, :C]
