"""Mamba-2 chunked SSD Pallas kernel for TPU.

The SSD recurrence ``h_t = a_t h_{t-1} + b_t (x) x_t``, ``y_t = c_t . h_t``
is blocked into chunks of length Q: within a chunk the output is a masked,
decay-weighted ``[Q, Q]`` matmul (MXU work); across chunks a state of shape
``[N, P]`` per (batch, head) is carried in VMEM scratch through the
sequential innermost grid dimension -- the same scratch-carry pattern as the
flash kernel, which is how TPU expresses the paper-style "linear scan with
quadratic tiles" decomposition of SSD.

Grid: (B, H, n_chunks); chunk tensors (x [Q, P], b/c [Q, N], loga [Q]) are
VMEM tiles; Q/N/P sized in multiples of the 128 lane width where possible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, loga_ref, b_ref, c_ref, y_ref, h_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # [Q, P]
    la = jnp.cumsum(loga_ref[0, 0].astype(jnp.float32), axis=0)  # [Q]
    b = b_ref[0].astype(jnp.float32)  # [Q, N]
    c = c_ref[0].astype(jnp.float32)  # [Q, N]
    h = h_ref[...]  # [N, P]

    # intra-chunk: masked decay-weighted attention-like matmul
    scores = c @ b.T  # [Q, Q]
    diff = la[:, None] - la[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (chunk, chunk), 1
    )
    decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    y = (scores * decay) @ x  # [Q, P]
    # inter-chunk: incoming state decayed through each position
    y = y + jnp.exp(la)[:, None] * (c @ h)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update to chunk end
    w = jnp.exp(la[-1] - la)  # [Q]
    h_ref[...] = h * jnp.exp(la[-1]) + (b * w[:, None]).T @ x


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_scan_kernel(
    xdt: jnp.ndarray,  # [B, S, H, P] float32 (dt-scaled inputs)
    loga: jnp.ndarray,  # [B, S, H]
    b: jnp.ndarray,  # [B, S, N]
    c: jnp.ndarray,  # [B, S, N]
    chunk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, H, P = xdt.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    # head-major layouts: x [B, H, S, P]; loga [B, H, S]; b/c [B, S, N]
    xh = xdt.transpose(0, 2, 1, 3)
    lh = loga.transpose(0, 2, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=Q),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, Q), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1, Q, N), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, Q, N), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda ib, ih, ic: (ib, ih, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, P), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xh, lh, b, c)
    return out.transpose(0, 2, 1, 3)[:, :S]
