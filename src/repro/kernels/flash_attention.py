"""Flash attention (forward) Pallas kernel for TPU.

Online-softmax over K/V blocks with the accumulator, running max and running
denominator held in VMEM scratch across the (sequential, innermost) K-block
grid dimension -- the canonical TPU flash pattern:

* grid = (batch*heads, n_q_blocks, n_k_blocks); TPU iterates the minor grid
  dim sequentially, so scratch carries the online-softmax state.
* BlockSpecs tile Q/K/V into ``[BLOCK_Q, D]`` / ``[BLOCK_K, D]`` VMEM tiles;
  D and the block sizes are multiples of 128 so the QK^T and PV matmuls map
  onto the MXU.
* causal / sliding-window masking is applied per (q-block, k-block) tile from
  absolute positions (mask-only: TPU grids cannot skip iterations; the HLO
  cost of masked tiles is noted in DESIGN.md).
* GQA is handled in the BlockSpec index map: the KV block index derives from
  the query head id, so KV tiles are never materially repeated.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, seq_k: int, offset: int):
    iq, ik = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # [BQ, D]
    k = k_ref[0].astype(jnp.float32)  # [BK, D]
    v = v_ref[0].astype(jnp.float32)  # [BK, D]
    logits = q @ k.T * scale  # [BQ, BK]

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + offset
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jnp.ndarray,  # [B, Sq, H, D]
    k: jnp.ndarray,  # [B, Sk, KV, D]
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, max(Sq, 16))
    block_k = min(block_k, max(Sk, 16))

    def pad_seq(x, blk):
        p = (-x.shape[1]) % blk
        return jnp.pad(x, ((0, 0), (0, p), (0, 0), (0, 0))) if p else x

    qp = pad_seq(q, block_q)
    kp = pad_seq(k, block_k)
    vp = pad_seq(v, block_k)
    Sqp, Skp = qp.shape[1], kp.shape[1]
    # head-major [B*H, S, D] layout
    qh = qp.transpose(0, 2, 1, 3).reshape(B * H, Sqp, D)
    kh = kp.transpose(0, 2, 1, 3).reshape(B * KV, Skp, D)
    vh = vp.transpose(0, 2, 1, 3).reshape(B * KV, Skp, D)

    def kv_index(bh, iq, ik):
        # query head bh = b*H + h  ->  kv row b*KV + h // rep
        return (bh // H) * KV + (bh % H) // rep, ik, 0

    grid = (B * H, Sqp // block_q, Skp // block_k)
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, seq_k=Sk, offset=Sk - Sq,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),  # acc
            pltpu.VMEM((block_q,), jnp.float32),  # running max
            pltpu.VMEM((block_q,), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out.reshape(B, H, Sqp, D).transpose(0, 2, 1, 3)
    return out[:, :Sq]
