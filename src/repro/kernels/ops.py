"""jit'd public wrappers for the Pallas kernels.

Selects interpret mode automatically on non-TPU backends so the same call
sites run in this CPU container (correctness) and on real TPUs (performance).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.spmv_ell import spmv_ell as _spmv_ell
from repro.kernels.ssd_scan import ssd_scan_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def spmv_ell(data, cols, x):
    """Blocked-ELL SpMV: ``w[i] = sum_k data[i,k] * x[cols[i,k]]``."""
    return _spmv_ell(data, cols, x, interpret=_interpret())


def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None):
    """Blocked online-softmax attention; q [B,Sq,H,D], k/v [B,Sk,KV,D]."""
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, scale=scale, interpret=_interpret()
    )


def ssd_chunked(xdt, loga, b, c, chunk: int = 128):
    """Mamba-2 SSD over chunks (matches repro.models.ssd.ssd_chunked)."""
    return ssd_scan_kernel(xdt, loga, b, c, chunk=chunk, interpret=_interpret())
