"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical definition the kernel must match; the
per-kernel tests sweep shapes/dtypes and ``assert_allclose`` kernel output
against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ell(data: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``w[i] = sum_k data[i,k] * x[cols[i,k]]``."""
    return (data * x[cols]).sum(axis=-1)


def spmm_ell(data: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """``W[i, c] = sum_k data[i,k] * x[cols[i,k], c]`` for ``x: [N, C]``.

    Reduction runs over axis 1 in the same order as :func:`spmv_ell`, so a
    single-column ``x`` reproduces the SpMV result exactly.
    """
    return (data[..., None] * x[cols]).sum(axis=1)


def spmv_ell_masked(
    data: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray, row_mask: jnp.ndarray
) -> jnp.ndarray:
    """Phase-masked SpMV oracle: rows where ``row_mask`` is False deliver
    exactly 0 (the jnp analogue of the kernel's skipped row tiles; the mask
    is the kernel's ``tile_mask`` expanded to rows)."""
    w = spmv_ell(data, cols, x)
    return jnp.where(row_mask, w, jnp.zeros_like(w))


def spmm_ell_masked(
    data: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray, row_mask: jnp.ndarray
) -> jnp.ndarray:
    """Phase-masked SpMM oracle; see :func:`spmv_ell_masked`."""
    w = spmm_ell(data, cols, x)
    return jnp.where(row_mask[:, None], w, jnp.zeros_like(w))


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Softmax attention. q: [Sq, H, D]; k/v: [Sk, Hkv, D] (GQA by repeat).

    ``window`` limits attention to the last ``window`` positions (sliding
    window); ``None`` is full attention.  Query position ``i`` is aligned to
    key position ``i + Sk - Sq`` (decode-friendly).
    """
    Sq, H, D = q.shape
    Sk, Hkv, _ = k.shape
    rep = H // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(q.dtype)
    logits = jnp.einsum("qhd,khd->hqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("hqk,khd->qhd", probs, v)


def ssd_scan(
    x: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
) -> jnp.ndarray:
    """Mamba-2 SSD (state-space duality) sequential reference.

    x: [S, H, P]  inputs (heads x head_dim)
    a: [S, H]     per-step log-decay (a_t = exp(a_log_t) in (0, 1])
    b: [S, N]     input projection onto state dim N
    c: [S, N]     output projection
    returns y: [S, H, P] with state h_t = a_t * h_{t-1} + b_t^T x_t
    (h: [N, H, P]), y_t = c_t @ h_t.
    """
    S, H, P = x.shape
    N = b.shape[1]

    def step(h, inp):
        xt, at, bt, ct = inp
        h = at[None, :, None] * h + bt[:, None, None] * xt[None]
        y = jnp.einsum("n,nhp->hp", ct, h)
        return h, y

    h0 = jnp.zeros((N, H, P), dtype=jnp.float32)
    _, ys = jax.lax.scan(step, h0, (x.astype(jnp.float32), a.astype(jnp.float32),
                                    b.astype(jnp.float32), c.astype(jnp.float32)))
    return ys.astype(x.dtype)
