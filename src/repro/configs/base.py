"""Architecture / run configuration schema.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py``; the shape grid (train_4k / prefill_32k /
decode_32k / long_500k) is shared by all LM-family archs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int  # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared (always-on) experts
    first_dense_layers: int = 0  # leading dense layers (deepseek-v2 style)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int  # N
    head_dim: int = 64  # P
    expand: int = 2  # d_inner = expand * d_model
    chunk: int = 256  # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    context: int  # encoder sequence length (e.g. 1500 audio frames)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | enc_dec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0  # fraction of head_dim rotated (chatglm: 0.5)
    window: Optional[int] = None  # sliding-window size (None = full)
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # vlm: one cross-attention layer every `cross_attn_every` layers
    cross_attn_every: Optional[int] = None
    cross_context: int = 0  # image/audio token count for cross-attn
    frontend: Optional[str] = None  # "audio" | "vision" stub
    # misc
    act: str = "silu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # whether this arch supports sub-quadratic 500k-token decode
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def padded_vocab(self, multiple: int = 16) -> int:
        return -(-self.vocab_size // multiple) * multiple

    def approx_params(self) -> int:
        """Rough dense-equivalent parameter count (used for MODEL_FLOPS)."""
        from repro.models.lm import LMModel  # local import to avoid cycle

        return LMModel(self).param_count()


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(config: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable?, reason) for an (arch x shape) cell -- DESIGN.md section 5."""
    if shape.name == "long_500k" and not config.subquadratic:
        return False, "full-attention arch: 500k-token decode skipped (DESIGN.md §5)"
    return True, ""
