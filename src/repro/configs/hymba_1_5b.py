"""hymba-1.5b [hybrid]: parallel attention + Mamba heads, sliding-window
attention -> runnable at 500k decode. [arXiv:2411.13676; hf]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    window=2048,  # SWA on all layers (global layers approximated; DESIGN §5)
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, chunk=128),
    subquadratic=True,
)
