"""mamba2-780m [ssm]: attention-free SSD (state-space duality); O(1)-state
decode -> runs the 500k shape. [arXiv:2405.21060]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,  # attention-free
    n_kv_heads=1,
    d_ff=0,  # no MLP blocks
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=128),
    subquadratic=True,
)
