"""chatglm3-6b [dense]: partial (2D) RoPE, 2 KV heads. [arXiv:2406.12793]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,  # ChatGLM rotates half the head dim
)
