"""llama-3.2-vision-90b [vlm]: 100L decoder with cross-attention image layers
every 5th layer; vision frontend STUB (precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-90B-Vision]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    cross_attn_every=5,
    cross_context=1600,
    frontend="vision",
)
