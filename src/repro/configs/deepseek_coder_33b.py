"""deepseek-coder-33b [dense]: llama architecture. [arXiv:2401.14196]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=1e5,
)
