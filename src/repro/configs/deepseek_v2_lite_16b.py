"""deepseek-v2-lite-16b [moe]: MLA (kv_lora 512) + MoE, 64 routed experts
top-6 + 2 shared, expert d_ff 1408, first layer dense. [arXiv:2405.04434]"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # expert width; dense layer uses 4x
    vocab_size=102400,
    head_dim=192,  # nope 128 + rope 64
    moe=MoEConfig(
        n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2, first_dense_layers=1
    ),
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
)
