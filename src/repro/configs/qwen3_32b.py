"""qwen3-32b [dense]: qk_norm + GQA. [hf:Qwen/Qwen3-32B]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
)
