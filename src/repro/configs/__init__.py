"""Assigned architecture registry: ``get_config(arch_id)``."""

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable

_MODULES = {
    "hymba-1.5b": "hymba_1_5b",
    "whisper-large-v3": "whisper_large_v3",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "stablelm-3b": "stablelm_3b",
    "qwen3-32b": "qwen3_32b",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "mamba2-780m": "mamba2_780m",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "all_configs",
    "get_config",
    "shape_applicable",
]
