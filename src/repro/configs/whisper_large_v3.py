"""whisper-large-v3 [audio]: encoder-decoder, conv frontend STUB (precomputed
1500 mel-frame embeddings). 32 enc + 32 dec layers. [arXiv:2212.04356]"""

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="enc_dec",
    n_layers=32,  # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    act="gelu",
    encoder=EncoderConfig(n_layers=32, context=1500),
    frontend="audio",
)
