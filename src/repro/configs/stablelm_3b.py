"""stablelm-3b [dense]. [hf:stabilityai/stablelm-3b-4e1t]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
)
