from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.compat import tree_flatten_with_path


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(template, arrays: Dict[str, np.ndarray], shardings=None):
    flat, treedef = tree_flatten_with_path(template)
    shard_flat = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (path, leaf), sh in zip(flat, shard_flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"leaf {key}: shape {arr.shape} != expected {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree.unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, state: dict, extra: Optional[dict] = None) -> str:
    """Atomic synchronous save. ``state`` is a pytree dict of arrays."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrays = _flatten(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": int(step), "extra": extra or {}, "n_leaves": len(arrays)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def load_checkpoint(
    directory: str, template, step: Optional[int] = None, shardings=None
) -> Tuple[Any, dict]:
    """Restore ``template``-shaped state (onto ``shardings`` if given)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    state = _unflatten_into(template, arrays, shardings)
    return state, manifest


class CheckpointManager:
    """Async checkpointing: serialize on the caller thread is avoided by
    snapshotting to host numpy, then writing on a worker thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, step: int, state: dict, extra: Optional[dict] = None) -> None:
        self.wait()  # bound outstanding writes to one
        snapshot = jax.tree.map(np.asarray, state)  # host copy now

        def _work():
            save_checkpoint(self.directory, step, snapshot, extra)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def restore(self, template, step=None, shardings=None):
        return load_checkpoint(self.directory, template, step, shardings)
