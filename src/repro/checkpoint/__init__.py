"""Mesh-independent checkpointing with atomic commits and an async writer.

Checkpoints store every leaf as a *full logical array* (npz shards keyed by
flattened tree path) plus a JSON manifest (step, data cursor, rng, config
fingerprint).  Restoring onto a different mesh / device count is therefore
trivial -- the restore path re-``device_put``s each array with the new
sharding (elastic resharding, tested in CI).  Commits are atomic
(write to ``<dir>.tmp`` then ``os.replace``), so a crash mid-save never
corrupts the latest checkpoint; the async writer overlaps serialization with
the next training steps and is joined before the next save (bounded memory).
"""

from repro.checkpoint.ckpt import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "latest_step",
    "load_checkpoint",
    "save_checkpoint",
]
