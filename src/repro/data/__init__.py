"""Deterministic synthetic token pipeline (sharded, resumable).

Batches are a pure function of ``(seed, step)`` -- the pipeline needs no
state beyond the step cursor, so checkpoint/restart and *elastic resharding*
(same step, different mesh) reproduce the exact global batch.  Tokens follow
a Zipf-like distribution with a short learnable n-gram structure so the loss
actually decreases during the example runs.
"""

from repro.data.synthetic import SyntheticTokens

__all__ = ["SyntheticTokens"]
