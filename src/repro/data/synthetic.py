from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class SyntheticTokens:
    """Deterministic ``(seed, step) -> {tokens, labels}`` batch source."""

    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    mesh: Optional[Mesh] = None
    batch_spec: P = P()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(step)])
        )
        # Zipf-ish marginal + deterministic bigram: next ~ (3*prev + noise)
        base = rng.zipf(1.3, size=(self.batch, self.seq_len + 1)) % self.vocab_size
        noise = rng.integers(0, 7, size=base.shape)
        seq = (3 * np.roll(base, 1, axis=1) + noise) % self.vocab_size
        seq[:, 0] = base[:, 0]
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if self.mesh is not None:
            sharding = NamedSharding(self.mesh, self.batch_spec)
            out = {k: jax.device_put(v, sharding) for k, v in out.items()}
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
