"""Distributed Krylov solvers over the node-aware exchange (CG, BiCGStab).

The workload the paper's closing discussion argues the strategy choice must
be judged on: an iterative solver re-runs ONE irregular exchange pattern
hundreds of times, so strategy setup cost amortizes while per-iteration
exchange and reduction latency multiply.  Both solvers here:

* run their matvecs through a distributed SpMV operator -- the device
  executor :class:`repro.sparse.spmv.DistributedSpMV` (any strategy,
  ``overlap=True`` supported) or the jax-free
  :class:`repro.solve.operator.NumpySpMV` -- whose ONE cached exchange plan
  serves every iteration (``repro.comm.cache_stats()`` shows exactly one
  plan miss per solve, pinned in ``tests/test_solver.py``);
* route every dot product / norm through the node-aware hierarchical
  reductions (:mod:`repro.solve.reductions`: per-chip partial -> on-pod
  tree -> one scalar per pod across the inter-pod hop, optionally
  int8-compressed there);
* record the relative-residual history so convergence trajectories can be
  compared bitwise across strategies and barrier-vs-overlap execution.

The iteration loops run at host level in numpy: with interpret-mode kernels
the matvec dominates wall time, and host-level scalars keep the control flow
(convergence tests, breakdown guards) exact and executor-independent.
Strategy selection for a whole solve (setup amortization, reduction latency)
lives in :func:`repro.core.advisor.advise_solver`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.solve.reductions import default_reductions

#: scalar all-reduces each solver issues per iteration (dot products and
#: norms, counting a norm as one dot) -- the ``reductions_per_iter`` input
#: of :func:`repro.core.advisor.advise_solver`
REDUCTIONS_PER_ITER = {"cg": 2.0, "bicgstab": 6.0}

#: matvecs (= irregular exchanges) each solver issues per iteration
MATVECS_PER_ITER = {"cg": 1.0, "bicgstab": 2.0}

#: iterations without a new best residual before a solve is declared
#: stagnant (and restarted once from the best iterate)
STALL_WINDOW = 50


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """Outcome of one Krylov solve.

    ``residuals[i]`` is the *relative* recursive residual norm
    ``||r_i|| / ||b||`` after ``i`` iterations (``residuals[0]`` is the
    starting residual), computed with the solver's own reductions -- on the
    numpy executor these histories are bitwise identical across strategies
    and barrier-vs-overlap execution.

    ``status`` names how the solve ended: ``"converged"``, ``"maxiter"``,
    a breakdown reason (``"breakdown:indefinite"``, ``"breakdown:rho"``,
    ``"breakdown:omega"``, ``"breakdown:denom"``, ``"breakdown:tt"``,
    ``"breakdown:nonfinite"``, ``"stagnation"``), with a ``"+restart"``
    suffix when the solver restarted from its best iterate and a
    ``"+exchange:<action>:<strategy>/<codec>"`` suffix when the operator's
    exchange recovered through the fault ladder
    (:func:`repro.comm.faults.run_ladder`) during the solve.

    The fused whole-solve path (:func:`repro.solve.fused.fused_cg` /
    :func:`repro.solve.fused.fused_bicgstab` with ``checkpoint_every``)
    additionally appends ``"+resume:<n>"`` when an integrity failure
    interrupted the on-device loop and the solve continued from its
    in-carry checkpoint, losing at most ``checkpoint_every`` iterations;
    suffix order is ``base[+resume][+restart][+exchange]``.
    """

    x: np.ndarray
    converged: bool
    iterations: int
    residuals: Tuple[float, ...]
    matvecs: int
    status: str = "converged"
    restarts: int = 0

    @property
    def final_residual(self) -> float:
        return self.residuals[-1]


def _recovery_baseline(op) -> int:
    health = getattr(op, "health", None)
    return health.recovery_count if health is not None else 0


def _finish_status(status: str, restarts: int, op, rc0: int) -> str:
    if restarts:
        status += "+restart"
    health = getattr(op, "health", None)
    if (
        health is not None
        and health.recovery_count > rc0
        and health.last_recovery
    ):
        status += "+exchange:" + health.last_recovery
    return status


def _prepare(op, b, x0, reductions):
    red = default_reductions(op) if reductions is None else reductions
    b = np.asarray(b)
    g, L = op.topo.nranks, op.rows_per_rank
    if b.shape != (g, L):
        raise ValueError(f"b must be [{g}, {L}], got {tuple(b.shape)}")
    x = np.zeros_like(b) if x0 is None else np.array(x0, dtype=b.dtype, copy=True)
    bnorm = red.norm(b)
    return red, b, x, bnorm


def cg(
    op,
    b: np.ndarray,
    x0: Optional[np.ndarray] = None,
    tol: float = 1e-6,
    maxiter: int = 500,
    reductions=None,
) -> SolveResult:
    """Conjugate gradients for a symmetric positive-definite operator.

    ``op`` is a distributed SpMV (``[nranks, L] -> [nranks, L]``); one
    matvec -- one irregular exchange under the single cached plan -- and two
    hierarchical reductions per iteration.  Build an SPD system from any
    generator matrix with :func:`repro.solve.problems.spd_system`.

    Non-finite residuals and stagnation (no new best residual within
    :data:`STALL_WINDOW` iterations) trigger ONE restart from the best
    iterate with a true-residual recompute ``r = b - A x``; a second
    trip ends the solve with the reason in ``SolveResult.status``.
    """
    red, b, x, bnorm = _prepare(op, b, x0, reductions)
    rc0 = _recovery_baseline(op)
    if bnorm == 0.0:
        # route through _finish_status like every other exit path, so the
        # recovery-suffix contract holds for trivial solves too
        return SolveResult(x=np.zeros_like(b), converged=True, iterations=0,
                           residuals=(0.0,), matvecs=0,
                           status=_finish_status("converged", 0, op, rc0))
    matvecs = 0
    if x0 is None:
        r = b.copy()
    else:
        r = b - np.asarray(op(x)).astype(b.dtype)
        matvecs += 1
    p = r.copy()
    rs = red.dot(r, r)
    hist = [float(np.sqrt(max(rs, 0.0)) / bnorm)]
    if hist[-1] <= tol:
        return SolveResult(x=x, converged=True, iterations=0,
                           residuals=tuple(hist), matvecs=matvecs,
                           status=_finish_status("converged", 0, op, rc0))
    it = 0
    converged = False
    restarts = 0
    status = "maxiter"
    best, best_x, best_it = hist[-1], x.copy(), 0
    while it < maxiter:
        Ap = np.asarray(op(p)).astype(b.dtype)
        matvecs += 1
        pAp = red.dot(p, Ap)
        if pAp <= 0.0:  # breakdown / loss of positive definiteness
            status = "breakdown:indefinite"
            break
        alpha = rs / pAp
        x = x + alpha * p
        r = r - alpha * Ap
        rs_new = red.dot(r, r)
        it += 1
        hist.append(float(np.sqrt(max(rs_new, 0.0)) / bnorm))
        if hist[-1] <= tol:
            converged = True
            break
        if hist[-1] < best:
            best, best_x, best_it = hist[-1], x.copy(), it
        bad = None
        if not np.isfinite(hist[-1]):
            bad = "breakdown:nonfinite"
        elif it - best_it >= STALL_WINDOW:
            bad = "stagnation"
        if bad is not None:
            if restarts:
                status = bad
                break
            # one restart from the best iterate: true-residual recompute
            restarts += 1
            x = best_x.copy()
            r = b - np.asarray(op(x)).astype(b.dtype)
            matvecs += 1
            p = r.copy()
            rs = red.dot(r, r)
            hist.append(float(np.sqrt(max(rs, 0.0)) / bnorm))
            best, best_it = hist[-1], it
            if hist[-1] <= tol:
                converged = True
                break
            if not np.isfinite(hist[-1]):
                status = bad
                break
            continue
        p = r + (rs_new / rs) * p
        rs = rs_new
    if converged:
        status = "converged"
    return SolveResult(x=x, converged=converged, iterations=it,
                       residuals=tuple(hist), matvecs=matvecs,
                       status=_finish_status(status, restarts, op, rc0),
                       restarts=restarts)


def bicgstab(
    op,
    b: np.ndarray,
    x0: Optional[np.ndarray] = None,
    tol: float = 1e-6,
    maxiter: int = 500,
    reductions=None,
) -> SolveResult:
    """BiCGStab for a general (nonsymmetric) operator.

    Two matvecs -- two exchanges under the same single cached plan -- and
    six hierarchical reductions per iteration.  Build a well-posed
    nonsymmetric system with :func:`repro.solve.problems.shifted_system`.

    Breakdown guards are tolerance-scaled (machine-eps relative to the
    quantities each ratio divides), not exact-zero tests, so near-breakdown
    no longer silently truncates the history: the first trip restarts once
    from the best iterate (true-residual recompute), the second ends the
    solve with the reason in ``SolveResult.status``.
    """
    red, b, x, bnorm = _prepare(op, b, x0, reductions)
    rc0 = _recovery_baseline(op)
    if bnorm == 0.0:
        # route through _finish_status like every other exit path, so the
        # recovery-suffix contract holds for trivial solves too
        return SolveResult(x=np.zeros_like(b), converged=True, iterations=0,
                           residuals=(0.0,), matvecs=0,
                           status=_finish_status("converged", 0, op, rc0))
    eps = float(np.finfo(b.dtype).eps)
    matvecs = 0
    if x0 is None:
        r = b.copy()
    else:
        r = b - np.asarray(op(x)).astype(b.dtype)
        matvecs += 1
    rhat = r.copy()
    rho = alpha = omega = 1.0
    v = np.zeros_like(b)
    p = np.zeros_like(b)
    hist = [red.norm(r) / bnorm]
    if hist[-1] <= tol:
        return SolveResult(x=x, converged=True, iterations=0,
                           residuals=tuple(hist), matvecs=matvecs,
                           status=_finish_status("converged", 0, op, rc0))
    rhat_nrm = hist[0] * bnorm  # ||rhat|| is fixed at ||r_0||
    it = 0
    converged = False
    restarts = 0
    status = "maxiter"
    best, best_x, best_it = hist[-1], x.copy(), 0
    while it < maxiter:
        rho_new = red.dot(rhat, r)
        r_nrm = hist[-1] * bnorm  # recursive residual norm, no extra reduce
        bad = None
        # |<rhat, r>| can only be meaningful above eps * ||rhat|| * ||r||
        if abs(rho_new) <= eps * rhat_nrm * r_nrm:
            bad = "breakdown:rho"
        elif abs(omega) <= eps * abs(alpha):
            bad = "breakdown:omega"
        if bad is None:
            beta = (rho_new / rho) * (alpha / omega)
            p = r + beta * (p - omega * v)
            v = np.asarray(op(p)).astype(b.dtype)
            matvecs += 1
            denom = red.dot(rhat, v)
            # alpha = rho_new / denom would exceed 1/eps
            if abs(denom) <= eps * abs(rho_new):
                bad = "breakdown:denom"
        if bad is None:
            alpha = rho_new / denom
            s = r - alpha * v
            it += 1
            snorm = red.norm(s)
            if snorm / bnorm <= tol:  # first half-step already converged
                x = x + alpha * p
                hist.append(snorm / bnorm)
                converged = True
                break
            t = np.asarray(op(s)).astype(b.dtype)
            matvecs += 1
            tt = red.dot(t, t)
            # omega = <t, s> / tt would exceed ~1/eps relative to ||s||
            if tt <= (eps * snorm) ** 2:
                bad = "breakdown:tt"
        if bad is None:
            omega = red.dot(t, s) / tt
            x = x + alpha * p + omega * s
            r = s - omega * t
            hist.append(red.norm(r) / bnorm)
            if hist[-1] <= tol:
                converged = True
                break
            if hist[-1] < best:
                best, best_x, best_it = hist[-1], x.copy(), it
            if not np.isfinite(hist[-1]):
                bad = "breakdown:nonfinite"
            elif it - best_it >= STALL_WINDOW:
                bad = "stagnation"
            if bad is None:
                rho = rho_new
                continue
        if restarts:
            status = bad
            break
        # one restart from the best iterate: true-residual recompute
        restarts += 1
        x = best_x.copy()
        r = b - np.asarray(op(x)).astype(b.dtype)
        matvecs += 1
        rhat = r.copy()
        rho = alpha = omega = 1.0
        v = np.zeros_like(b)
        p = np.zeros_like(b)
        hist.append(red.norm(r) / bnorm)
        rhat_nrm = hist[-1] * bnorm
        best, best_it = hist[-1], it
        if hist[-1] <= tol:
            converged = True
            break
        if not np.isfinite(hist[-1]):
            status = bad
            break
    if converged:
        status = "converged"
    return SolveResult(x=x, converged=converged, iterations=it,
                       residuals=tuple(hist), matvecs=matvecs,
                       status=_finish_status(status, restarts, op, rc0),
                       restarts=restarts)
