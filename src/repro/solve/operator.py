"""Jax-free distributed SpMV executor for the solver test/benchmark path.

:class:`NumpySpMV` runs the SAME planned stage programs as the device
executor -- the plan comes from the module-level plan cache
(:func:`repro.comm.strategies.planned`), the exchange runs through
:func:`repro.comm.exchange.execute_numpy` (the bit-exact numpy oracle of the
``shard_map`` executor), and the local compute is the blocked-ELL
contraction in plain numpy.  Because every strategy delivers the identical
canonical halo buffer, a Krylov solve on this operator produces
*bitwise-identical* residual histories across strategies and across
barrier-vs-split-phase execution -- the property pinned by
``tests/test_solver.py``.

``overlap=True`` exercises the split-phase decomposition: the pattern is
factored through the module ``_SPLIT_CACHE``
(:func:`repro.comm.strategies._split_phase_cached`, visible as
``split_hits``/``split_misses`` in :func:`repro.comm.cache_stats`), the
on-pod and inter-pod sub-plans execute separately, and
:func:`repro.comm.exchange.merge_split_phase` reassembles the halo --
bit-identical to the barrier buffer, so the local compute needs no masking
to stay bit-compatible (unlike the device pipeline, nothing actually runs
concurrently here; the decomposition is what is being exercised).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.comm import faults as faults_mod
from repro.comm import strategies as comm_strategies
from repro.comm import wire as wire_mod
from repro.comm.exchange import execute_numpy, merge_split_phase
from repro.comm.topology import PodTopology
from repro.sparse.partition import SpmvPartition


def _ell_matvec(data: np.ndarray, cols: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Blocked-ELL contraction over stacked ranks.

    ``data``/``cols``: ``[g, L, K]``; ``x``: ``[g, W]`` (per-rank source
    vector or halo buffer).  Padding slots have ``data == 0, cols == 0`` and
    contribute exact zeros.
    """
    g = x.shape[0]
    gathered = x[np.arange(g)[:, None, None], cols]  # [g, L, K]
    return (data * gathered).sum(axis=2)


@dataclasses.dataclass
class NumpySpMV:
    """One matrix + topology + strategy, executed without jax.

    Mirrors :class:`repro.sparse.spmv.DistributedSpMV`'s call contract for
    vectors (``v [nranks, L] -> w [nranks, L]``) and shares its plan cache,
    so a solve on either operator re-plans nothing and the
    one-plan-per-solve property is measurable via
    ``repro.comm.cache_stats()``.
    """

    partition: SpmvPartition
    strategy: str = "standard"
    message_cap_bytes: int = 16384
    overlap: bool = False
    #: inter-pod wire codec (repro.comm.wire); "none" keeps the bitwise
    #: residual-history property across strategies, lossy codecs trade the
    #: pinned per-element halo error bound for 2-4x fewer DCI bytes
    wire: str = "none"
    #: opt-in wire integrity verification; a failed check engages the
    #: retry -> codec-demotion -> strategy-re-advise ladder
    #: (:func:`repro.comm.faults.run_ladder`)
    verify: bool = False
    #: seeded deterministic fault injection (repro.comm.faults.FaultPlan)
    faults: Optional[faults_mod.FaultPlan] = None
    #: shared health tracker; created on demand when verify/faults are set
    health: Optional[faults_mod.HealthTracker] = None
    max_retries: int = 1
    fallback: bool = True

    def __post_init__(self) -> None:
        if self.strategy not in comm_strategies.STRATEGY_NAMES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; "
                f"known: {comm_strategies.STRATEGY_NAMES}"
            )
        wire_mod.check_codec(self.wire)
        pattern = self.partition.pattern
        if self.overlap:
            sp, _ = comm_strategies._split_phase_cached(pattern)
            self._split = sp
            self._remote_plan = comm_strategies.planned(
                sp.remote, self.strategy, message_cap_bytes=self.message_cap_bytes
            )
            self._local_plan = comm_strategies.planned(sp.local, "local")
            self._plan = None
        else:
            self._split = None
            self._plan = comm_strategies.planned(
                pattern, self.strategy, message_cap_bytes=self.message_cap_bytes
            )
        g, L = self.topo.nranks, self.partition.rows_per_rank
        self._diag_d = self.partition.diag.data.reshape(g, L, -1)
        self._diag_c = self.partition.diag.cols.reshape(g, L, -1)
        self._off_d = self.partition.off.data.reshape(g, L, -1)
        self._off_c = self.partition.off.cols.reshape(g, L, -1)
        if self.health is None and (self.verify or self.faults is not None):
            self.health = faults_mod.HealthTracker()
        self._fault_calls = 0
        #: RecoveryPath.key of the most recent recovered exchange, or None
        self.last_recovery: Optional[str] = None

    @property
    def topo(self) -> PodTopology:
        return self.partition.topo

    @property
    def rows_per_rank(self) -> int:
        return self.partition.rows_per_rank

    # ------------------------------------------------------------------
    def halo(self, v: np.ndarray) -> np.ndarray:
        """Exchange only: ``[nranks, L] -> [nranks, H]`` canonical buffer.

        With ``verify`` or ``faults`` set, the exchange runs inside the
        recovery ladder; faults and checks ride the inter-pod (sub-)plan
        only, so on-pod data is never touched.
        """
        v = np.asarray(v)
        if self.faults is None and not self.verify:
            if self.overlap:
                # inter-pod and on-pod sub-plans execute separately, then
                # merge -- bit-identical to the unsplit plan
                # (tests/test_overlap.py); the wire codec rides the
                # inter-pod sub-plan only
                remote = execute_numpy(self._remote_plan, v, wire=self.wire)
                local = execute_numpy(self._local_plan, v)
                return merge_split_phase(self._split, local, remote)
            return execute_numpy(self._plan, v, wire=self.wire)
        return self._guarded_halo(v)

    def _exchange(self, v: np.ndarray, strategy: str, wire: str,
                  fault_call: int) -> np.ndarray:
        """One physical halo attempt under (strategy, wire) -- the ladder's
        probe; plans come from the module cache, so variants replan once."""
        if self.overlap:
            remote_plan = comm_strategies.planned(
                self._split.remote, strategy,
                message_cap_bytes=self.message_cap_bytes,
            )
            remote = execute_numpy(
                remote_plan, v, wire=wire, faults=self.faults,
                fault_call=fault_call, verify=self.verify,
            )
            local = execute_numpy(self._local_plan, v)
            return merge_split_phase(self._split, local, remote)
        plan = comm_strategies.planned(
            self.partition.pattern, strategy,
            message_cap_bytes=self.message_cap_bytes,
        )
        return execute_numpy(
            plan, v, wire=wire, faults=self.faults,
            fault_call=fault_call, verify=self.verify,
        )

    def _guarded_halo(self, v: np.ndarray) -> np.ndarray:
        def attempt(strategy: str, wire: str) -> np.ndarray:
            idx = self._fault_calls
            self._fault_calls += 1
            return self._exchange(v, strategy, wire, idx)

        out, path = faults_mod.run_ladder(
            attempt,
            strategy=self.strategy,
            wire=self.wire,
            health=self.health,
            max_retries=self.max_retries,
            fallback=self.fallback,
            choose_alternative=faults_mod.advise_alternative(
                self.partition.pattern
            ),
        )
        if path is not None:
            self.last_recovery = path.key
        return out

    def __call__(self, v: np.ndarray) -> np.ndarray:
        v = np.asarray(v)
        g, L = self.topo.nranks, self.partition.rows_per_rank
        if v.shape != (g, L):
            raise ValueError(f"expected [{g}, {L}], got {tuple(v.shape)}")
        halo = self.halo(v)
        return _ell_matvec(self._diag_d, self._diag_c, v) + _ell_matvec(
            self._off_d, self._off_c, halo
        )

    @property
    def wire_bytes(self):
        """(intra-pod, inter-pod) wire bytes of one exchange, codec-scaled."""
        if self.overlap:
            ri, rj = wire_mod.scaled_wire_bytes(self._remote_plan, self.wire)
            li, _ = wire_mod.scaled_wire_bytes(self._local_plan, "none")
            return (ri + li, rj)
        return wire_mod.scaled_wire_bytes(self._plan, self.wire)


def build_numpy(matrix, topo: PodTopology, strategy: str = "standard", **kw) -> NumpySpMV:
    """Partition ``matrix`` and wrap it in a :class:`NumpySpMV`."""
    from repro.sparse.partition import partition_csr

    return NumpySpMV(partition_csr(matrix, topo), strategy=strategy, **kw)


# ---------------------------------------------------------------------------
# Traceable operator (whole-solve fusion support)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class TraceableOperator:
    """A distributed SpMV as a pure per-shard callable + operand pytree.

    The matvec analogue of :class:`repro.comm.strategies.TraceableExchange`:
    :attr:`operands` is a flat tuple of ``[nranks, ...]`` device arrays
    (exchange plan arrays, split-phase merge maps, blocked-ELL data/cols,
    overlap phase masks) that a caller threads through its own ``shard_map``
    input specs, and :meth:`matvec` is the pure per-shard
    ``v [1, L] -> w [1, L]`` program -- exchange stages, (masked) blocked-ELL
    contraction and, under ``overlap``, the split-phase decomposition, all
    expressed inline so the whole matvec can live inside a traced loop body
    (:mod:`repro.solve.fused`).

    Build with :func:`traceable_operator` from either executor flavor
    (:class:`repro.sparse.spmv.DistributedSpMV` or :class:`NumpySpMV`).
    """

    topo: PodTopology
    local_size: int
    overlap: bool
    use_pallas: bool
    mesh: object
    #: barrier path: the unsplit exchange (``None`` under ``overlap``)
    exchange: Optional[object]
    #: overlap path: inter-pod + on-pod sub-exchanges (``None`` otherwise)
    remote: Optional[object]
    local: Optional[object]
    #: flat ``[nranks, ...]`` device arrays; feed each through a
    #: ``P(WORLD_AXES)`` spec and pass the per-shard slices to :meth:`matvec`
    operands: tuple
    #: static operand layout: plan-array counts of the (remote) exchange and
    #: the on-pod exchange (0 in barrier mode)
    n_exchange_ops: int
    n_local_ops: int

    @property
    def verifier(self):
        """The exchange whose integrity checks guard this operator (the
        unsplit plan in barrier mode, the inter-pod sub-plan under overlap),
        or ``None`` when no DCI hop is checked."""
        tx = self.exchange if not self.overlap else self.remote
        return tx if (tx is not None and tx.emit_checks) else None

    # -- per-shard kernels ---------------------------------------------
    def _full(self, data, cols, x):
        if self.use_pallas:
            from repro.kernels.spmv_ell import spmv_ell

            return spmv_ell(data, cols, x, interpret=True)
        from repro.kernels import ref as kref

        return kref.spmv_ell(data, cols, x)

    def _masked(self, data, cols, x, tiles, rows):
        if self.use_pallas:
            from repro.kernels.spmv_ell import spmv_ell

            return spmv_ell(data, cols, x, interpret=True, tile_mask=tiles)
        from repro.kernels import ref as kref

        return kref.spmv_ell_masked(data, cols, x, rows)

    # ------------------------------------------------------------------
    def matvec(self, v, *operands):
        """Pure per-shard matvec: ``v [1, L] -> w [1, L]``."""
        w, _ = self._apply(v, operands, verified=False)
        return w

    def matvec_verified(self, v, *operands):
        """Like :meth:`matvec` but also returns the ``[n_checks]`` wire
        integrity violation vector of the DCI-crossing exchange (empty when
        nothing is checked); surface positives via
        ``self.verifier.raise_viols``."""
        return self._apply(v, operands, verified=True)

    def _apply(self, v, operands, verified: bool):
        import jax.numpy as jnp

        k = self.n_exchange_ops
        if not self.overlap:
            pa, (dd, dc, od, oc) = operands[:k], operands[k:]
            halo, viols = self._run_exchange(self.exchange, v, pa, verified)
            w = self._full(dd[0], dc[0], v[0]) + self._full(od[0], oc[0], halo[0])
            return w[None], viols
        rpa = operands[:k]
        lpa = operands[k : k + self.n_local_ops]
        (
            mask, valid, li, ri, dd, dc, od, oc,
            all_tiles, all_rows, bnd_tiles, bnd_rows,
        ) = operands[k + self.n_local_ops :]
        # split-phase decomposition in-body: the inter-pod sub-exchange and
        # the halo-independent diag pass carry no data dependency, so XLA is
        # free to overlap them; the boundary-masked off pass waits on the
        # merged halo exactly like the host pipeline's finish()
        remote_out, viols = self._run_exchange(self.remote, v, rpa, verified)
        local_out = self.local.run(v, *lpa)
        halo = _merge_shard(mask, valid, li, ri, local_out, remote_out)
        w = self._masked(dd[0], dc[0], v[0], all_tiles[0], all_rows[0])
        w = w + self._masked(od[0], oc[0], halo[0], bnd_tiles[0], bnd_rows[0])
        return w[None], viols

    @staticmethod
    def _run_exchange(tx, v, plan_arrays, verified: bool):
        import jax.numpy as jnp

        if verified and tx.emit_checks:
            return tx.run_verified(v, *plan_arrays)
        return tx.run(v, *plan_arrays), jnp.zeros((0,), jnp.float32)


def _merge_shard(mask, valid, li, ri, local_out, remote_out):
    """Per-shard split-phase merge -- the ``[1, H]``-sliced twin of
    :func:`repro.comm.strategies._build_merge`'s jitted gather."""
    import jax.numpy as jnp

    nfeat = local_out.ndim - 2

    def take(buf, idx):
        idx = jnp.minimum(idx, buf.shape[1] - 1)
        idx = idx.reshape(idx.shape + (1,) * nfeat)
        idx = jnp.broadcast_to(idx, idx.shape[:2] + buf.shape[2:])
        return jnp.take_along_axis(buf, idx, axis=1)

    m = mask.reshape(mask.shape + (1,) * nfeat)
    v = valid.reshape(valid.shape + (1,) * nfeat)
    lo = take(local_out, li)
    merged = jnp.where(m, lo, take(remote_out, ri))
    return jnp.where(v, merged, jnp.zeros_like(lo))


def traceable_operator(op) -> TraceableOperator:
    """Lower either SpMV executor flavor to its traceable program value.

    Accepts a :class:`repro.sparse.spmv.DistributedSpMV` (reusing its plans,
    mesh, device blocks and kernel flavor) or a :class:`NumpySpMV` (blocks
    are transferred, the jnp-oracle kernels are used, and the mesh is the
    default exchange mesh).  Plans come from the same module caches as the
    host executors, so lowering an already-constructed operator re-plans
    nothing.
    """
    import jax.numpy as jnp

    from repro.comm.strategies import _default_mesh, traceable_exchange
    from repro.core.split_plan import split_rows
    from repro.kernels.spmv_ell import TILE_R

    part = op.partition
    topo, L = part.topo, part.rows_per_rank
    g = topo.nranks
    is_device = hasattr(op, "use_pallas")
    use_pallas = bool(getattr(op, "use_pallas", False))
    mesh = getattr(op, "mesh", None) or _default_mesh(topo)
    wire = op.wire
    verify = getattr(op, "verify", False)
    faults = getattr(op, "faults", None)

    if is_device:
        blocks = op._blocks
    else:
        blocks = tuple(
            jnp.asarray(a)
            for a in (op._diag_d, op._diag_c, op._off_d, op._off_c)
        )

    if not op.overlap:
        if is_device:
            tx = op.exchange.traceable()
        else:
            tx = traceable_exchange(op._plan, codec=wire, verify=verify,
                                    faults=faults)
        return TraceableOperator(
            topo=topo, local_size=L, overlap=False, use_pallas=use_pallas,
            mesh=mesh, exchange=tx, remote=None, local=None,
            operands=tx.plan_arrays + blocks,
            n_exchange_ops=len(tx.plan_arrays), n_local_ops=0,
        )

    sp, _ = comm_strategies._split_phase_cached(part.pattern)
    remote_plan = comm_strategies.planned(
        sp.remote, op.strategy, message_cap_bytes=op.message_cap_bytes,
        fuse_program=getattr(op, "fuse_program", True),
    )
    local_plan = comm_strategies.planned(
        sp.local, "local", fuse_program=getattr(op, "fuse_program", True)
    )
    tx_remote = traceable_exchange(remote_plan, codec=wire, verify=verify,
                                   faults=faults)
    tx_local = traceable_exchange(local_plan)
    merge_ops = (
        jnp.asarray(sp.from_local),
        jnp.asarray(sp.valid),
        jnp.asarray(sp.local_idx),
        jnp.asarray(sp.remote_idx),
    )
    halo_dep = part.off_row_nnz.reshape(g, L) > 0
    split = split_rows(halo_dep, TILE_R)
    bnd = split.boundary_tiles
    bnd_rows = np.repeat(bnd, split.tile_rows, axis=1)[:, :L]
    masks = (
        jnp.ones(bnd.shape, np.int32),
        jnp.ones((g, L), bool),
        jnp.asarray(bnd.astype(np.int32)),
        jnp.asarray(bnd_rows),
    )
    return TraceableOperator(
        topo=topo, local_size=L, overlap=True, use_pallas=use_pallas,
        mesh=mesh, exchange=None, remote=tx_remote, local=tx_local,
        operands=(
            tx_remote.plan_arrays + tx_local.plan_arrays + merge_ops
            + blocks + masks
        ),
        n_exchange_ops=len(tx_remote.plan_arrays),
        n_local_ops=len(tx_local.plan_arrays),
    )
