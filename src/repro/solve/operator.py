"""Jax-free distributed SpMV executor for the solver test/benchmark path.

:class:`NumpySpMV` runs the SAME planned stage programs as the device
executor -- the plan comes from the module-level plan cache
(:func:`repro.comm.strategies.planned`), the exchange runs through
:func:`repro.comm.exchange.execute_numpy` (the bit-exact numpy oracle of the
``shard_map`` executor), and the local compute is the blocked-ELL
contraction in plain numpy.  Because every strategy delivers the identical
canonical halo buffer, a Krylov solve on this operator produces
*bitwise-identical* residual histories across strategies and across
barrier-vs-split-phase execution -- the property pinned by
``tests/test_solver.py``.

``overlap=True`` exercises the split-phase decomposition: the pattern is
factored through the module ``_SPLIT_CACHE``
(:func:`repro.comm.strategies._split_phase_cached`, visible as
``split_hits``/``split_misses`` in :func:`repro.comm.cache_stats`), the
on-pod and inter-pod sub-plans execute separately, and
:func:`repro.comm.exchange.merge_split_phase` reassembles the halo --
bit-identical to the barrier buffer, so the local compute needs no masking
to stay bit-compatible (unlike the device pipeline, nothing actually runs
concurrently here; the decomposition is what is being exercised).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.comm import faults as faults_mod
from repro.comm import strategies as comm_strategies
from repro.comm import wire as wire_mod
from repro.comm.exchange import execute_numpy, merge_split_phase
from repro.comm.topology import PodTopology
from repro.sparse.partition import SpmvPartition


def _ell_matvec(data: np.ndarray, cols: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Blocked-ELL contraction over stacked ranks.

    ``data``/``cols``: ``[g, L, K]``; ``x``: ``[g, W]`` (per-rank source
    vector or halo buffer).  Padding slots have ``data == 0, cols == 0`` and
    contribute exact zeros.
    """
    g = x.shape[0]
    gathered = x[np.arange(g)[:, None, None], cols]  # [g, L, K]
    return (data * gathered).sum(axis=2)


@dataclasses.dataclass
class NumpySpMV:
    """One matrix + topology + strategy, executed without jax.

    Mirrors :class:`repro.sparse.spmv.DistributedSpMV`'s call contract for
    vectors (``v [nranks, L] -> w [nranks, L]``) and shares its plan cache,
    so a solve on either operator re-plans nothing and the
    one-plan-per-solve property is measurable via
    ``repro.comm.cache_stats()``.
    """

    partition: SpmvPartition
    strategy: str = "standard"
    message_cap_bytes: int = 16384
    overlap: bool = False
    #: inter-pod wire codec (repro.comm.wire); "none" keeps the bitwise
    #: residual-history property across strategies, lossy codecs trade the
    #: pinned per-element halo error bound for 2-4x fewer DCI bytes
    wire: str = "none"
    #: opt-in wire integrity verification; a failed check engages the
    #: retry -> codec-demotion -> strategy-re-advise ladder
    #: (:func:`repro.comm.faults.run_ladder`)
    verify: bool = False
    #: seeded deterministic fault injection (repro.comm.faults.FaultPlan)
    faults: Optional[faults_mod.FaultPlan] = None
    #: shared health tracker; created on demand when verify/faults are set
    health: Optional[faults_mod.HealthTracker] = None
    max_retries: int = 1
    fallback: bool = True

    def __post_init__(self) -> None:
        if self.strategy not in comm_strategies.STRATEGY_NAMES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; "
                f"known: {comm_strategies.STRATEGY_NAMES}"
            )
        wire_mod.check_codec(self.wire)
        pattern = self.partition.pattern
        if self.overlap:
            sp, _ = comm_strategies._split_phase_cached(pattern)
            self._split = sp
            self._remote_plan = comm_strategies.planned(
                sp.remote, self.strategy, message_cap_bytes=self.message_cap_bytes
            )
            self._local_plan = comm_strategies.planned(sp.local, "local")
            self._plan = None
        else:
            self._split = None
            self._plan = comm_strategies.planned(
                pattern, self.strategy, message_cap_bytes=self.message_cap_bytes
            )
        g, L = self.topo.nranks, self.partition.rows_per_rank
        self._diag_d = self.partition.diag.data.reshape(g, L, -1)
        self._diag_c = self.partition.diag.cols.reshape(g, L, -1)
        self._off_d = self.partition.off.data.reshape(g, L, -1)
        self._off_c = self.partition.off.cols.reshape(g, L, -1)
        if self.health is None and (self.verify or self.faults is not None):
            self.health = faults_mod.HealthTracker()
        self._fault_calls = 0
        #: RecoveryPath.key of the most recent recovered exchange, or None
        self.last_recovery: Optional[str] = None

    @property
    def topo(self) -> PodTopology:
        return self.partition.topo

    @property
    def rows_per_rank(self) -> int:
        return self.partition.rows_per_rank

    # ------------------------------------------------------------------
    def halo(self, v: np.ndarray) -> np.ndarray:
        """Exchange only: ``[nranks, L] -> [nranks, H]`` canonical buffer.

        With ``verify`` or ``faults`` set, the exchange runs inside the
        recovery ladder; faults and checks ride the inter-pod (sub-)plan
        only, so on-pod data is never touched.
        """
        v = np.asarray(v)
        if self.faults is None and not self.verify:
            if self.overlap:
                # inter-pod and on-pod sub-plans execute separately, then
                # merge -- bit-identical to the unsplit plan
                # (tests/test_overlap.py); the wire codec rides the
                # inter-pod sub-plan only
                remote = execute_numpy(self._remote_plan, v, wire=self.wire)
                local = execute_numpy(self._local_plan, v)
                return merge_split_phase(self._split, local, remote)
            return execute_numpy(self._plan, v, wire=self.wire)
        return self._guarded_halo(v)

    def _exchange(self, v: np.ndarray, strategy: str, wire: str,
                  fault_call: int) -> np.ndarray:
        """One physical halo attempt under (strategy, wire) -- the ladder's
        probe; plans come from the module cache, so variants replan once."""
        if self.overlap:
            remote_plan = comm_strategies.planned(
                self._split.remote, strategy,
                message_cap_bytes=self.message_cap_bytes,
            )
            remote = execute_numpy(
                remote_plan, v, wire=wire, faults=self.faults,
                fault_call=fault_call, verify=self.verify,
            )
            local = execute_numpy(self._local_plan, v)
            return merge_split_phase(self._split, local, remote)
        plan = comm_strategies.planned(
            self.partition.pattern, strategy,
            message_cap_bytes=self.message_cap_bytes,
        )
        return execute_numpy(
            plan, v, wire=wire, faults=self.faults,
            fault_call=fault_call, verify=self.verify,
        )

    def _guarded_halo(self, v: np.ndarray) -> np.ndarray:
        def attempt(strategy: str, wire: str) -> np.ndarray:
            idx = self._fault_calls
            self._fault_calls += 1
            return self._exchange(v, strategy, wire, idx)

        out, path = faults_mod.run_ladder(
            attempt,
            strategy=self.strategy,
            wire=self.wire,
            health=self.health,
            max_retries=self.max_retries,
            fallback=self.fallback,
            choose_alternative=faults_mod.advise_alternative(
                self.partition.pattern
            ),
        )
        if path is not None:
            self.last_recovery = path.key
        return out

    def __call__(self, v: np.ndarray) -> np.ndarray:
        v = np.asarray(v)
        g, L = self.topo.nranks, self.partition.rows_per_rank
        if v.shape != (g, L):
            raise ValueError(f"expected [{g}, {L}], got {tuple(v.shape)}")
        halo = self.halo(v)
        return _ell_matvec(self._diag_d, self._diag_c, v) + _ell_matvec(
            self._off_d, self._off_c, halo
        )

    @property
    def wire_bytes(self):
        """(intra-pod, inter-pod) wire bytes of one exchange, codec-scaled."""
        if self.overlap:
            ri, rj = wire_mod.scaled_wire_bytes(self._remote_plan, self.wire)
            li, _ = wire_mod.scaled_wire_bytes(self._local_plan, "none")
            return (ri + li, rj)
        return wire_mod.scaled_wire_bytes(self._plan, self.wire)


def build_numpy(matrix, topo: PodTopology, strategy: str = "standard", **kw) -> NumpySpMV:
    """Partition ``matrix`` and wrap it in a :class:`NumpySpMV`."""
    from repro.sparse.partition import partition_csr

    return NumpySpMV(partition_csr(matrix, topo), strategy=strategy, **kw)
