"""Whole-solve on-device Krylov: ONE jitted ``lax.while_loop`` per solve.

The host loops in :mod:`repro.solve.krylov` dispatch every matvec, exchange
and reduction from Python, so at production iteration counts the per-call
host overhead (``T_launch`` in :mod:`repro.core.perfmodel`) bounds latency
regardless of the communication strategy.  This module compiles the ENTIRE
solve -- exchange stages, (masked, possibly split-phase) blocked-ELL SpMV,
hierarchical dot products, convergence and breakdown control flow -- into a
single jitted ``shard_map`` program whose iteration is a ``lax.while_loop``
body: zero host round-trips between iterations, one launch per solve.  This
is the jax analogue of pre-armed triggered-operation schedules (see
``docs/paper_mapping.md``).

Building blocks (all pure per-shard callables + operand pytrees):

* :class:`repro.solve.operator.TraceableOperator` -- the matvec
  (:func:`repro.solve.operator.traceable_operator` lowers either executor
  flavor; overlap mode expresses the split-phase decomposition inside the
  loop body);
* :func:`repro.solve.reductions.traceable_dot` -- the hierarchical
  reduction tree;
* :class:`repro.comm.strategies.TraceableExchange` -- the exchange stages
  (inside the operator).

Semantics mirror the host solvers statement-for-statement -- same breakdown
guards, stall window, best-iterate tracking and one-restart policy -- except
that control flow is data: branches become ``jnp.where`` selects and the
restart re-dispatches the SAME compiled program from the best iterate (the
program's init section IS the host's true-residual recompute).  Residual
histories are bitwise identical across strategies and barrier-vs-overlap
execution on the fused path, and match the host oracle to float32 scalar
precision (the host accumulates its scalars in float64).

Compiled programs live in the module fused-program cache
(``repro.comm.cache_stats().fused_*``), keyed by (pattern fingerprint,
solver, strategy, codec, overlap, kernel flavor, dtype, maxiter, ...): a
whole solve re-runs with zero plan work and zero retracing, and cache
pressure behaves like every other compiled artifact
(:func:`repro.comm.strategies.set_cache_limits`).

``verify=True`` operators carry their wire-integrity checks through the
loop: per-hop violations accumulate (elementwise max) in the loop carry and
surface after the solve as the same structured
:class:`repro.comm.faults.ExchangeIntegrityError` the host path raises.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.comm import strategies as comm_strategies
from repro.comm.faults import (
    ExchangeIntegrityError,
    HealthTracker,
    advise_alternative,
    run_ladder,
)
from repro.solve.krylov import (
    STALL_WINDOW,
    SolveResult,
    _finish_status,
    _recovery_baseline,
)
from repro.solve.operator import traceable_operator
from repro.solve.reductions import traceable_dot

# status codes carried through the loop (mapped back to the host solvers'
# status strings on exit)
_CONV = 0
_MAXITER = 1
_INDEF = 2
_NONFIN = 3
_STAG = 4
_RHO = 5
_OMEGA = 6
_DENOM = 7
_TT = 8

_STATUS_STR = {
    _CONV: "converged",
    _MAXITER: "maxiter",
    _INDEF: "breakdown:indefinite",
    _NONFIN: "breakdown:nonfinite",
    _STAG: "stagnation",
    _RHO: "breakdown:rho",
    _OMEGA: "breakdown:omega",
    _DENOM: "breakdown:denom",
    _TT: "breakdown:tt",
}

#: statuses that trigger the one-restart-from-best-iterate policy (matching
#: the host loops: CG restarts only on nonfinite/stagnation -- indefiniteness
#: ends the solve -- while BiCGStab restarts on every breakdown flavor)
_RESTART = {
    "cg": frozenset({_NONFIN, _STAG}),
    "bicgstab": frozenset({_NONFIN, _STAG, _RHO, _OMEGA, _DENOM, _TT}),
}


def _cg_body(mv, dot, tol, bnorm, hist_len):
    """The CG iteration as a pure ``lax.while_loop`` body (where-selected
    control flow; statement-for-statement twin of :func:`...krylov.cg`)."""
    import jax.numpy as jnp

    def body(c):
        (x, r, p, rs, best, best_x, best_it, it, k, hist, status, done,
         mvc, viols) = c
        Ap, vv = mv(p, mvc)
        mvc = mvc + 1
        viols = jnp.maximum(viols, vv) if vv.size else viols
        pAp = dot(p, Ap)
        indef = pAp <= 0.0
        alpha = rs / jnp.where(indef, jnp.ones_like(pAp), pAp)
        x1 = x + alpha * p
        r1 = r - alpha * Ap
        rs_new = dot(r1, r1)
        relres = jnp.sqrt(jnp.maximum(rs_new, 0.0)) / bnorm
        it1 = jnp.where(indef, it, it + 1)
        conv = (~indef) & (relres <= tol)
        improved = (~indef) & (~conv) & (relres < best)
        best1 = jnp.where(improved, relres, best)
        best_x1 = jnp.where(improved, x1, best_x)
        best_it1 = jnp.where(improved, it1, best_it)
        nonfin = (~indef) & (~conv) & (~jnp.isfinite(relres))
        stall = (~indef) & (~conv) & (~nonfin) & (
            it1 - best_it1 >= STALL_WINDOW
        )
        done1 = indef | conv | nonfin | stall
        status1 = jnp.where(
            indef, _INDEF,
            jnp.where(conv, _CONV,
                      jnp.where(nonfin, _NONFIN,
                                jnp.where(stall, _STAG, _MAXITER))),
        ).astype(jnp.int32)
        hist1 = jnp.where(indef, hist, hist.at[k].set(relres))
        k1 = jnp.where(indef, k, k + 1)
        x2 = jnp.where(indef, x, x1)
        r2 = jnp.where(indef, r, r1)
        # the search direction only matters on the continue path
        cont = ~done1
        p1 = jnp.where(cont, r1 + (rs_new / rs) * p, p)
        rs1 = jnp.where(cont, rs_new, rs)
        return (x2, r2, p1, rs1, best1, best_x1, best_it1, it1, k1, hist1,
                status1, done1, mvc, viols)

    return body


def _bicgstab_body(mv, dot, tol, bnorm, rhat, rhat_nrm, eps, hist_len):
    """The BiCGStab iteration as a pure loop body (twin of
    :func:`...krylov.bicgstab`; ``rhat`` is fixed per dispatch, a restart is
    a fresh dispatch)."""
    import jax.numpy as jnp

    def nz(a):
        return jnp.where(a == 0, jnp.ones_like(a), a)

    def body(c):
        (x, r, p, v, rho, alpha, omega, relprev, best, best_x, best_it, it,
         k, hist, status, done, mvc, viols) = c
        rho_new = dot(rhat, r)
        r_nrm = relprev * bnorm
        bad_rho = jnp.abs(rho_new) <= eps * rhat_nrm * r_nrm
        bad_omega = (~bad_rho) & (jnp.abs(omega) <= eps * jnp.abs(alpha))
        ok1 = (~bad_rho) & (~bad_omega)
        beta = (rho_new / nz(rho)) * (alpha / nz(omega))
        p1 = jnp.where(ok1, r + beta * (p - omega * v), p)
        v1m, vva = mv(p1, mvc)
        v1 = jnp.where(ok1, v1m, v)
        denom = dot(rhat, v1m)
        bad_denom = ok1 & (jnp.abs(denom) <= eps * jnp.abs(rho_new))
        ok2 = ok1 & (~bad_denom)
        alpha1 = jnp.where(ok2, rho_new / nz(denom), alpha)
        s = jnp.where(ok2, r - alpha1 * v1m, r)
        it1 = jnp.where(ok2, it + 1, it)
        snorm = jnp.sqrt(jnp.maximum(dot(s, s), 0.0))
        rel_s = snorm / bnorm
        s_conv = ok2 & (rel_s <= tol)
        t1, vvb = mv(s, mvc + ok1.astype(jnp.int32))
        tt = dot(t1, t1)
        bad_tt = ok2 & (~s_conv) & (tt <= (eps * snorm) ** 2)
        ok3 = ok2 & (~s_conv) & (~bad_tt)
        omega1 = jnp.where(ok3, dot(t1, s) / nz(tt), omega)
        x_sc = x + alpha1 * p1
        x1 = x_sc + omega1 * s
        r1 = s - omega1 * t1
        relres = jnp.sqrt(jnp.maximum(dot(r1, r1), 0.0)) / bnorm
        conv = ok3 & (relres <= tol)
        improved = ok3 & (~conv) & (relres < best)
        best1 = jnp.where(improved, relres, best)
        best_x1 = jnp.where(improved, x1, best_x)
        best_it1 = jnp.where(improved, it1, best_it)
        nonfin = ok3 & (~conv) & (~jnp.isfinite(relres))
        stall = ok3 & (~conv) & (~nonfin) & (it1 - best_it1 >= STALL_WINDOW)
        done1 = (
            bad_rho | bad_omega | bad_denom | s_conv | bad_tt | conv
            | nonfin | stall
        )
        status1 = jnp.where(
            bad_rho, _RHO,
            jnp.where(bad_omega, _OMEGA,
            jnp.where(bad_denom, _DENOM,
            jnp.where(s_conv, _CONV,
            jnp.where(bad_tt, _TT,
            jnp.where(conv, _CONV,
            jnp.where(nonfin, _NONFIN,
            jnp.where(stall, _STAG, _MAXITER))))))),
        ).astype(jnp.int32)
        # a history entry lands only on the paths the host appends on: the
        # half-step convergence exit and the full step (step 8)
        wrote = s_conv | ok3
        hist_val = jnp.where(s_conv, rel_s, relres)
        hist1 = jnp.where(wrote, hist.at[k].set(hist_val), hist)
        k1 = jnp.where(wrote, k + 1, k)
        relprev1 = jnp.where(wrote, hist_val, relprev)
        x2 = jnp.where(s_conv, x_sc, jnp.where(ok3, x1, x))
        r2 = jnp.where(ok3, r1, r)
        p2 = jnp.where(ok1, p1, p)
        rho1 = jnp.where(ok3, rho_new, rho)
        # matvec count matches the host's early-out structure per path
        mvc = mvc + ok1.astype(jnp.int32) + (ok2 & ~s_conv).astype(jnp.int32)
        vv = jnp.maximum(vva, vvb)
        viols = jnp.maximum(viols, vv) if vv.size else viols
        return (x2, r2, p2, v1, rho1, alpha1, omega1, relprev1, best1,
                best_x1, best_it1, it1, k1, hist1, status1, done1, mvc,
                viols)

    return body


def _build_fused(top, shard_dot, solver: str, hist_len: int, eps: float,
                 nviol: int, checkpoint_every: Optional[int] = None,
                 gate=None, resume: bool = False):
    """Compile ONE jitted shard_map program: init + ``lax.while_loop``.

    Signature (all device inputs ``[nranks, ...]`` under ``P(WORLD_AXES)``):
    ``fn(b, x0, tol[g,1], max_it[g,1], *operands)``.  The iteration cap is a
    TRACED scalar -- only the history buffer length is static -- so a restart
    re-dispatch with the remaining budget reuses the same executable.

    ``checkpoint_every=N`` carries a solver-state snapshot in the loop
    carry, refreshed every N clean iterations (zero extra dispatches), and
    appends it to the outputs as four packed arrays -- the fuel for
    host-side resume after an integrity failure.  ``resume=True`` builds the
    companion entry point ``fn(b, ck_vec, ck_f, ck_i, ck_hist, tol,
    max_it, *operands)`` that reconstructs the carry from a checkpoint and
    enters the SAME loop body: no init matvec, history/iteration/matvec
    counters continue exactly where the snapshot left them, so a resumed
    trajectory is bitwise the clean run's continuation.  ``gate`` --
    ``(top_clean, active_calls)`` -- selects per matvec call index between
    the faulted and clean lowerings of the operator, which is what lets a
    ``FaultPlan.active_calls`` schedule interrupt a fused solve mid-loop.
    With all three off, the trace is unchanged from the pre-resume program.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comm.topology import WORLD_AXES
    from repro.compat import shard_map

    ce = checkpoint_every

    def make_mv(ops):
        if gate is None:
            def mv(vec, call_idx):
                return top.matvec_verified(vec, *ops)
        else:
            top_clean, active = gate

            def mv(vec, call_idx):
                wf, vf = top.matvec_verified(vec, *ops)
                wc, vc = top_clean.matvec_verified(vec, *ops)
                use = jnp.zeros((), bool)
                for c in active:
                    use = use | (call_idx == jnp.int32(c))
                w = jnp.where(use, wf, wc)
                vv = jnp.where(use, vf, vc) if vf.size else vf
                return w, vv

        return mv

    def global_clean(jnp_mod, viols):
        """True iff NO shard has recorded a violation.  ``viols`` is the one
        per-shard carry component (each chip verifies its own halo), so any
        checkpoint decision derived from it must be all-reduced -- otherwise
        shards that did not see the corrupted halo keep snapshotting
        post-fault state and the harvested checkpoint mixes iterations."""
        return jax.lax.pmax(jnp_mod.max(viols), WORLD_AXES) == 0.0

    def run_loop(jnp_mod, carry, body, it_idx, done_idx, viol_idx, max_it,
                 snapshot):
        """The while_loop, optionally wrapped with the checkpoint carry."""

        def cond(c):
            return (~c[done_idx]) & (c[it_idx] < max_it)

        if ce is None:
            return jax.lax.while_loop(cond, body, carry), None

        ck0 = snapshot(carry)

        def body_ck(cc):
            inner, ck = cc
            prev_it = inner[it_idx]
            out = body(inner)
            take = (
                (~out[done_idx])
                & (out[it_idx] % jnp_mod.int32(ce) == 0)
                & (out[it_idx] > prev_it)
                & global_clean(jnp_mod, out[viol_idx])
            )
            fresh = snapshot(out)
            new_ck = tuple(
                jnp_mod.where(take, a, b) for a, b in zip(fresh, ck)
            )
            return out, new_ck

        def cond_ck(cc):
            return cond(cc[0])

        return jax.lax.while_loop(cond_ck, body_ck, (carry, ck0))

    def solve_from(b, carry_parts, tolt, maxitt, ops):
        """Shared tail: build the body, run the loop, pack the outputs."""
        tol = tolt[0, 0]
        max_it = maxitt[0, 0]
        mv = make_mv(ops)

        def dot(u, w):
            return shard_dot(u, w)

        (carry, bnorm, rhat, rhat_nrm, fdt) = carry_parts(mv, dot, tol)

        if solver == "cg":
            body = _cg_body(mv, dot, tol, bnorm, hist_len)
            best_x_idx, it_idx = 5, 7
            k_idx, st_idx, done_idx, mv_idx, viol_idx = 8, 10, 11, 12, 13

            def snapshot(c):
                flag = global_clean(jnp, c[viol_idx]).astype(jnp.int32)
                ck_vec = jnp.stack([c[0], c[1], c[2], c[best_x_idx]], axis=1)
                ck_f = jnp.stack([c[3], c[4]])[None].astype(fdt)
                ck_i = jnp.stack(
                    [c[it_idx], c[k_idx], c[6], c[mv_idx], flag]
                )[None].astype(jnp.int32)
                return ck_vec, ck_f, ck_i, c[9][None]
        else:
            body = _bicgstab_body(
                mv, dot, tol, bnorm, rhat, rhat_nrm,
                jnp.asarray(eps, fdt), hist_len,
            )
            best_x_idx, it_idx = 9, 11
            k_idx, st_idx, done_idx, mv_idx, viol_idx = 12, 14, 15, 16, 17

            def snapshot(c):
                flag = global_clean(jnp, c[viol_idx]).astype(jnp.int32)
                ck_vec = jnp.stack(
                    [c[0], c[1], c[2], c[3], c[best_x_idx], rhat], axis=1
                )
                ck_f = jnp.stack(
                    [c[4], c[5], c[6], c[7], c[8], rhat_nrm]
                )[None].astype(fdt)
                ck_i = jnp.stack(
                    [c[it_idx], c[k_idx], c[10], c[mv_idx], flag]
                )[None].astype(jnp.int32)
                return ck_vec, ck_f, ck_i, c[13][None]

        out, ck = run_loop(jnp, carry, body, it_idx, done_idx, viol_idx,
                           max_it, snapshot)

        def tile(a, dt):
            return jnp.reshape(a.astype(dt), (1, 1))

        packed = (
            out[0],                                 # x        [1, L]
            out[best_x_idx],                        # best_x   [1, L]
            out[k_idx + 1][None],                   # hist     [1, hist_len]
            tile(out[it_idx], jnp.int32),           # it       [1, 1]
            tile(out[k_idx], jnp.int32),            # entries  [1, 1]
            tile(out[st_idx], jnp.int32),           # status   [1, 1]
            tile(out[mv_idx], jnp.int32),           # matvecs  [1, 1]
            out[viol_idx][None],                    # viols    [1, nviol]
        )
        if ce is not None:
            packed = packed + tuple(ck)
        return packed

    def program(b, x0, tolt, maxitt, *ops):
        fdt = b.dtype

        def carry_parts(mv, dot, tol):
            one = jnp.asarray(1.0, fdt)
            Ax, vv0 = mv(x0, jnp.int32(0))
            r = b - Ax
            bnorm = jnp.sqrt(jnp.maximum(dot(b, b), 0.0))
            rs = dot(r, r)
            rel0 = jnp.sqrt(jnp.maximum(rs, 0.0)) / bnorm
            hist = jnp.full((hist_len,), jnp.nan, fdt).at[0].set(rel0)
            viols = jnp.zeros((nviol,), jnp.float32)
            if vv0.size:
                viols = jnp.maximum(viols, vv0)
            done0 = rel0 <= tol
            status0 = jnp.where(done0, _CONV, _MAXITER).astype(jnp.int32)
            i0 = jnp.int32(0)
            k0 = jnp.int32(1)
            mv0 = jnp.int32(1)
            if solver == "cg":
                #        x,  r, p, rs, best, best_x, best_it, it, k
                carry = (x0, r, r, rs, rel0, x0, i0, i0, k0, hist, status0,
                         done0, mv0, viols)
                return carry, bnorm, None, None, fdt
            zero = jnp.zeros_like(b)
            #        x,  r, p,    v,    rho, alpha, omega, relprev, best,
            #        best_x, best_it, it, k
            carry = (x0, r, zero, zero, one, one, one, rel0, rel0, x0, i0,
                     i0, k0, hist, status0, done0, mv0, viols)
            return carry, bnorm, r, rel0 * bnorm, fdt

        return solve_from(b, carry_parts, tolt, maxitt, ops)

    def program_resume(b, ckv, ckf, cki, ckh, tolt, maxitt, *ops):
        fdt = b.dtype

        def carry_parts(mv, dot, tol):
            bnorm = jnp.sqrt(jnp.maximum(dot(b, b), 0.0))
            it = cki[0, 0]
            k = cki[0, 1]
            best_it = cki[0, 2]
            mvc = cki[0, 3]
            hist = ckh[0]
            viols = jnp.zeros((nviol,), jnp.float32)
            done0 = jnp.zeros((), bool)
            status0 = jnp.asarray(_MAXITER, jnp.int32)
            x, r, p = ckv[:, 0], ckv[:, 1], ckv[:, 2]
            if solver == "cg":
                rs, best = ckf[0, 0], ckf[0, 1]
                best_x = ckv[:, 3]
                carry = (x, r, p, rs, best, best_x, best_it, it, k, hist,
                         status0, done0, mvc, viols)
                return carry, bnorm, None, None, fdt
            rho, alpha, omega = ckf[0, 0], ckf[0, 1], ckf[0, 2]
            relprev, best = ckf[0, 3], ckf[0, 4]
            v, best_x, rhat = ckv[:, 3], ckv[:, 4], ckv[:, 5]
            carry = (x, r, p, v, rho, alpha, omega, relprev, best, best_x,
                     best_it, it, k, hist, status0, done0, mvc, viols)
            return carry, bnorm, rhat, ckf[0, 5], fdt

        return solve_from(b, carry_parts, tolt, maxitt, ops)

    fn = program_resume if resume else program
    n_in = (7 if resume else 4) + len(top.operands)
    n_out = 8 if ce is None else 12
    return jax.jit(
        shard_map(
            fn,
            mesh=top.mesh,
            in_specs=(P(WORLD_AXES),) * n_in,
            out_specs=(P(WORLD_AXES),) * n_out,
            check_vma=False,
        )
    )


# ---------------------------------------------------------------------------
# Host wrapper: cache, dispatch, restart policy, SolveResult assembly
# ---------------------------------------------------------------------------


def _fused_entry(op, solver: str, maxiter: int, dtype, compressor,
                 checkpoint_every: Optional[int] = None,
                 resume: bool = False):
    """Fetch (or build) the compiled whole-solve program for ``op``.

    The key is derived from the operator's configuration alone -- the
    expensive lowering (:func:`traceable_operator`: device transfer of plan
    arrays, blocks, masks) runs only on a miss.  ``resume=True`` fetches
    the checkpoint-resume companion entry point (requires
    ``checkpoint_every``); the two share a key prefix but compile
    separately.
    """
    faults = getattr(op, "faults", None)
    mesh = getattr(op, "mesh", None)
    mesh_key = comm_strategies._mesh_key(mesh) if mesh is not None else None
    key = (
        "fused", solver, op.partition.pattern.fingerprint(), op.strategy,
        op.wire, bool(op.overlap), bool(getattr(op, "use_pallas", False)),
        bool(getattr(op, "verify", False)),
        faults.fingerprint() if faults is not None else None,
        op.message_cap_bytes, mesh_key, int(maxiter), str(dtype),
        None if compressor is None else str(compressor),
        checkpoint_every, "resume" if resume else "fwd",
    )

    def build():
        top = traceable_operator(op)
        gate = None
        if faults is not None and faults.active_calls is not None:
            # call-indexed fault schedule: trace BOTH lowerings and select
            # per matvec call, so a transient plan can interrupt the loop
            # mid-solve (operand layouts are identical -- fault masks are
            # trace constants and plan arrays ignore faults)
            top_clean = traceable_operator(dataclasses.replace(op, faults=None))
            gate = (top_clean, faults.active_calls)
        shard_dot = traceable_dot(compressor)
        nviol = len(top.verifier.checks) if top.verifier is not None else 1
        eps = float(np.finfo(dtype).eps)
        hist_len = int(maxiter) + 1
        fn = _build_fused(top, shard_dot, solver, hist_len, eps, nviol,
                          checkpoint_every=checkpoint_every, gate=gate,
                          resume=resume)
        return fn, top

    return comm_strategies.fused_cached(key, build)


def _dispatch(fn, top, b_dev, x0_dev, tol: float, max_it: int, dtype):
    import jax.numpy as jnp

    g = top.topo.nranks
    tolt = jnp.full((g, 1), tol, dtype)
    maxitt = jnp.full((g, 1), max_it, jnp.int32)
    outs = fn(b_dev, x0_dev, tolt, maxitt, *top.operands)
    x, best_x, hist, it, k, status, mvc, viols = outs[:8]
    if top.verifier is not None:
        top.verifier.raise_viols(np.asarray(viols))
    k = int(np.asarray(k)[0, 0])
    return (
        x,
        best_x,
        [float(h) for h in np.asarray(hist)[0, :k]],
        int(np.asarray(it)[0, 0]),
        int(np.asarray(status)[0, 0]),
        int(np.asarray(mvc)[0, 0]),
    )


class _Checkpoint(NamedTuple):
    """Harvested solver-state snapshot (device arrays + host counters)."""

    vec: object  # [g, nvec, L]
    f: object    # [g, nf]
    i: object    # [g, 5] int32: it, k, best_it, mvc, valid
    hist: object  # [g, hist_len]
    it: int
    k: int
    mvc: int


def _harvest(prev: Optional[_Checkpoint], outs) -> Optional[_Checkpoint]:
    """Keep the newest VALID checkpoint across dispatches (a failed resume
    attempt may still have advanced past the one it started from)."""
    ckv, ckf, cki, ckh = outs[8:12]
    i_np = np.asarray(cki)
    if int(i_np[0, 4]) != 1:
        return prev
    it = int(i_np[0, 0])
    if prev is not None and prev.it >= it:
        return prev
    return _Checkpoint(ckv, ckf, cki, ckh, it=it, k=int(i_np[0, 1]),
                       mvc=int(i_np[0, 3]))


def _raw_forward(fn, top, b_dev, x0_dev, tol: float, max_it: int, dtype):
    import jax.numpy as jnp

    g = top.topo.nranks
    tolt = jnp.full((g, 1), tol, dtype)
    maxitt = jnp.full((g, 1), max_it, jnp.int32)
    return fn(b_dev, x0_dev, tolt, maxitt, *top.operands)


def _raw_resume(fn, top, b_dev, ck: _Checkpoint, tol: float, max_it: int,
                dtype):
    import jax.numpy as jnp

    g = top.topo.nranks
    tolt = jnp.full((g, 1), tol, dtype)
    maxitt = jnp.full((g, 1), max_it, jnp.int32)
    return fn(b_dev, ck.vec, ck.f, ck.i, ck.hist, tolt, maxitt, *top.operands)


def _viol_error(top, viols_np):
    """The structured error a violation vector encodes, or None if clean."""
    if top.verifier is None:
        return None
    try:
        top.verifier.raise_viols(viols_np)
    except ExchangeIntegrityError as e:
        return e
    return None


def _unpack(outs):
    hist_k = int(np.asarray(outs[4])[0, 0])
    return (
        outs[0],
        outs[1],
        [float(h) for h in np.asarray(outs[2])[0, :hist_k]],
        int(np.asarray(outs[3])[0, 0]),
        int(np.asarray(outs[5])[0, 0]),
        int(np.asarray(outs[6])[0, 0]),
    )


def _fused_solve(op, b, x0, tol: float, maxiter: int, reductions,
                 solver: str, checkpoint_every: Optional[int] = None
                 ) -> SolveResult:
    import jax.numpy as jnp

    compressor = getattr(reductions, "compressor", None)
    b = np.asarray(b)
    g, L = op.topo.nranks, op.rows_per_rank
    if b.shape != (g, L):
        raise ValueError(f"b must be [{g}, {L}], got {tuple(b.shape)}")
    if checkpoint_every is not None and checkpoint_every < 1:
        raise ValueError(
            f"checkpoint_every must be >= 1, got {checkpoint_every}"
        )
    rc0 = _recovery_baseline(op)
    if not np.any(b):
        # mirror the host solvers' zero-rhs early return (same
        # _finish_status routing)
        return SolveResult(x=np.zeros_like(b), converged=True, iterations=0,
                           residuals=(0.0,), matvecs=0,
                           status=_finish_status("converged", 0, op, rc0))
    dtype = b.dtype
    b_dev = jnp.asarray(b)
    x0_arr = (
        np.zeros_like(b) if x0 is None
        else np.array(x0, dtype=dtype, copy=True)
    )
    # the program always runs the init matvec (for x0=0 it computes
    # b - A@0 = b exactly); the host loops only count it when x0 is given
    init_mv_adjust = 1 if x0 is None else 0
    if checkpoint_every is not None:
        return _fused_solve_resumable(
            op, b, b_dev, x0_arr, tol, maxiter, dtype, compressor, solver,
            checkpoint_every, rc0, init_mv_adjust,
        )
    fn, top = _fused_entry(op, solver, maxiter, dtype, compressor)

    x, best_x, hist, it, status, mvc, = _dispatch(
        fn, top, b_dev, jnp.asarray(x0_arr), tol, maxiter, dtype
    )
    restarts = 0
    matvecs = mvc - init_mv_adjust
    if status in _RESTART[solver]:
        bad = _STATUS_STR[status]
        restarts = 1
        # one restart from the best iterate: the program's init section IS
        # the host's true-residual recompute (r = b - A x_best), and its
        # hist[0] is the host's restart history entry
        x, _, hist2, it2, status2, mvc2 = _dispatch(
            fn, top, b_dev, best_x, tol, maxiter - it, dtype
        )
        hist = hist + hist2
        it = it + it2
        matvecs += mvc2
        if not np.isfinite(hist2[0]):
            # the host checks the recomputed residual before re-entering
            # the loop; keep the original breakdown reason
            status_str, converged = bad, False
        elif status2 == _CONV:
            status_str, converged = "converged", True
        elif status2 == _MAXITER:
            status_str, converged = "maxiter", False
        else:
            # second trip ends the solve with the new reason (no re-restart)
            status_str, converged = _STATUS_STR[status2], False
    else:
        status_str = _STATUS_STR[status]
        converged = status == _CONV

    return SolveResult(
        x=np.asarray(x),
        converged=converged,
        iterations=it,
        residuals=tuple(hist),
        matvecs=matvecs,
        status=_finish_status(status_str, restarts, op, rc0),
        restarts=restarts,
    )


def _fused_solve_resumable(op, b, b_dev, x0_arr, tol: float, maxiter: int,
                           dtype, compressor, solver: str, ce: int, rc0,
                           init_mv_adjust: int) -> SolveResult:
    """The checkpoint/resume host wrapper around the fused program.

    A clean dispatch behaves exactly like the legacy path (the checkpoint
    rides the loop carry -- zero extra dispatches).  On an integrity
    failure the wrapper harvests the newest pre-fault checkpoint and runs
    the recovery ladder where each attempt RESUMES the fused program --
    first on the same (strategy, codec), then demoted, then re-advised --
    so recovery loses at most ``checkpoint_every`` iterations.  If the
    ladder is exhausted it falls back to the host loop (which carries its
    own per-halo ladder) from the same checkpoint.  ``SolveResult.status``
    records ``+resume:<n>``.
    """
    import jax.numpy as jnp

    fn, top = _fused_entry(op, solver, maxiter, dtype, compressor, ce)
    outs = _raw_forward(fn, top, b_dev, jnp.asarray(x0_arr), tol, maxiter,
                        dtype)
    state = {"ck": _harvest(None, outs), "used": False}
    err = _viol_error(top, np.asarray(outs[7]))
    resumes = 0
    final_op = op
    if err is not None:
        health = getattr(op, "health", None)
        if health is None:
            health = HealthTracker()
        health.record_failure(err)

        def attempt(s: str, w: str):
            vop = (
                op if (s == op.strategy and w == op.wire)
                else dataclasses.replace(op, strategy=s, wire=w)
            )
            cur = state["ck"]
            if cur is not None:
                fnv, topv = _fused_entry(vop, solver, maxiter, dtype,
                                         compressor, ce, resume=True)
                o = _raw_resume(fnv, topv, b_dev, cur, tol, maxiter, dtype)
            else:
                fnv, topv = _fused_entry(vop, solver, maxiter, dtype,
                                         compressor, ce)
                o = _raw_forward(fnv, topv, b_dev, jnp.asarray(x0_arr), tol,
                                 maxiter, dtype)
            state["ck"] = _harvest(state["ck"], o)
            e = _viol_error(topv, np.asarray(o[7]))
            if e is not None:
                raise e
            state["used"] = cur is not None
            return o, vop

        try:
            (outs, final_op), _path = run_ladder(
                attempt,
                strategy=op.strategy,
                wire=op.wire,
                health=health,
                max_retries=getattr(op, "max_retries", 1),
                fallback=getattr(op, "fallback", True),
                choose_alternative=advise_alternative(op.partition.pattern),
            )
        except ExchangeIntegrityError:
            return _host_resume_fallback(op, b, tol, maxiter, solver,
                                         state["ck"], rc0, init_mv_adjust)
        resumes = 1 if state["used"] else 0

    x, best_x, hist, it, status, mvc = _unpack(outs)
    restarts = 0
    matvecs = mvc - init_mv_adjust
    if status in _RESTART[solver]:
        bad = _STATUS_STR[status]
        restarts = 1
        fnf, topf = _fused_entry(final_op, solver, maxiter, dtype,
                                 compressor, ce)
        o2 = _raw_forward(fnf, topf, b_dev, best_x, tol, maxiter - it, dtype)
        e2 = _viol_error(topf, np.asarray(o2[7]))
        if e2 is not None:
            raise e2
        x, _, hist2, it2, status2, mvc2 = _unpack(o2)
        hist = hist + hist2
        it = it + it2
        matvecs += mvc2
        if not np.isfinite(hist2[0]):
            status_str, converged = bad, False
        elif status2 == _CONV:
            status_str, converged = "converged", True
        elif status2 == _MAXITER:
            status_str, converged = "maxiter", False
        else:
            status_str, converged = _STATUS_STR[status2], False
    else:
        status_str = _STATUS_STR[status]
        converged = status == _CONV

    if resumes:
        status_str += f"+resume:{resumes}"
    return SolveResult(
        x=np.asarray(x),
        converged=converged,
        iterations=it,
        residuals=tuple(hist),
        matvecs=matvecs,
        status=_finish_status(status_str, restarts, op, rc0),
        restarts=restarts,
    )


def _host_resume_fallback(op, b, tol: float, maxiter: int, solver: str,
                          ck: Optional[_Checkpoint], rc0,
                          init_mv_adjust: int) -> SolveResult:
    """Ladder-exhausted last resort: continue on the host loop (whose
    ``halo`` carries its own per-exchange ladder) from the checkpoint,
    stitching the fused history prefix onto the host continuation."""
    from repro.solve import krylov

    host = krylov.cg if solver == "cg" else krylov.bicgstab
    if ck is None:
        res = host(op, b, tol=tol, maxiter=maxiter)
        base = res.status.split("+")[0]
        return dataclasses.replace(
            res, status=_finish_status(base + "+resume:0", res.restarts, op,
                                       rc0),
        )
    x0h = np.asarray(ck.vec)[:, 0, :]
    prefix = [float(h) for h in np.asarray(ck.hist)[0, :ck.k]]
    res = host(op, b, x0=x0h, tol=tol, maxiter=maxiter - ck.it)
    base = res.status.split("+")[0]
    return SolveResult(
        x=np.asarray(res.x),
        converged=res.converged,
        iterations=ck.it + res.iterations,
        residuals=tuple(prefix + list(res.residuals[1:])),
        matvecs=ck.mvc - init_mv_adjust + res.matvecs,
        status=_finish_status(base + "+resume:1", res.restarts, op, rc0),
        restarts=res.restarts,
    )


def fused_cg(op, b, x0=None, tol: float = 1e-6, maxiter: int = 500,
             reductions=None,
             checkpoint_every: Optional[int] = None) -> SolveResult:
    """Whole-solve CG: one jitted ``lax.while_loop`` per solve.

    Drop-in for :func:`repro.solve.krylov.cg` (same contract, same
    ``SolveResult`` fields); ``op`` may be either executor flavor.  The
    compiled program is cached per (pattern, strategy, codec, overlap,
    kernel flavor, dtype, maxiter) -- see ``repro.comm.cache_stats()``.
    ``reductions`` only contributes its inter-pod compressor (the
    hierarchical tree itself is traced inline); pass the
    :class:`~repro.solve.reductions.DeviceReductions` you would hand the
    host loop.

    ``checkpoint_every=N`` arms fault tolerance: the loop carries a
    solver-state snapshot refreshed every N clean iterations, and an
    ``ExchangeIntegrityError`` surfaced by a ``verify=True`` operator is
    recovered host-side -- the ladder re-runs the fused program from the
    checkpoint on a healthy (strategy, codec), falling back to the host
    loop -- losing at most N iterations (``status`` gains ``+resume:<n>``).
    Fault-free solves behave identically either way.
    """
    return _fused_solve(op, b, x0, tol, maxiter, reductions, "cg",
                        checkpoint_every)


def fused_bicgstab(op, b, x0=None, tol: float = 1e-6, maxiter: int = 500,
                   reductions=None,
                   checkpoint_every: Optional[int] = None) -> SolveResult:
    """Whole-solve BiCGStab: one jitted ``lax.while_loop`` per solve.

    Drop-in for :func:`repro.solve.krylov.bicgstab`; see :func:`fused_cg`
    (including ``checkpoint_every`` checkpoint/resume fault tolerance).
    """
    return _fused_solve(op, b, x0, tol, maxiter, reductions, "bicgstab",
                        checkpoint_every)


FUSED_SOLVERS = {"cg": fused_cg, "bicgstab": fused_bicgstab}
