"""Well-posed linear systems on the generators' communication structure.

The synthetic matrices in :mod:`repro.sparse.matrices` reproduce the
*communication regimes* of the paper's SuiteSparse suite, but their values
are i.i.d. normal -- fine for one SpMV, hopeless for an iterative solve (CG
needs symmetric positive definite, BiCGStab at least needs a spectrum away
from zero).  These transforms keep (a superset of) the sparsity -- and hence
the exchange pattern character -- while making the values solvable:

* :func:`spd_system` -- graph-Laplacian-style symmetrization: SPD and
  diagonally dominant; the CG workload.
* :func:`shifted_system` -- diagonal shift to strict row dominance, original
  (generally nonsymmetric) off-diagonals kept; the BiCGStab workload.
"""

from __future__ import annotations

import numpy as np

from repro.sparse.matrices import CSRMatrix, _from_coo


def _to_coo(A: CSRMatrix):
    rows = np.repeat(np.arange(A.n), np.diff(A.indptr))
    return rows, A.indices.astype(np.int64), A.data.astype(np.float64)


def spd_system(A: CSRMatrix, shift: float = 1.0) -> CSRMatrix:
    """Symmetric positive-definite matrix on ``A``'s symmetrized sparsity.

    Off-diagonal ``(i, j)`` becomes ``-(|a_ij| + |a_ji|) / 2`` (negative,
    symmetric); the diagonal becomes ``shift + sum_j |offdiag_ij|`` -- a
    weighted graph Laplacian plus ``shift * I``, hence strictly diagonally
    dominant with positive diagonal => SPD.  The sparsity is the symmetric
    closure of ``A``'s, so the induced exchange pattern keeps the regime's
    structure (banded, stencil, random) with at most the mirrored entries
    added.
    """
    if shift <= 0:
        raise ValueError(f"shift must be > 0, got {shift}")
    rows, cols, vals = _to_coo(A)
    # symmetrize |A| via (|A| + |A|^T) / 2 on the union sparsity
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    v2 = np.concatenate([np.abs(vals), np.abs(vals)]) * 0.5
    off = r2 != c2
    W = _from_coo(A.n, r2[off], c2[off], v2[off], duplicates="sum")
    wrows = np.repeat(np.arange(W.n), np.diff(W.indptr))
    degree = np.zeros(A.n, dtype=np.float64)
    np.add.at(degree, wrows, W.data.astype(np.float64))
    rows3 = np.concatenate([wrows, np.arange(A.n)])
    cols3 = np.concatenate([W.indices.astype(np.int64), np.arange(A.n)])
    vals3 = np.concatenate([-W.data.astype(np.float64), shift + degree])
    return _from_coo(A.n, rows3, cols3, vals3, duplicates="sum")


def shifted_system(A: CSRMatrix, margin: float = 0.5) -> CSRMatrix:
    """Strictly row-diagonally-dominant (generally nonsymmetric) system.

    Keeps every off-diagonal of ``A`` and sets the diagonal to
    ``margin + sum_j |a_ij|`` (row-wise), which bounds every eigenvalue away
    from zero (Gershgorin) without touching the communication structure.
    """
    if margin <= 0:
        raise ValueError(f"margin must be > 0, got {margin}")
    rows, cols, vals = _to_coo(A)
    off = rows != cols
    rows, cols, vals = rows[off], cols[off], vals[off]
    rowsum = np.zeros(A.n, dtype=np.float64)
    np.add.at(rowsum, rows, np.abs(vals))
    diag = np.arange(A.n)
    return _from_coo(
        A.n,
        np.concatenate([rows, diag]),
        np.concatenate([cols, diag]),
        np.concatenate([vals, margin + rowsum]),
        duplicates="sum",
    )
