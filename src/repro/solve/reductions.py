"""Node-aware reductions for the Krylov solvers.

Every dot product / norm inside :mod:`repro.solve.krylov` goes through one of
these backends so the solver's scalar traffic follows the paper's hierarchy:
reduce on the cheap on-pod fabric first, cross the expensive inter-pod hop
exactly once per pod.

* :class:`DeviceReductions` -- jitted ``shard_map`` program over the exchange
  mesh calling :func:`repro.comm.hierarchical.dot_hierarchical` (optionally
  int8-compressed on the inter-pod hop via
  :class:`repro.comm.compression.Compressor`).  This is the serving-path
  deployment of the hierarchical-collective layer that previously only the
  LM-training loop used.
* :class:`NumpyReductions` -- jax-free twin with the SAME summation tree
  (rank partials -> per-pod sums -> global sum) in float64.  Deterministic,
  so residual histories on the numpy executor are bitwise reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.comm import compression
from repro.comm.topology import LOCAL_AXIS, POD_AXIS, WORLD_AXES, PodTopology


@dataclasses.dataclass(frozen=True)
class NumpyReductions:
    """Hierarchical dot products in numpy (rank -> pod -> world order).

    Partials are accumulated in float64 regardless of the vector dtype: the
    solver's scalars (step sizes, residual norms) live at host level and the
    extra precision costs nothing while keeping float32 operands convergent
    to tight tolerances.
    """

    topo: PodTopology

    def dot(self, x: np.ndarray, y: np.ndarray) -> float:
        """``<x, y>`` for ``[nranks, L]`` operands, hierarchical order."""
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        part = (x * y).reshape(self.topo.nranks, -1).sum(axis=1)  # per rank
        pods = part.reshape(self.topo.npods, self.topo.ppn).sum(axis=1)
        return float(pods.sum())

    def norm(self, x: np.ndarray) -> float:
        return float(np.sqrt(max(self.dot(x, x), 0.0)))


def traceable_dot(compressor: Optional[compression.Compressor] = None):
    """Per-shard hierarchical dot product for embedding in traced programs.

    Returns a pure callable ``dot(x, y) -> scalar`` over per-shard ``[1, L]``
    operands -- the exact reduction tree :class:`DeviceReductions` wraps in
    its own ``shard_map`` (rank partial, on-pod ``psum``, one inter-pod hop,
    optionally int8-compressed), but exposed raw so a fused solver can call
    it inside a ``lax.while_loop`` body without leaving the trace.  The
    result is replicated across shards.
    """
    from repro.comm.hierarchical import dot_hierarchical

    def dot(x, y):
        return dot_hierarchical(x[0], y[0], POD_AXIS, LOCAL_AXIS, compressor)

    return dot


class DeviceReductions:
    """Hierarchical dot products as a jitted ``shard_map`` collective.

    One compiled program per instance: ``[nranks, L] x [nranks, L] -> scalar``
    where each chip reduces its shard, the partials all-reduce over the
    on-pod axis, and one scalar per pod crosses the inter-pod axis
    (:func:`repro.comm.hierarchical.dot_hierarchical`).

    ``compressor`` quantizes the inter-pod hop int8 (error ~0.4% per
    reduction -- documented as perturbing Krylov convergence; keep it off
    unless the surrounding system already runs compressed reductions).
    """

    def __init__(
        self,
        topo: PodTopology,
        mesh=None,
        compressor: Optional[compression.Compressor] = None,
    ):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.comm.strategies import _default_mesh
        from repro.compat import shard_map

        self.topo = topo
        self.mesh = mesh if mesh is not None else _default_mesh(topo)
        self.compressor = compressor
        shard_dot = traceable_dot(compressor)

        def body(x, y):
            return jnp.reshape(shard_dot(x, y), (1, 1))

        self._fn = jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=(P(WORLD_AXES), P(WORLD_AXES)),
                out_specs=P(WORLD_AXES),
            )
        )

    def dot(self, x, y) -> float:
        """``<x, y>`` for ``[nranks, L]`` operands (every rank's copy of the
        replicated result is identical; rank 0's is returned)."""
        return float(np.asarray(self._fn(x, y))[0, 0])

    def norm(self, x) -> float:
        return float(np.sqrt(max(self.dot(x, x), 0.0)))

    def traceable(self):
        """This backend's reduction tree as a pure per-shard callable
        (:func:`traceable_dot` with the same compressor)."""
        return traceable_dot(self.compressor)


def default_reductions(op) -> "NumpyReductions | DeviceReductions":
    """Pick the reduction backend matching an operator's executor.

    :class:`repro.sparse.spmv.DistributedSpMV` gets the device collectives
    (on its own mesh); anything else -- notably the jax-free
    :class:`repro.solve.operator.NumpySpMV` -- gets the numpy twin.
    """
    from repro.sparse.spmv import DistributedSpMV

    if isinstance(op, DistributedSpMV):
        return DeviceReductions(op.topo, mesh=op.mesh)
    return NumpyReductions(op.topo)
