"""Distributed Krylov solver workload over the node-aware exchange.

The iterative-solver layer the paper's models are ultimately judged against:
CG / BiCGStab re-running ONE cached exchange plan per iteration
(:mod:`repro.solve.krylov`), matvecs on the device executor
(:class:`repro.sparse.spmv.DistributedSpMV`, ``overlap=True`` supported) or
the jax-free numpy executor (:class:`repro.solve.operator.NumpySpMV`), and
scalar reductions through the node-aware hierarchical collectives
(:mod:`repro.solve.reductions`).  Whole-solve strategy selection -- setup
amortization over iterations -- lives in
:func:`repro.core.advisor.advise_solver`.
"""

from repro.solve.fused import fused_bicgstab, fused_cg
from repro.solve.krylov import (
    MATVECS_PER_ITER,
    REDUCTIONS_PER_ITER,
    SolveResult,
    bicgstab,
    cg,
)
from repro.solve.operator import (
    NumpySpMV,
    TraceableOperator,
    build_numpy,
    traceable_operator,
)
from repro.solve.problems import shifted_system, spd_system
from repro.solve.reductions import (
    DeviceReductions,
    NumpyReductions,
    default_reductions,
    traceable_dot,
)

__all__ = [
    "MATVECS_PER_ITER",
    "REDUCTIONS_PER_ITER",
    "SolveResult",
    "bicgstab",
    "cg",
    "fused_bicgstab",
    "fused_cg",
    "NumpySpMV",
    "TraceableOperator",
    "build_numpy",
    "traceable_operator",
    "shifted_system",
    "spd_system",
    "DeviceReductions",
    "NumpyReductions",
    "default_reductions",
    "traceable_dot",
]
