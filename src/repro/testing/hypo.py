"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface the
test-suite uses.

The CI image does not ship ``hypothesis`` and installing packages is not an
option, so the tests import it behind a ``try`` and fall back to this shim:

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from repro.testing.hypo import given, settings, st

Semantics: ``@given(**strategies)`` runs the decorated test once per drawn
example, ``max_examples`` (from ``@settings``) times, drawing from a
deterministic per-test RNG seeded by the test's qualified name — so runs are
reproducible and shrinking is simply "the failing example is printed".
Only the strategies the suite uses are provided: ``integers``,
``floats`` and ``sampled_from``.
"""

from __future__ import annotations

import functools
import inspect
import zlib
from types import SimpleNamespace
from typing import Any, Callable, Sequence

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25
_MAX_EXAMPLES_ATTR = "_hypo_max_examples"


class SearchStrategy:
    """A drawable value source: ``draw(rng) -> value``."""

    def __init__(self, draw: Callable[[np.random.Generator], Any], label: str):
        self._draw = draw
        self.label = label

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return self.label


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})",
    )


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        f"floats({min_value}, {max_value})",
    )


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    items = list(elements)
    if not items:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(
        lambda rng: items[int(rng.integers(len(items)))],
        f"sampled_from({items!r})",
    )


st = SimpleNamespace(integers=integers, floats=floats, sampled_from=sampled_from)


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Record ``max_examples``; ``deadline`` and anything else is ignored."""

    def deco(fn):
        setattr(fn, _MAX_EXAMPLES_ATTR, max_examples)
        return fn

    return deco


def given(**strategies: SearchStrategy):
    """Run the test once per example; works with @settings above or below."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(
                wrapper,
                _MAX_EXAMPLES_ATTR,
                getattr(fn, _MAX_EXAMPLES_ATTR, _DEFAULT_MAX_EXAMPLES),
            )
            seed = zlib.crc32(getattr(fn, "__qualname__", fn.__name__).encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception:
                    print(f"Falsifying example ({i + 1}/{n}): {drawn!r}")
                    raise

        # Hide the drawn parameters from pytest's fixture resolution: the
        # wrapper's visible signature is the original minus given() kwargs.
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items() if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        return wrapper

    return deco
