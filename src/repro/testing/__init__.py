"""Test-support utilities vendored with the library (no external deps)."""

from repro.testing.hypo import given, settings, st

__all__ = ["given", "settings", "st"]
