"""Test-support utilities vendored with the library (no external deps)."""

from repro.testing.hypo import given, settings, st
from repro.testing.traces import ARRIVAL_PATTERNS, make_trace, zipf_weights

__all__ = [
    "ARRIVAL_PATTERNS",
    "given",
    "make_trace",
    "settings",
    "st",
    "zipf_weights",
]
