"""Seeded traffic-trace generators for the serving simulator.

Every generator is a pure function of its ``seed`` (via
``np.random.default_rng``), so a trace -- and therefore the entire
simulation it drives -- replays bit-for-bit.  Fingerprint popularity is
Zipf-skewed (``weight(i) = 1 / (i + 1)**skew`` over the class list), the
regime the plan/compute/exchange LRU caches are designed for: a few hot
classes that should stay resident and a long tail that churns.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Request

ARRIVAL_PATTERNS = ("poisson", "burst", "uniform")


def zipf_weights(n: int, skew: float = 1.0) -> np.ndarray:
    """Normalized Zipf popularity over ``n`` classes (``skew=0`` = uniform)."""
    if n < 1:
        raise ValueError(f"need at least one class, got {n}")
    w = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), skew)
    return w / w.sum()


def make_trace(
    seed: int,
    n_requests: int,
    fps: Sequence[str],
    *,
    pattern: str = "poisson",
    rate: float = 1000.0,
    skew: float = 1.0,
    burst: int = 8,
    kinds: Optional[Dict[str, str]] = None,
    t0: float = 0.0,
) -> List[Request]:
    """A seeded request trace over fingerprint classes ``fps``.

    ``pattern`` shapes the arrival process at mean ``rate`` requests/s:

    * ``"poisson"`` -- exponential inter-arrival gaps (open-system load);
    * ``"burst"`` -- groups of ``burst`` simultaneous arrivals, groups
      spaced to preserve the mean rate (the coalescer's best case and the
      admission controller's worst);
    * ``"uniform"`` -- evenly spaced arrivals (steady trickle; the
      coalescing window, not lane depth, decides batch width).

    Fingerprints draw i.i.d. from :func:`zipf_weights` over ``fps`` in the
    given order (first = hottest).  ``kinds`` optionally maps fp -> request
    kind (default ``"spmv"``).
    """
    if pattern not in ARRIVAL_PATTERNS:
        raise ValueError(f"pattern must be one of {ARRIVAL_PATTERNS}, got {pattern!r}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    rng = np.random.default_rng(seed)
    fps = list(fps)
    picks = rng.choice(len(fps), size=n_requests, p=zipf_weights(len(fps), skew))
    if pattern == "poisson":
        gaps = rng.exponential(1.0 / rate, size=n_requests)
        arrivals = t0 + np.cumsum(gaps)
    elif pattern == "uniform":
        arrivals = t0 + (np.arange(n_requests, dtype=np.float64) + 1.0) / rate
    else:  # burst: group g lands together at the mean time of its members
        group = np.arange(n_requests) // burst
        arrivals = t0 + (group + 1.0) * (burst / rate)
    kinds = kinds or {}
    return [
        Request(
            arrival=float(arrivals[i]),
            rid=i,
            fp=fps[int(picks[i])],
            kind=kinds.get(fps[int(picks[i])], "spmv"),
        )
        for i in range(n_requests)
    ]
