"""Continuous batching over cached plans.

The batcher turns per-fingerprint FIFO lanes into *batches*: contiguous
prefixes of one lane, coalesced up to a per-class width cap and dispatched
either when the lane is full or when its oldest request has waited the
coalescing ``window``.  Each batch is advised as ONE exchange at the
combined payload width (``base_width * n_requests``), so the strategy/codec
choice sees the batched byte terms the paper's model flips on -- coalescing
trades per-request latency (bounded by the window) for fewer, larger
messages, which is exactly the message-count vs. message-size axis of
Table 7.

Scheduling invariants (property-tested in ``tests/test_serving.py``):

* width never exceeds ``max_width`` or the memory budget
  (``n * bytes_per_request <= memory_budget``);
* FIFO within a fingerprint class (batches are lane prefixes);
* no request waits past its coalescing deadline once the executor keeps up
  (a ripe lane is always preferred over an unripe one, oldest deadline
  first);
* all decisions are pure functions of (queue contents, virtual now), so a
  seeded simulation replays bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.advisor import EXECUTABLE_STRATEGY, Advice, advise_stats

from .queue import RequestQueue
from .request import Request, WorkloadClass


@dataclasses.dataclass(frozen=True)
class Batch:
    """One coalesced dispatch: a FIFO prefix of a single fingerprint lane."""

    fp: str
    requests: Tuple[Request, ...]
    payload_width: int  # base_width * len(requests): the advisor/executor k
    resident_bytes: int
    strategy: str  # executable strategy name ("standard", "two_step", ...)
    wire: str  # wire codec name ("none" = full precision)
    key: str  # full recommendation key, e.g. "two_step/device_aware+wire:bf16"
    predicted_time: float  # advisor-modeled exchange seconds at payload_width
    kind: str

    @property
    def width(self) -> int:
        """Number of coalesced requests."""
        return len(self.requests)


class ContinuousBatcher:
    """Coalesce same-fingerprint requests under a window and memory budget."""

    def __init__(
        self,
        classes: Dict[str, WorkloadClass],
        queue: Optional[RequestQueue] = None,
        *,
        window: float = 1e-3,
        max_width: int = 8,
        memory_budget: Optional[int] = None,
        machine: str = "tpu_v5e_pod",
        wire=None,
        health=None,
        strategy: Optional[str] = None,
    ) -> None:
        if not classes:
            raise ValueError("ContinuousBatcher needs at least one WorkloadClass")
        if window < 0:
            raise ValueError(f"window must be >= 0, got {window}")
        if max_width < 1:
            raise ValueError(f"max_width must be >= 1, got {max_width}")
        executable = set(EXECUTABLE_STRATEGY.values())
        if strategy is not None and strategy not in executable:
            raise ValueError(
                f"unknown strategy {strategy!r}; known: {sorted(executable)}"
            )
        self.classes = dict(classes)
        self.queue = queue if queue is not None else RequestQueue()
        self.window = float(window)
        self.max_width = int(max_width)
        self.memory_budget = None if memory_budget is None else int(memory_budget)
        self.machine = machine
        self.wire = wire
        self.health = health
        #: None lets the advisor pick per batch; an executable strategy name
        #: pins it (the ranking still chooses codec/transport within it)
        self.strategy = strategy
        self.batches = 0
        self.coalesced = 0  # requests dispatched in batches of width >= 2
        self._advice: Dict[Tuple[str, int], Advice] = {}
        self.advice_hits = 0
        self.advice_misses = 0
        for fp, cls in self.classes.items():
            if cls.fp != fp:
                raise ValueError(f"class key {fp!r} != class fingerprint {cls.fp!r}")
            if self.width_cap(fp) < 1:
                raise ValueError(
                    f"memory budget {self.memory_budget} cannot hold one "
                    f"request of class {fp!r} ({cls.bytes_per_request} bytes)"
                )

    def width_cap(self, fp: str) -> int:
        """Max requests one batch of class ``fp`` may coalesce."""
        cap = self.max_width
        if self.memory_budget is not None:
            cap = min(cap, self.memory_budget // self.classes[fp].bytes_per_request)
        return cap

    def submit(self, req: Request) -> bool:
        if req.fp not in self.classes:
            raise KeyError(f"unknown fingerprint class {req.fp!r}")
        return self.queue.submit(req)

    def advise(self, fp: str, n_requests: int) -> Advice:
        """Advisor ranking for a batch of ``n_requests`` of class ``fp``,
        memoized per (fp, width) -- the serving analogue of the plan cache."""
        key = (fp, n_requests)
        cached = self._advice.get(key)
        if cached is not None:
            self.advice_hits += 1
            return cached
        self.advice_misses += 1
        cls = self.classes[fp]
        adv = advise_stats(
            cls.stats,
            machine=self.machine,
            payload_width=cls.base_width * n_requests,
            wire=self.wire,
            health=self.health,
        )
        self._advice[key] = adv
        return adv

    def readvise(self, fp: str, n_requests: int) -> Advice:
        """Recompute a lane's advice under the CURRENT health penalties and
        overwrite the memo -- the executor's re-advise rung calls this after
        an integrity failure so subsequent batches of the class inherit the
        re-ranked (strategy, codec) instead of the pre-fault choice."""
        self._advice.pop((fp, n_requests), None)
        return self.advise(fp, n_requests)

    def next_deadline(self, now: float) -> Optional[float]:
        """Earliest instant at which some queued lane becomes ripe, or None
        if the queue is empty.  Lanes already ripe return ``now``."""
        best = None
        for fp, depth, oldest in self.queue.lanes():
            t = oldest + self.window if depth < self.width_cap(fp) else now
            if best is None or t < best:
                best = t
        return None if best is None else max(best, now)

    def next_batch(self, now: float) -> Optional[Batch]:
        """Dispatch the ripest lane, or None if nothing is ripe at ``now``.

        A lane is ripe when its oldest request has aged past the coalescing
        window or the lane already fills a whole batch.  Among ripe lanes
        the oldest deadline wins (fingerprint breaks ties), which is what
        bounds per-class waiting: a lane at its deadline can be overtaken
        only by lanes with even older deadlines.
        """
        ripe = []  # (deadline, fp)
        for fp, depth, oldest in self.queue.lanes():
            deadline = oldest + self.window
            if deadline <= now or depth >= self.width_cap(fp):
                ripe.append((deadline, fp))
        if not ripe:
            return None
        _, fp = min(ripe)
        cls = self.classes[fp]
        reqs = tuple(self.queue.take(fp, self.width_cap(fp)))
        adv = self.advise(fp, len(reqs))
        best = adv.best
        if self.strategy is not None:
            # pinned strategy: fastest variant (transport/codec) within it
            best = next(
                r for r in adv.ranked
                if EXECUTABLE_STRATEGY[r.strategy] == self.strategy
            )
        self.batches += 1
        if len(reqs) >= 2:
            self.coalesced += len(reqs)
        return Batch(
            fp=fp,
            requests=reqs,
            payload_width=cls.base_width * len(reqs),
            resident_bytes=cls.bytes_per_request * len(reqs),
            strategy=EXECUTABLE_STRATEGY[best.strategy],
            wire=best.wire,
            key=best.key,
            predicted_time=best.predicted_time,
            kind=cls.kind,
        )
