"""Seeded, virtual-clock traffic simulation for the serving front-end.

Tier-1 tests must exercise scheduler behavior -- bursty arrivals, skewed
fingerprint popularity, starvation bounds, cache thrash -- without
wall-clock flakiness, so the simulator is a discrete-event loop on a
virtual clock: time advances only to the next arrival, coalescing
deadline, or batch completion, and service times come from the advisor's
performance model (:func:`repro.core.advisor.advise_stats`) plus a fixed
per-dispatch host overhead.  Every quantity is a pure function of the
(trace, config) pair, so identical seeds produce identical event traces,
identical p50/p99, and an identical ``trace_hash`` -- pinned in
``tests/test_serving.py``.

Event tuples, in emission order (ties: arrivals, then dispatch+completion):

* ``("arrive", t, rid, fp)`` -- request admitted to its lane
* ``("reject", t, rid, fp)`` -- request shed by admission control
* ``("dispatch", t, fp, width, key, rids)`` -- batch started; ``key`` is the
  advisor's strategy/codec key, ``rids`` the coalesced request ids
* ``("complete", t, fp, rids)`` -- batch finished at virtual ``t``

Under a seeded chaos schedule (``SimConfig.chaos``) a dispatch may also
emit, between its ``dispatch`` and ``complete``/``shed``:

* ``("fault", t, fp, "strategy/wire")`` -- one seeded integrity failure
* ``("probe", t, fp, "strategy/wire")`` -- a half-open breaker probing
* ``("recover", t, fp, "action:strategy/wire")`` -- ladder rung that saved
  the batch
* ``("shed", t, fp, rids)`` -- ladder exhausted; the batch's requests shed

All chaos decisions are pure functions of (plan seed, ladder-attempt
index, spec ordinal), so ``trace_hash`` covers fault handling too; with
``chaos=None`` the event trace is byte-identical to pre-chaos simulators.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.comm.faults import (
    ExchangeIntegrityError,
    FaultPlan,
    HealthTracker,
    run_ladder,
)
from repro.runtime import AdmissionController, StragglerWatchdog

from .batcher import ContinuousBatcher
from .queue import RequestQueue
from .request import Request, WorkloadClass


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Knobs of one simulated serving deployment."""

    window: float = 1e-3  # coalescing window (virtual seconds)
    max_width: int = 8  # request cap per batch
    memory_budget: Optional[int] = None  # resident bytes cap per batch
    machine: str = "tpu_v5e_pod"
    wire: object = None  # advisor wire= argument (None keeps full precision)
    #: pin every batch to one executable strategy; None = advisor's choice
    strategy: Optional[str] = None
    #: fixed per-dispatch host cost: queue pop, plan-cache lookup, launch.
    #: This is the term coalescing amortizes even when byte terms dominate.
    host_overhead_s: float = 50e-6
    max_queue_depth: int = 4096
    #: seeded fault schedule: each ladder attempt draws one deterministic
    #: firing decision per spec (None = fault-free, trace unchanged)
    chaos: Optional[FaultPlan] = None
    #: ladder retries per faulted dispatch before codec demote / re-advise
    chaos_retries: int = 1
    #: per-request latency SLO; completions past it count as deadline
    #: misses (ladder attempts charge service time, so faults can miss it)
    deadline_s: Optional[float] = None

    def __post_init__(self) -> None:
        if self.host_overhead_s <= 0:
            raise ValueError(
                "host_overhead_s must be > 0 (a zero-cost dispatch would let "
                f"the event loop stall), got {self.host_overhead_s}"
            )


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Everything a test may pin about one simulation."""

    events: Tuple[tuple, ...]
    latencies: Tuple[Tuple[int, float], ...]  # (rid, complete - arrival), rid order
    p50: float
    p99: float
    throughput: float  # completed requests per virtual second
    makespan: float  # first arrival -> last completion
    completed: int
    rejected: int
    batches: int
    mean_width: float
    escalations: int  # watchdog escalations from admission overload
    shed: int = 0  # requests lost to exhausted recovery ladders
    fault_events: int = 0  # seeded integrity failures injected
    recoveries: int = 0  # batches saved by a ladder rung below the first
    probes: int = 0  # half-open breaker probe attempts
    probe_recoveries: int = 0  # probes that closed a breaker
    deadline_misses: int = 0  # completions past config.deadline_s

    @property
    def trace_hash(self) -> str:
        """sha1 over the full event trace -- equal hashes mean the two runs
        made bit-identical scheduling decisions."""
        return hashlib.sha1(repr(self.events).encode()).hexdigest()

    def summary(self) -> Dict[str, float]:
        return {
            "p50_s": self.p50,
            "p99_s": self.p99,
            "throughput_rps": self.throughput,
            "completed": float(self.completed),
            "rejected": float(self.rejected),
            "batches": float(self.batches),
            "mean_width": self.mean_width,
        }


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals) + 0.5) - 1))
    return sorted_vals[idx]


def simulate(
    classes: Dict[str, WorkloadClass],
    trace: Sequence[Request],
    config: SimConfig = SimConfig(),
) -> SimResult:
    """Run ``trace`` through a single-executor serving deployment.

    The executor is the serial resource: one batch's exchange + fused
    compute at a time, matching the host-side dispatch loop of the real
    front-end.  Service time for a batch is the advisor's predicted
    exchange time at the coalesced payload width plus
    ``config.host_overhead_s``; under chaos, every extra ladder attempt
    charges another full service quantum (and ``"slow"`` specs their
    ``delay_s``), so faults degrade latency even when they recover.
    """
    watchdog = StragglerWatchdog()
    admission = AdmissionController(
        max_queue_depth=config.max_queue_depth, watchdog=watchdog
    )
    # faults and overload share ONE escalation budget: the health tracker's
    # integrity failures land on the same watchdog as admission rejections
    health = HealthTracker(watchdog=watchdog) if config.chaos is not None else None
    batcher = ContinuousBatcher(
        classes,
        RequestQueue(admission),
        window=config.window,
        max_width=config.max_width,
        memory_budget=config.memory_budget,
        machine=config.machine,
        wire=config.wire,
        health=health,
        strategy=config.strategy,
    )
    order = sorted(trace)  # (arrival, rid): generator interleaving is irrelevant
    events = []
    latencies: Dict[int, float] = {}
    now = 0.0
    busy_until = 0.0
    ti = 0
    n = len(order)
    last_complete = 0.0
    widths = []
    attempt_clock = [0]  # global ladder-attempt index (the chaos seed axis)
    fault_events = 0
    shed_requests = 0
    recoveries = 0
    # Generous stall guard: every loop iteration either consumes an arrival,
    # dispatches a batch, or advances the clock to a strictly later event.
    for _ in range(8 * n + 64):
        while ti < n and order[ti].arrival <= now:
            req = order[ti]
            ti += 1
            tag = "arrive" if batcher.submit(req) else "reject"
            events.append((tag, req.arrival, req.rid, req.fp))
        if busy_until <= now:
            batch = batcher.next_batch(now)
            if batch is not None:
                rids = tuple(r.rid for r in batch.requests)
                quantum = batch.predicted_time + config.host_overhead_s
                events.append(("dispatch", now, batch.fp, batch.width, batch.key, rids))
                ok, service, nfaults, path = True, quantum, 0, None
                if config.chaos is not None:
                    ok, service, nfaults, path = _chaos_dispatch(
                        config, batch, health, attempt_clock, events, now, quantum
                    )
                    fault_events += nfaults
                done = now + service
                if ok:
                    if path is not None:
                        recoveries += 1
                        events.append(("recover", now, batch.fp, path.key))
                    events.append(("complete", done, batch.fp, rids))
                    for r in batch.requests:
                        latencies[r.rid] = done - r.arrival
                else:
                    shed_requests += len(rids)
                    admission.record_shed(
                        len(rids), {"fp": batch.fp, "requests": len(rids)}
                    )
                    events.append(("shed", done, batch.fp, rids))
                widths.append(batch.width)
                busy_until = done
                last_complete = done
                continue
        if ti >= n and len(batcher.queue) == 0:
            break
        candidates = []
        if ti < n:
            candidates.append(order[ti].arrival)
        if len(batcher.queue):
            deadline = batcher.next_deadline(now)
            if deadline is not None:
                candidates.append(max(deadline, busy_until))
        if not candidates:
            break
        now = max(now, min(candidates))
    else:
        raise RuntimeError(
            "simulate() exceeded its event budget -- the scheduler stalled "
            f"with {len(batcher.queue)} queued and {n - ti} arrivals pending"
        )
    lat_sorted = sorted(latencies.values())
    t0 = order[0].arrival if order else 0.0
    makespan = max(last_complete - t0, 0.0)
    completed = len(latencies)
    deadline_misses = (
        0
        if config.deadline_s is None
        else sum(1 for v in lat_sorted if v > config.deadline_s)
    )
    return SimResult(
        events=tuple(events),
        latencies=tuple(sorted(latencies.items())),
        p50=_percentile(lat_sorted, 0.50),
        p99=_percentile(lat_sorted, 0.99),
        throughput=completed / makespan if makespan > 0 else 0.0,
        makespan=makespan,
        completed=completed,
        rejected=admission.rejected,
        batches=batcher.batches,
        mean_width=sum(widths) / len(widths) if widths else 0.0,
        escalations=admission.escalations,
        shed=shed_requests,
        fault_events=fault_events,
        recoveries=recoveries,
        probes=0 if health is None else health.probes,
        probe_recoveries=0 if health is None else health.probe_recoveries,
        deadline_misses=deadline_misses,
    )


def _chaos_dispatch(
    config: SimConfig,
    batch,
    health: HealthTracker,
    attempt_clock,
    events,
    now: float,
    quantum: float,
):
    """One batch through the REAL recovery ladder under the seeded schedule.

    Each ladder attempt consumes one tick of the global attempt clock; a
    spec fires iff ``plan.active(tick)``, it matches the attempted
    (strategy, wire), and its seeded coin (``rng([seed, tick, spec])``)
    lands under ``prob`` -- so the full fault/recovery history is a pure
    function of (plan, trace) and lands in ``trace_hash``.  Returns
    ``(ok, service_s, n_faults, recovery_path)``.
    """
    plan = config.chaos
    state = {"attempts": 0, "faults": 0, "delay": 0.0}

    def attempt(strategy: str, wire: str):
        tick = attempt_clock[0]
        attempt_clock[0] += 1
        state["attempts"] += 1
        for si, spec in enumerate(plan.specs):
            if not plan.active(tick) or not spec.matches(strategy, wire):
                continue
            coin = np.random.default_rng([plan.seed, tick, si]).random()
            if coin >= spec.prob:
                continue
            if spec.kind == "slow":
                state["delay"] += spec.delay_s
                continue
            state["faults"] += 1
            events.append(("fault", now, batch.fp, f"{strategy}/{wire}"))
            raise ExchangeIntegrityError(
                strategy=strategy,
                codec=wire,
                stage_kind="a2a_pod",
                op_index=0,
                violation=1.0,
            )
        return True

    probes_before = health.probes
    try:
        _, path = run_ladder(
            attempt,
            strategy=batch.strategy,
            wire=batch.wire,
            health=health,
            max_retries=config.chaos_retries,
            choose_alternative=_fixed_preference,
        )
    except ExchangeIntegrityError:
        ok, path = False, None
    else:
        ok = True
    if health.probes > probes_before:
        events.append(("probe", now, batch.fp, f"{batch.strategy}/{batch.wire}"))
    service = state["attempts"] * quantum + state["delay"]
    return ok, service, state["faults"], path


def _fixed_preference(health: HealthTracker, current: str):
    """The simulator's re-advise chooser: deterministic fixed preference
    order over the executable strategies, skipping degraded ones (the real
    executor re-ranks via the advisor; the sim keeps the decision cheap
    and trace-stable)."""
    for name in ("two_step", "three_step", "split", "standard"):
        if name != current and not health.is_degraded(name):
            return name
    return None


def sequential_baseline(
    classes: Dict[str, WorkloadClass],
    trace: Sequence[Request],
    config: SimConfig = SimConfig(),
) -> SimResult:
    """The no-coalescing control: same trace, same advisor, but every
    request dispatches alone (``max_width=1``, zero window)."""
    return simulate(
        classes, trace, dataclasses.replace(config, window=0.0, max_width=1)
    )


def serving_report(
    classes: Dict[str, WorkloadClass],
    trace: Sequence[Request],
    config: SimConfig = SimConfig(),
) -> Dict[str, object]:
    """Coalesced vs. sequential on one trace -- the acceptance-criterion
    record (`BENCH_exchange.json` schema 4 ``serving`` section)."""
    coalesced = simulate(classes, trace, config)
    sequential = sequential_baseline(classes, trace, config)
    speedup = (
        coalesced.throughput / sequential.throughput
        if sequential.throughput > 0
        else 0.0
    )
    return {
        "coalesced": coalesced.summary(),
        "sequential": sequential.summary(),
        "speedup": speedup,
        "max_width": config.max_width,
        "window_s": config.window,
        "trace_hash": coalesced.trace_hash,
    }
