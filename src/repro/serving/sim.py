"""Seeded, virtual-clock traffic simulation for the serving front-end.

Tier-1 tests must exercise scheduler behavior -- bursty arrivals, skewed
fingerprint popularity, starvation bounds, cache thrash -- without
wall-clock flakiness, so the simulator is a discrete-event loop on a
virtual clock: time advances only to the next arrival, coalescing
deadline, or batch completion, and service times come from the advisor's
performance model (:func:`repro.core.advisor.advise_stats`) plus a fixed
per-dispatch host overhead.  Every quantity is a pure function of the
(trace, config) pair, so identical seeds produce identical event traces,
identical p50/p99, and an identical ``trace_hash`` -- pinned in
``tests/test_serving.py``.

Event tuples, in emission order (ties: arrivals, then dispatch+completion):

* ``("arrive", t, rid, fp)`` -- request admitted to its lane
* ``("reject", t, rid, fp)`` -- request shed by admission control
* ``("dispatch", t, fp, width, key, rids)`` -- batch started; ``key`` is the
  advisor's strategy/codec key, ``rids`` the coalesced request ids
* ``("complete", t, fp, rids)`` -- batch finished at virtual ``t``
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Sequence, Tuple

from repro.runtime import AdmissionController, StragglerWatchdog

from .batcher import ContinuousBatcher
from .queue import RequestQueue
from .request import Request, WorkloadClass


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Knobs of one simulated serving deployment."""

    window: float = 1e-3  # coalescing window (virtual seconds)
    max_width: int = 8  # request cap per batch
    memory_budget: Optional[int] = None  # resident bytes cap per batch
    machine: str = "tpu_v5e_pod"
    wire: object = None  # advisor wire= argument (None keeps full precision)
    #: pin every batch to one executable strategy; None = advisor's choice
    strategy: Optional[str] = None
    #: fixed per-dispatch host cost: queue pop, plan-cache lookup, launch.
    #: This is the term coalescing amortizes even when byte terms dominate.
    host_overhead_s: float = 50e-6
    max_queue_depth: int = 4096

    def __post_init__(self) -> None:
        if self.host_overhead_s <= 0:
            raise ValueError(
                "host_overhead_s must be > 0 (a zero-cost dispatch would let "
                f"the event loop stall), got {self.host_overhead_s}"
            )


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Everything a test may pin about one simulation."""

    events: Tuple[tuple, ...]
    latencies: Tuple[Tuple[int, float], ...]  # (rid, complete - arrival), rid order
    p50: float
    p99: float
    throughput: float  # completed requests per virtual second
    makespan: float  # first arrival -> last completion
    completed: int
    rejected: int
    batches: int
    mean_width: float
    escalations: int  # watchdog escalations from admission overload

    @property
    def trace_hash(self) -> str:
        """sha1 over the full event trace -- equal hashes mean the two runs
        made bit-identical scheduling decisions."""
        return hashlib.sha1(repr(self.events).encode()).hexdigest()

    def summary(self) -> Dict[str, float]:
        return {
            "p50_s": self.p50,
            "p99_s": self.p99,
            "throughput_rps": self.throughput,
            "completed": float(self.completed),
            "rejected": float(self.rejected),
            "batches": float(self.batches),
            "mean_width": self.mean_width,
        }


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals) + 0.5) - 1))
    return sorted_vals[idx]


def simulate(
    classes: Dict[str, WorkloadClass],
    trace: Sequence[Request],
    config: SimConfig = SimConfig(),
) -> SimResult:
    """Run ``trace`` through a single-executor serving deployment.

    The executor is the serial resource: one batch's exchange + fused
    compute at a time, matching the host-side dispatch loop of the real
    front-end.  Service time for a batch is the advisor's predicted
    exchange time at the coalesced payload width plus
    ``config.host_overhead_s``.
    """
    watchdog = StragglerWatchdog()
    admission = AdmissionController(
        max_queue_depth=config.max_queue_depth, watchdog=watchdog
    )
    batcher = ContinuousBatcher(
        classes,
        RequestQueue(admission),
        window=config.window,
        max_width=config.max_width,
        memory_budget=config.memory_budget,
        machine=config.machine,
        wire=config.wire,
        strategy=config.strategy,
    )
    order = sorted(trace)  # (arrival, rid): generator interleaving is irrelevant
    events = []
    latencies: Dict[int, float] = {}
    now = 0.0
    busy_until = 0.0
    ti = 0
    n = len(order)
    last_complete = 0.0
    widths = []
    # Generous stall guard: every loop iteration either consumes an arrival,
    # dispatches a batch, or advances the clock to a strictly later event.
    for _ in range(8 * n + 64):
        while ti < n and order[ti].arrival <= now:
            req = order[ti]
            ti += 1
            tag = "arrive" if batcher.submit(req) else "reject"
            events.append((tag, req.arrival, req.rid, req.fp))
        if busy_until <= now:
            batch = batcher.next_batch(now)
            if batch is not None:
                rids = tuple(r.rid for r in batch.requests)
                service = batch.predicted_time + config.host_overhead_s
                done = now + service
                events.append(("dispatch", now, batch.fp, batch.width, batch.key, rids))
                events.append(("complete", done, batch.fp, rids))
                for r in batch.requests:
                    latencies[r.rid] = done - r.arrival
                widths.append(batch.width)
                busy_until = done
                last_complete = done
                continue
        if ti >= n and len(batcher.queue) == 0:
            break
        candidates = []
        if ti < n:
            candidates.append(order[ti].arrival)
        if len(batcher.queue):
            deadline = batcher.next_deadline(now)
            if deadline is not None:
                candidates.append(max(deadline, busy_until))
        if not candidates:
            break
        now = max(now, min(candidates))
    else:
        raise RuntimeError(
            "simulate() exceeded its event budget -- the scheduler stalled "
            f"with {len(batcher.queue)} queued and {n - ti} arrivals pending"
        )
    lat_sorted = sorted(latencies.values())
    t0 = order[0].arrival if order else 0.0
    makespan = max(last_complete - t0, 0.0)
    completed = len(latencies)
    return SimResult(
        events=tuple(events),
        latencies=tuple(sorted(latencies.items())),
        p50=_percentile(lat_sorted, 0.50),
        p99=_percentile(lat_sorted, 0.99),
        throughput=completed / makespan if makespan > 0 else 0.0,
        makespan=makespan,
        completed=completed,
        rejected=admission.rejected,
        batches=batcher.batches,
        mean_width=sum(widths) / len(widths) if widths else 0.0,
        escalations=admission.escalations,
    )


def sequential_baseline(
    classes: Dict[str, WorkloadClass],
    trace: Sequence[Request],
    config: SimConfig = SimConfig(),
) -> SimResult:
    """The no-coalescing control: same trace, same advisor, but every
    request dispatches alone (``max_width=1``, zero window)."""
    return simulate(
        classes, trace, dataclasses.replace(config, window=0.0, max_width=1)
    )


def serving_report(
    classes: Dict[str, WorkloadClass],
    trace: Sequence[Request],
    config: SimConfig = SimConfig(),
) -> Dict[str, object]:
    """Coalesced vs. sequential on one trace -- the acceptance-criterion
    record (`BENCH_exchange.json` schema 4 ``serving`` section)."""
    coalesced = simulate(classes, trace, config)
    sequential = sequential_baseline(classes, trace, config)
    speedup = (
        coalesced.throughput / sequential.throughput
        if sequential.throughput > 0
        else 0.0
    )
    return {
        "coalesced": coalesced.summary(),
        "sequential": sequential.summary(),
        "speedup": speedup,
        "max_width": config.max_width,
        "window_s": config.window,
        "trace_hash": coalesced.trace_hash,
    }
