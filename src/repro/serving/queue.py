"""Fingerprint-keyed request queue with admission control.

Requests enter through :meth:`RequestQueue.submit`, which consults an
:class:`repro.runtime.AdmissionController` against the *total* backlog --
overload sheds load instead of growing an unbounded queue, and sustained
shedding escalates through the straggler watchdog's control plane.  Admitted
requests land in per-fingerprint FIFO lanes, which is the invariant the
batcher's coalescing relies on: a batch is always a contiguous FIFO prefix
of one lane, so requests within a class complete in arrival order.
"""

from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Tuple

from repro.runtime import AdmissionController

from .request import Request


class RequestQueue:
    """Per-fingerprint FIFO lanes behind one admission gate."""

    def __init__(self, admission: Optional[AdmissionController] = None) -> None:
        self.admission = admission if admission is not None else AdmissionController()
        self._lanes: Dict[str, Deque[Request]] = {}
        self._depth = 0

    def __len__(self) -> int:
        return self._depth

    def submit(self, req: Request) -> bool:
        """Enqueue ``req``; False means the admission controller shed it."""
        if not self.admission.admit(self._depth):
            return False
        self._lanes.setdefault(req.fp, collections.deque()).append(req)
        self._depth += 1
        return True

    def lanes(self) -> List[Tuple[str, int, float]]:
        """Non-empty lanes as ``(fp, depth, oldest_arrival)``, sorted by
        fingerprint so iteration order never depends on dict history."""
        return sorted(
            (fp, len(lane), lane[0].arrival)
            for fp, lane in self._lanes.items()
            if lane
        )

    def peek_oldest(self, fp: str) -> Optional[Request]:
        lane = self._lanes.get(fp)
        return lane[0] if lane else None

    def take(self, fp: str, n: int) -> List[Request]:
        """Dequeue up to ``n`` requests from the front of lane ``fp``."""
        lane = self._lanes.get(fp)
        if not lane:
            return []
        out = []
        while lane and len(out) < n:
            out.append(lane.popleft())
        self._depth -= len(out)
        if not lane:
            del self._lanes[fp]
        return out
