"""Multi-tenant serving front-end: continuous batching over cached plans.

Turns the per-request machinery of PRs 2-7 (fused SpMM, plan/compute/
exchange LRU caches, the advisor) into system throughput: concurrent
SpMV/SpMM solves and MoE dispatches enter per-fingerprint FIFO lanes
(:class:`RequestQueue`, admission via
:class:`repro.runtime.AdmissionController`), coalesce into wider payload
batches under a window and memory budget (:class:`ContinuousBatcher`),
and drain through the real kernels (:class:`BatchExecutor`).  The seeded
virtual-clock simulator (:func:`simulate`) makes every scheduling decision
bit-reproducible for tier-1 tests and benchmarks.
"""

from .batcher import Batch, ContinuousBatcher
from .executor import BatchExecutor, BatchOutcome, measure_spmv_replay
from .queue import RequestQueue
from .request import Request, WorkloadClass
from .sim import SimConfig, SimResult, sequential_baseline, serving_report, simulate

__all__ = [
    "Batch",
    "BatchExecutor",
    "BatchOutcome",
    "ContinuousBatcher",
    "Request",
    "RequestQueue",
    "SimConfig",
    "SimResult",
    "WorkloadClass",
    "measure_spmv_replay",
    "sequential_baseline",
    "serving_report",
    "simulate",
]
