"""Executor loop: drain batches through the real exchange stack.

The simulator (:mod:`repro.serving.sim`) decides *what* to coalesce; the
executor proves those decisions run -- and pay off -- on real devices.
:class:`BatchExecutor` maps each fingerprint class to a handler (a
:class:`repro.sparse.spmv.DistributedSpMV` for solves, a
``MoELayer(dispatch="exchange")`` closure for token dispatch) and replays a
batch schedule in dispatch order.  :func:`measure_spmv_replay` is the
benchmark primitive behind the acceptance criterion: the same right-hand
sides run once coalesced (``ceil(n/k)`` fused-SpMM exchanges at width
``k``) and once sequentially (``n`` single-column exchanges), with a
numerical parity check between the two paths.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Sequence

import jax
import numpy as np

from .batcher import Batch


class BatchExecutor:
    """Per-fingerprint handlers, drained in dispatch order."""

    def __init__(self) -> None:
        self._handlers: Dict[str, Callable] = {}
        self.executed = 0

    def register(self, fp: str, handler: Callable) -> None:
        """``handler(payload)`` runs one coalesced batch of class ``fp``."""
        self._handlers[fp] = handler

    def register_spmv(self, fp: str, sp) -> None:
        """Solve batches execute as one fused SpMM over the coalesced
        columns (:meth:`repro.sparse.spmv.DistributedSpMV.matmat`)."""
        self.register(fp, sp.matmat)

    def register_moe(self, fp: str, layer, params, mesh) -> None:
        """MoE batches execute one exchange-dispatch layer call; coalesced
        requests arrive stacked on the batch axis, so wider batches route
        more tokens through the same planned exchange."""
        self.register(fp, lambda x: layer(params, x, mesh=mesh))

    def execute(self, batch: Batch, payload):
        handler = self._handlers.get(batch.fp)
        if handler is None:
            raise KeyError(f"no handler registered for class {batch.fp!r}")
        self.executed += 1
        return handler(payload)

    def run_schedule(self, batches: Sequence[Batch], payloads: Sequence) -> List:
        """Execute ``batches[i]`` on ``payloads[i]``, preserving order."""
        if len(batches) != len(payloads):
            raise ValueError(
                f"{len(batches)} batches but {len(payloads)} payloads"
            )
        return [self.execute(b, p) for b, p in zip(batches, payloads)]


def _timed(fn: Callable[[], object]) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def measure_spmv_replay(
    sp,
    n_requests: int,
    width: int,
    rng: np.random.Generator,
    repeats: int = 1,
) -> Dict[str, float]:
    """Coalesced vs. sequential dispatch of ``n_requests`` solves.

    Returns wall seconds per path (best of ``repeats``, after one warmup
    each so jit compilation never lands in the measurement), the realized
    throughput speedup, and the max absolute difference between the
    coalesced and per-request results (``parity``).
    """
    if n_requests < 1 or width < 1:
        raise ValueError("n_requests and width must be >= 1")
    topo = sp.topo
    L = sp.rows_per_rank
    V = rng.standard_normal((topo.nranks, L, n_requests)).astype(np.float32)

    def coalesced() -> List:
        return [
            sp.matmat(V[:, :, a : min(a + width, n_requests)])
            for a in range(0, n_requests, width)
        ]

    def sequential() -> List:
        return [sp.matmat(V[:, :, i : i + 1]) for i in range(n_requests)]

    co = np.concatenate([np.asarray(x) for x in coalesced()], axis=-1)
    seq = np.concatenate([np.asarray(x) for x in sequential()], axis=-1)
    parity = float(np.max(np.abs(co - seq))) if n_requests else 0.0

    t_co = min(_timed(coalesced) for _ in range(repeats))
    t_seq = min(_timed(sequential) for _ in range(repeats))
    return {
        "coalesced_s": t_co,
        "sequential_s": t_seq,
        "speedup": t_seq / t_co if t_co > 0 else 0.0,
        "parity": parity,
        "n_requests": float(n_requests),
        "width": float(width),
    }
