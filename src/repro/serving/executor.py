"""Executor loop: drain batches through the real exchange stack.

The simulator (:mod:`repro.serving.sim`) decides *what* to coalesce; the
executor proves those decisions run -- and pay off -- on real devices.
:class:`BatchExecutor` maps each fingerprint class to a handler (a
:class:`repro.sparse.spmv.DistributedSpMV` for solves, a
``MoELayer(dispatch="exchange")`` closure for token dispatch) and replays a
batch schedule in dispatch order.  :func:`measure_spmv_replay` is the
benchmark primitive behind the acceptance criterion: the same right-hand
sides run once coalesced (``ceil(n/k)`` fused-SpMM exchanges at width
``k``) and once sequentially (``n`` single-column exchanges), with a
numerical parity check between the two paths.

Fault tolerance: each batch drains through the PR 6 recovery ladder
(:func:`repro.comm.faults.run_ladder` -- retry, demote the wire codec,
re-advise the strategy under health penalties) with a per-batch deadline
and bounded exponential backoff between attempts.  An exhausted ladder
sheds only that batch (a failed :class:`BatchOutcome`; completed work is
preserved) and feeds the shared
:class:`repro.runtime.watchdog.StragglerWatchdog` /
:class:`~repro.runtime.watchdog.AdmissionController` escalation budget, so
fault pressure and overload reach the control plane through one path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.comm.faults import ExchangeIntegrityError, HealthTracker, run_ladder

from .batcher import Batch


@dataclasses.dataclass(frozen=True)
class BatchOutcome:
    """One batch's fate through the resilient drain.

    ``ok`` batches carry the handler's return in ``value``; failed batches
    carry the terminal exception in ``error`` and the shed request ids in
    ``shed_rids`` (the batch's whole FIFO prefix -- partial batches are
    never delivered).  ``recovery`` is the ladder's
    :class:`repro.comm.faults.RecoveryPath` key (``"retry:..."``,
    ``"demote:..."``, ``"readvise:..."``) when a rung below the first had
    to run, ``None`` on a clean first attempt.
    """

    batch: Batch
    ok: bool
    value: object = None
    error: Optional[BaseException] = None
    recovery: Optional[str] = None
    attempts: int = 1
    shed_rids: Tuple[int, ...] = ()
    deadline_missed: bool = False
    elapsed_s: float = 0.0
    backoff_s: float = 0.0


class _DeadlineExceeded(Exception):
    """Internal: aborts the ladder once the per-batch deadline is spent.

    Deliberately NOT an :class:`ExchangeIntegrityError` subclass, so it
    escapes ``run_ladder`` (which only catches integrity errors) instead
    of consuming further rungs."""


class BatchExecutor:
    """Per-fingerprint handlers, drained in dispatch order.

    Construction is backwards compatible: ``BatchExecutor()`` behaves as
    before for :meth:`execute`.  The resilience knobs opt the *drain*
    (:meth:`run_schedule` / :meth:`execute_resilient`) into the recovery
    ladder:

    * ``health`` -- shared :class:`~repro.comm.faults.HealthTracker`
      (circuit breaker + advisor penalties); created on demand if absent.
    * ``watchdog`` / ``admission`` -- shed batches are charged against the
      same escalation budget as straggler steps and queue overload.
    * ``deadline_s`` -- wall budget per batch; once spent, no further
      ladder attempts run and the batch is shed with
      ``deadline_missed=True``.
    * ``backoff_base_s`` / ``backoff_max_s`` -- bounded exponential pause
      before each non-first attempt (``base * 2**failures``, capped).
    * ``batcher`` -- a :class:`~repro.serving.batcher.ContinuousBatcher`
      whose advice memo the re-advise rung refreshes
      (:meth:`~repro.serving.batcher.ContinuousBatcher.readvise`).
    * ``clock`` / ``sleep`` -- injectable for deterministic tests.
    """

    def __init__(
        self,
        *,
        health: Optional[HealthTracker] = None,
        watchdog=None,
        admission=None,
        max_retries: int = 1,
        fallback: bool = True,
        deadline_s: Optional[float] = None,
        backoff_base_s: float = 0.0,
        backoff_max_s: float = 1.0,
        batcher=None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ) -> None:
        self._handlers: Dict[str, Callable] = {}
        self._variant_makers: Dict[str, Callable[[str, str], Callable]] = {}
        self.executed = 0
        self.health = health if health is not None else HealthTracker(
            watchdog=watchdog
        )
        self.watchdog = watchdog
        self.admission = admission
        self.max_retries = int(max_retries)
        self.fallback = bool(fallback)
        self.deadline_s = deadline_s
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_max_s = float(backoff_max_s)
        self.batcher = batcher
        self._clock = clock
        self._sleep = sleep
        self.shed_batches = 0
        self.shed_requests = 0
        self.recovered_batches = 0
        self.deadline_misses = 0

    def register(self, fp: str, handler: Callable) -> None:
        """``handler(payload)`` runs one coalesced batch of class ``fp``."""
        self._handlers[fp] = handler

    def register_variants(
        self, fp: str, make: Callable[[str, str], Callable]
    ) -> None:
        """Register a handler *family*: ``make(strategy, wire)`` returns the
        handler for one (strategy, codec) pair, which is what lets the
        demote and re-advise rungs of the ladder actually run on a
        different wire or strategy.  The batch's own (strategy, wire) pair
        serves the first rung."""
        self._variant_makers[fp] = make

    def register_spmv(self, fp: str, sp) -> None:
        """Solve batches execute as one fused SpMM over the coalesced
        columns (:meth:`repro.sparse.spmv.DistributedSpMV.matmat`)."""
        self.register(fp, sp.matmat)

    def register_moe(self, fp: str, layer, params, mesh) -> None:
        """MoE batches execute one exchange-dispatch layer call; coalesced
        requests arrive stacked on the batch axis, so wider batches route
        more tokens through the same planned exchange."""
        self.register(fp, lambda x: layer(params, x, mesh=mesh))

    def execute(self, batch: Batch, payload):
        handler = self._handlers.get(batch.fp)
        if handler is None:
            maker = self._variant_makers.get(batch.fp)
            if maker is None:
                raise KeyError(f"no handler registered for class {batch.fp!r}")
            handler = maker(batch.strategy, batch.wire)
        self.executed += 1
        return handler(payload)

    # -- resilient drain ---------------------------------------------------

    def _choose_alternative(self, batch: Batch):
        """Re-advise chooser for one batch: refresh the batcher's advice
        memo under the current health penalties and return the best
        non-degraded executable strategy different from the batch's."""

        def choose(health: HealthTracker, current: str) -> Optional[str]:
            if self.batcher is not None:
                from repro.core.advisor import healthy_alternatives

                adv = self.batcher.readvise(batch.fp, batch.width)
                for name in healthy_alternatives(adv.ranked, health, current):
                    return name
            for name in ("two_step", "three_step", "split", "standard"):
                if name != current and not health.is_degraded(name):
                    return name
            return None

        return choose

    def execute_resilient(self, batch: Batch, payload) -> BatchOutcome:
        """Run one batch through the recovery ladder; never raises on an
        integrity failure -- an exhausted ladder becomes a failed outcome
        that sheds exactly this batch's requests."""
        maker = self._variant_makers.get(batch.fp)
        plain = self._handlers.get(batch.fp)
        if maker is None and plain is None:
            return self._shed(
                batch,
                KeyError(f"no handler registered for class {batch.fp!r}"),
                attempts=0,
                elapsed_s=0.0,
                backoff_s=0.0,
            )
        t0 = self._clock()
        state = {"attempts": 0, "failed": 0, "backoff": 0.0}

        def attempt(strategy: str, wire: str):
            if state["attempts"] > 0:
                if (
                    self.deadline_s is not None
                    and self._clock() - t0 > self.deadline_s
                ):
                    raise _DeadlineExceeded(
                        f"batch {batch.fp!r} out of deadline budget "
                        f"({self.deadline_s}s) after {state['attempts']} attempts"
                    )
                pause = min(
                    self.backoff_max_s,
                    self.backoff_base_s * (2.0 ** state["failed"]),
                )
                if pause > 0.0:
                    state["backoff"] += pause
                    self._sleep(pause)
            state["attempts"] += 1
            handler = maker(strategy, wire) if maker is not None else plain
            try:
                out = handler(payload)
            except ExchangeIntegrityError:
                state["failed"] += 1
                raise
            return out

        try:
            value, path = run_ladder(
                attempt,
                strategy=batch.strategy,
                wire=batch.wire,
                health=self.health,
                max_retries=self.max_retries,
                # plain handlers cannot change (strategy, wire): retry only
                fallback=self.fallback and maker is not None,
                choose_alternative=self._choose_alternative(batch),
            )
        except (ExchangeIntegrityError, _DeadlineExceeded) as e:
            missed = isinstance(e, _DeadlineExceeded)
            return self._shed(
                batch,
                e,
                attempts=state["attempts"],
                elapsed_s=self._clock() - t0,
                backoff_s=state["backoff"],
                deadline_missed=missed,
            )
        self.executed += 1
        if path is not None:
            self.recovered_batches += 1
        return BatchOutcome(
            batch=batch,
            ok=True,
            value=value,
            recovery=None if path is None else path.key,
            attempts=max(1, state["attempts"]),
            elapsed_s=self._clock() - t0,
            backoff_s=state["backoff"],
        )

    def _shed(
        self,
        batch: Batch,
        error: BaseException,
        *,
        attempts: int,
        elapsed_s: float,
        backoff_s: float,
        deadline_missed: bool = False,
    ) -> BatchOutcome:
        rids = tuple(r.rid for r in batch.requests)
        self.shed_batches += 1
        self.shed_requests += len(rids)
        if deadline_missed:
            self.deadline_misses += 1
        info = {
            "fp": batch.fp,
            "requests": len(rids),
            "attempts": attempts,
            "deadline_missed": deadline_missed,
        }
        if self.watchdog is not None:
            self.watchdog.record_external("batch_shed", info)
        if self.admission is not None and hasattr(self.admission, "record_shed"):
            self.admission.record_shed(len(rids), info)
        return BatchOutcome(
            batch=batch,
            ok=False,
            error=error,
            attempts=attempts,
            shed_rids=rids,
            deadline_missed=deadline_missed,
            elapsed_s=elapsed_s,
            backoff_s=backoff_s,
        )

    def run_schedule(
        self, batches: Sequence[Batch], payloads: Sequence
    ) -> List[BatchOutcome]:
        """Execute ``batches[i]`` on ``payloads[i]``, preserving order.

        Returns one :class:`BatchOutcome` per batch.  A handler failure --
        including the pre-existing ``KeyError`` on an unregistered
        fingerprint -- no longer discards the schedule's completed work: the
        failing batch's outcome carries the error (and, for integrity
        errors, the exhausted ladder's shed bookkeeping) while every other
        batch's result is preserved.
        """
        if len(batches) != len(payloads):
            raise ValueError(
                f"{len(batches)} batches but {len(payloads)} payloads"
            )
        outcomes: List[BatchOutcome] = []
        for b, p in zip(batches, payloads):
            try:
                outcomes.append(self.execute_resilient(b, p))
            except Exception as e:  # non-integrity handler bug: attach, keep going
                outcomes.append(
                    self._shed(b, e, attempts=1, elapsed_s=0.0, backoff_s=0.0)
                )
        return outcomes


def _timed(fn: Callable[[], object]) -> float:
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def measure_spmv_replay(
    sp,
    n_requests: int,
    width: int,
    rng: np.random.Generator,
    repeats: int = 1,
) -> Dict[str, float]:
    """Coalesced vs. sequential dispatch of ``n_requests`` solves.

    Returns wall seconds per path (best of ``repeats``, after one warmup
    each so jit compilation never lands in the measurement), the realized
    throughput speedup, and the max absolute difference between the
    coalesced and per-request results (``parity``).
    """
    if n_requests < 1 or width < 1:
        raise ValueError("n_requests and width must be >= 1")
    topo = sp.topo
    L = sp.rows_per_rank
    V = rng.standard_normal((topo.nranks, L, n_requests)).astype(np.float32)

    def coalesced() -> List:
        return [
            sp.matmat(V[:, :, a : min(a + width, n_requests)])
            for a in range(0, n_requests, width)
        ]

    def sequential() -> List:
        return [sp.matmat(V[:, :, i : i + 1]) for i in range(n_requests)]

    co = np.concatenate([np.asarray(x) for x in coalesced()], axis=-1)
    seq = np.concatenate([np.asarray(x) for x in sequential()], axis=-1)
    parity = float(np.max(np.abs(co - seq))) if n_requests else 0.0

    t_co = min(_timed(coalesced) for _ in range(repeats))
    t_seq = min(_timed(sequential) for _ in range(repeats))
    return {
        "coalesced_s": t_co,
        "sequential_s": t_seq,
        "speedup": t_seq / t_co if t_co > 0 else 0.0,
        "parity": parity,
        "n_requests": float(n_requests),
        "width": float(width),
    }
