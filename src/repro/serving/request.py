"""Serving-front-end request model.

A request names *which* exchange workload it belongs to (its fingerprint
class) and *when* it arrived (virtual seconds on the simulator's clock, or
wall seconds in a live front-end); the payload itself stays with the
executor.  Two requests with the same fingerprint are coalescable: they ride
one plan, one exchange, and one fused SpMM at the combined payload width
(:meth:`repro.sparse.spmv.DistributedSpMV.matmat`), which is the serving
layer's whole throughput lever -- the paper's message-count vs. message-size
tradeoff, decided per batch instead of per matrix.
"""

from __future__ import annotations

import dataclasses

from repro.core.perfmodel import PatternStats


@dataclasses.dataclass(frozen=True, order=True)
class Request:
    """One tenant request.  Ordered by ``(arrival, rid)`` so traces sort
    deterministically regardless of generator interleaving."""

    arrival: float  # seconds on the serving clock
    rid: int  # unique id (trace order)
    fp: str  # fingerprint class (coalescing key)
    kind: str = "spmv"  # "spmv" | "solve" | "moe" (executor routing only)

    @property
    def deadline(self) -> float:
        """Placeholder so schedulers can treat requests uniformly; the real
        deadline is ``arrival + window`` with the batcher's window."""
        return self.arrival


@dataclasses.dataclass(frozen=True)
class WorkloadClass:
    """One fingerprint class: the static facts the scheduler needs.

    ``stats`` are the paper's Table 7 parameters for the class's exchange
    pattern -- what :func:`repro.core.advisor.advise_stats` ranks strategies
    from, at the *coalesced* payload width.  ``base_width`` is the payload
    width of a single request (1 column for an SpMV solve; ``d_model`` for a
    MoE dispatch, since every routed token ships a d_model-wide activation
    row); a batch of ``w`` requests runs at ``payload_width = base_width * w``.
    ``bytes_per_request`` is the device memory one request's payload pins
    while the batch is resident (the memory-budget unit).
    """

    fp: str
    stats: PatternStats
    bytes_per_request: int
    base_width: int = 1
    kind: str = "spmv"

    def __post_init__(self) -> None:
        if self.bytes_per_request < 1:
            raise ValueError(
                f"bytes_per_request must be >= 1, got {self.bytes_per_request}"
            )
        if self.base_width < 1:
            raise ValueError(f"base_width must be >= 1, got {self.base_width}")

    @staticmethod
    def from_pattern(pattern, fp=None, elem_bytes: int = 4, kind: str = "spmv"):
        """Class for an :class:`repro.comm.ExchangePattern` (SpMV/SpMM halo).

        One request = one right-hand-side column: its resident bytes are the
        local rows plus the halo buffer, across all ranks.
        """
        topo = pattern.topo
        per_rank = pattern.local_size + pattern.max_recv_size()
        return WorkloadClass(
            fp=fp if fp is not None else pattern.fingerprint(),
            stats=pattern.to_comm_pattern(elem_bytes=elem_bytes).stats(),
            bytes_per_request=max(per_rank * topo.nranks * elem_bytes, 1),
            base_width=1,
            kind=kind,
        )

    @staticmethod
    def from_routing(counts, ppn: int, d_model: int, fp: str, elem_bytes: int = 4):
        """Class for a MoE dispatch hop with measured routing ``counts``.

        ``counts[s, d]`` are routed tokens per (src shard, dst shard); one
        request is one token batch, shipping ``d_model`` features per token
        (``base_width = d_model`` -- the advisor's byte terms scale with the
        activation row, exactly as :func:`repro.launch.serve.dispatch_advice`
        scales them).
        """
        import numpy as np

        from repro.core.perfmodel import dispatch_stats

        c = np.asarray(counts, dtype=np.int64)
        stats = dispatch_stats(c, ppn=ppn, elem_bytes=elem_bytes)
        tokens = int(c.sum())
        return WorkloadClass(
            fp=fp,
            stats=stats,
            bytes_per_request=max(tokens * d_model * elem_bytes, 1),
            base_width=d_model,
            kind="moe",
        )
