"""Sharded AdamW with warmup-cosine schedule and global-norm clipping.

Self-contained (no optax).  Optimizer state mirrors the parameter tree leaf
for leaf, so the parameter ``NamedSharding`` tree shards the moments
identically (ZeRO-style: every chip owns the states of its own parameter
shards; the update is elementwise, so no extra communication is introduced
by the optimizer itself).
"""

from repro.optim.adamw import (
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    warmup_cosine,
)

__all__ = [
    "AdamWConfig",
    "OptState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "warmup_cosine",
]
