"""AdamW + schedule + clipping, functional style."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    mu: Any  # first moments (tree like params)
    nu: Any  # second moments


def warmup_cosine(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new params, new state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = warmup_cosine(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, mu=new_m, nu=new_v), metrics
