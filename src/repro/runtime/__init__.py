"""Training/serving runtime: jitted steps, fault tolerance, elasticity."""

from repro.runtime.trainer import Trainer, TrainerConfig, build_train_step
from repro.runtime.watchdog import AdmissionController, StragglerWatchdog

__all__ = [
    "AdmissionController",
    "Trainer",
    "TrainerConfig",
    "build_train_step",
    "StragglerWatchdog",
]
