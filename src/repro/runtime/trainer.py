"""Trainer: jitted sharded train step + fault-tolerant step loop.

* GSPMD-sharded ``train_step`` (params/opt-state shardings from the logical
  rules table, batch over the DP axes, donated state).
* checkpoint/restart via :mod:`repro.checkpoint` -- checkpoints are
  mesh-independent, so a restart may use a different mesh (elastic scaling).
* straggler watchdog -- escalates to checkpoint + restart-request.
* optional simulated failure injection (``fail_at_step``) used by the
  fault-tolerance tests: the process raises mid-run, and a fresh Trainer
  resumes losslessly from the last checkpoint.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, latest_step
from repro.configs.base import ModelConfig
from repro.data import SyntheticTokens
from repro.models import LMModel, param_shardings, rules_for_mesh, spec_for
from repro.optim import AdamWConfig, OptState, adamw_init, adamw_update
from repro.runtime.watchdog import StragglerWatchdog

log = logging.getLogger(__name__)


def batch_sharding(mesh: Mesh, rules, batch: int, seq: int) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, rules, ("batch", "seq"), (batch, seq)))


def build_train_step(
    model: LMModel,
    mesh: Mesh,
    opt_cfg: AdamWConfig,
    impl: str = "dot",
    remat: bool = True,
) -> Callable:
    """jit'd (state, batch) -> (state, metrics) with explicit shardings."""
    rules = rules_for_mesh(mesh)
    specs = model.param_specs()
    p_shard = param_shardings(specs, mesh, rules)
    opt_shard = OptState(
        step=NamedSharding(mesh, P()),
        mu=p_shard,
        nu=p_shard,
    )
    state_shard = {"params": p_shard, "opt": opt_shard}
    metric_shard = NamedSharding(mesh, P())

    def step(state, batch):
        def loss_fn(p):
            return model.loss(p, batch, impl=impl, mesh=mesh, remat=remat)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        new_p, new_opt, metrics = adamw_update(
            opt_cfg, state["params"], grads, state["opt"]
        )
        metrics["loss"] = loss
        return {"params": new_p, "opt": new_opt}, metrics

    return jax.jit(
        step,
        in_shardings=(state_shard, None),
        out_shardings=(state_shard, metric_shard),
        donate_argnums=(0,),
    )


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    batch: int = 8
    seq_len: int = 128
    seed: int = 0
    impl: str = "dot"
    remat: bool = True
    fail_at_step: Optional[int] = None  # fault-injection for tests


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        mesh: Mesh,
        cfg: TrainerConfig,
        opt_cfg: Optional[AdamWConfig] = None,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.model = LMModel(model_cfg, tp=mesh.shape.get("model", 1))
        self.opt_cfg = opt_cfg or AdamWConfig(total_steps=cfg.steps)
        self.rules = rules_for_mesh(mesh)
        self.step_fn = build_train_step(
            self.model, mesh, self.opt_cfg, impl=cfg.impl, remat=cfg.remat
        )
        self.data = SyntheticTokens(
            vocab_size=model_cfg.vocab_size,
            batch=cfg.batch,
            seq_len=cfg.seq_len,
            seed=cfg.seed,
            mesh=mesh,
            batch_spec=spec_for(mesh, self.rules, ("batch", "seq"), (cfg.batch, cfg.seq_len)),
        )
        self.ckpt = (
            CheckpointManager(cfg.checkpoint_dir) if cfg.checkpoint_dir else None
        )
        self.watchdog = StragglerWatchdog()
        self.history: list = []

    # ------------------------------------------------------------------
    def init_state(self, rng_seed: int = 0) -> Dict[str, Any]:
        specs = self.model.param_specs()
        p_shard = param_shardings(specs, self.mesh, self.rules)

        @jax.jit
        def _init(key):
            params = self.model.init(key)
            return {"params": params, "opt": adamw_init(params)}

        with jax.sharding.use_mesh(self.mesh) if hasattr(jax.sharding, "use_mesh") else _null():
            state = _init(jax.random.PRNGKey(rng_seed))
        # place on mesh
        shard_tree = {"params": p_shard, "opt": OptState(
            step=NamedSharding(self.mesh, P()), mu=p_shard, nu=p_shard)}
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shard_tree
        )

    def state_shardings(self):
        p_shard = param_shardings(self.model.param_specs(), self.mesh, self.rules)
        return {
            "params": p_shard,
            "opt": OptState(step=NamedSharding(self.mesh, P()), mu=p_shard, nu=p_shard),
        }

    # ------------------------------------------------------------------
    def run(self, resume: bool = True) -> Dict[str, Any]:
        start = 0
        state = None
        if resume and self.ckpt and latest_step(self.ckpt.directory) is not None:
            template = jax.eval_shape(lambda: self.init_state())
            state, manifest = self.ckpt.restore(
                template, shardings=self.state_shardings()
            )
            start = manifest["step"]
            log.info("resumed from step %d", start)
        if state is None:
            state = self.init_state()

        for step in range(start, self.cfg.steps):
            if self.cfg.fail_at_step is not None and step == self.cfg.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            self.watchdog.start_step()
            batch = self.data.batch_at(step)
            state, metrics = self.step_fn(state, batch)
            escalate = self.watchdog.end_step(step)
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                self.history.append({"step": step + 1, "loss": loss})
                log.info("step %d loss %.4f", step + 1, loss)
            if self.ckpt and (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save_async(step + 1, state, extra={"seed": self.cfg.seed})
            if escalate:
                log.warning("straggler budget exhausted at step %d: checkpoint + restart", step)
                if self.ckpt:
                    self.ckpt.save_async(step + 1, state, extra={"straggler": True})
                self.watchdog.consecutive = 0
        if self.ckpt:
            self.ckpt.save_async(self.cfg.steps, state)
            self.ckpt.wait()
        return {"state": state, "history": self.history, "straggler_events": self.watchdog.events}


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
