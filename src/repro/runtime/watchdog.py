"""Straggler detection for the step loop.

XLA SPMD steps are globally synchronous, so a slow host shows up as a slow
*step*.  The watchdog keeps an EMA of step wall-time and flags steps beyond
``factor x EMA`` as straggler events; the trainer's policy (see DESIGN.md
section 8) is control-plane: log, and after ``budget`` consecutive events
checkpoint + request an elastic restart (possibly on a smaller mesh), which
:class:`repro.runtime.trainer.Trainer` implements via its mesh-independent
checkpoints.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class StragglerWatchdog:
    factor: float = 3.0
    budget: int = 3  # consecutive straggler steps before escalation
    decay: float = 0.9

    ema: Optional[float] = None
    consecutive: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)
    _t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> bool:
        """Returns True if the escalation budget is exhausted.

        Raises :class:`RuntimeError` if no step is open (``start_step`` was
        never called, or this is the second ``end_step`` in a row) instead of
        crashing with ``TypeError`` on the ``None`` timestamp.
        """
        if self._t0 is None:
            raise RuntimeError(
                "StragglerWatchdog.end_step called with no open step; "
                "call start_step() first"
            )
        dt = time.monotonic() - self._t0
        self._t0 = None
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = dt > self.factor * self.ema
        if is_straggler:
            self.consecutive += 1
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:
            self.consecutive = 0
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        return self.consecutive >= self.budget

    def record_external(self, kind: str, info: Optional[dict] = None) -> bool:
        """Record a non-timing health event (e.g. an exchange integrity
        failure from :class:`repro.comm.faults.HealthTracker`) against the
        same escalation budget as straggler steps.

        Returns True if the budget is exhausted, mirroring ``end_step``.
        """
        self.consecutive += 1
        self.events.append({"kind": kind, **(info or {})})
        return self.consecutive >= self.budget


@dataclasses.dataclass
class AdmissionController:
    """Queue-depth admission control for the serving front-end.

    The multi-tenant batcher (:mod:`repro.serving`) calls :meth:`admit`
    before enqueueing each request; past ``max_queue_depth`` the request is
    rejected (shed) instead of growing an unbounded backlog.  Sustained
    rejection pressure escalates through the SAME control plane as
    straggler steps: every ``reject_burst`` *consecutive* rejections records
    one external event against the shared :class:`StragglerWatchdog` budget,
    so an overload and a slow host reach the trainer's restart policy
    through one code path.

    Purely counter-based (no wall clock): admission decisions are a
    deterministic function of the call sequence, which the seeded traffic
    simulator relies on for bit-reproducible event traces.
    """

    max_queue_depth: int = 1024
    watchdog: Optional["StragglerWatchdog"] = None
    #: consecutive rejections per escalation event (debounce: one burst of
    #: shed requests is one control-plane event, not hundreds)
    reject_burst: int = 32

    admitted: int = 0
    rejected: int = 0
    shed: int = 0
    escalations: int = 0
    _consecutive_rejects: int = 0

    def __post_init__(self) -> None:
        if self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {self.max_queue_depth}"
            )
        if self.reject_burst < 1:
            raise ValueError(f"reject_burst must be >= 1, got {self.reject_burst}")

    def admit(self, queue_depth: int) -> bool:
        """True iff a request may enter a queue currently ``queue_depth`` deep."""
        if queue_depth >= self.max_queue_depth:
            self.rejected += 1
            self._consecutive_rejects += 1
            if (
                self.watchdog is not None
                and self._consecutive_rejects % self.reject_burst == 0
            ):
                exhausted = self.watchdog.record_external(
                    "admission_overload",
                    {"rejected": self.rejected, "depth": queue_depth},
                )
                if exhausted:
                    self.escalations += 1
            return False
        self._consecutive_rejects = 0
        self.admitted += 1
        return True

    def record_shed(self, n_requests: int, info: Optional[dict] = None) -> None:
        """Count ``n_requests`` shed by an exhausted executor ladder.

        Fault-pressure sheds share the overload escalation budget: each
        shed batch is one external event against the watchdog, so a fault
        storm and a queue overload reach the trainer's restart policy
        through the same counter (``escalations``).
        """
        self.shed += int(n_requests)
        if self.watchdog is not None:
            if self.watchdog.record_external("batch_shed", info or {}):
                self.escalations += 1
