"""Straggler detection for the step loop.

XLA SPMD steps are globally synchronous, so a slow host shows up as a slow
*step*.  The watchdog keeps an EMA of step wall-time and flags steps beyond
``factor x EMA`` as straggler events; the trainer's policy (see DESIGN.md
section 8) is control-plane: log, and after ``budget`` consecutive events
checkpoint + request an elastic restart (possibly on a smaller mesh), which
:class:`repro.runtime.trainer.Trainer` implements via its mesh-independent
checkpoints.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class StragglerWatchdog:
    factor: float = 3.0
    budget: int = 3  # consecutive straggler steps before escalation
    decay: float = 0.9

    ema: Optional[float] = None
    consecutive: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)
    _t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> bool:
        """Returns True if the escalation budget is exhausted.

        Raises :class:`RuntimeError` if no step is open (``start_step`` was
        never called, or this is the second ``end_step`` in a row) instead of
        crashing with ``TypeError`` on the ``None`` timestamp.
        """
        if self._t0 is None:
            raise RuntimeError(
                "StragglerWatchdog.end_step called with no open step; "
                "call start_step() first"
            )
        dt = time.monotonic() - self._t0
        self._t0 = None
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = dt > self.factor * self.ema
        if is_straggler:
            self.consecutive += 1
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:
            self.consecutive = 0
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        return self.consecutive >= self.budget

    def record_external(self, kind: str, info: Optional[dict] = None) -> bool:
        """Record a non-timing health event (e.g. an exchange integrity
        failure from :class:`repro.comm.faults.HealthTracker`) against the
        same escalation budget as straggler steps.

        Returns True if the budget is exhausted, mirroring ``end_step``.
        """
        self.consecutive += 1
        self.events.append({"kind": kind, **(info or {})})
        return self.consecutive >= self.budget
