"""Pod topology helpers: the TPU analogue of the paper's node hierarchy.

A :class:`PodTopology` describes a machine as ``npods`` pods of ``ppn`` chips
(the paper's nodes of PPN processes).  World rank ``r`` lives on pod
``r // ppn`` with pod-local rank ``r % ppn``; this matches the mesh built by
:func:`make_exchange_mesh`, which lays ranks out row-major over
``("pod", "local")``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax

POD_AXIS = "pod"
LOCAL_AXIS = "local"
WORLD_AXES: Tuple[str, str] = (POD_AXIS, LOCAL_AXIS)


@dataclasses.dataclass(frozen=True)
class PodTopology:
    npods: int
    ppn: int  # chips per pod

    @property
    def nranks(self) -> int:
        return self.npods * self.ppn

    def pod_of(self, rank: int) -> int:
        return rank // self.ppn

    def local_of(self, rank: int) -> int:
        return rank % self.ppn

    def rank_of(self, pod: int, local: int) -> int:
        return pod * self.ppn + local

    # ------------------------------------------------------------------
    def agent_local(self, src_pod: int, dst_pod: int) -> int:
        """Pod-local rank of the 3-Step agent for the (src, dst) pod pair.

        The paper pairs "all processes with a receiving process on distinct
        nodes [to] ensure every process remains active"; ``(src+dst) % ppn``
        spreads agent duty over pod-local ranks so different pod pairs use
        different chips.
        """
        return (src_pod + dst_pod) % self.ppn

    def pod_shift_rounds(self) -> List[int]:
        """Inter-pod exchange rounds: pod shifts ``1 .. npods-1``."""
        return list(range(1, self.npods))


def make_exchange_mesh(topology: PodTopology) -> jax.sharding.Mesh:
    """Build a ``(npods, ppn)`` device mesh named ``("pod", "local")``.

    Requires ``jax.device_count() >= topology.nranks`` (tests use
    ``--xla_force_host_platform_device_count``).
    """
    if jax.device_count() < topology.nranks:
        raise ValueError(
            f"need {topology.nranks} devices for {topology}, "
            f"have {jax.device_count()}"
        )
    return jax.make_mesh((topology.npods, topology.ppn), WORLD_AXES)
