"""Deterministic fault injection, wire integrity checks, and self-healing.

The paper's closing discussion argues strategy *choice* must survive real
machines: inter-node links degrade, lossy wire formats misbehave, and one
corrupted DCI payload can silently poison a whole solve.  This module gives
the exchange stack three things:

* **Injection** -- a seeded :class:`FaultPlan` compiled against a concrete
  :class:`~repro.comm.exchange.StagePlan` into per-hop boolean masks over
  exactly the DCI-crossing wire blocks (``A2APod`` off-diagonal blocks,
  inter-pod ``PermuteWorld`` rounds).  The same compiled masks drive both
  :func:`repro.comm.exchange.execute_numpy` and the device executor in
  :mod:`repro.comm.strategies`, so the two stay in bitwise lockstep under
  identical injections.  Fault models: non-finite corruption (``corrupt``),
  value perturbation (``perturb``), zeroed/dropped wire blocks (``zero``),
  and injected slow-hop latency (``slow``).
* **Detection** -- cheap per-wire-block check values (finite-|x| sum,
  non-finite count, finite amax) computed before encode and validated after
  decode.  Exact for codec ``none``; tolerance-aware for lossy codecs using
  :data:`repro.comm.wire.REL_ERROR_BOUND` / ``ABS_ERROR_FLOOR``.  A failed
  check raises a structured :class:`ExchangeIntegrityError` naming the
  stage, hop class, and codec.
* **Recovery** -- :func:`run_ladder`, the shared retry -> codec-demotion ->
  strategy-re-advise policy used by
  :class:`repro.comm.strategies.IrregularExchange` and
  :class:`repro.solve.operator.NumpySpMV`, with a :class:`HealthTracker`
  that marks degraded (strategy, codec) hops, biases the advisor
  (``advise(..., health=...)``) away from them, and feeds the escalation
  budget of :class:`repro.runtime.watchdog.StragglerWatchdog`.

Faults model *link* corruption: they are applied to the decoded values of
wire blocks that actually crossed pods, never to on-pod traffic or the
``A2APod`` own-pod (diagonal) blocks.  Everything here is jax-free; the
device-side twins of the check/injection arithmetic live in
:mod:`repro.comm.strategies` and share the tolerance formula below.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.comm import wire as wire_codec
from repro.comm.exchange import A2APod, PermuteWorld, StagePlan

#: multiplier applied by HealthTracker.penalty to a (strategy, codec) pair
#: that failed integrity verification (effectively excluded from ranking)
DEGRADED_PENALTY = 1e6
#: milder multiplier for a strategy that failed under a *different* codec
SUSPECT_PENALTY = 1e3

FAULT_KINDS = ("corrupt", "perturb", "zero", "slow")

#: expandable codec group accepted in FaultSpec.codecs
LOSSY_CODECS = ("bf16", "f16", "int8")

_EPS32 = float(np.finfo(np.float32).eps)


# ---------------------------------------------------------------------------
# Fault specification
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault model, applied independently to matching DCI wire blocks.

    ``kind``:

    * ``"corrupt"`` -- hit elements are replaced by ``value`` (default
      ``nan``: non-finite corruption).
    * ``"perturb"`` -- hit elements are scaled by ``1 + scale`` (a silent
      value error, large enough by default for the check values to see).
    * ``"zero"``    -- the whole wire block is zeroed (a dropped block).
    * ``"slow"``    -- no value change; adds ``delay_s`` of host-visible
      latency to the exchange (a slow hop, observable by the watchdog).

    ``prob`` fires each candidate wire block independently; ``frac`` is the
    fraction of elements hit inside a fired block (corrupt/perturb; at
    least one element is always hit).  ``hops`` / ``strategies`` /
    ``codecs`` optionally restrict the spec to specific inter-pod hop
    ordinals, plan strategies, or wire codecs (``"lossy"`` expands to
    ``bf16/f16/int8`` -- the idiom for faults that codec demotion cures).
    """

    kind: str = "corrupt"
    prob: float = 1.0
    frac: float = 0.25
    value: float = float("nan")
    scale: float = 0.5
    delay_s: float = 0.0
    hops: Optional[Tuple[int, ...]] = None
    strategies: Optional[Tuple[str, ...]] = None
    codecs: Optional[Tuple[str, ...]] = None

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob must be in [0, 1], got {self.prob}")
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError(f"frac must be in [0, 1], got {self.frac}")

    def matches(self, strategy: str, codec: str) -> bool:
        if self.strategies is not None and strategy not in self.strategies:
            return False
        if self.codecs is not None:
            allowed = []
            for c in self.codecs:
                allowed.extend(LOSSY_CODECS if c == "lossy" else (c,))
            if codec not in allowed:
                return False
        return True


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic set of fault specs.

    Stateless: compiling the same plan against the same stage program and
    codec always yields the same masks, which is what keeps the numpy and
    device executors in bitwise lockstep.  ``active_calls`` optionally
    limits injection to specific call indices of the owning exchange
    (``(0,)`` models a transient fault that a retry cures; ``None`` -- the
    default -- models a persistent fault that needs codec demotion or a
    strategy re-advise).
    """

    seed: int
    specs: Tuple[FaultSpec, ...]
    active_calls: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        if not self.specs:
            raise ValueError("FaultPlan needs at least one FaultSpec")

    def active(self, call_index: int) -> bool:
        return self.active_calls is None or call_index in self.active_calls

    def fingerprint(self) -> str:
        parts = [f"seed={self.seed}", f"calls={self.active_calls}"]
        for s in self.specs:
            parts.append(
                f"{s.kind}:p{s.prob}:f{s.frac}:v{s.value!r}:s{s.scale}:"
                f"d{s.delay_s}:h{s.hops}:st{s.strategies}:c{s.codecs}"
            )
        return "|".join(parts)


# ---------------------------------------------------------------------------
# Hop enumeration + compilation to masks
# ---------------------------------------------------------------------------


def iter_inter_hops(plan: StagePlan):
    """Yield ``(ordinal, op_index, stage_kind, round_index, stage, perm)``
    for every DCI-crossing hop of ``plan``, in program order.

    ``stage_kind`` is ``"a2a_pod"`` (``round_index`` None) or ``"permute"``
    (one entry per inter-pod round with a non-empty permutation).  The
    ordinal is the stable hop id FaultSpec.hops and the check-value
    metadata key on; both executors enumerate hops with this function.
    """
    ordinal = 0
    for i, st in enumerate(plan.stages):
        if isinstance(st, A2APod):
            yield ordinal, i, "a2a_pod", None, st, None
            ordinal += 1
        elif isinstance(st, PermuteWorld):
            inters = st.inter if st.inter is not None else (False,) * len(st.blks)
            for r, (perm, inter) in enumerate(zip(st.rounds, inters)):
                if inter and perm:
                    yield ordinal, i, "permute", r, st, perm
                    ordinal += 1


@dataclasses.dataclass(frozen=True)
class HopInjection:
    """One fault applied to one DCI hop, in both executor layouts.

    ``np_mask`` is the canonical (sender-side) layout used by
    ``execute_numpy``: ``[npods, ppn, npods, blk]`` for ``a2a_pod`` (the
    pre-transpose buffer view), ``[nranks, blk]`` sender rows for
    ``permute``.  ``dev_mask`` is the receiver layout the device executor
    indexes by its own rank: ``[nranks, npods, blk]`` for ``a2a_pod``
    (row r = the mask over that rank's post-collective ``[npods, blk]``
    result), ``[nranks, blk]`` receiver rows for ``permute``.  ``value``
    is the injected constant (``corrupt``), the ``1 + scale`` factor
    (``perturb``), or unused (``zero``).
    """

    ordinal: int
    op_index: int
    stage_kind: str
    round_index: Optional[int]
    kind: str
    value: float
    np_mask: np.ndarray
    dev_mask: np.ndarray


@dataclasses.dataclass(frozen=True)
class CompiledFaults:
    """A FaultPlan bound to one stage program + codec."""

    strategy: str
    codec: str
    delay_s: float
    injections: Tuple[HopInjection, ...]

    def for_hop(self, op_index: int, round_index: Optional[int]) -> Tuple[HopInjection, ...]:
        return tuple(
            inj
            for inj in self.injections
            if inj.op_index == op_index and inj.round_index == round_index
        )


def _elem_mask(rng: np.random.Generator, fire: np.ndarray, blk: int, frac: float) -> np.ndarray:
    """Per-element hit mask ``fire.shape + (blk,)``; fired blocks hit at
    least one element (the draw's argmin position is forced on)."""
    em = rng.random(fire.shape + (blk,))
    elem = em < frac
    idx = em.argmin(axis=-1)
    np.put_along_axis(elem, idx[..., None], True, axis=-1)
    return elem & fire[..., None]


def compile_faults(plan: StagePlan, codec: str, faults: FaultPlan) -> CompiledFaults:
    """Resolve ``faults`` into concrete masks over ``plan``'s DCI hops.

    Deterministic in ``(faults.seed, hop ordinal, spec index)``: every
    random draw comes from ``np.random.default_rng([seed, ordinal, si])``,
    so numpy and device executors compile identical masks independently.
    """
    wire_codec.check_codec(codec)
    topo = plan.pattern.topo
    nranks, ppn, npods = topo.nranks, topo.ppn, topo.npods
    injections: List[HopInjection] = []
    delay = 0.0
    for ordinal, op_index, stage_kind, round_index, st, perm in iter_inter_hops(plan):
        for si, spec in enumerate(faults.specs):
            if not spec.matches(plan.strategy, codec):
                continue
            if spec.hops is not None and ordinal not in spec.hops:
                continue
            rng = np.random.default_rng([faults.seed, ordinal, si])
            if spec.kind == "slow":
                if rng.random() < spec.prob:
                    delay += spec.delay_s
                continue
            if stage_kind == "a2a_pod":
                blk = st.buflen // npods
                fire = rng.random((npods, ppn, npods)) < spec.prob
                diag = np.arange(npods)
                fire[diag, :, diag] = False  # own-pod blocks never cross DCI
                if not fire.any():
                    continue
                if spec.kind == "zero":
                    np_mask = np.broadcast_to(fire[..., None], fire.shape + (blk,)).copy()
                else:
                    np_mask = _elem_mask(rng, fire, blk, spec.frac)
                # receiver layout: rank (p, l) sees res[q] = b[q, l, p]
                dev_mask = np.ascontiguousarray(
                    np_mask.transpose(2, 1, 0, 3).reshape(nranks, npods, blk)
                )
            else:  # permute round
                blk = st.blks[round_index]
                np_mask = np.zeros((nranks, blk), dtype=bool)
                dev_mask = np.zeros((nranks, blk), dtype=bool)
                fires = rng.random(len(perm)) < spec.prob
                rows = (
                    np.broadcast_to(fires[:, None], (len(perm), blk)).copy()
                    if spec.kind == "zero"
                    else _elem_mask(rng, fires, blk, spec.frac)
                )
                if not rows.any():
                    continue
                for k, (s, d) in enumerate(perm):
                    np_mask[s] = rows[k]
                    dev_mask[d] = rows[k]
            value = spec.value if spec.kind == "corrupt" else 1.0 + spec.scale
            injections.append(
                HopInjection(
                    ordinal=ordinal,
                    op_index=op_index,
                    stage_kind=stage_kind,
                    round_index=round_index,
                    kind=spec.kind,
                    value=float(value),
                    np_mask=np_mask,
                    dev_mask=dev_mask,
                )
            )
    return CompiledFaults(
        strategy=plan.strategy,
        codec=codec,
        delay_s=delay,
        injections=tuple(injections),
    )


def apply_injection_np(x: np.ndarray, mask: np.ndarray, kind: str, value: float) -> np.ndarray:
    """Numpy twin of the device-side injection: broadcast ``mask`` over the
    trailing feature dims of ``x`` and apply the fault.  Arithmetic is kept
    in ``x.dtype`` (constant replacement / one same-dtype multiply) so both
    executors round identically."""
    m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
    if kind == "zero":
        return np.where(m, np.zeros((), dtype=x.dtype), x)
    if kind == "corrupt":
        return np.where(m, np.asarray(value, dtype=x.dtype), x)
    if kind == "perturb":
        return np.where(m, x * np.asarray(value, dtype=x.dtype), x)
    raise ValueError(f"unknown injection kind {kind!r}")


# ---------------------------------------------------------------------------
# Wire integrity checks
# ---------------------------------------------------------------------------


def block_check_np(x: np.ndarray, axes: Tuple[int, ...]):
    """Per-wire-block check triple ``(sum |finite x|, nonfinite count,
    finite amax)`` in float32, reduced over ``axes``.

    These are the sender-side check values shipped alongside the payload in
    ``verify=True`` mode and recomputed on the receiver after decode.  The
    device executor computes the same triple with jnp; each executor only
    ever compares values it computed itself, so cross-library summation
    order differences never enter a comparison.
    """
    f = np.asarray(x).astype(np.float32)
    finite = np.isfinite(f)
    mag = np.where(finite, np.abs(f), np.float32(0.0))
    s = mag.sum(axis=axes, dtype=np.float32)
    c = (~finite).sum(axis=axes).astype(np.float32)
    a = np.max(mag, axis=axes, initial=0.0).astype(np.float32)
    return s, c, a


def sum_tolerance(codec: str, nelem: int, amax, sum_abs, encoded: bool):
    """Allowed |sum drift| of a decoded wire block vs its sender check.

    Exact (0) when the codec did not encode the payload; otherwise the
    per-element bound ``REL_ERROR_BOUND * amax + ABS_ERROR_FLOOR`` summed
    over the block, plus a small float32-accumulation margin.  Pure
    arithmetic over python scalars and the ``amax`` / ``sum_abs`` arrays,
    so the numpy and device executors share this exact formula.
    """
    if not encoded:
        return 0.0 * amax
    rel = wire_codec.REL_ERROR_BOUND[codec]
    floor = wire_codec.ABS_ERROR_FLOOR[codec]
    return nelem * (rel * amax + floor) * 1.0625 + 64.0 * _EPS32 * (sum_abs + 1.0)


def check_violation(pre, post, nelem: int, codec: str, encoded: bool) -> np.ndarray:
    """Per-block violation amount: ``> 0`` means the check failed.

    A non-finite-count mismatch is an unconditional violation (``inf``);
    otherwise the sum drift less its tolerance.
    """
    s0, c0, a0 = pre
    s1, c1, _ = post
    tol = sum_tolerance(codec, nelem, a0, s0, encoded)
    drift = np.abs(s1.astype(np.float64) - s0.astype(np.float64)) - tol
    return np.where(c1 != c0, np.float64(np.inf), drift)


class ExchangeIntegrityError(RuntimeError):
    """A wire integrity check failed on a DCI-crossing hop.

    Structured: ``strategy``, ``stage_kind`` (``a2a_pod`` | ``permute``),
    ``op_index`` (stage index in the plan), ``round_index`` (permute round
    or None), ``hop_class`` (always ``"inter_pod"`` -- on-pod hops are
    never checked because they are never encoded or faulted), ``codec``,
    and the worst ``violation`` amount.  :meth:`diagnostics` returns the
    executor-independent fields -- the numpy and device executors raise
    identical diagnostics for the same injection.
    """

    def __init__(
        self,
        *,
        strategy: str,
        codec: str,
        stage_kind: str,
        op_index: int,
        round_index: Optional[int] = None,
        hop_class: str = "inter_pod",
        violation: Optional[float] = None,
    ) -> None:
        self.strategy = strategy
        self.codec = codec
        self.stage_kind = stage_kind
        self.op_index = op_index
        self.round_index = round_index
        self.hop_class = hop_class
        self.violation = violation
        where = f"stage#{op_index} {stage_kind}"
        if round_index is not None:
            where += f" round {round_index}"
        msg = (
            f"exchange integrity violation: strategy={strategy} {where} "
            f"hop_class={hop_class} codec={codec}"
        )
        if violation is not None:
            msg += f" violation={violation:g}"
        super().__init__(msg)

    def diagnostics(self) -> Dict[str, object]:
        """Executor-independent identity of the failure (no float amounts)."""
        return {
            "strategy": self.strategy,
            "stage_kind": self.stage_kind,
            "op_index": self.op_index,
            "round_index": self.round_index,
            "hop_class": self.hop_class,
            "codec": self.codec,
        }


def raise_if_violated(
    viol: np.ndarray,
    *,
    strategy: str,
    codec: str,
    stage_kind: str,
    op_index: int,
    round_index: Optional[int] = None,
) -> None:
    v = np.asarray(viol)
    if v.size and bool((v > 0.0).any()):
        raise ExchangeIntegrityError(
            strategy=strategy,
            codec=codec,
            stage_kind=stage_kind,
            op_index=op_index,
            round_index=round_index,
            violation=float(v.max()),
        )


# ---------------------------------------------------------------------------
# Health tracking + the recovery ladder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HealthTracker:
    """Per-(strategy, codec) integrity health, shared across the ladder.

    ``record_failure`` marks the offending hop degraded and (optionally)
    feeds :meth:`repro.runtime.watchdog.StragglerWatchdog.record_external`
    so integrity failures draw on the same escalation budget as straggler
    steps.  :meth:`penalty` is the multiplier
    ``repro.core.advisor.advise(..., health=...)`` applies to a degraded
    pair's predicted time, which is what steers the re-advise step of the
    ladder away from the offending hop.

    Degradation is a circuit breaker, not a permanent sentence.  A pair
    that crosses ``degrade_after`` failures opens its breaker and, after a
    deterministic call-count cooldown, moves to half-open: the next ladder
    entry on that pair runs as a probe.  A successful probe closes the
    breaker (failure count and penalty reset); a failed probe re-opens it
    with the cooldown doubled.  The clock is :meth:`record_call` ticks --
    one per ladder entry -- so recovery is reproducible under replay.

    ``events`` is a ring buffer capped at ``max_events`` entries; overflow
    increments ``dropped`` instead of leaking memory on long-running
    serves.
    """

    degrade_after: int = 1
    watchdog: Optional[object] = None
    failures: Dict[Tuple[str, str], int] = dataclasses.field(default_factory=dict)
    events: List[dict] = dataclasses.field(default_factory=list)
    recovery_count: int = 0
    last_recovery: Optional[str] = None
    max_events: int = 256
    dropped: int = 0
    cooldown: int = 8
    cooldown_growth: float = 2.0
    calls: int = 0
    probes: int = 0
    probe_recoveries: int = 0
    _opened_at: Dict[Tuple[str, str], int] = dataclasses.field(default_factory=dict)
    _cooldowns: Dict[Tuple[str, str], int] = dataclasses.field(default_factory=dict)

    def _push_event(self, ev: dict) -> None:
        self.events.append(ev)
        over = len(self.events) - self.max_events
        if over > 0:
            del self.events[:over]
            self.dropped += over

    def record_call(self) -> None:
        """Advance the breaker clock by one ladder entry."""
        self.calls += 1

    def breaker_state(self, strategy: str, wire: str) -> str:
        """``"closed"`` (healthy), ``"open"`` (priced out), or
        ``"half_open"`` (cooldown elapsed -- next call probes)."""
        key = (strategy, wire)
        if self.failures.get(key, 0) < self.degrade_after:
            return "closed"
        opened = self._opened_at.get(key)
        if opened is None:
            # degraded without breaker bookkeeping (e.g. failures set
            # directly by a test or imported from a prior run): stay open
            return "open"
        wait = self._cooldowns.get(key, self.cooldown)
        return "half_open" if self.calls - opened >= wait else "open"

    def record_failure(self, err: ExchangeIntegrityError) -> None:
        key = (err.strategy, err.codec)
        was = self.breaker_state(*key)
        self.failures[key] = self.failures.get(key, 0) + 1
        if self.failures[key] >= self.degrade_after:
            if was == "closed":
                self._opened_at[key] = self.calls
                self._cooldowns.setdefault(key, max(1, self.cooldown))
            elif was == "half_open":
                # failed probe: re-open with doubled cooldown
                old = self._cooldowns.get(key, self.cooldown)
                self._opened_at[key] = self.calls
                self._cooldowns[key] = max(1, int(old * self.cooldown_growth))
            # was == "open": a ladder-rung failure while already open does
            # not extend the cooldown clock
        self._push_event({"kind": "integrity_failure", **err.diagnostics()})
        if self.watchdog is not None:
            self.watchdog.record_external("exchange_integrity", err.diagnostics())

    def record_success(self, strategy: str, wire: str) -> bool:
        """Close a half-open breaker after a clean probe exchange.

        No-op unless ``(strategy, wire)`` is half-open; returns whether the
        breaker closed.  Closing resets the pair's failure count (so
        :meth:`penalty` returns 1.0 again and ``advise(health=...)``
        rankings recover) and its cooldown back to the base value.
        """
        key = (strategy, wire)
        if self.breaker_state(strategy, wire) != "half_open":
            return False
        self.failures.pop(key, None)
        self._opened_at.pop(key, None)
        self._cooldowns.pop(key, None)
        self.probe_recoveries += 1
        self._push_event(
            {"kind": "probe_recovery", "strategy": strategy, "wire": wire}
        )
        return True

    def note_probe(self, strategy: str, wire: str) -> None:
        self.probes += 1
        self._push_event({"kind": "probe", "strategy": strategy, "wire": wire})

    def record_recovery(self, action: str, strategy: str, wire: str) -> None:
        self.recovery_count += 1
        self.last_recovery = f"{action}:{strategy}/{wire}"
        self._push_event(
            {"kind": "recovery", "action": action, "strategy": strategy, "wire": wire}
        )

    def is_degraded(self, strategy: str, wire: Optional[str] = None) -> bool:
        if wire is None:
            return any(
                k[0] == strategy and v >= self.degrade_after
                for k, v in self.failures.items()
            )
        return self.failures.get((strategy, wire), 0) >= self.degrade_after

    def degraded(self) -> Tuple[Tuple[str, str], ...]:
        return tuple(
            sorted(k for k, v in self.failures.items() if v >= self.degrade_after)
        )

    def penalty(self, strategy: str, wire: str = "none") -> float:
        if self.is_degraded(strategy, wire):
            return DEGRADED_PENALTY
        if self.is_degraded(strategy):
            return SUSPECT_PENALTY
        return 1.0


@dataclasses.dataclass(frozen=True)
class RecoveryPath:
    """How the ladder recovered: the action taken and what it ran on."""

    action: str  # "retry" | "demote" | "readvise"
    strategy: str
    wire: str

    @property
    def key(self) -> str:
        return f"{self.action}:{self.strategy}/{self.wire}"


def run_ladder(
    attempt: Callable[[str, str], object],
    *,
    strategy: str,
    wire: str,
    health: Optional[HealthTracker] = None,
    max_retries: int = 1,
    fallback: bool = True,
    choose_alternative: Optional[Callable[[HealthTracker, str], Optional[str]]] = None,
):
    """The retry -> demote -> re-advise recovery ladder.

    ``attempt(strategy, wire)`` runs one exchange and raises
    :class:`ExchangeIntegrityError` on a failed check.  The ladder tries
    the configured pair up to ``1 + max_retries`` times (a transient fault
    recovers here), then demotes a lossy codec to ``"none"`` (a
    codec-triggered fault recovers here), then asks ``choose_alternative``
    for a replacement strategy with the offending hops marked degraded in
    ``health``.  Returns ``(value, RecoveryPath | None)``; every failure is
    recorded in ``health`` before the next rung runs, so the re-advise rung
    sees the demotion failure too.  Raises the last integrity error when
    the ladder is exhausted (or ``fallback`` is off).

    Each entry also advances the health tracker's breaker clock: a pair
    whose breaker has cooled to half-open runs its first attempt as a
    probe, and any clean attempt on a half-open pair closes that breaker
    (:meth:`HealthTracker.record_success`) so the advisor's penalties
    recover once the link heals.
    """
    health = health if health is not None else HealthTracker()
    health.record_call()
    if health.breaker_state(strategy, wire) == "half_open":
        health.note_probe(strategy, wire)
    last: Optional[ExchangeIntegrityError] = None
    for i in range(1 + max(0, max_retries)):
        try:
            out = attempt(strategy, wire)
        except ExchangeIntegrityError as e:
            last = e
            health.record_failure(e)
            continue
        health.record_success(strategy, wire)
        if i == 0:
            return out, None
        health.record_recovery("retry", strategy, wire)
        return out, RecoveryPath("retry", strategy, wire)
    if fallback and wire != "none":
        try:
            out = attempt(strategy, "none")
        except ExchangeIntegrityError as e:
            last = e
            health.record_failure(e)
        else:
            health.record_success(strategy, "none")
            health.record_recovery("demote", strategy, "none")
            return out, RecoveryPath("demote", strategy, "none")
    if fallback and choose_alternative is not None:
        alt = choose_alternative(health, strategy)
        if alt is not None and alt != strategy:
            try:
                out = attempt(alt, "none")
            except ExchangeIntegrityError as e:
                health.record_failure(e)
                raise
            health.record_success(alt, "none")
            health.record_recovery("readvise", alt, "none")
            return out, RecoveryPath("readvise", alt, "none")
    assert last is not None
    raise last


def advise_alternative(
    pattern, elem_bytes: int = 4, machine: str = "tpu_v5e_pod"
) -> Callable[[HealthTracker, str], Optional[str]]:
    """Build the ladder's re-advise chooser for one exchange pattern.

    Ranks strategies with :func:`repro.core.advisor.advise` under the
    health tracker's degradation penalties (the paper's per-hop-class model
    terms re-ranked with the offending hop priced out) and returns the best
    non-degraded strategy different from the current one; falls back to a
    fixed preference order if the advisor's whole ranking is degraded.
    """

    def choose(health: HealthTracker, current: str) -> Optional[str]:
        # local import: repro.core.advisor -> perfmodel is a heavier import
        # chain and must not be paid at comm-module import time
        from repro.core.advisor import EXECUTABLE_STRATEGY, advise

        adv = advise(
            pattern.to_comm_pattern(elem_bytes), machine=machine, health=health
        )
        for rec in adv.ranked:
            name = EXECUTABLE_STRATEGY[rec.strategy]
            if name != current and not health.is_degraded(name):
                return name
        for name in ("two_step", "three_step", "split", "standard"):
            if name != current and not health.is_degraded(name):
                return name
        return None

    return choose
