"""Setup-time planning for irregular element exchanges.

This is the executable heart of the paper on TPU: an irregular
"who needs which elements from whom" pattern (e.g. the SpMV halo, MoE token
routing) is compiled, at setup time, into a static **stage program** -- a
sequence of gathers and mesh collectives -- one program per node-aware
strategy (Standard / 3-Step / 2-Step / Split).  The stage program is then
executed by :mod:`repro.comm.strategies` under ``shard_map``, optionally
after the rewrites in :mod:`repro.comm.fusion`.

Planning is *verified by construction*: a symbolic token simulator runs the
same stage semantics over ``(owner, element)`` tokens, so the planner can
resolve "where does token t live in rank r's buffer right now" exactly, and
tests can assert every strategy delivers the canonical receive layout.

The planner's symbolic state is **vectorized**: tokens are encoded as int64
codes ``owner * local_size + elem`` (``PAD_CODE = -1``), buffers are dense
``[nranks, buflen]`` arrays, and every stage transition / position lookup /
byte-accounting sweep is a numpy array op.  The original pure-Python
token-list planner survives in :mod:`repro.comm._legacy_planner` as a
benchmark baseline; the token-list simulator below stays as the oracle.

Stage semantics (mirrored exactly by the JAX executor):

* ``Gather(idx)``      -- per rank: ``new_buf[k] = ext[idx[k]]`` where
  ``ext = concat(current_buf, original_local)`` and ``idx == len(ext)`` is a
  PAD sentinel (delivers 0).
* ``A2ALocal()``       -- ``all_to_all`` over the pod-local axis on the
  ``[ppn, blk]`` view of the buffer.  An optional fused ``idx`` (installed
  by the fusion pass) applies a Gather to ``ext`` first.
* ``A2APod()``         -- ``all_to_all`` over the pod axis on ``[npods, blk]``,
  with the same optional fused input ``idx``.
* ``PermuteWorld(...)``-- rounds of world-level ``ppermute``; each round the
  sender selects ``sel[round]`` from ``ext`` and the received blocks are
  concatenated into the new buffer.

For overlapped execution, :func:`split_phase` factors a pattern into its
on-pod and inter-pod sub-patterns (the two phases of
:meth:`repro.comm.strategies.IrregularExchange.start`), and
:func:`merge_split_phase` is the numpy oracle for reassembling the full
canonical buffer from the two phase outputs.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm import wire as wire_codec
from repro.comm.topology import PodTopology
from repro.core.patterns import CommPattern, Message

Token = Tuple[int, int]  # (owner rank, element index)

#: PAD marker in token-code arrays (token codes are ``owner * L + elem``).
PAD_CODE = -1

_EMPTY = np.zeros((0,), dtype=np.int64)


# ---------------------------------------------------------------------------
# Pattern
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Need:
    """Rank ``dst`` needs elements ``idx`` of rank ``src``'s local buffer."""

    dst: int
    src: int
    idx: Tuple[int, ...]

    def __post_init__(self) -> None:
        if list(self.idx) != sorted(set(self.idx)):
            raise ValueError("Need.idx must be sorted and unique")


@dataclasses.dataclass(frozen=True)
class ExchangePattern:
    """Static irregular exchange pattern over a pod topology."""

    topo: PodTopology
    local_size: int
    needs: Tuple[Need, ...]

    def __post_init__(self) -> None:
        seen = set()
        for n in self.needs:
            if (n.dst, n.src) in seen:
                raise ValueError(f"duplicate need for (dst={n.dst}, src={n.src})")
            seen.add((n.dst, n.src))
            if n.src == n.dst:
                raise ValueError("self-needs are not communication")
            if n.idx and max(n.idx) >= self.local_size:
                raise ValueError("need index out of range")

    # -- canonical receive layout -------------------------------------
    def needs_of(self, dst: int) -> List[Need]:
        return sorted((n for n in self.needs if n.dst == dst), key=lambda n: n.src)

    def recv_size(self, dst: int) -> int:
        return sum(len(n.idx) for n in self.needs_of(dst))

    def max_recv_size(self) -> int:
        return max((self.recv_size(r) for r in range(self.topo.nranks)), default=0)

    def canonical_tokens(self, dst: int) -> List[Token]:
        out: List[Token] = []
        for n in self.needs_of(dst):
            out.extend((n.src, e) for e in n.idx)
        return out

    def canonical_code_rows(self) -> List[np.ndarray]:
        """``canonical_codes`` for every rank, in one pass over ``needs``."""
        acc: List[List[Need]] = [[] for _ in range(self.topo.nranks)]
        for n in self.needs:
            acc[n.dst].append(n)
        out = []
        for row in acc:
            row.sort(key=lambda n: n.src)
            parts = [
                n.src * self.local_size + np.asarray(n.idx, dtype=np.int64)
                for n in row
            ]
            out.append(np.concatenate(parts) if parts else _EMPTY)
        return out

    def fingerprint(self) -> str:
        """Stable content hash: cache / CSV key for this exact pattern.

        Hashes one flat int64 buffer -- header ``(npods, ppn, local_size,
        n_needs)``, then a ``(dst, src, len)`` triple per need in
        ``(dst, src)`` order, then every need's indices concatenated -- so
        the digest is a bijective, need-order-invariant function of the
        pattern at the cost of a single numpy conversion + hash pass,
        instead of O(total indices) Python string formatting.  This is on
        the per-batch path for dynamic (MoE routing) patterns.  The digest
        is memoized on the instance: patterns are frozen, so repeated
        cache lookups under the same pattern hash nothing.
        """
        cached = getattr(self, "_fp_memo", None)
        if cached is not None:
            return cached
        rows = sorted(self.needs, key=lambda n: (n.dst, n.src))
        buf = [self.topo.npods, self.topo.ppn, self.local_size, len(rows)]
        for n in rows:
            buf.append(n.dst)
            buf.append(n.src)
            buf.append(len(n.idx))
        for n in rows:
            buf.extend(n.idx)
        fp = hashlib.sha1(np.asarray(buf, dtype=np.int64).tobytes()).hexdigest()
        object.__setattr__(self, "_fp_memo", fp)
        return fp

    # -- derived views -------------------------------------------------
    def dedup_for_pod(self, src: int, dst_pod: int) -> List[int]:
        """Union of elements of ``src`` needed by any rank in ``dst_pod``
        (the node-aware data-redundancy elimination, paper §2.3)."""
        elems: set = set()
        for n in self.needs:
            if n.src == src and self.topo.pod_of(n.dst) == dst_pod:
                elems.update(n.idx)
        return sorted(elems)

    def to_comm_pattern(self, elem_bytes: int = 4) -> CommPattern:
        """Byte-level view for the performance models / advisor."""
        msgs = [
            Message(n.src, n.dst, len(n.idx) * elem_bytes)
            for n in self.needs
            if n.idx
        ]
        return CommPattern.from_messages(self.topo.nranks, self.topo.ppn, msgs)

    # -- reference oracle ----------------------------------------------
    def reference(self, local: np.ndarray) -> np.ndarray:
        """Numpy oracle: ``local [nranks, L] -> canonical recv [nranks, H]``."""
        nranks, H = self.topo.nranks, self.max_recv_size()
        out = np.zeros((nranks, H) + local.shape[2:], dtype=local.dtype)
        for r in range(nranks):
            toks = self.canonical_tokens(r)
            for k, (owner, e) in enumerate(toks):
                out[r, k] = local[owner, e]
        return out


def random_pattern(
    rng: np.random.Generator,
    topo: PodTopology,
    local_size: int,
    p_connect: float = 0.5,
    max_elems: Optional[int] = None,
) -> ExchangePattern:
    """Random irregular pattern for property tests."""
    max_elems = max_elems or local_size
    needs = []
    for dst in range(topo.nranks):
        for src in range(topo.nranks):
            if src == dst or rng.random() > p_connect:
                continue
            k = int(rng.integers(1, max_elems + 1))
            idx = np.sort(rng.choice(local_size, size=min(k, local_size), replace=False))
            needs.append(Need(dst, src, tuple(int(i) for i in idx)))
    return ExchangePattern(topo=topo, local_size=local_size, needs=tuple(needs))


# ---------------------------------------------------------------------------
# All-to-all-shaped (routing) patterns and count bucketing
# ---------------------------------------------------------------------------


def block_pattern(
    topo: PodTopology,
    block: int,
    widths: Optional[np.ndarray] = None,
) -> ExchangePattern:
    """The element-level pattern of a (possibly ragged) tiled all-to-all.

    Every rank's local buffer is ``nranks`` destination blocks of ``block``
    slots; rank ``s`` sends the first ``widths[s, d]`` slots of its ``d``-th
    block to rank ``d`` (``widths=None`` means full blocks -- the flat
    ``jax.lax.all_to_all``).  This is exactly the shape of capacity-based
    MoE token dispatch: the router fills block ``d`` with the tokens bound
    for shard ``d``, and ``widths`` is the (quantized) per-pair token count,
    so skewed routing ships only the occupied slot prefix per pair.

    Self blocks never appear (they stay on-device); the canonical receive
    layout is src-major, matching the tiled all-to-all's block order minus
    the self block.
    """
    n = topo.nranks
    if widths is None:
        w = np.full((n, n), block, dtype=np.int64)
    else:
        w = np.asarray(widths, dtype=np.int64)
        if w.shape != (n, n):
            raise ValueError(f"widths must be [{n}, {n}], got {w.shape}")
        if (w < 0).any() or (w > block).any():
            raise ValueError(f"widths must lie in [0, {block}]")
    needs = []
    for d in range(n):
        base = d * block
        for s in range(n):
            k = int(w[s, d])
            if s == d or k == 0:
                continue
            needs.append(Need(dst=d, src=s, idx=tuple(range(base, base + k))))
    return ExchangePattern(topo=topo, local_size=n * block, needs=tuple(needs))


def quantize_widths(counts: np.ndarray, quantum: int, cap: int) -> np.ndarray:
    """Bucket per-pair token counts up to ``quantum``-slot granularity.

    ``counts[s, d]`` is the measured number of tokens rank ``s`` routed to
    rank ``d`` this batch; the result is the per-pair slot width to actually
    exchange: counts are clipped to the capacity ``cap`` (tokens beyond it
    were dropped anyway), then rounded UP to a multiple of ``quantum`` (and
    re-clipped to ``cap``).  Rounding up makes the width a safe upper bound
    on the occupied slot prefix, and quantization collapses nearby counts
    onto the same width so :meth:`ExchangePattern.fingerprint`-keyed plan
    caches hit under fluctuating-but-stationary load skew.  Zero counts stay
    zero (the pair drops out of the pattern entirely).
    """
    if quantum < 1:
        raise ValueError(f"quantum must be >= 1, got {quantum}")
    c = np.minimum(np.asarray(counts, dtype=np.int64), cap)
    if (c < 0).any():
        raise ValueError("counts must be non-negative")
    q = -(-c // quantum) * quantum  # ceil to quantum
    return np.minimum(q, cap)


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Gather:
    idx: np.ndarray  # [nranks, K] int32; idx == len(ext) means PAD


@dataclasses.dataclass(frozen=True)
class A2ALocal:
    buflen: int  # divisible by ppn
    #: optional fused input layout (a Gather folded in by repro.comm.fusion)
    idx: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class A2APod:
    buflen: int  # divisible by npods
    idx: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class PermuteWorld:
    #: rounds[r] = tuple of (src_rank, dst_rank) pairs (a partial permutation)
    rounds: Tuple[Tuple[Tuple[int, int], ...], ...]
    #: per-round block length
    blks: Tuple[int, ...]
    #: sel[round] = [nranks, blks[round]] indices into ext (PAD = len(ext))
    sels: Tuple[np.ndarray, ...]
    #: inter[round] = True iff every pair in the round crosses pods -- the
    #: stage metadata wire codecs key on (a mixed round stays full
    #: precision; ``None`` means unclassified and is treated as on-pod)
    inter: Optional[Tuple[bool, ...]] = None


Stage = object  # union of the four dataclasses above


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """A full strategy program plus bookkeeping for benchmarks/tests."""

    strategy: str
    pattern: ExchangePattern
    stages: Tuple[Stage, ...]
    out_size: int
    #: payload bytes moved (excluding padding) per fabric, per whole machine
    intra_pod_bytes: int
    inter_pod_bytes: int
    #: bytes actually on the wire including padding (what XLA would move)
    wire_intra_pod_bytes: int
    wire_inter_pod_bytes: int
    #: True once repro.comm.fusion rewrote the stage program
    fused: bool = False


# ---------------------------------------------------------------------------
# Program lowering (ext-once execution layout)
# ---------------------------------------------------------------------------


def rebase_indices(idx: np.ndarray, w: int, L: int, sentinel: int) -> np.ndarray:
    """Re-base stage indices from ``ext = [buf(w) | local(L)]`` coordinates
    onto the fixed ``[local(L) | buf(W_max)]`` scratch layout.

    PADs (``idx >= w + L``) map to ``sentinel`` (one past the scratch), which
    ``.get(mode='fill')`` turns into zeros.
    """
    idx = np.asarray(idx)
    out = np.full(idx.shape, sentinel, dtype=np.int32)
    np.copyto(out, (idx + L).astype(np.int32), where=idx < w)
    np.copyto(out, (idx - w).astype(np.int32), where=(idx >= w) & (idx < w + L))
    return out


@dataclasses.dataclass(frozen=True)
class LoweredProgram:
    """A stage program lowered to interpreter ops + re-based index arrays.

    The value half of a traceable exchange: ``ops`` is a static tuple of
    interpreter opcodes (hashable -- safe to close over inside ``jit``) and
    ``arrays`` is the pytree of per-rank ``[nranks, ...]`` int32 index
    arrays the ops address, every one re-based onto the single
    ``[local(L) | buf(W_max)]`` scratch so no stage re-concatenates
    ``[buf, local]``.  Built by :func:`lower_program`; interpreted per shard
    by the pure ``run`` callable of
    :class:`repro.comm.strategies.TraceableExchange`.
    """

    ops: Tuple[tuple, ...]
    arrays: Tuple[np.ndarray, ...]
    w_max: int
    local_size: int
    out_size: int


def lower_program(sp: StagePlan) -> LoweredProgram:
    """Lower a planned stage program to its traceable ext-once form.

    Returns a :class:`LoweredProgram` whose every index array addresses the
    ``[local | buf]`` scratch of width ``L + W_max`` directly.
    """
    L = sp.pattern.local_size
    widths: List[int] = []
    w = 0
    for st in sp.stages:
        if isinstance(st, Gather):
            w = st.idx.shape[1]
        elif isinstance(st, (A2ALocal, A2APod)):
            w = st.buflen
        elif isinstance(st, PermuteWorld):
            w = sum(st.blks)
        else:
            raise TypeError(f"unknown stage {st!r}")
        widths.append(w)
    w_max = max(widths, default=0)
    w_max = max(w_max, sp.out_size)
    sentinel = L + w_max

    ops: List[tuple] = []
    arrays: List[np.ndarray] = []
    w = 0
    for st in sp.stages:
        if isinstance(st, Gather):
            arrays.append(rebase_indices(st.idx, w, L, sentinel))
            w = st.idx.shape[1]
            ops.append(("gather", w))
        elif isinstance(st, (A2ALocal, A2APod)):
            kind = "a2a_local" if isinstance(st, A2ALocal) else "a2a_pod"
            has_idx = st.idx is not None
            if has_idx:
                arrays.append(rebase_indices(st.idx, w, L, sentinel))
            ops.append((kind, st.buflen, has_idx))
            w = st.buflen
        elif isinstance(st, PermuteWorld):
            for sel in st.sels:
                arrays.append(rebase_indices(sel, w, L, sentinel))
            inter = st.inter if st.inter is not None else (False,) * len(st.blks)
            ops.append(("permute", st.rounds, st.blks, inter))
            w = sum(st.blks)
    return LoweredProgram(
        ops=tuple(ops),
        arrays=tuple(arrays),
        w_max=w_max,
        local_size=L,
        out_size=sp.out_size,
    )


# ---------------------------------------------------------------------------
# Symbolic simulator, token-list flavor (oracle for tests and planning)
# ---------------------------------------------------------------------------

PAD: Optional[Token] = None


def _token_gather(stage_idx, buf, local):
    new = []
    for r in range(len(buf)):
        ext = buf[r] + list(local[r])
        row = []
        for i in stage_idx[r]:
            row.append(PAD if i >= len(ext) else ext[int(i)])
        new.append(row)
    return new


def simulate_stage(
    topo: PodTopology,
    stage: Stage,
    buf: List[List[Optional[Token]]],
    local: List[List[Token]],
) -> List[List[Optional[Token]]]:
    nranks, ppn, npods = topo.nranks, topo.ppn, topo.npods
    if isinstance(stage, Gather):
        return _token_gather(stage.idx, buf, local)
    if isinstance(stage, A2ALocal):
        if stage.idx is not None:
            buf = _token_gather(stage.idx, buf, local)
        blk = stage.buflen // ppn
        new = [[PAD] * stage.buflen for _ in range(nranks)]
        for p in range(npods):
            for l in range(ppn):
                r = topo.rank_of(p, l)
                for j in range(ppn):
                    src = topo.rank_of(p, j)
                    new[r][j * blk : (j + 1) * blk] = buf[src][l * blk : (l + 1) * blk]
        return new
    if isinstance(stage, A2APod):
        if stage.idx is not None:
            buf = _token_gather(stage.idx, buf, local)
        blk = stage.buflen // npods
        new = [[PAD] * stage.buflen for _ in range(nranks)]
        for p in range(npods):
            for l in range(ppn):
                r = topo.rank_of(p, l)
                for q in range(npods):
                    src = topo.rank_of(q, l)
                    new[r][q * blk : (q + 1) * blk] = buf[src][p * blk : (p + 1) * blk]
        return new
    if isinstance(stage, PermuteWorld):
        new = [[] for _ in range(nranks)]
        for rnd, (perm, blk, sel) in enumerate(zip(stage.rounds, stage.blks, stage.sels)):
            send = []
            for r in range(nranks):
                ext = buf[r] + list(local[r])
                send.append(
                    [PAD if i >= len(ext) else ext[int(i)] for i in sel[r]]
                )
            got = {d: send[s] for s, d in perm}
            for r in range(nranks):
                new[r].extend(got.get(r, [PAD] * blk))
        return new
    raise TypeError(f"unknown stage {stage!r}")


def simulate(plan: StagePlan) -> List[List[Optional[Token]]]:
    topo = plan.pattern.topo
    local = [
        [(r, e) for e in range(plan.pattern.local_size)]
        for r in range(topo.nranks)
    ]
    buf: List[List[Optional[Token]]] = [[] for _ in range(topo.nranks)]
    for stage in plan.stages:
        buf = simulate_stage(topo, stage, buf, local)
    return buf


# ---------------------------------------------------------------------------
# Symbolic simulator, vectorized token-code flavor (used by the planner)
# ---------------------------------------------------------------------------


def _gather_codes(ext: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """``out[r, k] = ext[r, idx[r, k]]`` with ``idx >= E`` -> PAD_CODE."""
    n, E = ext.shape
    if E == 0:
        return np.full(idx.shape, PAD_CODE, dtype=np.int64)
    safe = np.minimum(idx, E - 1)
    out = ext[np.arange(n)[:, None], safe]
    return np.where(idx >= E, PAD_CODE, out)


def simulate_stage_codes(
    topo: PodTopology,
    stage: Stage,
    buf: np.ndarray,  # [nranks, W] int64 token codes, PAD_CODE = -1
    local: np.ndarray,  # [nranks, L]
) -> np.ndarray:
    nranks, ppn, npods = topo.nranks, topo.ppn, topo.npods
    if isinstance(stage, Gather):
        return _gather_codes(np.concatenate([buf, local], axis=1), np.asarray(stage.idx))
    if isinstance(stage, (A2ALocal, A2APod)):
        if stage.idx is not None:
            buf = _gather_codes(
                np.concatenate([buf, local], axis=1), np.asarray(stage.idx)
            )
        if isinstance(stage, A2ALocal):
            blk = stage.buflen // ppn
            b = buf.reshape(npods, ppn, ppn, blk)
            return b.transpose(0, 2, 1, 3).reshape(nranks, stage.buflen)
        blk = stage.buflen // npods
        b = buf.reshape(npods, ppn, npods, blk)
        return b.transpose(2, 1, 0, 3).reshape(nranks, stage.buflen)
    if isinstance(stage, PermuteWorld):
        ext = np.concatenate([buf, local], axis=1)
        parts = []
        for perm, blk, sel in zip(stage.rounds, stage.blks, stage.sels):
            send = _gather_codes(ext, np.asarray(sel))
            out = np.full((nranks, blk), PAD_CODE, dtype=np.int64)
            if perm:
                srcs = [s for s, _ in perm]
                dsts = [d for _, d in perm]
                out[dsts] = send[srcs]
            parts.append(out)
        if not parts:
            return np.zeros((nranks, 0), dtype=np.int64)
        return np.concatenate(parts, axis=1)
    raise TypeError(f"unknown stage {stage!r}")


def local_codes(pattern: ExchangePattern) -> np.ndarray:
    """``[nranks, L]`` token codes of every rank's own elements."""
    n, L = pattern.topo.nranks, pattern.local_size
    return (np.arange(n, dtype=np.int64)[:, None] * L + np.arange(L)[None, :]).reshape(
        n, L
    )


def simulate_codes(plan: StagePlan) -> np.ndarray:
    """Run the whole stage program over token codes; final ``[nranks, W]``."""
    topo = plan.pattern.topo
    local = local_codes(plan.pattern)
    buf = np.zeros((topo.nranks, 0), dtype=np.int64)
    for stage in plan.stages:
        buf = simulate_stage_codes(topo, stage, buf, local)
    return buf


# ---------------------------------------------------------------------------
# Numpy value executor (jax-free oracle for the fused/unfused programs)
# ---------------------------------------------------------------------------


def _take_fill(ext: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Value gather with 0-fill for PAD; ``ext [n, E, *feat]``."""
    n, E = ext.shape[:2]
    if E == 0:
        return np.zeros((n,) + idx.shape[1:] + ext.shape[2:], dtype=ext.dtype)
    safe = np.minimum(idx, E - 1)
    out = ext[np.arange(n)[:, None], safe]
    out[idx >= E] = 0
    return out


def execute_numpy(
    plan: StagePlan,
    local: np.ndarray,
    wire: str = "none",
    *,
    faults=None,
    fault_call: int = 0,
    verify: bool = False,
) -> np.ndarray:
    """Execute a stage program in numpy: ``local [n, L, *feat] -> [n, H, *feat]``.

    Exact (bit-identical) data movement; no jax required.  Used to verify
    that fused and unfused programs deliver identical values.

    ``wire`` selects the inter-pod codec (:mod:`repro.comm.wire`): payloads
    crossing pods -- every non-diagonal ``A2APod`` block and every inter-pod
    ``PermuteWorld`` round -- are encode/decode round-tripped exactly the
    way the device executor would, while on-pod hops stay full precision.
    ``wire="none"`` (the default) is the unchanged bit-exact movement.

    ``faults`` (a :class:`repro.comm.faults.FaultPlan`) injects seeded
    deterministic corruption into the decoded DCI-crossing wire blocks --
    bitwise identical to the device executor under the same plan --
    gated by ``faults.active(fault_call)``.  ``verify=True`` computes the
    per-wire-block check values of :mod:`repro.comm.faults` before the
    codec round-trip and validates them after decode+injection, raising a
    structured :class:`repro.comm.faults.ExchangeIntegrityError` at the
    first violating hop; fault-free verified runs return the same values
    as unverified ones.
    """
    wire_codec.check_codec(wire)
    # local import: repro.comm.faults imports this module's stage types
    from repro.comm import faults as faults_mod

    cf = None
    if faults is not None and faults.active(fault_call):
        cf = faults_mod.compile_faults(plan, wire, faults)
    topo = plan.pattern.topo
    nranks, ppn, npods = topo.nranks, topo.ppn, topo.npods
    local = np.asarray(local)
    feat = local.shape[2:]
    encoded = wire_codec.applies(wire, local.dtype)
    buf = np.zeros((nranks, 0) + feat, dtype=local.dtype)
    for op_i, stage in enumerate(plan.stages):
        if isinstance(stage, Gather):
            buf = _take_fill(np.concatenate([buf, local], axis=1), np.asarray(stage.idx))
        elif isinstance(stage, (A2ALocal, A2APod)):
            if stage.idx is not None:
                buf = _take_fill(
                    np.concatenate([buf, local], axis=1), np.asarray(stage.idx)
                )
            if isinstance(stage, A2ALocal):
                blk = stage.buflen // ppn
                b = buf.reshape((npods, ppn, ppn, blk) + feat)
                buf = b.transpose((0, 2, 1, 3) + tuple(range(4, 4 + len(feat)))).reshape(
                    (nranks, stage.buflen) + feat
                )
            else:
                blk = stage.buflen // npods
                b = buf.reshape((npods, ppn, npods, blk) + feat)
                axes = tuple(range(3, b.ndim))
                pre = faults_mod.block_check_np(b, axes) if verify else None
                # the inter-pod hop: round-trip off-diagonal blocks through
                # the wire codec (diagonal blocks never cross DCI)
                b = wire_codec.roundtrip_pod_blocks_np(b, wire)
                if cf is not None:
                    for inj in cf.for_hop(op_i, None):
                        b = faults_mod.apply_injection_np(
                            b, inj.np_mask, inj.kind, inj.value
                        )
                if verify:
                    post = faults_mod.block_check_np(b, axes)
                    nelem = blk * int(np.prod(feat, dtype=np.int64))
                    faults_mod.raise_if_violated(
                        faults_mod.check_violation(pre, post, nelem, wire, encoded),
                        strategy=plan.strategy,
                        codec=wire,
                        stage_kind="a2a_pod",
                        op_index=op_i,
                    )
                buf = b.transpose((2, 1, 0, 3) + tuple(range(4, 4 + len(feat)))).reshape(
                    (nranks, stage.buflen) + feat
                )
        elif isinstance(stage, PermuteWorld):
            ext = np.concatenate([buf, local], axis=1)
            inters = (
                stage.inter if stage.inter is not None else (False,) * len(stage.blks)
            )
            parts = []
            for ri, (perm, blk, sel, inter) in enumerate(
                zip(stage.rounds, stage.blks, stage.sels, inters)
            ):
                send = _take_fill(ext, np.asarray(sel))
                if inter:
                    check = verify and bool(perm)
                    axes = tuple(range(1, send.ndim))
                    pre = faults_mod.block_check_np(send, axes) if check else None
                    # one wire block per sending rank
                    send = wire_codec.roundtrip_np(send, wire, block_ndim=send.ndim - 1)
                    if cf is not None:
                        for inj in cf.for_hop(op_i, ri):
                            send = faults_mod.apply_injection_np(
                                send, inj.np_mask, inj.kind, inj.value
                            )
                    if check:
                        post = faults_mod.block_check_np(send, axes)
                        nelem = blk * int(np.prod(feat, dtype=np.int64))
                        faults_mod.raise_if_violated(
                            faults_mod.check_violation(pre, post, nelem, wire, encoded),
                            strategy=plan.strategy,
                            codec=wire,
                            stage_kind="permute",
                            op_index=op_i,
                            round_index=ri,
                        )
                out = np.zeros((nranks, blk) + feat, dtype=local.dtype)
                if perm:
                    srcs = [s for s, _ in perm]
                    dsts = [d for _, d in perm]
                    out[dsts] = send[srcs]
                parts.append(out)
            buf = (
                np.concatenate(parts, axis=1)
                if parts
                else np.zeros((nranks, 0) + feat, dtype=local.dtype)
            )
        else:
            raise TypeError(f"unknown stage {stage!r}")
    if cf is not None and cf.delay_s > 0.0:
        import time

        time.sleep(cf.delay_s)  # the injected slow-hop latency
    return buf[:, : plan.out_size]


# ---------------------------------------------------------------------------
# Vectorized planner
# ---------------------------------------------------------------------------


def _pad_rows(rows: Sequence[np.ndarray], width: Optional[int] = None) -> np.ndarray:
    """Stack ragged code rows into ``[len(rows), W]`` with PAD_CODE fill."""
    n = len(rows)
    lens = np.fromiter((len(x) for x in rows), dtype=np.int64, count=n)
    W = int(lens.max()) if n else 0
    if width is not None:
        W = width
    W = max(W, 1)
    out = np.full((n, W), PAD_CODE, dtype=np.int64)
    if n and lens.sum():
        mask = np.arange(W)[None, :] < lens[:, None]
        out[mask] = np.concatenate([np.asarray(r, dtype=np.int64) for r in rows if len(r)])
    return out


def _dedup_codes(pattern: ExchangePattern) -> Dict[Tuple[int, int], np.ndarray]:
    """All (src rank, dst pod) deduped element unions in one pass over needs."""
    topo = pattern.topo
    acc: Dict[Tuple[int, int], set] = defaultdict(set)
    for n in pattern.needs:
        acc[(n.src, topo.pod_of(n.dst))].update(n.idx)
    return {
        k: np.fromiter(sorted(v), dtype=np.int64, count=len(v))
        for k, v in acc.items()
    }


class _Planner:
    """Builds stages while tracking the symbolic buffer state (token codes)."""

    def __init__(self, pattern: ExchangePattern):
        self.pattern = pattern
        self.topo = pattern.topo
        self.L = pattern.local_size
        n = self.topo.nranks
        self.ntok = n * self.L
        self.local = local_codes(pattern)
        self.buf = np.zeros((n, 0), dtype=np.int64)
        self.canon = pattern.canonical_code_rows()
        self.max_recv = max((len(c) for c in self.canon), default=0)
        self.stages: List[Stage] = []
        self.intra_payload = 0
        self.inter_payload = 0
        self.wire_intra = 0
        self.wire_inter = 0
        self._lut: Optional[np.ndarray] = None

    # -- symbolic state ------------------------------------------------
    @property
    def ext_len(self) -> int:
        return self.buf.shape[1] + self.L

    def _apply(self, stage: Stage) -> None:
        self.stages.append(stage)
        self.buf = simulate_stage_codes(self.topo, stage, self.buf, self.local)
        self._lut = None

    def _pos_lut(self) -> np.ndarray:
        """``lut[r, code]`` = first position of token ``code`` in rank ``r``'s
        ext buffer, or ``ext_len`` (the PAD sentinel) when not held."""
        if self._lut is not None:
            return self._lut
        ext = np.concatenate([self.buf, self.local], axis=1)
        n, E = ext.shape
        lut = np.full((n, max(self.ntok, 1)), E, dtype=np.int64)
        if E and self.ntok:
            rows = np.repeat(np.arange(n), E)
            cols = np.tile(np.arange(E), n)
            codes = ext.reshape(-1)
            valid = codes >= 0
            # min over duplicate writes = first occurrence
            np.minimum.at(lut, (rows[valid], codes[valid]), cols[valid])
        self._lut = lut
        return lut

    def _map_codes(self, want: np.ndarray) -> np.ndarray:
        """Token codes ``[n, K]`` (PAD_CODE allowed) -> Gather/sel indices."""
        n = want.shape[0]
        E = self.ext_len
        lut = self._pos_lut()
        idx = lut[np.arange(n)[:, None], np.maximum(want, 0)]
        missing = (want >= 0) & (idx >= E)
        if missing.any():
            r, k = map(int, np.argwhere(missing)[0])
            code = int(want[r, k])
            tok = (code // self.L, code % self.L) if self.L else code
            raise AssertionError(f"planner bug: token {tok} not held by rank {r}")
        idx = np.where(want < 0, E, idx)
        return idx.astype(np.int32)

    # -- stage emitters ---------------------------------------------------
    def gather_codes(self, want: np.ndarray) -> None:
        self._apply(Gather(idx=self._map_codes(want)))

    def a2a_local(self, elem_bytes: int) -> None:
        n, W = self.buf.shape
        ppn, npods = self.topo.ppn, self.topo.npods
        assert W % ppn == 0
        blk = W // ppn
        nonpad = (self.buf.reshape(npods, ppn, ppn, blk) >= 0).sum(axis=3)
        # self block (j == l) does not hit the wire
        diag = int(np.einsum("pll->", nonpad))
        self.intra_payload += (int(nonpad.sum()) - diag) * elem_bytes
        self.wire_intra += n * (ppn - 1) * blk * elem_bytes
        self._apply(A2ALocal(buflen=W))

    def a2a_pod(self, elem_bytes: int) -> None:
        n, W = self.buf.shape
        ppn, npods = self.topo.ppn, self.topo.npods
        assert W % npods == 0
        blk = W // npods
        nonpad = (self.buf.reshape(npods, ppn, npods, blk) >= 0).sum(axis=3)
        diag = int(np.einsum("qlq->", nonpad))
        self.inter_payload += (int(nonpad.sum()) - diag) * elem_bytes
        self.wire_inter += n * (npods - 1) * blk * elem_bytes
        self._apply(A2APod(buflen=W))

    def permute_world(
        self,
        rounds: List[Dict[int, Tuple[int, np.ndarray]]],
        elem_bytes: int,
    ) -> None:
        """``rounds[i][src] = (dst, codes)``: src sends those tokens to dst."""
        n = self.topo.nranks
        perm_list, blks, sels, inters = [], [], [], []
        for rnd in rounds:
            blk = max((len(c) for _, c in rnd.values()), default=0)
            blk = max(blk, 1)
            want = np.full((n, blk), PAD_CODE, dtype=np.int64)
            perm = []
            crossings = []
            for s in sorted(rnd):
                dst, codes = rnd[s]
                perm.append((s, dst))
                want[s, : len(codes)] = codes
                payload = len(codes) * elem_bytes
                crosses = self.topo.pod_of(s) != self.topo.pod_of(dst)
                crossings.append(crosses)
                if crosses:
                    self.inter_payload += payload
                    self.wire_inter += blk * elem_bytes
                else:
                    self.intra_payload += payload
                    self.wire_intra += blk * elem_bytes
            perm_list.append(tuple(perm))
            blks.append(blk)
            sels.append(self._map_codes(want))
            inters.append(bool(crossings) and all(crossings))
        self._apply(
            PermuteWorld(
                rounds=tuple(perm_list),
                blks=tuple(blks),
                sels=tuple(sels),
                inter=tuple(inters),
            )
        )

    # -- shared epilogue ---------------------------------------------------
    def redistribute_and_finish(self, elem_bytes: int, extra_local_direct: bool) -> None:
        """Intra-pod redistribution (local_Rcomm) + canonical projection.

        Block ``j`` of each rank's redistribution buffer = tokens this rank
        holds that rank ``(mypod, j)`` needs, optionally including this
        rank's *own* elements (the paper's ``local_comm`` merged in).
        """
        topo = self.topo
        n, L = topo.nranks, self.L
        lut = self._pos_lut()
        E = self.ext_len
        held = lut < E  # [n, ntok]
        blocks: List[np.ndarray] = []
        for r in range(n):
            p = topo.pod_of(r)
            hr = held[r]
            if not extra_local_direct and L:
                hr = hr.copy()
                hr[r * L : (r + 1) * L] = False
            for j in range(topo.ppn):
                d = topo.rank_of(p, j)
                c = self.canon[d]
                m = hr[c] if len(c) else np.zeros((0,), dtype=bool)
                if d == r and L:
                    # self block stays on-device; own local elements are
                    # always reachable via ext, so exclude them.
                    m = m & (c // L != r)
                blocks.append(c[m])
        want = _pad_rows(blocks).reshape(n, -1)
        self.gather_codes(want)
        self.a2a_local(elem_bytes)
        self.finish_canonical()

    def finish_canonical(self) -> None:
        self.gather_codes(_pad_rows(self.canon, width=max(self.max_recv, 1)))

    def build(self, strategy: str) -> StagePlan:
        pat = self.pattern
        # verify delivery: every rank's canonical prefix must be in place
        n, H = self.buf.shape
        want = _pad_rows(self.canon, width=H)
        lens = np.fromiter((len(c) for c in self.canon), dtype=np.int64, count=n)
        mask = np.arange(H)[None, :] < lens[:, None]
        ok = (self.buf == want) | ~mask
        if not ok.all():
            r = int(np.argwhere(~ok)[0, 0])
            raise AssertionError(f"strategy {strategy}: rank {r} canonical mismatch")
        return StagePlan(
            strategy=strategy,
            pattern=pat,
            stages=tuple(self.stages),
            out_size=max(self.max_recv, 1),
            intra_pod_bytes=self.intra_payload,
            inter_pod_bytes=self.inter_payload,
            wire_intra_pod_bytes=self.wire_intra,
            wire_inter_pod_bytes=self.wire_inter,
        )


# ---------------------------------------------------------------------------
# Strategy planners
# ---------------------------------------------------------------------------


def plan_standard(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """Standard communication: dense per-(src,dst) exchange.

    Both redundancies of paper Fig 2.2 are present: every (src, dst) pair
    gets its own message slot, and the same element is sent once per
    requesting rank.
    """
    topo = pattern.topo
    pl = _Planner(pattern)
    n, L = topo.nranks, pattern.local_size
    by_pair: Dict[Tuple[int, int], np.ndarray] = {}
    for nd in pattern.needs:
        by_pair[(nd.src, nd.dst)] = nd.src * L + np.asarray(nd.idx, dtype=np.int64)
    B = max((len(v) for v in by_pair.values()), default=0)
    B = max(B, 1)

    # layout [npods, ppn, B] by destination (pod, local)
    blocks = [by_pair.get((r, d), _EMPTY) for r in range(n) for d in range(n)]
    pl.gather_codes(_pad_rows(blocks, width=B).reshape(n, n * B))
    pl.a2a_pod(elem_bytes)
    # transpose [q, j, B] -> [j, q, B] so A2ALocal blocks are contiguous
    want = (
        pl.buf.reshape(n, topo.npods, topo.ppn, B)
        .transpose(0, 2, 1, 3)
        .reshape(n, n * B)
    )
    pl.gather_codes(want)
    pl.a2a_local(elem_bytes)
    pl.finish_canonical()
    return pl.build("standard")


def plan_two_step(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """2-Step: per-(src rank -> dst pod) fused, deduped messages to the
    pod-rank pair, then intra-pod redistribution (paper §2.3.2)."""
    topo = pattern.topo
    pl = _Planner(pattern)
    n, L = topo.nranks, pattern.local_size
    dedup = _dedup_codes(pattern)
    fused = {
        (r, p): r * L + dedup.get((r, p), _EMPTY)
        for r in range(n)
        for p in range(topo.npods)
    }
    B = max((len(v) for v in fused.values()), default=0)
    B = max(B, 1)

    blocks = [
        fused[(r, p)] if p != topo.pod_of(r) else _EMPTY
        for r in range(n)
        for p in range(topo.npods)
    ]
    pl.gather_codes(_pad_rows(blocks, width=B).reshape(n, topo.npods * B))
    pl.a2a_pod(elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("two_step")


def plan_three_step(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """3-Step: intra-pod gather to the pair agent, single fused inter-pod
    message per pod pair, intra-pod redistribution (paper §2.3.1)."""
    topo = pattern.topo
    pl = _Planner(pattern)
    n, L = topo.nranks, pattern.local_size
    dedup = _dedup_codes(pattern)
    # deduped contribution of each rank to each foreign pod
    contrib = {
        (r, p): r * L + dedup.get((r, p), _EMPTY)
        for r in range(n)
        for p in range(topo.npods)
        if p != topo.pod_of(r)
    }

    # step 1: route contributions to the (src pod, dst pod) agent
    blocks: List[np.ndarray] = []
    for r in range(n):
        q = topo.pod_of(r)
        per_agent: List[List[np.ndarray]] = [[] for _ in range(topo.ppn)]
        for p in range(topo.npods):
            if p == q:
                continue
            per_agent[topo.agent_local(q, p)].append(contrib[(r, p)])
        blocks.extend(
            np.concatenate(b) if b else _EMPTY for b in per_agent
        )
    pl.gather_codes(_pad_rows(blocks).reshape(n, -1))
    pl.a2a_local(elem_bytes)

    # step 2: one fused message per pod pair, spread over shifts
    rounds = []
    for d in topo.pod_shift_rounds():
        rnd: Dict[int, Tuple[int, np.ndarray]] = {}
        for q in range(topo.npods):
            p = (q + d) % topo.npods
            a = topo.agent_local(q, p)
            src = topo.rank_of(q, a)
            dst = topo.rank_of(p, a)
            toks = [contrib[(topo.rank_of(q, l), p)] for l in range(topo.ppn)]
            rnd[src] = (dst, np.unique(np.concatenate(toks))) if toks else (dst, _EMPTY)
        rounds.append(rnd)
    pl.permute_world(rounds, elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("three_step")


def _greedy_rounds(
    chunks: List[Tuple[int, int, np.ndarray]]
) -> List[Dict[int, Tuple[int, np.ndarray]]]:
    """Edge-color the chunk multigraph into rounds where every rank sends
    and receives at most one chunk (largest chunks first)."""
    remaining = sorted(chunks, key=lambda c: -len(c[2]))
    rounds = []
    while remaining:
        used_s, used_d = set(), set()
        rnd: Dict[int, Tuple[int, np.ndarray]] = {}
        rest = []
        for s, d, toks in remaining:
            if s in used_s or d in used_d:
                rest.append((s, d, toks))
                continue
            used_s.add(s)
            used_d.add(d)
            rnd[s] = (d, toks)
        rounds.append(rnd)
        remaining = rest
    return rounds


def plan_split(
    pattern: ExchangePattern,
    message_cap_bytes: int,
    elem_bytes: int = 4,
) -> StagePlan:
    """Split node-aware communication (paper §2.3.3 / Algorithm 1).

    Inter-pod volume is deduped and conglomerated per (origin pod -> dest
    pod), split into chunks of at most the effective ``message_cap`` (lines
    12-17), balanced over on-pod senders/receivers (line 18), exchanged, and
    redistributed.
    """
    topo = pattern.topo
    pl = _Planner(pattern)
    n, L = topo.nranks, pattern.local_size
    dedup = _dedup_codes(pattern)

    # per recv pod: per origin pod: owner-major deduped token list
    chunks: List[Tuple[int, int, np.ndarray]] = []  # (sender, receiver, codes)
    stage0_rows: List[List[List[np.ndarray]]] = [
        [[] for _ in range(topo.ppn)] for _ in range(n)
    ]
    for recv_pod in range(topo.npods):
        per_origin: Dict[int, np.ndarray] = {}
        for origin in range(topo.npods):
            if origin == recv_pod:
                continue
            toks = [
                topo.rank_of(origin, l) * L
                + dedup.get((topo.rank_of(origin, l), recv_pod), _EMPTY)
                for l in range(topo.ppn)
            ]
            cat = np.concatenate(toks) if toks else _EMPTY
            if len(cat):
                per_origin[origin] = cat
        if not per_origin:
            continue
        vols = {o: len(t) * elem_bytes for o, t in per_origin.items()}
        total = sum(vols.values())
        biggest = max(vols.values())
        # Algorithm 1, lines 12-17
        if biggest < message_cap_bytes:
            cap = biggest  # conglomerate: one message per origin pod
        elif total / message_cap_bytes > topo.ppn:
            cap = -(-total // topo.ppn)  # ceil
        else:
            cap = message_cap_bytes
        cap_elems = max(cap // elem_bytes, 1)

        raw: List[Tuple[int, np.ndarray]] = []  # (origin, chunk codes)
        for origin in sorted(per_origin):
            toks = per_origin[origin]
            for i in range(0, len(toks), cap_elems):
                raw.append((origin, toks[i : i + cap_elems]))
        # line 18: receives descending from local 0; sends from local ppn-1
        raw.sort(key=lambda t: -len(t[1]))
        send_counter: Dict[int, int] = defaultdict(int)
        for i, (origin, toks) in enumerate(raw):
            receiver = topo.rank_of(recv_pod, i % topo.ppn)
            k = send_counter[origin]
            sender = topo.rank_of(origin, topo.ppn - 1 - (k % topo.ppn))
            send_counter[origin] += 1
            chunks.append((sender, receiver, toks))
            # stage 0 (local_Scomm): owners stage chunk bytes on the sender
            owners = toks // L if L else toks * 0
            j = topo.local_of(sender)
            for owner in np.unique(owners):
                if int(owner) != sender:
                    stage0_rows[int(owner)][j].append(toks[owners == owner])

    blocks = [
        np.concatenate(b) if b else _EMPTY
        for row in stage0_rows
        for b in row
    ]
    pl.gather_codes(_pad_rows(blocks).reshape(n, -1))
    pl.a2a_local(elem_bytes)
    pl.permute_world(_greedy_rounds(chunks), elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("split")


def plan_local(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """Intra-pod-only program: one gather + one ``A2ALocal`` + projection.

    This is the on-node phase of the split-phase (overlap) exchange: every
    need must be pod-local.  All four node-aware strategies degenerate to the
    same program for pod-local data -- the node-aware rewrites only touch
    inter-node traffic -- so the local phase has a single planner.
    """
    topo = pattern.topo
    for n in pattern.needs:
        if topo.pod_of(n.src) != topo.pod_of(n.dst):
            raise ValueError(
                f"plan_local requires a pod-local pattern; need "
                f"{n.dst}<-{n.src} crosses pods"
            )
    pl = _Planner(pattern)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("local")


PLANNERS: Dict[str, Callable[..., StagePlan]] = {
    "standard": plan_standard,
    "two_step": plan_two_step,
    "three_step": plan_three_step,
    "split": plan_split,
    "local": plan_local,
}


def plan(strategy: str, pattern: ExchangePattern, *, message_cap_bytes: int = 16384, elem_bytes: int = 4) -> StagePlan:
    if strategy == "split":
        return plan_split(pattern, message_cap_bytes, elem_bytes)
    try:
        return PLANNERS[strategy](pattern, elem_bytes)
    except KeyError as e:
        raise KeyError(f"unknown strategy {strategy!r}; known: {sorted(PLANNERS)}") from e


# ---------------------------------------------------------------------------
# Split-phase decomposition (the overlap-capable two-phase exchange)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SplitPhase:
    """A pattern factored into an on-pod phase and an inter-pod phase.

    ``local`` holds the needs whose source is on the destination's own pod
    (deliverable with intra-pod communication only, :func:`plan_local`);
    ``remote`` holds the inter-pod needs (planned by any node-aware
    strategy).  The merge maps route each slot of the *full* canonical recv
    buffer to its position in the phase that delivers it:

    ``merged[r, j] = local_out[r, local_idx[r, j]]``  if ``from_local[r, j]``
    else ``remote_out[r, remote_idx[r, j]]``.

    Because both sub-patterns keep the full pattern's src-major canonical
    ordering, each phase's canonical buffer is a subsequence of the full one
    and the merge is a pure per-rank gather -- no communication.
    """

    full: ExchangePattern
    local: ExchangePattern
    remote: ExchangePattern
    from_local: np.ndarray  # [nranks, H] bool
    local_idx: np.ndarray  # [nranks, H] int32 into the local phase's buffer
    remote_idx: np.ndarray  # [nranks, H] int32 into the remote phase's buffer
    #: slots past a rank's canonical length are zero-filled, like the
    #: barrier executor's PAD handling
    valid: np.ndarray  # [nranks, H] bool


def split_phase(pattern: ExchangePattern) -> SplitPhase:
    """Factor ``pattern`` into its on-pod and inter-pod sub-patterns."""
    topo = pattern.topo
    loc: List[Need] = []
    rem: List[Need] = []
    for n in pattern.needs:
        (loc if topo.pod_of(n.src) == topo.pod_of(n.dst) else rem).append(n)
    local = ExchangePattern(
        topo=topo, local_size=pattern.local_size, needs=tuple(loc)
    )
    remote = ExchangePattern(
        topo=topo, local_size=pattern.local_size, needs=tuple(rem)
    )
    nranks = topo.nranks
    L = pattern.local_size
    H = max(pattern.max_recv_size(), 1)
    from_local = np.zeros((nranks, H), dtype=bool)
    local_idx = np.zeros((nranks, H), dtype=np.int32)
    remote_idx = np.zeros((nranks, H), dtype=np.int32)
    valid = np.zeros((nranks, H), dtype=bool)
    for r, codes in enumerate(pattern.canonical_code_rows()):
        n = len(codes)
        if not n:
            continue
        is_local = (codes // L) // topo.ppn == topo.pod_of(r)
        valid[r, :n] = True
        from_local[r, :n] = is_local
        local_idx[r, :n] = np.cumsum(is_local) - 1
        remote_idx[r, :n] = np.cumsum(~is_local) - 1
    np.maximum(local_idx, 0, out=local_idx)
    np.maximum(remote_idx, 0, out=remote_idx)
    return SplitPhase(
        full=pattern,
        local=local,
        remote=remote,
        from_local=from_local,
        local_idx=local_idx,
        remote_idx=remote_idx,
        valid=valid,
    )


def merge_split_phase(
    sp: SplitPhase, local_out: np.ndarray, remote_out: np.ndarray
) -> np.ndarray:
    """Numpy oracle for the split-phase merge: phase outputs -> full buffer.

    ``local_out`` / ``remote_out`` are the two phases' canonical buffers
    (e.g. from :func:`execute_numpy` on their plans); the result is
    bit-identical to executing the unsplit plan.
    """
    n, H = sp.from_local.shape
    feat = local_out.shape[2:]
    rows = np.arange(n)[:, None]
    lo = local_out[rows, np.minimum(sp.local_idx, local_out.shape[1] - 1)]
    ro = remote_out[rows, np.minimum(sp.remote_idx, remote_out.shape[1] - 1)]
    expand = (n, H) + (1,) * len(feat)
    mask = sp.from_local.reshape(expand)
    valid = sp.valid.reshape(expand)
    return np.where(valid, np.where(mask, lo, ro), np.zeros_like(lo))
