"""Setup-time planning for irregular element exchanges.

This is the executable heart of the paper on TPU: an irregular
"who needs which elements from whom" pattern (e.g. the SpMV halo, MoE token
routing) is compiled, at setup time, into a static **stage program** -- a
sequence of gathers and mesh collectives -- one program per node-aware
strategy (Standard / 3-Step / 2-Step / Split).  The stage program is then
executed by :mod:`repro.comm.strategies` under ``shard_map``.

Planning is *verified by construction*: a symbolic token simulator runs the
same stage semantics over ``(owner, element)`` tokens, so the planner can
resolve "where does token t live in rank r's buffer right now" exactly, and
tests can assert every strategy delivers the canonical receive layout.

Stage semantics (mirrored exactly by the JAX executor):

* ``Gather(idx)``      -- per rank: ``new_buf[k] = ext[idx[k]]`` where
  ``ext = concat(current_buf, original_local)`` and ``idx == len(ext)`` is a
  PAD sentinel (delivers 0).
* ``A2ALocal()``       -- ``all_to_all`` over the pod-local axis on the
  ``[ppn, blk]`` view of the buffer.
* ``A2APod()``         -- ``all_to_all`` over the pod axis on ``[npods, blk]``.
* ``PermuteWorld(...)``-- rounds of world-level ``ppermute``; each round the
  sender selects ``sel[round]`` from ``ext`` and the received blocks are
  concatenated into the new buffer.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm.topology import PodTopology
from repro.core.patterns import CommPattern, Message

Token = Tuple[int, int]  # (owner rank, element index)


# ---------------------------------------------------------------------------
# Pattern
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Need:
    """Rank ``dst`` needs elements ``idx`` of rank ``src``'s local buffer."""

    dst: int
    src: int
    idx: Tuple[int, ...]

    def __post_init__(self) -> None:
        if list(self.idx) != sorted(set(self.idx)):
            raise ValueError("Need.idx must be sorted and unique")


@dataclasses.dataclass(frozen=True)
class ExchangePattern:
    """Static irregular exchange pattern over a pod topology."""

    topo: PodTopology
    local_size: int
    needs: Tuple[Need, ...]

    def __post_init__(self) -> None:
        seen = set()
        for n in self.needs:
            if (n.dst, n.src) in seen:
                raise ValueError(f"duplicate need for (dst={n.dst}, src={n.src})")
            seen.add((n.dst, n.src))
            if n.src == n.dst:
                raise ValueError("self-needs are not communication")
            if n.idx and max(n.idx) >= self.local_size:
                raise ValueError("need index out of range")

    # -- canonical receive layout -------------------------------------
    def needs_of(self, dst: int) -> List[Need]:
        return sorted((n for n in self.needs if n.dst == dst), key=lambda n: n.src)

    def recv_size(self, dst: int) -> int:
        return sum(len(n.idx) for n in self.needs_of(dst))

    def max_recv_size(self) -> int:
        return max((self.recv_size(r) for r in range(self.topo.nranks)), default=0)

    def canonical_tokens(self, dst: int) -> List[Token]:
        out: List[Token] = []
        for n in self.needs_of(dst):
            out.extend((n.src, e) for e in n.idx)
        return out

    # -- derived views -------------------------------------------------
    def dedup_for_pod(self, src: int, dst_pod: int) -> List[int]:
        """Union of elements of ``src`` needed by any rank in ``dst_pod``
        (the node-aware data-redundancy elimination, paper §2.3)."""
        elems: set = set()
        for n in self.needs:
            if n.src == src and self.topo.pod_of(n.dst) == dst_pod:
                elems.update(n.idx)
        return sorted(elems)

    def to_comm_pattern(self, elem_bytes: int = 4) -> CommPattern:
        """Byte-level view for the performance models / advisor."""
        msgs = [
            Message(n.src, n.dst, len(n.idx) * elem_bytes)
            for n in self.needs
            if n.idx
        ]
        return CommPattern.from_messages(self.topo.nranks, self.topo.ppn, msgs)

    # -- reference oracle ----------------------------------------------
    def reference(self, local: np.ndarray) -> np.ndarray:
        """Numpy oracle: ``local [nranks, L] -> canonical recv [nranks, H]``."""
        nranks, H = self.topo.nranks, self.max_recv_size()
        out = np.zeros((nranks, H), dtype=local.dtype)
        for r in range(nranks):
            toks = self.canonical_tokens(r)
            for k, (owner, e) in enumerate(toks):
                out[r, k] = local[owner, e]
        return out


def random_pattern(
    rng: np.random.Generator,
    topo: PodTopology,
    local_size: int,
    p_connect: float = 0.5,
    max_elems: Optional[int] = None,
) -> ExchangePattern:
    """Random irregular pattern for property tests."""
    max_elems = max_elems or local_size
    needs = []
    for dst in range(topo.nranks):
        for src in range(topo.nranks):
            if src == dst or rng.random() > p_connect:
                continue
            k = int(rng.integers(1, max_elems + 1))
            idx = np.sort(rng.choice(local_size, size=min(k, local_size), replace=False))
            needs.append(Need(dst, src, tuple(int(i) for i in idx)))
    return ExchangePattern(topo=topo, local_size=local_size, needs=tuple(needs))


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Gather:
    idx: np.ndarray  # [nranks, K] int32; idx == len(ext) means PAD


@dataclasses.dataclass(frozen=True)
class A2ALocal:
    buflen: int  # divisible by ppn


@dataclasses.dataclass(frozen=True)
class A2APod:
    buflen: int  # divisible by npods


@dataclasses.dataclass(frozen=True)
class PermuteWorld:
    #: rounds[r] = tuple of (src_rank, dst_rank) pairs (a partial permutation)
    rounds: Tuple[Tuple[Tuple[int, int], ...], ...]
    #: per-round block length
    blks: Tuple[int, ...]
    #: sel[round] = [nranks, blks[round]] indices into ext (PAD = len(ext))
    sels: Tuple[np.ndarray, ...]


Stage = object  # union of the four dataclasses above


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """A full strategy program plus bookkeeping for benchmarks/tests."""

    strategy: str
    pattern: ExchangePattern
    stages: Tuple[Stage, ...]
    out_size: int
    #: payload bytes moved (excluding padding) per fabric, per whole machine
    intra_pod_bytes: int
    inter_pod_bytes: int
    #: bytes actually on the wire including padding (what XLA would move)
    wire_intra_pod_bytes: int
    wire_inter_pod_bytes: int


# ---------------------------------------------------------------------------
# Symbolic simulator (used for planning and by tests)
# ---------------------------------------------------------------------------

PAD: Optional[Token] = None


def simulate_stage(
    topo: PodTopology,
    stage: Stage,
    buf: List[List[Optional[Token]]],
    local: List[List[Token]],
) -> List[List[Optional[Token]]]:
    nranks, ppn, npods = topo.nranks, topo.ppn, topo.npods
    if isinstance(stage, Gather):
        new = []
        for r in range(nranks):
            ext = buf[r] + list(local[r])
            row = []
            for i in stage.idx[r]:
                row.append(PAD if i >= len(ext) else ext[int(i)])
            new.append(row)
        return new
    if isinstance(stage, A2ALocal):
        blk = stage.buflen // ppn
        new = [[PAD] * stage.buflen for _ in range(nranks)]
        for p in range(npods):
            for l in range(ppn):
                r = topo.rank_of(p, l)
                for j in range(ppn):
                    src = topo.rank_of(p, j)
                    new[r][j * blk : (j + 1) * blk] = buf[src][l * blk : (l + 1) * blk]
        return new
    if isinstance(stage, A2APod):
        blk = stage.buflen // npods
        new = [[PAD] * stage.buflen for _ in range(nranks)]
        for p in range(npods):
            for l in range(ppn):
                r = topo.rank_of(p, l)
                for q in range(npods):
                    src = topo.rank_of(q, l)
                    new[r][q * blk : (q + 1) * blk] = buf[src][p * blk : (p + 1) * blk]
        return new
    if isinstance(stage, PermuteWorld):
        new = [[] for _ in range(nranks)]
        for rnd, (perm, blk, sel) in enumerate(zip(stage.rounds, stage.blks, stage.sels)):
            send = []
            for r in range(nranks):
                ext = buf[r] + list(local[r])
                send.append(
                    [PAD if i >= len(ext) else ext[int(i)] for i in sel[r]]
                )
            got = {d: send[s] for s, d in perm}
            for r in range(nranks):
                new[r].extend(got.get(r, [PAD] * blk))
        return new
    raise TypeError(f"unknown stage {stage!r}")


def simulate(plan: StagePlan) -> List[List[Optional[Token]]]:
    topo = plan.pattern.topo
    local = [
        [(r, e) for e in range(plan.pattern.local_size)]
        for r in range(topo.nranks)
    ]
    buf: List[List[Optional[Token]]] = [[] for _ in range(topo.nranks)]
    for stage in plan.stages:
        buf = simulate_stage(topo, stage, buf, local)
    return buf


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


class _Planner:
    """Builds stages while tracking the symbolic buffer state."""

    def __init__(self, pattern: ExchangePattern):
        self.pattern = pattern
        self.topo = pattern.topo
        self.local = [
            [(r, e) for e in range(pattern.local_size)]
            for r in range(self.topo.nranks)
        ]
        self.buf: List[List[Optional[Token]]] = [[] for _ in range(self.topo.nranks)]
        self.stages: List[Stage] = []
        self.intra_payload = 0
        self.inter_payload = 0
        self.wire_intra = 0
        self.wire_inter = 0

    # -- position lookup ------------------------------------------------
    def _positions(self, r: int) -> Dict[Token, int]:
        pos: Dict[Token, int] = {}
        ext = self.buf[r] + self.local[r]
        for i, t in enumerate(ext):
            if t is not None and t not in pos:
                pos[t] = i
        return pos

    def _apply(self, stage: Stage) -> None:
        self.stages.append(stage)
        self.buf = simulate_stage(self.topo, stage, self.buf, self.local)

    # -- stage emitters ---------------------------------------------------
    def gather(self, select: Callable[[int], List[Optional[Token]]], width: Optional[int] = None) -> None:
        nranks = self.topo.nranks
        rows = [select(r) for r in range(nranks)]
        K = width if width is not None else max((len(x) for x in rows), default=0)
        K = max(K, 1)
        idx = np.zeros((nranks, K), dtype=np.int32)
        for r in range(nranks):
            pos = self._positions(r)
            sentinel = len(self.buf[r]) + len(self.local[r])
            for k in range(K):
                tok = rows[r][k] if k < len(rows[r]) else PAD
                if tok is PAD:
                    idx[r, k] = sentinel
                else:
                    if tok not in pos:
                        raise AssertionError(
                            f"planner bug: token {tok} not held by rank {r}"
                        )
                    idx[r, k] = pos[tok]
        self._apply(Gather(idx=idx))

    def a2a_local(self, elem_bytes: int) -> None:
        buflen = len(self.buf[0])
        assert buflen % self.topo.ppn == 0
        blk = buflen // self.topo.ppn
        for r in range(self.topo.nranks):
            l = self.topo.local_of(r)
            for j in range(self.topo.ppn):
                if j == l:
                    continue  # self block does not hit the wire
                seg = self.buf[r][j * blk : (j + 1) * blk]
                self.intra_payload += sum(t is not None for t in seg) * elem_bytes
                self.wire_intra += blk * elem_bytes
        self._apply(A2ALocal(buflen=buflen))

    def a2a_pod(self, elem_bytes: int) -> None:
        buflen = len(self.buf[0])
        assert buflen % self.topo.npods == 0
        blk = buflen // self.topo.npods
        for r in range(self.topo.nranks):
            p = self.topo.pod_of(r)
            for q in range(self.topo.npods):
                if q == p:
                    continue
                seg = self.buf[r][q * blk : (q + 1) * blk]
                self.inter_payload += sum(t is not None for t in seg) * elem_bytes
                self.wire_inter += blk * elem_bytes
        self._apply(A2APod(buflen=buflen))

    def permute_world(
        self,
        rounds: List[Dict[int, Tuple[int, List[Token]]]],
        elem_bytes: int,
    ) -> None:
        """``rounds[i][src] = (dst, tokens)``: src sends tokens to dst."""
        nranks = self.topo.nranks
        perm_list, blks, sels = [], [], []
        for rnd in rounds:
            blk = max((len(toks) for _, toks in rnd.values()), default=0)
            blk = max(blk, 1)
            sel = np.zeros((nranks, blk), dtype=np.int32)
            perm = []
            for r in range(nranks):
                pos = self._positions(r)
                sentinel = len(self.buf[r]) + len(self.local[r])
                if r in rnd:
                    dst, toks = rnd[r]
                    perm.append((r, dst))
                    inter = self.topo.pod_of(r) != self.topo.pod_of(dst)
                    payload = len(toks) * elem_bytes
                    if inter:
                        self.inter_payload += payload
                        self.wire_inter += blk * elem_bytes
                    else:
                        self.intra_payload += payload
                        self.wire_intra += blk * elem_bytes
                    for k in range(blk):
                        sel[r, k] = pos[toks[k]] if k < len(toks) else sentinel
                else:
                    sel[r, :] = len(self.buf[r]) + len(self.local[r])
            perm_list.append(tuple(perm))
            blks.append(blk)
            sels.append(sel)
        self._apply(
            PermuteWorld(rounds=tuple(perm_list), blks=tuple(blks), sels=tuple(sels))
        )

    # -- shared epilogue ---------------------------------------------------
    def redistribute_and_finish(self, elem_bytes: int, extra_local_direct: bool) -> None:
        """Intra-pod redistribution (local_Rcomm) + canonical projection.

        Block ``j`` of each rank's redistribution buffer = tokens this rank
        holds that rank ``(mypod, j)`` needs, optionally including this
        rank's *own* elements (the paper's ``local_comm`` merged in).
        """
        topo, pat = self.topo, self.pattern
        rows: List[List[List[Optional[Token]]]] = []
        for r in range(topo.nranks):
            p = topo.pod_of(r)
            pos = self._positions(r)
            held = set(t for t in pos if extra_local_direct or t[0] != r)
            blocks = []
            for j in range(topo.ppn):
                d = topo.rank_of(p, j)
                if d == r:
                    # self block: stays on-device (never hits the wire), but
                    # must carry tokens this rank holds *for itself*, because
                    # the gather replaces the buffer.  Own local elements are
                    # always reachable via ext, so exclude them.
                    want = [
                        t for t in pat.canonical_tokens(d) if t in held and t[0] != r
                    ]
                else:
                    want = [t for t in pat.canonical_tokens(d) if t in held]
                blocks.append(sorted(set(want)))
            rows.append(blocks)
        B = max(max(len(b) for b in blocks) for blocks in rows)
        B = max(B, 1)

        def sel(r: int) -> List[Optional[Token]]:
            out: List[Optional[Token]] = []
            for b in rows[r]:
                out.extend(b)
                out.extend([PAD] * (B - len(b)))
            return out

        self.gather(sel, width=B * topo.ppn)
        self.a2a_local(elem_bytes)
        self.finish_canonical()

    def finish_canonical(self) -> None:
        pat = self.pattern
        H = max(pat.max_recv_size(), 1)
        self.gather(lambda r: list(pat.canonical_tokens(r)), width=H)

    def build(self, strategy: str) -> StagePlan:
        pat = self.pattern
        # verify delivery
        for r in range(self.topo.nranks):
            want = pat.canonical_tokens(r)
            got = self.buf[r][: len(want)]
            if got != want:
                raise AssertionError(
                    f"strategy {strategy}: rank {r} canonical mismatch"
                )
        return StagePlan(
            strategy=strategy,
            pattern=pat,
            stages=tuple(self.stages),
            out_size=max(pat.max_recv_size(), 1),
            intra_pod_bytes=self.intra_payload,
            inter_pod_bytes=self.inter_payload,
            wire_intra_pod_bytes=self.wire_intra,
            wire_inter_pod_bytes=self.wire_inter,
        )


# ---------------------------------------------------------------------------
# Strategy planners
# ---------------------------------------------------------------------------


def plan_standard(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """Standard communication: dense per-(src,dst) exchange.

    Both redundancies of paper Fig 2.2 are present: every (src, dst) pair
    gets its own message slot, and the same element is sent once per
    requesting rank.
    """
    topo = pattern.topo
    pl = _Planner(pattern)
    by_pair: Dict[Tuple[int, int], List[Token]] = defaultdict(list)
    for n in pattern.needs:
        by_pair[(n.src, n.dst)] = [(n.src, e) for e in n.idx]
    B = max((len(v) for v in by_pair.values()), default=0)
    B = max(B, 1)

    # layout [npods, ppn, B] by destination (pod, local)
    def sel(r: int) -> List[Optional[Token]]:
        out: List[Optional[Token]] = []
        for d in range(topo.nranks):
            toks = by_pair.get((r, d), [])
            out.extend(toks)
            out.extend([PAD] * (B - len(toks)))
        return out

    pl.gather(sel, width=topo.nranks * B)
    pl.a2a_pod(elem_bytes)
    # transpose [q, j, B] -> [j, q, B] so A2ALocal blocks are contiguous
    buf = pl.buf

    def transpose_sel(r: int) -> List[Optional[Token]]:
        row = buf[r]
        out: List[Optional[Token]] = []
        for j in range(topo.ppn):
            for q in range(topo.npods):
                base = (q * topo.ppn + j) * B
                out.extend(row[base : base + B])
        return out

    pl.gather(transpose_sel, width=topo.nranks * B)
    pl.a2a_local(elem_bytes)
    pl.finish_canonical()
    return pl.build("standard")


def plan_two_step(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """2-Step: per-(src rank -> dst pod) fused, deduped messages to the
    pod-rank pair, then intra-pod redistribution (paper §2.3.2)."""
    topo = pattern.topo
    pl = _Planner(pattern)
    fused: Dict[Tuple[int, int], List[Token]] = {}
    for r in range(topo.nranks):
        for p in range(topo.npods):
            fused[(r, p)] = [(r, e) for e in pattern.dedup_for_pod(r, p)]
    B = max((len(v) for v in fused.values()), default=0)
    B = max(B, 1)

    def sel(r: int) -> List[Optional[Token]]:
        out: List[Optional[Token]] = []
        for p in range(topo.npods):
            toks = fused[(r, p)] if p != topo.pod_of(r) else []
            out.extend(toks)
            out.extend([PAD] * (B - len(toks)))
        return out

    pl.gather(sel, width=topo.npods * B)
    pl.a2a_pod(elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("two_step")


def plan_three_step(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """3-Step: intra-pod gather to the pair agent, single fused inter-pod
    message per pod pair, intra-pod redistribution (paper §2.3.1)."""
    topo = pattern.topo
    pl = _Planner(pattern)
    # deduped contribution of each rank to each foreign pod
    contrib: Dict[Tuple[int, int], List[Token]] = {}
    for r in range(topo.nranks):
        for p in range(topo.npods):
            if p == topo.pod_of(r):
                continue
            contrib[(r, p)] = [(r, e) for e in pattern.dedup_for_pod(r, p)]

    # step 1: route contributions to the (src pod, dst pod) agent
    rows: Dict[int, List[List[Optional[Token]]]] = {}
    for r in range(topo.nranks):
        q = topo.pod_of(r)
        blocks: List[List[Optional[Token]]] = [[] for _ in range(topo.ppn)]
        for p in range(topo.npods):
            if p == q:
                continue
            blocks[topo.agent_local(q, p)].extend(contrib[(r, p)])
        rows[r] = blocks
    B1 = max(max(len(b) for b in blocks) for blocks in rows.values())
    B1 = max(B1, 1)

    def sel1(r: int) -> List[Optional[Token]]:
        out: List[Optional[Token]] = []
        for b in rows[r]:
            out.extend(b)
            out.extend([PAD] * (B1 - len(b)))
        return out

    pl.gather(sel1, width=B1 * topo.ppn)
    pl.a2a_local(elem_bytes)

    # step 2: one fused message per pod pair, spread over shifts
    rounds = []
    for d in topo.pod_shift_rounds():
        rnd: Dict[int, Tuple[int, List[Token]]] = {}
        for q in range(topo.npods):
            p = (q + d) % topo.npods
            a = topo.agent_local(q, p)
            src = topo.rank_of(q, a)
            dst = topo.rank_of(p, a)
            toks: List[Token] = []
            for l in range(topo.ppn):
                toks.extend(contrib[(topo.rank_of(q, l), p)])
            rnd[src] = (dst, sorted(set(toks)))
        rounds.append(rnd)
    pl.permute_world(rounds, elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("three_step")


def _greedy_rounds(
    chunks: List[Tuple[int, int, List[Token]]]
) -> List[Dict[int, Tuple[int, List[Token]]]]:
    """Edge-color the chunk multigraph into rounds where every rank sends
    and receives at most one chunk (largest chunks first)."""
    remaining = sorted(chunks, key=lambda c: -len(c[2]))
    rounds = []
    while remaining:
        used_s, used_d = set(), set()
        rnd: Dict[int, Tuple[int, List[Token]]] = {}
        rest = []
        for s, d, toks in remaining:
            if s in used_s or d in used_d:
                rest.append((s, d, toks))
                continue
            used_s.add(s)
            used_d.add(d)
            rnd[s] = (d, toks)
        rounds.append(rnd)
        remaining = rest
    return rounds


def plan_split(
    pattern: ExchangePattern,
    message_cap_bytes: int,
    elem_bytes: int = 4,
) -> StagePlan:
    """Split node-aware communication (paper §2.3.3 / Algorithm 1).

    Inter-pod volume is deduped and conglomerated per (origin pod -> dest
    pod), split into chunks of at most the effective ``message_cap`` (lines
    12-17), balanced over on-pod senders/receivers (line 18), exchanged, and
    redistributed.
    """
    topo = pattern.topo
    pl = _Planner(pattern)

    # per recv pod: per origin pod: owner-major deduped token list
    chunks: List[Tuple[int, int, List[Token]]] = []  # (sender, receiver, tokens)
    stage0_rows: Dict[int, List[List[Optional[Token]]]] = {
        r: [[] for _ in range(topo.ppn)] for r in range(topo.nranks)
    }
    for recv_pod in range(topo.npods):
        per_origin: Dict[int, List[Token]] = {}
        for origin in range(topo.npods):
            if origin == recv_pod:
                continue
            toks: List[Token] = []
            for l in range(topo.ppn):
                src = topo.rank_of(origin, l)
                toks.extend((src, e) for e in pattern.dedup_for_pod(src, recv_pod))
            if toks:
                per_origin[origin] = toks
        if not per_origin:
            continue
        vols = {o: len(t) * elem_bytes for o, t in per_origin.items()}
        total = sum(vols.values())
        biggest = max(vols.values())
        # Algorithm 1, lines 12-17
        if biggest < message_cap_bytes:
            cap = biggest  # conglomerate: one message per origin pod
        elif total / message_cap_bytes > topo.ppn:
            cap = -(-total // topo.ppn)  # ceil
        else:
            cap = message_cap_bytes
        cap_elems = max(cap // elem_bytes, 1)

        raw: List[Tuple[int, List[Token]]] = []  # (origin, chunk tokens)
        for origin in sorted(per_origin):
            toks = per_origin[origin]
            for i in range(0, len(toks), cap_elems):
                raw.append((origin, toks[i : i + cap_elems]))
        # line 18: receives descending from local 0; sends from local ppn-1
        raw.sort(key=lambda t: -len(t[1]))
        send_counter: Dict[int, int] = defaultdict(int)
        for i, (origin, toks) in enumerate(raw):
            receiver = topo.rank_of(recv_pod, i % topo.ppn)
            k = send_counter[origin]
            sender = topo.rank_of(origin, topo.ppn - 1 - (k % topo.ppn))
            send_counter[origin] += 1
            chunks.append((sender, receiver, toks))
            # stage 0 (local_Scomm): owners stage chunk bytes on the sender
            for tok in toks:
                owner = tok[0]
                if owner != sender:
                    stage0_rows[owner][topo.local_of(sender)].append(tok)

    B0 = max(
        (len(b) for blocks in stage0_rows.values() for b in blocks), default=0
    )
    B0 = max(B0, 1)

    def sel0(r: int) -> List[Optional[Token]]:
        out: List[Optional[Token]] = []
        for b in stage0_rows[r]:
            out.extend(b)
            out.extend([PAD] * (B0 - len(b)))
        return out

    pl.gather(sel0, width=B0 * topo.ppn)
    pl.a2a_local(elem_bytes)
    pl.permute_world(_greedy_rounds(chunks), elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("split")


PLANNERS: Dict[str, Callable[..., StagePlan]] = {
    "standard": plan_standard,
    "two_step": plan_two_step,
    "three_step": plan_three_step,
    "split": plan_split,
}


def plan(strategy: str, pattern: ExchangePattern, *, message_cap_bytes: int = 16384, elem_bytes: int = 4) -> StagePlan:
    if strategy == "split":
        return plan_split(pattern, message_cap_bytes, elem_bytes)
    try:
        return PLANNERS[strategy](pattern, elem_bytes)
    except KeyError as e:
        raise KeyError(f"unknown strategy {strategy!r}; known: {sorted(PLANNERS)}") from e
