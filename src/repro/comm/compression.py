"""Lossy compression for the inter-pod (DCI) hop: the ONE int8 quantizer.

Both lossy-int8 consumers in the repo route through the three primitives
below, so scale arithmetic and round-trip semantics cannot drift apart:

* :class:`Compressor` -- the error-feedback gradient/reduction compressor
  (``psum_hierarchical`` / ``dot_hierarchical``): the scale is agreed
  across pods via ``pmax`` and carries the *payload's* dtype so bf16
  error-feedback residuals round-trip as bf16;
* the exchange wire codec (``wire="int8"`` in
  :mod:`repro.comm.strategies`): one float32 scale per wire block rides
  the collective next to the int8 payload (no cross-pod agreement -- each
  block is decoded with its sender's scale).

int8 linear quantization with a shared scale: the payload's max magnitude
picks ``scale = amax / qmax``, the int8 payload crosses DCI (4x fewer bytes
than fp32), and values are dequantized on arrival.  Error feedback (the
residual returned by ``psum_hierarchical``) carries the quantization error
into the next step so the scheme stays convergent (Karimireddy et al.,
2019 -- standard practice; not from the reproduced paper, recorded as a
beyond-paper optimization).

Non-finite payloads never poison their finite neighbors: the scale is
taken over *finite* magnitudes (:func:`finite_amax` -- an ``inf`` amax
would quantize every element to 0 and dequantize it to ``0 * inf = nan``),
and :func:`int8_quantize` masks non-finite elements out of the division.
What a non-finite element itself becomes depends on how the payload moves:

* *permutation-moved* payloads (the exchange wire) pass a reserved
  ``nonfinite_code`` (outside the symmetric ``[-qmax, qmax]`` range) that
  :func:`int8_dequantize` decodes to ``nan``, so divergence stays visible
  to downstream ``isfinite`` guards;
* *summed* payloads (:class:`Compressor`, whose codes cross pods through a
  ``psum``) cannot carry a reserved code through the sum, so ``+/-inf``
  saturates to ``sign(x) * qmax`` and ``nan`` contributes 0 -- the
  non-finiteness is not lost: the error-feedback residual
  (``shard - decompress(q)``) stays ``inf``/``nan`` at exactly those
  elements and re-enters the next step.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


def finite_amax(x: jnp.ndarray, axis=None, keepdims: bool = False) -> jnp.ndarray:
    """Max magnitude over the *finite* elements of ``x`` (0 where none are).

    The quantization scale must come from this, never from a plain
    ``max(abs(x))``: one ``inf``/``nan`` element would otherwise inflate
    the scale to ``inf`` and destroy every finite neighbor in the block.
    """
    mag = jnp.where(jnp.isfinite(x), jnp.abs(x), 0)
    return jnp.max(mag, axis=axis, keepdims=keepdims)


def int8_scale(amax: jnp.ndarray, qmax: float) -> jnp.ndarray:
    """Quantization scale for a payload of max magnitude ``amax``.

    The scale keeps ``amax``'s dtype (callers choose: the payload dtype for
    error-feedback round-trips, float32 for wire blocks).  The tiny-scale
    guard against an all-zero payload uses ``finfo(amax.dtype)``: a
    float32 constant would promote narrower scales out of their dtype, and
    for float16 (min normal ~6e-5) a float32 tiny would flush to zero
    inside the payload dtype anyway.
    """
    return jnp.maximum(amax / qmax, jnp.finfo(amax.dtype).tiny)


def int8_quantize(
    x: jnp.ndarray, scale: jnp.ndarray, qmax: float, nonfinite_code: "int | None" = None
) -> jnp.ndarray:
    """Linear quantization to int8 under a precomputed ``scale``.

    Non-finite elements are masked out of the division (``inf / scale``
    would survive the clip as a spurious ``+/-qmax`` and ``nan`` would hit
    an undefined float->int cast) and become ``nonfinite_code`` when one is
    given (permutation-moved wire payloads), else ``sign(x) * qmax`` with
    ``nan -> 0`` (summable payloads; see the module docstring).
    """
    finite = jnp.isfinite(x)
    q = jnp.clip(jnp.round(jnp.where(finite, x, 0) / scale), -qmax, qmax)
    if nonfinite_code is None:
        fallback = jnp.where(jnp.isnan(x), 0.0, jnp.sign(x) * qmax)
    else:
        fallback = jnp.asarray(float(nonfinite_code))
    return jnp.where(finite, q, fallback).astype(jnp.int8)


def int8_dequantize(
    q: jnp.ndarray, scale: jnp.ndarray, nonfinite_code: "int | None" = None
) -> jnp.ndarray:
    """Dequantize an int8/int32 payload; the result carries ``scale.dtype``.

    The multiply runs at float32-or-wider so an int32 *sum* of quantized
    values stays exact (a bfloat16 product would round ``q`` itself once it
    exceeds 256, e.g. summing near-saturated int8 over many pods) and only
    the final result rounds to ``scale.dtype``.  With ``nonfinite_code``,
    elements carrying that code decode to ``nan`` (the inverse of
    :func:`int8_quantize`'s wire-payload mode).
    """
    wide = jnp.promote_types(scale.dtype, jnp.float32)
    deq = q.astype(wide) * scale.astype(wide)
    if nonfinite_code is not None:
        deq = jnp.where(q == nonfinite_code, jnp.nan, deq)
    return deq.astype(scale.dtype)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """int8 quantizer with a cross-pod shared scale."""

    bits: int = 8

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)

    def compress(self, x: jnp.ndarray, outer_axis: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Quantize ``x`` with a scale agreed over ``outer_axis`` via pmax.

        The returned ``scale`` keeps ``x``'s floating dtype, so a
        bfloat16 payload round-trips through :meth:`decompress` as bfloat16
        (error-feedback residuals must not silently upcast); see
        :func:`int8_scale` for the dtype-aware tiny guard and
        :func:`finite_amax` for why one inf/nan element must not set the
        scale (its non-finiteness survives in the error-feedback residual,
        not in the summed codes).
        """
        amax = jax.lax.pmax(finite_amax(x), outer_axis)
        scale = int8_scale(amax, self.qmax)
        return int8_quantize(x, scale, self.qmax), scale

    def decompress(self, q_sum: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
        """Dequantize back to the payload's own dtype (``scale`` carries it)."""
        return int8_dequantize(q_sum, scale)

    def wire_bytes(self, x: jnp.ndarray) -> int:
        """Bytes this leaf puts on the DCI per hop (vs 4*size uncompressed)."""
        return x.size * self.bits // 8
