"""Gradient compression for the inter-pod (DCI) hop.

int8 linear quantization with a pod-agreed scale: every pod computes the max
magnitude of its shard, ``pmax`` over the outer axis agrees on one scale, the
int8 payload crosses DCI (4x fewer bytes than fp32), and the sum is
dequantized on arrival.  Error feedback (the residual returned by
``psum_hierarchical``) carries the quantization error into the next step so
the scheme stays convergent (Karimireddy et al., 2019 -- standard practice;
not from the reproduced paper, recorded as a beyond-paper optimization).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Compressor:
    """int8 quantizer with a cross-pod shared scale."""

    bits: int = 8

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)

    def compress(self, x: jnp.ndarray, outer_axis: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Quantize ``x`` with a scale agreed over ``outer_axis`` via pmax."""
        amax = jnp.max(jnp.abs(x))
        amax = jax.lax.pmax(amax, outer_axis)
        scale = jnp.maximum(amax / self.qmax, jnp.finfo(jnp.float32).tiny)
        q = jnp.clip(jnp.round(x / scale), -self.qmax, self.qmax).astype(jnp.int8)
        return q, scale

    def decompress(self, q_sum: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
        return q_sum.astype(jnp.float32) * scale

    def wire_bytes(self, x: jnp.ndarray) -> int:
        """Bytes this leaf puts on the DCI per hop (vs 4*size uncompressed)."""
        return x.size * self.bits // 8
