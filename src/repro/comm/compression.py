"""Lossy compression for the inter-pod (DCI) hop: the ONE int8 quantizer.

Both lossy-int8 consumers in the repo route through the three primitives
below, so scale arithmetic and round-trip semantics cannot drift apart:

* :class:`Compressor` -- the error-feedback gradient/reduction compressor
  (``psum_hierarchical`` / ``dot_hierarchical``): the scale is agreed
  across pods via ``pmax`` and carries the *payload's* dtype so bf16
  error-feedback residuals round-trip as bf16;
* the exchange wire codec (``wire="int8"`` in
  :mod:`repro.comm.strategies`): one float32 scale per wire block rides
  the collective next to the int8 payload (no cross-pod agreement -- each
  block is decoded with its sender's scale).

int8 linear quantization with a shared scale: the payload's max magnitude
picks ``scale = amax / qmax``, the int8 payload crosses DCI (4x fewer bytes
than fp32), and values are dequantized on arrival.  Error feedback (the
residual returned by ``psum_hierarchical``) carries the quantization error
into the next step so the scheme stays convergent (Karimireddy et al.,
2019 -- standard practice; not from the reproduced paper, recorded as a
beyond-paper optimization).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


def int8_scale(amax: jnp.ndarray, qmax: float) -> jnp.ndarray:
    """Quantization scale for a payload of max magnitude ``amax``.

    The scale keeps ``amax``'s dtype (callers choose: the payload dtype for
    error-feedback round-trips, float32 for wire blocks).  The tiny-scale
    guard against an all-zero payload uses ``finfo(amax.dtype)``: a
    float32 constant would promote narrower scales out of their dtype, and
    for float16 (min normal ~6e-5) a float32 tiny would flush to zero
    inside the payload dtype anyway.
    """
    return jnp.maximum(amax / qmax, jnp.finfo(amax.dtype).tiny)


def int8_quantize(x: jnp.ndarray, scale: jnp.ndarray, qmax: float) -> jnp.ndarray:
    """Linear quantization to int8 under a precomputed ``scale``."""
    return jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Dequantize an int8/int32 payload; the result carries ``scale.dtype``.

    The multiply runs at float32-or-wider so an int32 *sum* of quantized
    values stays exact (a bfloat16 product would round ``q`` itself once it
    exceeds 256, e.g. summing near-saturated int8 over many pods) and only
    the final result rounds to ``scale.dtype``.
    """
    wide = jnp.promote_types(scale.dtype, jnp.float32)
    return (q.astype(wide) * scale.astype(wide)).astype(scale.dtype)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """int8 quantizer with a cross-pod shared scale."""

    bits: int = 8

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)

    def compress(self, x: jnp.ndarray, outer_axis: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Quantize ``x`` with a scale agreed over ``outer_axis`` via pmax.

        The returned ``scale`` keeps ``x``'s floating dtype, so a
        bfloat16 payload round-trips through :meth:`decompress` as bfloat16
        (error-feedback residuals must not silently upcast); see
        :func:`int8_scale` for the dtype-aware tiny guard.
        """
        amax = jnp.max(jnp.abs(x))
        amax = jax.lax.pmax(amax, outer_axis)
        scale = int8_scale(amax, self.qmax)
        return int8_quantize(x, scale, self.qmax), scale

    def decompress(self, q_sum: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
        """Dequantize back to the payload's own dtype (``scale`` carries it)."""
        return int8_dequantize(q_sum, scale)

    def wire_bytes(self, x: jnp.ndarray) -> int:
        """Bytes this leaf puts on the DCI per hop (vs 4*size uncompressed)."""
        return x.size * self.bits // 8
