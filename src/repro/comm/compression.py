"""Gradient compression for the inter-pod (DCI) hop.

int8 linear quantization with a pod-agreed scale: every pod computes the max
magnitude of its shard, ``pmax`` over the outer axis agrees on one scale, the
int8 payload crosses DCI (4x fewer bytes than fp32), and the sum is
dequantized on arrival.  Error feedback (the residual returned by
``psum_hierarchical``) carries the quantization error into the next step so
the scheme stays convergent (Karimireddy et al., 2019 -- standard practice;
not from the reproduced paper, recorded as a beyond-paper optimization).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Compressor:
    """int8 quantizer with a cross-pod shared scale."""

    bits: int = 8

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)

    def compress(self, x: jnp.ndarray, outer_axis: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Quantize ``x`` with a scale agreed over ``outer_axis`` via pmax.

        The returned ``scale`` keeps ``x``'s floating dtype, so a
        bfloat16 payload round-trips through :meth:`decompress` as bfloat16
        (error-feedback residuals must not silently upcast).  The tiny-scale
        guard against an all-zero shard therefore uses ``finfo(x.dtype)``:
        the old ``finfo(float32).tiny`` constant promoted the whole
        ``maximum`` -- and with it ``scale`` -- to float32 for narrower
        payloads, and for a float16 payload (min normal ~6e-5) a float32
        tiny would flush to zero inside the payload dtype anyway.
        """
        amax = jnp.max(jnp.abs(x))
        amax = jax.lax.pmax(amax, outer_axis)
        scale = jnp.maximum(amax / self.qmax, jnp.finfo(x.dtype).tiny)
        q = jnp.clip(jnp.round(x / scale), -self.qmax, self.qmax).astype(jnp.int8)
        return q, scale

    def decompress(self, q_sum: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
        """Dequantize back to the payload's own dtype (``scale`` carries it).

        The multiply runs at float32-or-wider so the int32 sum stays exact
        (a bfloat16 product would round ``q_sum`` itself once it exceeds
        256, e.g. summing near-saturated int8 over many pods) and only the
        final result rounds to the payload dtype.
        """
        wide = jnp.promote_types(scale.dtype, jnp.float32)
        return (q_sum.astype(wide) * scale.astype(wide)).astype(scale.dtype)

    def wire_bytes(self, x: jnp.ndarray) -> int:
        """Bytes this leaf puts on the DCI per hop (vs 4*size uncompressed)."""
        return x.size * self.bits // 8
