"""Wire formats for the irregular exchange's inter-pod (DCI) hop.

The paper's models make the inter-node *bandwidth* term the dominant cost
once node-aware strategies have capped inter-node message counts: every
byte crossing the slow fabric costs ``beta_inter >> beta_intra``.  A wire
codec shrinks exactly those bytes -- and only those bytes:

* the plan compiler marks which stages cross pods (``A2APod`` by
  construction; ``PermuteWorld`` rounds via their ``inter`` flags),
* the executor encodes the payload right before the inter-pod collective
  and decodes right after it, leaving every on-pod hop (``A2ALocal``,
  gathers, the pod-local redistribution) at full precision,
* the destination's *own-pod* block of an ``A2APod`` never crossed DCI, so
  it is delivered bit-exactly even under a lossy codec.

Codecs
------
``none``   identity -- the executor runs the exact pre-codec program
           (bitwise identical delivery).
``bf16``   ``f32 -> bfloat16`` truncation on the wire (2x fewer DCI bytes
           for f32 payloads).  Exact for bf16-representable values;
           otherwise relative error <= ``2**-8`` per element.  *Finite*
           f32 magnitudes above bf16's max (~3.39e38) saturate to it so a
           large-but-valid value never overflows on the wire; true
           ``+/-inf`` and ``nan`` are bf16-representable and propagate
           bit-faithfully (divergence must stay visible to ``isfinite``
           guards downstream).
``f16``    ``f32 -> float16`` (2x).  Relative error <= ``2**-11`` for
           values in f16's normal range; *finite* magnitudes beyond f16's
           max saturate to ``+/-65504`` on the wire while ``+/-inf`` and
           ``nan`` propagate, values below the normal range degrade to
           the absolute subnormal step ``2**-24``.
``int8``   blockwise linear int8 quantization with one float32 scale per
           wire block (an ``A2APod`` destination block or a
           ``PermuteWorld`` send block): ~4x fewer DCI bytes for f32.
           Absolute error <= ``scale/2``, i.e. relative to the block's max
           magnitude at most ``0.5/127`` -- the pinned bound below.  The
           scale is taken over the block's *finite* magnitudes; non-finite
           elements ship as the reserved code :data:`INT8_NONFINITE` and
           decode to ``nan`` (int8 cannot carry ``inf``), so one diverging
           element never poisons its finite neighbors.

A codec only *applies* to floating payloads wider than its wire type
(:func:`applies`): a bfloat16 payload rides a ``bf16`` wire untouched, and
integer payloads are never encoded.

This module is jax-free on purpose: the numpy executor
(:func:`repro.comm.exchange.execute_numpy`) and the plan-level byte
accounting (:func:`scaled_wire_bytes`) must run without devices.  The
device-side encode/decode lives in :mod:`repro.comm.strategies` and shares
its int8 quantizer with :class:`repro.comm.compression.Compressor`.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

#: executable wire codecs, in ranking order
WIRE_CODECS = ("none", "bf16", "f16", "int8")

#: wire bytes per element (None = payload's own width)
WIRE_ITEMSIZE = {"none": None, "bf16": 2, "f16": 2, "int8": 1}

#: int8 quantization range: symmetric [-QMAX, QMAX]
QMAX = 127.0

#: bytes of side information (the float32 scale) shipped per int8 wire block
INT8_SCALE_BYTES = 4

#: reserved int8 wire code for a non-finite element (outside the symmetric
#: quantization range [-QMAX, QMAX]); decodes to ``nan``
INT8_NONFINITE = -128

#: pinned per-element error bounds (see module docstring): casts are
#: relative to |x|, int8 is relative to the wire block's max magnitude
REL_ERROR_BOUND = {
    "none": 0.0,
    "bf16": 2.0 ** -8,
    "f16": 2.0 ** -11,
    "int8": 0.5 / QMAX,
}

#: absolute error floor: the wire type's smallest subnormal step (values
#: below the normal range quantize to multiples of it, so the relative
#: bound above only holds down to this magnitude)
ABS_ERROR_FLOOR = {
    "none": 0.0,
    "bf16": 2.0 ** -133,
    "f16": 2.0 ** -24,
    "int8": 0.0,
}


def check_codec(codec: str) -> str:
    if codec not in WIRE_CODECS:
        raise ValueError(f"unknown wire codec {codec!r}; known: {WIRE_CODECS}")
    return codec


def wire_itemsize(codec: str, elem_bytes: int) -> int:
    """Bytes per element on the DCI wire (never wider than the payload)."""
    w = WIRE_ITEMSIZE[check_codec(codec)]
    return elem_bytes if w is None or w >= elem_bytes else w


def _is_floating(dt: np.dtype) -> bool:
    """Floating-point check that also recognizes ml_dtypes extension floats
    (``np.dtype(bfloat16).kind`` is ``'V'``, not ``'f'``)."""
    if dt.kind == "f":
        return True
    try:
        import ml_dtypes

        return dt == np.dtype(ml_dtypes.bfloat16)
    except ImportError:  # pragma: no cover - ml_dtypes ships with jax
        return False


def applies(codec: str, dtype) -> bool:
    """Whether ``codec`` actually encodes a payload of ``dtype``.

    Floating payloads only (including bfloat16), and only when the wire
    type is strictly narrower than the payload -- a bf16 payload on a
    ``bf16`` wire (or any payload under ``none``) passes through untouched,
    but the same payload IS quantized by the ``int8`` wire.
    """
    w = WIRE_ITEMSIZE[check_codec(codec)]
    if w is None:
        return False
    dt = np.dtype(dtype)
    return _is_floating(dt) and dt.itemsize > w


def compression_ratio(codec: str, elem_bytes: int = 4) -> float:
    """Payload-only inter-pod byte multiplier (scale overhead excluded)."""
    return wire_itemsize(codec, elem_bytes) / float(elem_bytes)


# ---------------------------------------------------------------------------
# Numpy round-trips (the oracle for the device encode/decode)
# ---------------------------------------------------------------------------


def _cast_dtype(codec: str):
    if codec == "f16":
        return np.float16
    # numpy has no native bfloat16; ml_dtypes ships with jax
    import ml_dtypes

    return ml_dtypes.bfloat16


def ml_finfo_max(dtype) -> float:
    """Largest finite value of ``dtype`` (np.finfo handles ml_dtypes too)."""
    try:
        return float(np.finfo(dtype).max)
    except (TypeError, ValueError):
        import ml_dtypes

        return float(ml_dtypes.finfo(dtype).max)


def roundtrip_np(x: np.ndarray, codec: str, block_ndim: int) -> np.ndarray:
    """Encode+decode ``x`` the way the wire would, without moving it.

    The trailing ``block_ndim`` axes form one wire block (one scale for the
    int8 codec); leading axes index independent blocks.  Inter-pod data
    movement is a permutation of whole blocks, so round-tripping before the
    move equals moving the encoded payload and decoding after -- this is
    what lets :func:`repro.comm.exchange.execute_numpy` stay a faithful
    oracle of the device executor.

    >>> import numpy as np
    >>> roundtrip_np(np.float32([1.5, 0.25]), "bf16", 1).tolist()
    [1.5, 0.25]
    >>> x = np.float32([[1.0, 1e-4]])
    >>> abs(roundtrip_np(x, "int8", 1)[0, 1]) <= 0.5 / 127
    True
    >>> roundtrip_np(np.float32([np.inf, 1.5]), "bf16", 1).tolist()
    [inf, 1.5]
    >>> rt = roundtrip_np(np.float32([[-np.inf, 2.0]]), "int8", 1)
    >>> bool(np.isnan(rt[0, 0])), float(rt[0, 1])
    (True, 2.0)
    """
    if not applies(codec, x.dtype):
        return x
    if codec in ("bf16", "f16"):
        # saturate finite overflow only: a finite f32 above the wire max
        # must not become inf, but a true inf/nan must stay non-finite
        # (both wire types represent them) so divergence remains visible
        wdt = _cast_dtype(codec)
        fmax = float(ml_finfo_max(wdt))
        sat = np.where(np.isfinite(x), np.clip(x, -fmax, fmax), x)
        return sat.astype(wdt).astype(x.dtype)
    # int8: one float32 scale per block, taken over finite magnitudes so a
    # single inf/nan cannot poison the block; non-finite elements ship as
    # the reserved INT8_NONFINITE code and decode to nan
    f = x.astype(np.float32)
    axes = tuple(range(x.ndim - block_ndim, x.ndim))
    finite = np.isfinite(f)
    mag = np.where(finite, np.abs(f), 0.0)
    amax = np.max(mag, axis=axes, keepdims=True) if f.size else f
    scale = np.maximum(amax / QMAX, np.finfo(np.float32).tiny)
    q = np.clip(np.round(np.where(finite, f, 0.0) / scale), -QMAX, QMAX)
    q = np.where(finite, q, INT8_NONFINITE).astype(np.int8)
    deq = np.where(
        q == INT8_NONFINITE, np.float32(np.nan), q.astype(np.float32) * scale
    )
    return deq.astype(x.dtype)


def roundtrip_pod_blocks_np(b: np.ndarray, codec: str) -> np.ndarray:
    """Round-trip an ``A2APod`` buffer view ``[npods, ppn, npods, blk, *feat]``.

    Each ``(src pod, local, dst pod)`` block is one wire block; the
    diagonal ``dst == src`` blocks never cross DCI and stay bit-exact.
    """
    if not applies(codec, b.dtype):
        return b
    rt = roundtrip_np(b, codec, block_ndim=b.ndim - 3)
    rt = np.ascontiguousarray(rt)
    i = np.arange(b.shape[0])
    rt[i, :, i] = b[i, :, i]
    return rt


# ---------------------------------------------------------------------------
# Plan-level byte accounting
# ---------------------------------------------------------------------------


def scaled_wire_bytes(plan, codec: str, elem_bytes: int = 4) -> Tuple[int, int]:
    """(intra-pod, inter-pod) wire bytes of ``plan`` under ``codec``.

    ``codec="none"`` returns the planner's own accounting verbatim.  For a
    real codec the walk re-derives the same padding-inclusive sums with the
    inter-pod hops costed at :func:`wire_itemsize` (plus
    :data:`INT8_SCALE_BYTES` of side information per int8 wire block);
    intra-pod hops are untouched.  This is the number
    :attr:`repro.comm.strategies.IrregularExchange.wire_bytes` reports.
    """
    check_codec(codec)
    if codec == "none":
        return (plan.wire_intra_pod_bytes, plan.wire_inter_pod_bytes)
    # local import: repro.comm.exchange imports this module's helpers
    from repro.comm.exchange import A2ALocal, A2APod, Gather, PermuteWorld

    topo = plan.pattern.topo
    n, ppn, npods = topo.nranks, topo.ppn, topo.npods
    wsize = wire_itemsize(codec, elem_bytes)
    scale_extra = INT8_SCALE_BYTES if codec == "int8" else 0
    intra = inter = 0
    for st in plan.stages:
        if isinstance(st, Gather):
            continue
        if isinstance(st, A2ALocal):
            intra += n * (ppn - 1) * (st.buflen // ppn) * elem_bytes
        elif isinstance(st, A2APod):
            blk = st.buflen // npods
            inter += n * (npods - 1) * (blk * wsize + scale_extra)
        elif isinstance(st, PermuteWorld):
            inters = st.inter if st.inter is not None else (False,) * len(st.blks)
            for perm, blk, enc in zip(st.rounds, st.blks, inters):
                for s, d in perm:
                    if topo.pod_of(s) != topo.pod_of(d):
                        if enc:
                            inter += blk * wsize + scale_extra
                        else:
                            inter += blk * elem_bytes
                    else:
                        intra += blk * elem_bytes
        else:
            raise TypeError(f"unknown stage {st!r}")
    return (intra, inter)
