"""The original pure-Python token-list planner, kept verbatim as a baseline.

This is the pre-vectorization ``_Planner`` (token tuples in Python lists,
per-token dict lookups in ``_positions``).  It produces byte-for-byte the
same stage programs as the vectorized planner in
:mod:`repro.comm.exchange`; it exists so that

* ``benchmarks/bench_planning.py`` can report the planner speedup against a
  real baseline rather than a guess, and
* tests can cross-check the vectorized planner's stage programs and byte
  accounting against an independent implementation.

Do not use it on hot paths -- planning here is O(nranks x buflen) Python
loops per stage.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.comm.exchange import (
    PAD,
    A2ALocal,
    A2APod,
    ExchangePattern,
    Gather,
    PermuteWorld,
    Stage,
    StagePlan,
    Token,
    simulate_stage,
)


class _LegacyPlanner:
    """Builds stages while tracking the symbolic buffer state (token lists)."""

    def __init__(self, pattern: ExchangePattern):
        self.pattern = pattern
        self.topo = pattern.topo
        self.local = [
            [(r, e) for e in range(pattern.local_size)]
            for r in range(self.topo.nranks)
        ]
        self.buf: List[List[Optional[Token]]] = [[] for _ in range(self.topo.nranks)]
        self.stages: List[Stage] = []
        self.intra_payload = 0
        self.inter_payload = 0
        self.wire_intra = 0
        self.wire_inter = 0

    # -- position lookup ------------------------------------------------
    def _positions(self, r: int) -> Dict[Token, int]:
        pos: Dict[Token, int] = {}
        ext = self.buf[r] + self.local[r]
        for i, t in enumerate(ext):
            if t is not None and t not in pos:
                pos[t] = i
        return pos

    def _apply(self, stage: Stage) -> None:
        self.stages.append(stage)
        self.buf = simulate_stage(self.topo, stage, self.buf, self.local)

    # -- stage emitters ---------------------------------------------------
    def gather(self, select: Callable[[int], List[Optional[Token]]], width: Optional[int] = None) -> None:
        nranks = self.topo.nranks
        rows = [select(r) for r in range(nranks)]
        K = width if width is not None else max((len(x) for x in rows), default=0)
        K = max(K, 1)
        idx = np.zeros((nranks, K), dtype=np.int32)
        for r in range(nranks):
            pos = self._positions(r)
            sentinel = len(self.buf[r]) + len(self.local[r])
            for k in range(K):
                tok = rows[r][k] if k < len(rows[r]) else PAD
                if tok is PAD:
                    idx[r, k] = sentinel
                else:
                    if tok not in pos:
                        raise AssertionError(
                            f"planner bug: token {tok} not held by rank {r}"
                        )
                    idx[r, k] = pos[tok]
        self._apply(Gather(idx=idx))

    def a2a_local(self, elem_bytes: int) -> None:
        buflen = len(self.buf[0])
        assert buflen % self.topo.ppn == 0
        blk = buflen // self.topo.ppn
        for r in range(self.topo.nranks):
            l = self.topo.local_of(r)
            for j in range(self.topo.ppn):
                if j == l:
                    continue  # self block does not hit the wire
                seg = self.buf[r][j * blk : (j + 1) * blk]
                self.intra_payload += sum(t is not None for t in seg) * elem_bytes
                self.wire_intra += blk * elem_bytes
        self._apply(A2ALocal(buflen=buflen))

    def a2a_pod(self, elem_bytes: int) -> None:
        buflen = len(self.buf[0])
        assert buflen % self.topo.npods == 0
        blk = buflen // self.topo.npods
        for r in range(self.topo.nranks):
            p = self.topo.pod_of(r)
            for q in range(self.topo.npods):
                if q == p:
                    continue
                seg = self.buf[r][q * blk : (q + 1) * blk]
                self.inter_payload += sum(t is not None for t in seg) * elem_bytes
                self.wire_inter += blk * elem_bytes
        self._apply(A2APod(buflen=buflen))

    def permute_world(
        self,
        rounds: List[Dict[int, Tuple[int, List[Token]]]],
        elem_bytes: int,
    ) -> None:
        """``rounds[i][src] = (dst, tokens)``: src sends tokens to dst."""
        nranks = self.topo.nranks
        perm_list, blks, sels = [], [], []
        for rnd in rounds:
            blk = max((len(toks) for _, toks in rnd.values()), default=0)
            blk = max(blk, 1)
            sel = np.zeros((nranks, blk), dtype=np.int32)
            perm = []
            for r in range(nranks):
                pos = self._positions(r)
                sentinel = len(self.buf[r]) + len(self.local[r])
                if r in rnd:
                    dst, toks = rnd[r]
                    perm.append((r, dst))
                    inter = self.topo.pod_of(r) != self.topo.pod_of(dst)
                    payload = len(toks) * elem_bytes
                    if inter:
                        self.inter_payload += payload
                        self.wire_inter += blk * elem_bytes
                    else:
                        self.intra_payload += payload
                        self.wire_intra += blk * elem_bytes
                    for k in range(blk):
                        sel[r, k] = pos[toks[k]] if k < len(toks) else sentinel
                else:
                    sel[r, :] = len(self.buf[r]) + len(self.local[r])
            perm_list.append(tuple(perm))
            blks.append(blk)
            sels.append(sel)
        self._apply(
            PermuteWorld(rounds=tuple(perm_list), blks=tuple(blks), sels=tuple(sels))
        )

    # -- shared epilogue ---------------------------------------------------
    def redistribute_and_finish(self, elem_bytes: int, extra_local_direct: bool) -> None:
        """Intra-pod redistribution (local_Rcomm) + canonical projection."""
        topo, pat = self.topo, self.pattern
        rows: List[List[List[Optional[Token]]]] = []
        for r in range(topo.nranks):
            p = topo.pod_of(r)
            pos = self._positions(r)
            held = set(t for t in pos if extra_local_direct or t[0] != r)
            blocks = []
            for j in range(topo.ppn):
                d = topo.rank_of(p, j)
                if d == r:
                    # self block: stays on-device (never hits the wire), but
                    # must carry tokens this rank holds *for itself*, because
                    # the gather replaces the buffer.  Own local elements are
                    # always reachable via ext, so exclude them.
                    want = [
                        t for t in pat.canonical_tokens(d) if t in held and t[0] != r
                    ]
                else:
                    want = [t for t in pat.canonical_tokens(d) if t in held]
                blocks.append(sorted(set(want)))
            rows.append(blocks)
        B = max(max(len(b) for b in blocks) for blocks in rows)
        B = max(B, 1)

        def sel(r: int) -> List[Optional[Token]]:
            out: List[Optional[Token]] = []
            for b in rows[r]:
                out.extend(b)
                out.extend([PAD] * (B - len(b)))
            return out

        self.gather(sel, width=B * topo.ppn)
        self.a2a_local(elem_bytes)
        self.finish_canonical()

    def finish_canonical(self) -> None:
        pat = self.pattern
        H = max(pat.max_recv_size(), 1)
        self.gather(lambda r: list(pat.canonical_tokens(r)), width=H)

    def build(self, strategy: str) -> StagePlan:
        pat = self.pattern
        # verify delivery
        for r in range(self.topo.nranks):
            want = pat.canonical_tokens(r)
            got = self.buf[r][: len(want)]
            if got != want:
                raise AssertionError(
                    f"strategy {strategy}: rank {r} canonical mismatch"
                )
        return StagePlan(
            strategy=strategy,
            pattern=pat,
            stages=tuple(self.stages),
            out_size=max(pat.max_recv_size(), 1),
            intra_pod_bytes=self.intra_payload,
            inter_pod_bytes=self.inter_payload,
            wire_intra_pod_bytes=self.wire_intra,
            wire_inter_pod_bytes=self.wire_inter,
        )


# ---------------------------------------------------------------------------
# Strategy planners (token-list versions)
# ---------------------------------------------------------------------------


def plan_standard(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """Standard communication: dense per-(src,dst) exchange."""
    topo = pattern.topo
    pl = _LegacyPlanner(pattern)
    by_pair: Dict[Tuple[int, int], List[Token]] = defaultdict(list)
    for n in pattern.needs:
        by_pair[(n.src, n.dst)] = [(n.src, e) for e in n.idx]
    B = max((len(v) for v in by_pair.values()), default=0)
    B = max(B, 1)

    # layout [npods, ppn, B] by destination (pod, local)
    def sel(r: int) -> List[Optional[Token]]:
        out: List[Optional[Token]] = []
        for d in range(topo.nranks):
            toks = by_pair.get((r, d), [])
            out.extend(toks)
            out.extend([PAD] * (B - len(toks)))
        return out

    pl.gather(sel, width=topo.nranks * B)
    pl.a2a_pod(elem_bytes)
    # transpose [q, j, B] -> [j, q, B] so A2ALocal blocks are contiguous
    buf = pl.buf

    def transpose_sel(r: int) -> List[Optional[Token]]:
        row = buf[r]
        out: List[Optional[Token]] = []
        for j in range(topo.ppn):
            for q in range(topo.npods):
                base = (q * topo.ppn + j) * B
                out.extend(row[base : base + B])
        return out

    pl.gather(transpose_sel, width=topo.nranks * B)
    pl.a2a_local(elem_bytes)
    pl.finish_canonical()
    return pl.build("standard")


def plan_two_step(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """2-Step: per-(src rank -> dst pod) fused, deduped messages (§2.3.2)."""
    topo = pattern.topo
    pl = _LegacyPlanner(pattern)
    fused: Dict[Tuple[int, int], List[Token]] = {}
    for r in range(topo.nranks):
        for p in range(topo.npods):
            fused[(r, p)] = [(r, e) for e in pattern.dedup_for_pod(r, p)]
    B = max((len(v) for v in fused.values()), default=0)
    B = max(B, 1)

    def sel(r: int) -> List[Optional[Token]]:
        out: List[Optional[Token]] = []
        for p in range(topo.npods):
            toks = fused[(r, p)] if p != topo.pod_of(r) else []
            out.extend(toks)
            out.extend([PAD] * (B - len(toks)))
        return out

    pl.gather(sel, width=topo.npods * B)
    pl.a2a_pod(elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("two_step")


def plan_three_step(pattern: ExchangePattern, elem_bytes: int = 4) -> StagePlan:
    """3-Step: gather to the pair agent, one fused inter-pod message per pod
    pair, intra-pod redistribution (§2.3.1)."""
    topo = pattern.topo
    pl = _LegacyPlanner(pattern)
    # deduped contribution of each rank to each foreign pod
    contrib: Dict[Tuple[int, int], List[Token]] = {}
    for r in range(topo.nranks):
        for p in range(topo.npods):
            if p == topo.pod_of(r):
                continue
            contrib[(r, p)] = [(r, e) for e in pattern.dedup_for_pod(r, p)]

    # step 1: route contributions to the (src pod, dst pod) agent
    rows: Dict[int, List[List[Optional[Token]]]] = {}
    for r in range(topo.nranks):
        q = topo.pod_of(r)
        blocks: List[List[Optional[Token]]] = [[] for _ in range(topo.ppn)]
        for p in range(topo.npods):
            if p == q:
                continue
            blocks[topo.agent_local(q, p)].extend(contrib[(r, p)])
        rows[r] = blocks
    B1 = max(max(len(b) for b in blocks) for blocks in rows.values())
    B1 = max(B1, 1)

    def sel1(r: int) -> List[Optional[Token]]:
        out: List[Optional[Token]] = []
        for b in rows[r]:
            out.extend(b)
            out.extend([PAD] * (B1 - len(b)))
        return out

    pl.gather(sel1, width=B1 * topo.ppn)
    pl.a2a_local(elem_bytes)

    # step 2: one fused message per pod pair, spread over shifts
    rounds = []
    for d in topo.pod_shift_rounds():
        rnd: Dict[int, Tuple[int, List[Token]]] = {}
        for q in range(topo.npods):
            p = (q + d) % topo.npods
            a = topo.agent_local(q, p)
            src = topo.rank_of(q, a)
            dst = topo.rank_of(p, a)
            toks: List[Token] = []
            for l in range(topo.ppn):
                toks.extend(contrib[(topo.rank_of(q, l), p)])
            rnd[src] = (dst, sorted(set(toks)))
        rounds.append(rnd)
    pl.permute_world(rounds, elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("three_step")


def _greedy_rounds(
    chunks: List[Tuple[int, int, List[Token]]]
) -> List[Dict[int, Tuple[int, List[Token]]]]:
    """Edge-color the chunk multigraph into rounds (largest chunks first)."""
    remaining = sorted(chunks, key=lambda c: -len(c[2]))
    rounds = []
    while remaining:
        used_s, used_d = set(), set()
        rnd: Dict[int, Tuple[int, List[Token]]] = {}
        rest = []
        for s, d, toks in remaining:
            if s in used_s or d in used_d:
                rest.append((s, d, toks))
                continue
            used_s.add(s)
            used_d.add(d)
            rnd[s] = (d, toks)
        rounds.append(rnd)
        remaining = rest
    return rounds


def plan_split(
    pattern: ExchangePattern,
    message_cap_bytes: int,
    elem_bytes: int = 4,
) -> StagePlan:
    """Split node-aware communication (paper §2.3.3 / Algorithm 1)."""
    topo = pattern.topo
    pl = _LegacyPlanner(pattern)

    # per recv pod: per origin pod: owner-major deduped token list
    chunks: List[Tuple[int, int, List[Token]]] = []  # (sender, receiver, tokens)
    stage0_rows: Dict[int, List[List[Optional[Token]]]] = {
        r: [[] for _ in range(topo.ppn)] for r in range(topo.nranks)
    }
    for recv_pod in range(topo.npods):
        per_origin: Dict[int, List[Token]] = {}
        for origin in range(topo.npods):
            if origin == recv_pod:
                continue
            toks: List[Token] = []
            for l in range(topo.ppn):
                src = topo.rank_of(origin, l)
                toks.extend((src, e) for e in pattern.dedup_for_pod(src, recv_pod))
            if toks:
                per_origin[origin] = toks
        if not per_origin:
            continue
        vols = {o: len(t) * elem_bytes for o, t in per_origin.items()}
        total = sum(vols.values())
        biggest = max(vols.values())
        # Algorithm 1, lines 12-17
        if biggest < message_cap_bytes:
            cap = biggest  # conglomerate: one message per origin pod
        elif total / message_cap_bytes > topo.ppn:
            cap = -(-total // topo.ppn)  # ceil
        else:
            cap = message_cap_bytes
        cap_elems = max(cap // elem_bytes, 1)

        raw: List[Tuple[int, List[Token]]] = []  # (origin, chunk tokens)
        for origin in sorted(per_origin):
            toks = per_origin[origin]
            for i in range(0, len(toks), cap_elems):
                raw.append((origin, toks[i : i + cap_elems]))
        # line 18: receives descending from local 0; sends from local ppn-1
        raw.sort(key=lambda t: -len(t[1]))
        send_counter: Dict[int, int] = defaultdict(int)
        for i, (origin, toks) in enumerate(raw):
            receiver = topo.rank_of(recv_pod, i % topo.ppn)
            k = send_counter[origin]
            sender = topo.rank_of(origin, topo.ppn - 1 - (k % topo.ppn))
            send_counter[origin] += 1
            chunks.append((sender, receiver, toks))
            # stage 0 (local_Scomm): owners stage chunk bytes on the sender
            for tok in toks:
                owner = tok[0]
                if owner != sender:
                    stage0_rows[owner][topo.local_of(sender)].append(tok)

    B0 = max(
        (len(b) for blocks in stage0_rows.values() for b in blocks), default=0
    )
    B0 = max(B0, 1)

    def sel0(r: int) -> List[Optional[Token]]:
        out: List[Optional[Token]] = []
        for b in stage0_rows[r]:
            out.extend(b)
            out.extend([PAD] * (B0 - len(b)))
        return out

    pl.gather(sel0, width=B0 * topo.ppn)
    pl.a2a_local(elem_bytes)
    pl.permute_world(_greedy_rounds(chunks), elem_bytes)
    pl.redistribute_and_finish(elem_bytes, extra_local_direct=True)
    return pl.build("split")


PLANNERS: Dict[str, Callable[..., StagePlan]] = {
    "standard": plan_standard,
    "two_step": plan_two_step,
    "three_step": plan_three_step,
    "split": plan_split,
}


def plan(strategy: str, pattern: ExchangePattern, *, message_cap_bytes: int = 16384, elem_bytes: int = 4) -> StagePlan:
    if strategy == "split":
        return plan_split(pattern, message_cap_bytes, elem_bytes)
    try:
        return PLANNERS[strategy](pattern, elem_bytes)
    except KeyError as e:
        raise KeyError(f"unknown strategy {strategy!r}; known: {sorted(PLANNERS)}") from e
