"""Pod-aware hierarchical collectives (the paper's insight applied to the
regular collectives of LM training).

The paper's node-aware schemes concentrate inter-node traffic on the cheap
local fabric and minimize what crosses the expensive one.  For the *regular*
collectives of multi-pod training the same decomposition applies:

* all-reduce(pod x data)  ->  reduce-scatter(data/ICI)
                              -> all-reduce(pod/DCI, 1/|data| of the bytes)
                              -> all-gather(data/ICI)

Each chip then injects only ``bytes/|data|`` onto the inter-pod fabric --
exactly the Split strategy's "use all available on-node processes to
communicate inter-node data" (paper §4.6), with |data| playing the role of
PPN.  An optional int8 error-feedback compressor
(:mod:`repro.comm.compression`) further shrinks the DCI hop only, keeping
full precision on ICI.

These primitives run *inside* ``shard_map`` bodies.  :func:`sync_grads`
wraps a whole gradient pytree for data-parallel training loops.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import compression
from repro.compat import axis_size


def _flatten_pad(x: jnp.ndarray, n: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def psum_hierarchical(
    x: jnp.ndarray,
    outer_axis: str,
    inner_axis: str,
    compressor: Optional[compression.Compressor] = None,
    residual: Optional[jnp.ndarray] = None,
):
    """All-reduce over (outer x inner) as RS(inner) -> AR(outer) -> AG(inner).

    Must be called inside ``shard_map`` with both axes in scope.  Returns the
    reduced array (and the new compression residual if ``compressor``).
    """
    n_in = axis_size(inner_axis)
    flat, pad = _flatten_pad(x, n_in)
    shard = jax.lax.psum_scatter(
        flat.reshape(n_in, -1), inner_axis, scatter_dimension=0, tiled=False
    )
    new_residual = None
    if compressor is not None:
        if residual is not None:
            shard = shard + residual.reshape(shard.shape)
        q, scale = compressor.compress(shard, outer_axis)
        q_sum = jax.lax.psum(q.astype(jnp.int32), outer_axis)
        reduced = compressor.decompress(q_sum, scale)
        new_residual = (shard - compressor.decompress(q.astype(jnp.int32), scale)).reshape(-1)
    else:
        reduced = jax.lax.psum(shard, outer_axis)
    full = jax.lax.all_gather(reduced, inner_axis, axis=0, tiled=False).reshape(-1)
    if pad:
        full = full[:-pad]
    out = full.reshape(x.shape)
    if compressor is not None:
        return out, new_residual
    return out


def psum_flat(x: jnp.ndarray, outer_axis: str, inner_axis: str) -> jnp.ndarray:
    """Baseline: one flat all-reduce over the joint axis (standard comm)."""
    return jax.lax.psum(x, (outer_axis, inner_axis))


def dot_hierarchical(
    x: jnp.ndarray,
    y: jnp.ndarray,
    outer_axis: str,
    inner_axis: str,
    compressor: Optional[compression.Compressor] = None,
) -> jnp.ndarray:
    """Global ``<x, y>`` over (outer x inner)-sharded leaves, node-aware.

    The paper's decomposition applied to the scalar reductions of a Krylov
    solver: each chip reduces its shard locally, the partial sums reduce over
    the cheap on-pod fabric (ICI) first, and exactly ONE scalar per pod
    crosses the expensive inter-pod hop -- the 3-Step shape (fuse on-node,
    minimize inter-node) degenerated to a reduction tree.  Must be called
    inside ``shard_map`` with both axes in scope.

    ``compressor`` int8-quantizes the per-pod partial on the inter-pod hop
    only (ICI stays full precision).  For a scalar this saves 3 bytes and
    costs ~``1/(2*qmax)`` relative error per reduction, so it exists to keep
    the solver's reduction path byte-compatible with the compressed gradient
    path, not as a bandwidth optimization -- leave it off when bitwise
    reduction accuracy matters (it perturbs Krylov convergence).
    """
    part = jnp.sum(x * y)
    part = jax.lax.psum(part, inner_axis)  # on-pod tree, full precision
    if compressor is None:
        return jax.lax.psum(part, outer_axis)
    q, scale = compressor.compress(part[None], outer_axis)
    q_sum = jax.lax.psum(q.astype(jnp.int32), outer_axis)
    return compressor.decompress(q_sum, scale)[0]


def all_gather_hierarchical(x: jnp.ndarray, outer_axis: str, inner_axis: str) -> jnp.ndarray:
    """All-gather over (outer x inner): AG(outer/DCI) then AG(inner/ICI).

    Gathering the small per-chip shard across pods first minimizes DCI bytes;
    the fan-out to full size happens on ICI.
    """
    x = jax.lax.all_gather(x, outer_axis, axis=0, tiled=True)
    return jax.lax.all_gather(x, inner_axis, axis=0, tiled=True)


def all_to_all_hierarchical(
    x: jnp.ndarray, outer_axis: str, inner_axis: str
) -> jnp.ndarray:
    """All-to-all over the joint (outer x inner) axis, decomposed 3-Step-style.

    ``x`` has leading dim ``n_out * n_in`` (one block per destination device,
    destination-major ``(outer, inner)``).  Step 1 fuses all blocks bound for
    the same destination pod and moves them in one inter-pod exchange
    (a2a over outer); step 2 redistributes within the destination pod
    (a2a over inner).  Equivalent to a flat all_to_all over the joint axis but
    with pod-fused inter-pod messages (the 3-Step/2-Step hybrid the paper
    calls 2-Step when every chip stays active).
    """
    n_out = axis_size(outer_axis)
    n_in = axis_size(inner_axis)
    blk = x.shape[0] // (n_out * n_in)
    rest = x.shape[1:]
    # [n_out, n_in * blk, ...]: fuse per destination pod
    y = x.reshape(n_out, n_in * blk, *rest)
    y = jax.lax.all_to_all(y, outer_axis, split_axis=0, concat_axis=0, tiled=True)
    # now [n_out * n_in * blk]: block (q, j) = from (q, me) to (mypod, j)
    y = y.reshape(n_out, n_in, blk, *rest).transpose(1, 0, *range(2, 3 + len(rest)))
    y = y.reshape(n_in, n_out * blk, *rest)
    y = jax.lax.all_to_all(y, inner_axis, split_axis=0, concat_axis=0, tiled=True)
    # [n_in, n_out, blk] -> destination-major (outer, inner)
    y = y.reshape(n_in, n_out, blk, *rest).transpose(1, 0, *range(2, 3 + len(rest)))
    return y.reshape(n_out * n_in * blk, *rest)


# ---------------------------------------------------------------------------
# Gradient-tree synchronisation for data-parallel loops
# ---------------------------------------------------------------------------


def init_residuals(grads, inner_size: int):
    """Zero error-feedback residuals matching :func:`sync_grad_tree`'s shards."""
    return jax.tree.map(
        lambda g: jnp.zeros((-(-g.size // inner_size),), g.dtype), grads
    )


def sync_grad_tree(
    grads,
    outer_axis: str = "pod",
    inner_axis: str = "data",
    mode: str = "hierarchical",
    compressor: Optional[compression.Compressor] = None,
    residuals=None,
):
    """Average a gradient pytree over the DP axes (call inside ``shard_map``).

    ``grads`` leaves are this device's local-batch gradients; returns the
    global average.  ``mode`` is "flat" (standard, one joint all-reduce) or
    "hierarchical" (paper technique).  With ``compressor``, returns
    ``(grads, new_residuals)`` implementing error feedback on the DCI hop.
    """
    ndev = axis_size(outer_axis) * axis_size(inner_axis)

    def one(leaf, res):
        if mode == "flat":
            return jax.lax.psum(leaf, (outer_axis, inner_axis)) / ndev, res
        if compressor is not None:
            out, new_res = psum_hierarchical(
                leaf, outer_axis, inner_axis, compressor, res
            )
            return out / ndev, new_res
        return psum_hierarchical(leaf, outer_axis, inner_axis) / ndev, res

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = (
        jax.tree.flatten(residuals)[0]
        if residuals is not None
        else [None] * len(flat_g)
    )
    outs = [one(a, b) for a, b in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    if compressor is not None:
        new_r = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return new_g, new_r
    return new_g
