"""Stage-program fusion: rewrite exchange programs to touch memory less.

Stage-program IR
----------------
A strategy plan (:class:`repro.comm.exchange.StagePlan`) is a straight-line
program over a per-rank buffer ``buf`` (initially empty) and the immutable
per-rank ``local`` array.  Every stage reads ``ext = concat(buf, local)``
and replaces ``buf``:

=================  =========================================================
``Gather(idx)``    ``buf'[k] = ext[idx[k]]``; ``idx >= len(ext)`` delivers
                   PAD (zero).  Output width = ``idx.shape[1]``.
``A2ALocal(W,     ``all_to_all`` over the pod-local mesh axis on the
  idx=None)``      ``[ppn, W/ppn]`` view of ``buf``.  The optional ``idx``
                   is a Gather applied to ``ext`` *first* (the fused input
                   layout); output width = ``W``.
``A2APod(W,        same, over the pod axis on ``[npods, W/npods]``.
  idx=None)``
``PermuteWorld``   rounds of world-level ``ppermute``; round ``i`` sends
                   ``ext[sels[i]]`` along the partial permutation
                   ``rounds[i]``; the received blocks are concatenated.
                   Output width = ``sum(blks)``.
=================  =========================================================

Legal rewrites (applied by :func:`fuse`)
----------------------------------------
R1  **Gather composition.**  ``Gather(g); Gather(h) -> Gather(h ∘ g)``:
    ``h`` indexes ``concat(g_out, local)``, so positions ``< K`` route
    through ``g.idx``, positions in the local region re-base to the input
    ext's local region, and PADs stay PADs.  Associative; a whole chain of
    adjacent gathers collapses into one index map.  A zero-width gather
    composes away entirely (this is how zero-width stages are dropped).
R2  **Gather -> all-to-all folding.**  A (composed) Gather feeding an
    ``A2ALocal``/``A2APod`` becomes the collective's fused input layout
    ``idx``: one take + collective instead of materializing an
    intermediate buffer.  The bytes on the wire are unchanged -- the
    collective still moves exactly ``buflen`` elements per rank.
R3  **Gather -> permute folding.**  A pending Gather before a
    ``PermuteWorld`` is composed into every round's ``sels`` (same R1
    arithmetic), since the sels address ``ext`` of the gather's output.
R4  **No-op elimination.**  An identity Gather (``idx == arange(W)`` on a
    width-``W`` buffer) is dropped wherever it appears.

Every rewrite is *verified by construction*: :func:`fuse` runs the
vectorized token simulator over the original and rewritten programs and
requires identical final buffers, so an illegal rewrite cannot escape.
Values are checked separately by tests against
:func:`repro.comm.exchange.execute_numpy` and
:meth:`ExchangePattern.reference`.

Wire cost is monotone: fusion never adds a collective, never widens one,
and drops only on-device gathers, so ``wire_*_bytes`` carry over verbatim.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.comm.exchange import (
    A2ALocal,
    A2APod,
    Gather,
    PermuteWorld,
    Stage,
    StagePlan,
    simulate_codes,
)


def compose_gathers(
    g1: np.ndarray, g2: np.ndarray, w_in: int, local_size: int
) -> np.ndarray:
    """Index map of ``Gather(g2) ∘ Gather(g1)`` relative to ``g1``'s input.

    ``g1`` reads ``ext0`` (width ``E0 = w_in + local_size``) producing a
    ``K1``-wide buffer; ``g2`` reads ``ext1 = concat(that, local)``.  The
    composition reads ``ext0`` directly.
    """
    g1 = np.asarray(g1)
    g2 = np.asarray(g2)
    K1 = g1.shape[1]
    E0 = w_in + local_size
    fused = np.full(g2.shape, E0, dtype=np.int32)  # default: PAD
    in_local = (g2 >= K1) & (g2 < K1 + local_size)
    np.copyto(fused, (g2 - K1 + w_in).astype(np.int32), where=in_local)
    in_buf = g2 < K1
    if K1:
        rows = np.arange(g1.shape[0])[:, None]
        routed = g1[rows, np.clip(g2, 0, K1 - 1)]
        np.copyto(fused, routed.astype(np.int32), where=in_buf)
    return fused


def _is_identity(idx: np.ndarray, w_in: int) -> bool:
    K = idx.shape[1]
    return K == w_in and bool((idx == np.arange(K, dtype=idx.dtype)).all())


def fuse_stages(
    stages: Tuple[Stage, ...], local_size: int
) -> Tuple[Stage, ...]:
    """Apply rewrites R1-R4 to a stage tuple (see module docstring)."""
    out: List[Stage] = []
    pending: Optional[np.ndarray] = None  # composed Gather index map
    pend_w = 0  # buffer width the pending map's indices are relative to
    w = 0  # current (pre-pending) buffer width

    def absorb(idx: np.ndarray) -> None:
        nonlocal pending, pend_w
        if pending is not None:
            pending = compose_gathers(pending, idx, pend_w, local_size)
        else:
            pending, pend_w = np.asarray(idx), w

    for st in stages:
        if isinstance(st, Gather):
            absorb(st.idx)
        elif isinstance(st, (A2ALocal, A2APod)):
            if st.idx is not None:  # re-fusing an already-fused program
                absorb(st.idx)
            if pending is not None and _is_identity(pending, pend_w):
                pending = None
            if pending is not None:
                assert pending.shape[1] == st.buflen
                out.append(dataclasses.replace(st, idx=pending))
                w, pending = st.buflen, None
            else:
                assert w == st.buflen
                out.append(dataclasses.replace(st, idx=None))
        elif isinstance(st, PermuteWorld):
            if pending is not None and _is_identity(pending, pend_w):
                pending = None
            if pending is not None:
                sels = tuple(
                    compose_gathers(pending, s, pend_w, local_size)
                    for s in st.sels
                )
                out.append(dataclasses.replace(st, sels=sels))
                pending = None
            else:
                out.append(st)
            w = sum(st.blks)
        else:
            raise TypeError(f"unknown stage {st!r}")
    if pending is not None and not _is_identity(pending, pend_w):
        out.append(Gather(idx=pending))
    return tuple(out)


def fuse(plan: StagePlan, verify: bool = True) -> StagePlan:
    """Return an equivalent plan with a fused stage program.

    ``verify=True`` (default) replays both programs through the vectorized
    token simulator and asserts identical final buffers -- fusion is
    correct by construction or it refuses to return.

    Planning and fusion are pure numpy, so this runs without any devices:

    >>> import numpy as np
    >>> from repro.comm.exchange import plan, random_pattern
    >>> from repro.comm.topology import PodTopology
    >>> pat = random_pattern(np.random.default_rng(0),
    ...                      PodTopology(npods=2, ppn=2), local_size=4)
    >>> sp = plan("two_step", pat)
    >>> fused = fuse(sp)
    >>> fused.fused and len(fused.stages) < len(sp.stages)
    True
    >>> fused.wire_inter_pod_bytes == sp.wire_inter_pod_bytes  # wire cost kept
    True
    """
    stages = fuse_stages(plan.stages, plan.pattern.local_size)
    fused = dataclasses.replace(plan, stages=stages, fused=True)
    if verify:
        want = simulate_codes(plan)
        got = simulate_codes(fused)
        if want.shape != got.shape or not np.array_equal(want, got):
            raise AssertionError(
                f"fusion changed delivery for strategy {plan.strategy!r}"
            )
    return fused


def stage_summary(plan: StagePlan) -> str:
    """Compact one-line program dump, e.g. ``G->A2APod[idx]->A2ALocal->G``."""
    parts = []
    for st in plan.stages:
        if isinstance(st, Gather):
            parts.append(f"G[{st.idx.shape[1]}]")
        elif isinstance(st, A2ALocal):
            parts.append(f"A2ALocal[{st.buflen}{',idx' if st.idx is not None else ''}]")
        elif isinstance(st, A2APod):
            parts.append(f"A2APod[{st.buflen}{',idx' if st.idx is not None else ''}]")
        elif isinstance(st, PermuteWorld):
            parts.append(f"PW[{len(st.rounds)}r,{sum(st.blks)}]")
    return "->".join(parts)
