"""Strategy execution layer: irregular exchanges and pod-aware collectives."""

from repro.comm.topology import (
    LOCAL_AXIS,
    POD_AXIS,
    WORLD_AXES,
    PodTopology,
    make_exchange_mesh,
)
from repro.comm.exchange import (
    ExchangePattern,
    Need,
    StagePlan,
    execute_numpy,
    plan,
    plan_split,
    plan_standard,
    plan_three_step,
    plan_two_step,
    random_pattern,
    simulate,
    simulate_codes,
)
from repro.comm.fusion import fuse, stage_summary
from repro.comm.strategies import (
    STRATEGY_NAMES,
    CacheStats,
    IrregularExchange,
    cache_stats,
    clear_caches,
    planned,
    register_cache,
)
from repro.comm.hierarchical import (
    all_gather_hierarchical,
    all_to_all_hierarchical,
    init_residuals,
    psum_flat,
    psum_hierarchical,
    sync_grad_tree,
)
from repro.comm.compression import Compressor

__all__ = [
    "LOCAL_AXIS",
    "POD_AXIS",
    "WORLD_AXES",
    "PodTopology",
    "make_exchange_mesh",
    "ExchangePattern",
    "Need",
    "StagePlan",
    "execute_numpy",
    "plan",
    "plan_split",
    "plan_standard",
    "plan_three_step",
    "plan_two_step",
    "random_pattern",
    "simulate",
    "simulate_codes",
    "fuse",
    "stage_summary",
    "STRATEGY_NAMES",
    "CacheStats",
    "IrregularExchange",
    "cache_stats",
    "clear_caches",
    "planned",
    "register_cache",
    "all_gather_hierarchical",
    "all_to_all_hierarchical",
    "init_residuals",
    "psum_flat",
    "psum_hierarchical",
    "sync_grad_tree",
    "Compressor",
]
